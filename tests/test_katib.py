"""Katib HPO: all three algorithms optimise an analytic objective; grid
covers the lattice; bayesian beats random given equal budget (statistically
on this smooth objective); median early stopping fires."""
import math

import numpy as np
import pytest

from repro.core.experiment import Experiment
from repro.tuning import katib


def quadratic(params, report):
    x, y = params["x"], params["y"]
    val = (x - 0.3) ** 2 + (y - 0.7) ** 2
    for step in range(1, 4):
        report(step, val + 1.0 / step)
    return {"loss": val}


SPACE = {"x": katib.Double(0.0, 1.0), "y": katib.Double(0.0, 1.0)}


@pytest.mark.parametrize("algo", ["grid", "random", "bayesian"])
def test_algorithms_find_reasonable_optimum(algo):
    exp = katib.tune(quadratic, SPACE, algorithm=algo, max_trials=16, seed=0)
    best = exp.best_trial()
    assert best is not None
    assert exp.objective(best) < 0.15
    assert len(exp.trials) <= 16


def test_grid_is_deterministic_lattice():
    g1 = katib.GridSearch(SPACE, 9)
    g2 = katib.GridSearch(SPACE, 9)
    exp = Experiment("e", "loss")
    pts1 = [g1.suggest(exp) for _ in range(9)]
    pts2 = [g2.suggest(exp) for _ in range(9)]
    assert pts1 == pts2
    xs = sorted({round(p["x"], 6) for p in pts1})
    assert xs == [0.0, 0.5, 1.0]


def test_random_respects_bounds_and_log_scale():
    space = {"lr": katib.Double(1e-5, 1e-1, log=True),
             "bs": katib.Integer(16, 128),
             "act": katib.Categorical(("relu", "gelu"))}
    rs = katib.RandomSearch(space, 64, seed=3)
    exp = Experiment("e", "loss")
    for _ in range(64):
        p = rs.suggest(exp)
        assert 1e-5 <= p["lr"] <= 1e-1
        assert 16 <= p["bs"] <= 128
        assert p["act"] in ("relu", "gelu")


def test_bayesian_outperforms_random_on_smooth_objective():
    wins = 0
    for seed in range(5):
        eb = katib.tune(quadratic, SPACE, algorithm="bayesian", max_trials=12,
                        seed=seed)
        er = katib.tune(quadratic, SPACE, algorithm="random", max_trials=12,
                        seed=seed)
        if eb.objective(eb.best_trial()) <= er.objective(er.best_trial()):
            wins += 1
    assert wins >= 3


def test_median_early_stopping_fires():
    def objective(params, report):
        # bad configs report terrible intermediates
        bad = params["x"] > 0.5
        for step in range(1, 6):
            report(step, 100.0 if bad else 1.0 / step)
        return {"loss": 100.0 if bad else 0.01}

    exp = katib.tune(objective, {"x": katib.Double(0, 1)}, algorithm="random",
                     max_trials=12, early_stopping=katib.MedianStop(min_trials=2),
                     seed=0)
    assert any(t.status == "early_stopped" for t in exp.trials)


def test_goal_value_stops_experiment_early():
    exp = katib.tune(quadratic, SPACE, algorithm="random", max_trials=64,
                     goal_value=0.2, seed=1)
    assert len(exp.trials) < 64
