"""Oracle suite for the chunked batched prefill path (ISSUE 8 tentpole).

`lm.prefill_chunk` replaces the 1-token-per-step teacher-forced prompt
catch-up in ContinuousBatcher: C prompt tokens per call, KV cache rows
written directly, decode-exact masking.  These tests pin it to the
teacher-forced `lm.decode_step` reference:

  * BITWISE archs: logits at every prompt position AND the final cache are
    bit-identical to running decode_step once per token.  This holds for
    every single-phase program (pure global / M-RoPE / ring-window local /
    MLA / pure recurrent) on the XLA CPU backend.
  * TOKENWISE archs (gemma3 local+global mix, xlstm mlstm+slstm mix,
    deepseek dense-first+moe two-phase): XLA CPU specializes transcendental
    codegen per program context, so multi-phase programs drift by ~1 ulp
    between the chunked and per-token compilations.  For those the oracle
    asserts argmax equality at every position plus a tight allclose.

The batcher-level property (hypothesis + seeded fallback, rotating-seed CI
pass) asserts the prefill-enabled ContinuousBatcher emits exactly the same
output tokens as the teacher-forced seed batcher across random prompt
mixes, chunk sizes and slot counts -- plus the unbounded-prompt regression
(a prompt with len >= max_len used to walk `pos` past the cache bound with
its KV scatter silently dropped; submit() now rejects it).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import lm
from repro.serving.continuous import ContinuousBatcher

try:
    from hypothesis import given, strategies as hyp_st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

# empirically bit-stable single-phase programs (see module docstring)
BITWISE_ARCHS = ("h2o_danube_3_4b", "qwen2_vl_7b", "minitron_4b",
                 "granite_3_8b", "granite_moe_3b_a800m", "zamba2_1_2b")
# multi-phase programs: ~1-ulp context-sensitive codegen, argmax stable
TOKENWISE_ARCHS = ("gemma3_4b", "xlstm_1_3b", "deepseek_v2_lite_16b")


@functools.lru_cache(maxsize=None)
def _setup(arch):
    cfg = registry.get_smoke_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _positions(cfg, t0, c):
    pos = jnp.arange(t0, t0 + c, dtype=jnp.int32)[None]
    if cfg.use_mrope:
        pos = jnp.broadcast_to(pos[:, None], (1, 3, c))
    return pos


def _teacher_forced(cfg, params, toks, cache_len):
    """Reference: one decode_step per prompt token at B=1."""
    dec = jax.jit(lambda p, c, t, pos: lm.decode_step(p, cfg, t, pos, c))
    cache = lm.init_cache(cfg, 1, cache_len)
    logits = []
    for t in range(toks.shape[1]):
        pos = jnp.array([t], jnp.int32)
        if cfg.use_mrope:
            pos = jnp.broadcast_to(pos[:, None], (1, 3))
        lg, cache = dec(params, cache, toks[:, t:t + 1], pos)
        logits.append(lg)
    return jnp.stack(logits, axis=1), cache


def _chunked(cfg, params, toks, cache_len, chunk):
    pf = jax.jit(lambda p, c, t, pos: lm.prefill_chunk(p, cfg, t, pos, c))
    cache = lm.init_cache(cfg, 1, cache_len)
    outs, t0, n = [], 0, toks.shape[1]
    while t0 < n:
        c = min(chunk, n - t0)
        lg, cache = pf(params, cache, toks[:, t0:t0 + c], _positions(cfg, t0, c))
        outs.append(lg)
        t0 += c
    return jnp.concatenate(outs, axis=1), cache


def _cache_leaves(cache):
    return {jax.tree_util.keystr(k): v
            for k, v in jax.tree_util.tree_leaves_with_path(cache)}


@pytest.mark.parametrize("arch", BITWISE_ARCHS)
def test_prefill_bitwise_oracle(arch):
    cfg, params = _setup(arch)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab_size)
    ref_logits, ref_cache = _teacher_forced(cfg, params, toks, 64)
    pf_logits, pf_cache = _chunked(cfg, params, toks, 64, chunk=5)
    assert bool(jnp.all(pf_logits == ref_logits)), (
        f"{arch}: prefill logits not bit-identical to teacher-forced decode "
        f"(max |diff| {float(jnp.max(jnp.abs(pf_logits - ref_logits))):.3g})")
    ref_leaves, pf_leaves = _cache_leaves(ref_cache), _cache_leaves(pf_cache)
    assert ref_leaves.keys() == pf_leaves.keys()
    for k in ref_leaves:
        assert bool(jnp.all(ref_leaves[k] == pf_leaves[k])), (
            f"{arch}: cache leaf {k} not bit-identical")


@pytest.mark.parametrize("arch", TOKENWISE_ARCHS)
def test_prefill_tokenwise_oracle(arch):
    cfg, params = _setup(arch)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab_size)
    ref_logits, ref_cache = _teacher_forced(cfg, params, toks, 64)
    pf_logits, pf_cache = _chunked(cfg, params, toks, 64, chunk=5)
    assert bool(jnp.all(jnp.argmax(pf_logits, -1) == jnp.argmax(ref_logits, -1)))
    np.testing.assert_allclose(np.asarray(pf_logits), np.asarray(ref_logits),
                               rtol=1e-4, atol=1e-4)
    ref_leaves, pf_leaves = _cache_leaves(ref_cache), _cache_leaves(pf_cache)
    for k in ref_leaves:
        np.testing.assert_allclose(
            np.asarray(ref_leaves[k], np.float32),
            np.asarray(pf_leaves[k], np.float32), rtol=1e-3, atol=1e-4,
            err_msg=f"{arch}: cache leaf {k}")


def test_prefill_chunk_size_invariant():
    """Chunk size must not change logits at all (same program family)."""
    cfg, params = _setup("h2o_danube_3_4b")
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 16), 0, cfg.vocab_size)
    base, _ = _chunked(cfg, params, toks, 64, chunk=16)
    for chunk in (1, 3, 8):
        lg, _ = _chunked(cfg, params, toks, 64, chunk=chunk)
        assert bool(jnp.all(lg == base)), f"chunk={chunk} changed logits"


# -- batcher-level property --------------------------------------------------

def _batcher_outputs(arch, prompts, max_new, max_slots, chunk):
    cfg, params = _setup(arch)
    out = {}
    for pc in (0, chunk):
        b = ContinuousBatcher(cfg, params, max_slots=max_slots, max_len=64,
                              prefill_chunk=pc)
        for p in prompts:
            b.submit(list(p), max_new)
        done = b.run()
        out[pc] = sorted((r.rid, tuple(r.output)) for r in done)
    return out


def _check_scenario(rng):
    arch = ("h2o_danube_3_4b", "gemma3_4b")[int(rng.integers(0, 2))]
    cfg, _ = _setup(arch)
    n_req = int(rng.integers(1, 5))
    prompts = [list(rng.integers(0, cfg.vocab_size, int(rng.integers(1, 14))))
               for _ in range(n_req)]
    max_new = int(rng.integers(1, 6))
    max_slots = int(rng.integers(1, 4))
    chunk = int(rng.integers(1, 8))
    out = _batcher_outputs(arch, prompts, max_new, max_slots, chunk)
    assert out[0] == out[chunk], (
        f"{arch}: prefill batcher diverged from teacher-forced seed "
        f"(slots={max_slots}, chunk={chunk}, prompts={prompts})")


if HAS_HYPOTHESIS:
    @given(hyp_st.integers(min_value=0, max_value=2**32 - 1))
    def test_batcher_prefill_equals_teacher_forced(seed):
        _check_scenario(np.random.default_rng(seed))


@pytest.mark.parametrize("seed", [0, 7, 1234])
def test_batcher_prefill_equals_teacher_forced_seeded(seed):
    _check_scenario(np.random.default_rng(seed))


# -- unbounded-prompt regression (ISSUE 8 satellite) -------------------------

@pytest.mark.parametrize("chunk", [0, 4])
def test_unbounded_prompt_rejected(chunk):
    """Before the fix a prompt with len >= max_len was admitted, its pos
    walked past the cache bound (KV scatter silently dropped out-of-range
    rows) and the request terminated with garbage; submit() now rejects."""
    cfg, params = _setup("h2o_danube_3_4b")
    b = ContinuousBatcher(cfg, params, max_slots=1, max_len=16,
                          prefill_chunk=chunk)
    with pytest.raises(ValueError, match="max_len"):
        b.submit(list(range(1, 17)), max_new=4)
    with pytest.raises(ValueError, match="max_len"):
        b.submit(list(range(1, 40)), max_new=4)
    # the longest admissible prompt still produces output
    req = b.submit(list(range(1, 16)), max_new=4)
    done = b.run()
    assert [r.rid for r in done] == [req.rid] and len(req.output) >= 1
