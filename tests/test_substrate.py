"""Substrate: data pipelines, optimizer, checkpoint store, telemetry."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import ArtifactStore, tree_hash
from repro.configs import registry
from repro.data.mnist import Batches, make_dataset
from repro.data.tokens import TokenStream, lm_batches
from repro.optim import adamw
from repro.optim.schedules import warmup_cosine
from repro.telemetry.events import EventLog


def test_mnist_deterministic_and_shaped():
    i1, l1 = make_dataset(32, seed=5)
    i2, l2 = make_dataset(32, seed=5)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_array_equal(l1, l2)
    assert i1.shape == (32, 28, 28, 1) and i1.min() >= 0 and i1.max() <= 1
    assert set(np.unique(l1)).issubset(set(range(10)))


def test_mnist_classes_distinguishable(mnist_data):
    """Mean images of different digits differ substantially."""
    imgs, labels = mnist_data
    means = {d: imgs[labels == d].mean(0) for d in (0, 1)}
    assert np.abs(means[0] - means[1]).mean() > 0.02


def test_batches_iterator_drops_remainder():
    imgs, labels = make_dataset(70, seed=1)
    batches = list(Batches(imgs, labels, 32))
    assert len(batches) == 2
    assert batches[0]["image"].shape == (32, 28, 28, 1)


def test_token_stream_deterministic_and_in_range():
    s1 = TokenStream(1000, seed=2).sample(4, 64)
    s2 = TokenStream(1000, seed=2).sample(4, 64)
    np.testing.assert_array_equal(s1, s2)
    assert s1.min() >= 0 and s1.max() < 1000


def test_lm_batches_family_fields():
    cfg = registry.get_smoke_config("qwen2_vl_7b")
    b = next(iter(lm_batches(cfg, 2, 16, n_batches=1)))
    assert "vision_embeds" in b and "mrope_positions" in b
    cfg = registry.get_smoke_config("whisper_base")
    b = next(iter(lm_batches(cfg, 2, 16, n_batches=1)))
    assert b["frames"].shape == (2, cfg.encoder_len, cfg.d_model)


def test_adamw_optimises_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw.init_opt_state(params)
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        grads = jax.grad(loss)(params)
        params, opt, m = adamw.adamw_update(params, grads, opt, cfg)
    assert float(loss(params)) < 1e-2
    assert int(opt["step"]) == 150


def test_adamw_grad_clip_bounds_update():
    params = {"w": jnp.zeros(3)}
    opt = adamw.init_opt_state(params)
    cfg = adamw.AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    grads = {"w": jnp.full(3, 1e6)}
    new, _, m = adamw.adamw_update(params, grads, opt, cfg)
    assert float(m["grad_norm"]) > 1e5
    assert float(jnp.abs(new["w"]).max()) < 10.0


def test_warmup_cosine_shape():
    assert float(warmup_cosine(0, warmup=10, total=100)) == 0.0
    assert float(warmup_cosine(10, warmup=10, total=100)) > 0.9
    assert float(warmup_cosine(100, warmup=10, total=100)) < 0.2


def test_checkpoint_roundtrip(tmp_path):
    store = ArtifactStore(str(tmp_path))
    tree = {"layer": {"w": jnp.arange(6.0).reshape(2, 3),
                      "b": jnp.ones(3, jnp.float32)},
            "step": jnp.array(7, jnp.int32)}
    uri = store.save_tree("ckpt", tree, meta={"loss": 1.0})
    assert uri.startswith("file://")
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    restored = store.load_tree("ckpt", like)
    assert tree_hash(restored) == tree_hash(tree)


def test_event_log_stage_and_totals():
    log = EventLog()
    with log.stage("a"):
        pass
    log.record("a", 1.0)
    log.record("b", 2.0)
    totals = log.totals()
    assert totals["b"] == 2.0 and totals["a"] >= 1.0
