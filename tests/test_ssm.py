"""SSM-family math: chunked parallel forms vs sequential oracles, and
forward/decode state handoff for all three recurrent blocks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.kernels.ref import mlstm_scan_ref
from repro.models import ssm

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("chunk", [4, 8, 37])
def test_mlstm_chunked_matches_sequential_oracle(chunk):
    ks = jax.random.split(KEY, 5)
    B, S, H, D = 2, 37, 3, 8
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    logi = jax.random.normal(ks[3], (B, S, H)) * 0.5
    fpre = jax.random.normal(ks[4], (B, S, H)) + 2.0
    want = mlstm_scan_ref(q, k, v, logi, fpre)
    got, _ = ssm._mlstm_chunked(q, k, v, logi, jax.nn.log_sigmoid(fpre), chunk)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("block", ["mamba2", "mlstm", "slstm"])
def test_forward_then_decode_equals_longer_forward(block):
    cfg = registry.get_smoke_config("zamba2_1_2b" if block == "mamba2"
                                    else "xlstm_1_3b")
    init, fwd, dec = {
        "mamba2": (ssm.mamba2_init, ssm.mamba2_forward, ssm.mamba2_decode),
        "mlstm": (ssm.mlstm_init, ssm.mlstm_forward, ssm.mlstm_decode),
        "slstm": (ssm.slstm_init, ssm.slstm_forward, ssm.slstm_decode),
    }[block]
    p = init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 17, cfg.d_model)) * 0.5
    full = fwd(p, x, cfg)
    _, state = fwd(p, x[:, :16], cfg, return_state=True)
    got, _ = dec(p, x[:, 16:17], state, cfg)
    np.testing.assert_allclose(got[:, 0], full[:, 16], rtol=2e-3, atol=2e-3)


def test_mamba2_decode_state_advances():
    cfg = registry.get_smoke_config("zamba2_1_2b")
    p = ssm.mamba2_init(KEY, cfg, jnp.float32)
    shp = ssm.mamba2_cache_shape(cfg, batch=2)
    cache = {k: jnp.zeros(v, jnp.float32) for k, v in shp.items()}
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 1, cfg.d_model))
    _, c1 = ssm.mamba2_decode(p, x, cache, cfg)
    _, c2 = ssm.mamba2_decode(p, x, c1, cfg)
    assert float(jnp.abs(c2["ssm"] - c1["ssm"]).max()) > 0.0


def test_slstm_stabiliser_monotone_bounded():
    """m is a running max of log-gates: finite after the first step."""
    cfg = registry.get_smoke_config("xlstm_1_3b")
    p = ssm.slstm_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, cfg.d_model))
    _, state = ssm.slstm_forward(p, x, cfg, return_state=True)
    assert np.isfinite(np.asarray(state["m"])).all()
    assert np.isfinite(np.asarray(state["h"])).all()
