"""Queue-aware split routing + per-class admission control (ISSUE 4):
the weighted-JSQ routing blend, deadline-hopeless shedding (exactly once,
batch never shed, shed-rate as an overload signal), and the three
satellite regression suites -- per-pool deadline bases (a slow split
cloud must not fake a miss storm), the `_apportion` min-1 floor for
live-weight pools, and the (n-1)/window observed arrival rate."""
import math

import pytest

from repro.clouds.profiles import TPU_V5E, CloudProfile, get_profile
from repro.serving.gateway import (AdmissionConfig, AutoscalerConfig,
                                   Gateway, ReplanConfig, RoutingConfig,
                                   SLOClass, TrafficSpec)
from repro.serving.gateway.router import _apportion
from repro.telemetry.events import EventLog

from conftest import AnalyticBackend


def warm_config(**kw):
    return AutoscalerConfig(min_replicas=kw.pop("min_replicas", 1),
                            idle_window_s=kw.pop("idle_window_s", math.inf),
                            **kw)


def split_gcp_ibm(f_ibm):
    return {get_profile("gcp"): 1.0 - f_ibm, get_profile("ibm"): f_ibm}


# -- queue-aware routing (the tentpole blend) ---------------------------------

def test_config_validation():
    with pytest.raises(ValueError, match="policy"):
        RoutingConfig(policy="jsq")
    with pytest.raises(ValueError, match="slack"):
        RoutingConfig(slack=-0.1)
    with pytest.raises(ValueError, match="margin"):
        AdmissionConfig(margin=0.0)
    with pytest.raises(ValueError, match="max_shed_rate"):
        ReplanConfig(max_shed_rate=0.0)


def _stale_weights_fleet(routing):
    """0.9/0.1 declared split over EQUAL 1+1 replica pools: the weights
    are stale relative to capacity, the canonical queue-aware win."""
    gw = Gateway(record_batches=True, routing=routing)
    gw.deploy("m", AnalyticBackend("m", base_s=0.1), split=split_gcp_ibm(0.1),
              autoscaler=warm_config(min_replicas=2, max_replicas=2),
              max_batch=1)
    return gw


def test_queue_aware_drains_to_idle_sibling_pool():
    """Pure weights sends ~90% of a burst into one queue while the sibling
    idles; queue-aware keeps joining the shorter expected queue, so the
    load lands balanced and the tail collapses."""
    traffic = [TrafficSpec("m", 40)]
    by_policy = {}
    for policy in ("weights", "queue_aware"):
        gw = _stale_weights_fleet(RoutingConfig(policy=policy))
        out = gw.run(traffic, seed=0)
        per_cloud = {}
        for rec in gw.batch_log:
            per_cloud[rec["cloud"]] = per_cloud.get(rec["cloud"], 0) \
                + len(rec["idx"])
        by_policy[policy] = (out.per_model["m"].p99, per_cloud)
    p99_w, cloud_w = by_policy["weights"]
    p99_q, cloud_q = by_policy["queue_aware"]
    assert cloud_w.get("ibm", 0) < 10        # stale weights starve ibm
    assert cloud_q["ibm"] >= 15              # JSQ balances 1:1 capacity
    assert abs(cloud_q["gcp"] - cloud_q["ibm"]) <= 6
    assert p99_q < p99_w                     # the point of the blend


def test_queue_aware_respects_weights_when_balanced():
    """With balanced pools (service time comparable to the network
    constants, no backlog) every candidate stays in the slack band, so
    the declared weights still set the split (the bias half of the
    blend).  An ultra-fast backend would instead strictly prefer the
    lower-RTT cloud -- that dominance is by design."""
    gw = Gateway(record_batches=True)
    gw.deploy("m", AnalyticBackend("m", base_s=0.05),
              split=split_gcp_ibm(0.3),
              autoscaler=warm_config(min_replicas=2, max_replicas=2),
              max_batch=8)
    gw.run([TrafficSpec("m", 300, arrival="poisson", rate=10.0)], seed=3)
    share = sum(len(r["idx"]) for r in gw.batch_log
                if r["cloud"] == "ibm") / 300
    assert 0.2 < share < 0.45


def test_queue_aware_routing_is_deterministic():
    traffic = [TrafficSpec("m", 60, arrival="poisson", rate=120.0),
               TrafficSpec("m", 20, slo="latency", start_s=0.1)]
    runs = []
    for _ in range(2):
        gw = _stale_weights_fleet(RoutingConfig())
        out = gw.run(traffic, seed=7)
        runs.append((out.summary(),
                     [(r["cloud"], r["idx"]) for r in gw.batch_log]))
    assert runs[0] == runs[1]


def test_queue_hint_biases_first_arrivals_off_congested_plan():
    """A planner expected-queue hint steers traffic before any real queue
    exists: with a huge hint on gcp, the first burst lands on ibm."""
    gw = Gateway(record_batches=True)
    gw.deploy("m", AnalyticBackend("m", base_s=0.01),
              split=split_gcp_ibm(0.5),
              autoscaler=warm_config(min_replicas=2, max_replicas=2),
              max_batch=4, queue_hint={"gcp": 5.0})
    gw.run([TrafficSpec("m", 4)], seed=0)
    first = min(gw.batch_log, key=lambda r: (r["start_s"], min(r["idx"])))
    assert first["cloud"] == "ibm"


# -- admission control / shedding ---------------------------------------------

def _hopeless(margin=1.0, **deploy_kw):
    """One slow replica, max_batch=1: a burst's tail is deadline-hopeless
    for the latency class the moment the queue is a few deep."""
    log = EventLog()
    gw = Gateway(log=log, record_batches=True,
                 admission=AdmissionConfig(margin=margin))
    gw.deploy("m", AnalyticBackend("m", base_s=0.2), get_profile("gcp"),
              autoscaler=deploy_kw.pop(
                  "autoscaler", warm_config(max_replicas=1)),
              max_batch=1, **deploy_kw)
    return gw, log


def test_hopeless_requests_shed_exactly_once_and_reported():
    gw, log = _hopeless()
    out = gw.run([TrafficSpec("m", 30, slo="latency")], seed=0)
    res = out.per_model["m"]
    sheds = log.named("gateway:shed")
    assert sheds, "an overloaded burst must shed"
    idx = [e["idx"] for e in sheds]
    assert len(idx) == len(set(idx))                 # exactly once
    assert res.n_requests == 30                      # offered
    assert len(res.latencies_s) == 30 - len(idx)     # percentiles exclude
    assert res.shed_total == len(idx)
    assert res.class_shed == {"latency": len(idx)}
    served = sorted(i for rec in gw.batch_log if not rec["preempted"]
                    for i in rec["idx"])
    assert sorted(served + idx) == list(range(30))   # complete xor shed
    pc = res.per_class()["latency"]
    assert pc["shed"] == len(idx)
    assert pc["shed_rate"] == pytest.approx(len(idx) / 30, abs=1e-4)
    assert 0 < res.shed_rate < 1
    assert out.shed_total == res.shed_total
    assert "shed" in res.summary() and "shed" in out.summary()
    # every survivor really was servable inside margin x deadline
    assert all(l > 0 for l in res.latencies_s)


def test_batch_class_is_deferred_never_shed():
    gw, log = _hopeless()
    out = gw.run([TrafficSpec("m", 30, slo="batch"),
                  TrafficSpec("m", 10, slo="latency", start_s=0.01)],
                 seed=0)
    res = out.per_model["m"]
    assert res.class_shed.get("batch", 0) == 0
    assert len(res.class_latencies["batch"]) == 30   # all complete, late
    assert all(e["cls"] != "batch" for e in log.named("gateway:shed"))


def test_infinite_deadline_class_never_shed():
    gw, log = _hopeless()
    out = gw.run([TrafficSpec("m", 30,
                              slo=SLOClass("lazy", 1.0, math.inf))], seed=0)
    assert log.count("gateway:shed") == 0
    assert out.per_model["m"].shed_total == 0


def test_admission_off_is_legacy_behavior():
    gw = Gateway()
    gw.deploy("m", AnalyticBackend("m", base_s=0.2), get_profile("gcp"),
              autoscaler=warm_config(max_replicas=1), max_batch=1)
    res = gw.run([TrafficSpec("m", 30, slo="latency")],
                 seed=0).per_model["m"]
    assert res.shed_total == 0 and res.class_shed == {}
    assert len(res.latencies_s) == 30
    assert "shed" not in res.summary()


def test_dispatch_recheck_sheds_aged_backlog():
    """Requests admitted on an optimistic estimate (a scheduled replica
    counts toward pool size but serves nothing until its "up" fires) can
    still turn hopeless in the queue: the dispatch re-check sheds them
    with at=dispatch, and each request is still shed at most once."""
    log = EventLog()
    gw = Gateway(log=log, admission=AdmissionConfig())
    gw.deploy("m", AnalyticBackend("m", base_s=0.1), get_profile("gcp"),
              autoscaler=AutoscalerConfig(min_replicas=1, max_replicas=4,
                                          target_queue=2,
                                          scale_up_delay_s=0.5,
                                          idle_window_s=math.inf),
              max_batch=1)
    out = gw.run([TrafficSpec("m", 6, slo="latency"),
                  TrafficSpec("m", 8, slo="latency", start_s=0.05)], seed=0)
    sheds = log.named("gateway:shed")
    at = {e["at"] for e in sheds}
    assert at == {"enqueue", "dispatch"}
    idx = [e["idx"] for e in sheds]
    assert len(idx) == len(set(idx))
    assert len(out.per_model["m"].latencies_s) == 14 - len(idx)


def test_shedding_triggers_scale_up_not_masking():
    """Shed-pressure counts as queue depth for the KPA rule: a pool whose
    queue stays short only BECAUSE it sheds must still scale up."""
    log = EventLog()
    gw = Gateway(log=log, admission=AdmissionConfig())
    gw.deploy("m", AnalyticBackend("m", base_s=0.2), get_profile("gcp"),
              autoscaler=AutoscalerConfig(min_replicas=1, max_replicas=4,
                                          target_queue=2,
                                          scale_up_delay_s=0.05,
                                          idle_window_s=math.inf),
              max_batch=1)
    gw.run([TrafficSpec("m", 40, slo="latency")], seed=0)
    assert log.count("gateway:shed") > 0
    assert log.count("gateway:scale_up") >= 1, \
        "shedding masked the overload from the autoscaler"


def test_probe_treats_shed_rate_as_overload_signal():
    """A pool serving inside its queue bound but shedding a class whose
    deadline it cannot meet must still shift weight away
    (gateway:migrate reason=shed_rate)."""
    log = EventLog()
    gw = Gateway(log=log, admission=AdmissionConfig(),
                 replan=ReplanConfig(check_every_s=0.1, sustain=2,
                                     min_window_n=4, max_shed_rate=0.1,
                                     consolidate=False))
    # standard traffic completes comfortably; the strict class is hopeless
    # on ibm (deadline < even an empty-queue pass) -> pure shed signal,
    # no queue overload, no completion misses
    strict = SLOClass("strict", weight=4.0, deadline_mult=0.5)
    gw.deploy("m", AnalyticBackend("m", base_s=0.2), get_profile("ibm"),
              standby=get_profile("gcp"),
              autoscaler=warm_config(max_replicas=2, target_queue=64),
              max_batch=8)
    out = gw.run([TrafficSpec("m", 30, arrival="poisson", rate=40.0),
                  TrafficSpec("m", 30, slo=strict,
                              arrival="poisson", rate=40.0)], seed=0)
    migs = log.named("gateway:migrate")
    assert migs and migs[0]["reason"] == "shed_rate", migs
    assert migs[0]["src"] == "ibm" and migs[0]["dst"] == "gcp"
    assert out.per_model["m"].class_shed.get("strict", 0) > 0


# -- satellite 1: per-pool deadline bases -------------------------------------

SLOW = CloudProfile("slowcloud", TPU_V5E, (1, 1),
                    network_rtt_s=0.5, lb_overhead_s=0.0,
                    model_load_s=0.2, startup_s=1.0, cost_per_s=0.9 / 3600)


def test_slow_split_cloud_does_not_oscillate_replan():
    """Regression (ISSUE 4): the in-run miss window used to charge every
    pool against the PRIMARY cloud's warm path, so a cheap-but-slow split
    cloud looked like a 50% miss storm and ReplanConfig probes shifted
    weight away for ever.  Misses must be charged per serving pool."""
    log = EventLog()
    gw = Gateway(log=log, routing=RoutingConfig(policy="weights"),
                 replan=ReplanConfig(check_every_s=0.25, sustain=2,
                                     max_miss_rate=0.3, consolidate=False))
    gw.deploy("m", AnalyticBackend("m", base_s=0.01),
              split={get_profile("gcp"): 0.5, SLOW: 0.5},
              autoscaler=warm_config(min_replicas=2, max_replicas=4),
              max_batch=8)
    out = gw.run([TrafficSpec("m", 120, arrival="poisson", rate=50.0)],
                 seed=0)
    assert out.per_model["m"].n_requests == 120
    assert log.named("gateway:migrate") == [], \
        "slow-but-honest split cloud must not trigger miss_rate replans"
    assert gw.final_weights["m"] == {"gcp": 0.5, "slowcloud": 0.5}
    # the REPORTED promise stays primary-relative (documented): requests
    # served by the slow cloud still count as misses in per_class()
    assert out.per_model["m"].per_class()["standard"]["miss_rate"] > 0.2


def test_shedder_uses_serving_pools_own_base():
    """The slow cloud's own warm path is ~0.5s; with admission on, its
    requests must NOT be shed against the fast primary's ~12ms deadline
    (standard: 20x base) when its queue is empty."""
    gw = Gateway(routing=RoutingConfig(policy="weights"),
                 admission=AdmissionConfig())
    gw.deploy("m", AnalyticBackend("m", base_s=0.01),
              split={get_profile("gcp"): 0.5, SLOW: 0.5},
              autoscaler=warm_config(min_replicas=2, max_replicas=4),
              max_batch=8)
    out = gw.run([TrafficSpec("m", 60, arrival="poisson", rate=20.0)],
                 seed=0)
    assert out.per_model["m"].shed_total == 0


# -- satellite 2: _apportion min-1 floor --------------------------------------

def test_apportion_min1_floor_for_live_pools():
    """Regression (ISSUE 4): a 0.95/0.05 split at total=2 floored the
    low-weight pool at ZERO replicas while routing still sent it traffic."""
    assert _apportion(2, {"a": 0.95, "b": 0.05}) == {"a": 1, "b": 1}
    assert _apportion(3, {"a": 0.95, "b": 0.05}) == {"a": 2, "b": 1}
    # total < live pools: no floor to give -- largest weight wins
    assert _apportion(1, {"a": 0.95, "b": 0.05}) == {"a": 1, "b": 0}
    # zero-weight pools are never floored
    assert _apportion(2, {"a": 0.9, "b": 0.1, "standby": 0.0}) == \
        {"a": 1, "b": 1, "standby": 0}
    # plenty of replicas: plain largest-remainder is untouched
    assert _apportion(20, {"a": 0.95, "b": 0.05}) == {"a": 19, "b": 1}
    assert _apportion(0, {"a": 1.0}) == {"a": 0}


def test_low_weight_pool_serves_immediately_at_small_replica_counts():
    """End-to-end: min_replicas=2 over a 0.95/0.05 split must give the
    5% pool a warm replica, so its share of a burst is served without
    waiting for an autoscaler round-trip."""
    gw = Gateway(record_batches=True, routing=RoutingConfig("weights"))
    gw.deploy("m", AnalyticBackend("m", base_s=0.05),
              split=split_gcp_ibm(0.05),
              autoscaler=warm_config(min_replicas=2, max_replicas=2),
              max_batch=8)
    out = gw.run([TrafficSpec("m", 100)], seed=4)
    assert out.per_model["m"].n_requests == 100
    ibm_first = min(r["start_s"] for r in gw.batch_log
                    if r["cloud"] == "ibm")
    assert ibm_first == 0.0, "ibm floor replica must serve the burst at t=0"


# -- satellite 3: observed arrival rate ---------------------------------------

def test_observed_rate_counts_intervals_not_arrivals():
    """Regression (ISSUE 4): n arrivals span n-1 gaps; rate_rps used to be
    n/window, overestimating small-n demand and biasing replan upward."""
    gw = Gateway()
    gw.deploy("m", AnalyticBackend("m", base_s=0.001), get_profile("gcp"),
              autoscaler=warm_config(), max_batch=4)
    out = gw.run([TrafficSpec("m", 4, arrivals=[0.0, 1.0, 2.0, 3.0])])
    obs = out.per_model["m"].observed
    assert obs["window_s"] == pytest.approx(3.0)
    assert obs["rate_rps"] == pytest.approx(1.0)     # was 4/3


def test_observed_rate_burst_fallback_unchanged():
    gw = Gateway()
    gw.deploy("m", AnalyticBackend("m", base_s=0.01), get_profile("gcp"),
              autoscaler=warm_config(), max_batch=8)
    out = gw.run([TrafficSpec("m", 16)])             # pure burst at t=0
    obs = out.per_model["m"].observed
    assert obs["rate_rps"] == pytest.approx(16 / obs["window_s"])
    assert obs["rate_rps"] > 0
