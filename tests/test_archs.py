"""Per-arch smoke tests (deliverable f): each assigned architecture's
REDUCED config runs forward / train_step / prefill / decode on CPU with
correct shapes, no NaNs, and prefill+decode == full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs import registry
from repro.models import lm, steps
from repro.optim import adamw

ARCHS = registry.list_archs()


def _smoke(arch):
    cfg = registry.get_smoke_config(arch)
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    return cfg


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = _smoke(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    logits, aux, _ = lm.forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_nothing_nan(arch):
    cfg = _smoke(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_opt_state(params)
    batch = make_batch(cfg, 2, 32)
    params, opt, m = steps.train_step(params, opt, batch, cfg=cfg)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    assert int(opt["step"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    cfg = _smoke(arch)
    if cfg.n_experts:   # capacity drops differ between prefill and decode
        cfg = cfg.replace(capacity_factor=8.0)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 33
    full = make_batch(cfg, B, S, labels=False)
    logits_full, _, _ = lm.forward(params, cfg, full)
    pre = {k: (v[:, :S - 1] if k == "tokens"
               else (v[:, :, :S - 1] if k == "mrope_positions" else v))
           for k, v in full.items()}
    _, cache = steps.prefill(params, pre, cfg=cfg, cache_len=S + 4)
    pos = jnp.full((B,), S - 1, jnp.int32)
    if cfg.use_mrope:
        pos = jnp.broadcast_to(pos[:, None], (B, 3))
    got, _ = lm.decode_step(params, cfg, full["tokens"][:, S - 1:S], pos, cache)
    want = logits_full[:, -1]
    rel = float(jnp.max(jnp.abs(got - want))) / (float(jnp.max(jnp.abs(want))) + 1e-9)
    assert rel < 2e-3, f"{arch}: prefill+decode diverges from forward ({rel})"


@pytest.mark.parametrize("arch", ["gemma3_4b", "h2o_danube_3_4b", "zamba2_1_2b",
                                  "xlstm_1_3b"])
def test_multi_step_decode_stays_finite(arch):
    cfg = _smoke(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S, n_gen = 2, 16, 12
    batch = make_batch(cfg, B, S, labels=False)
    last, cache = steps.prefill(params, batch, cfg=cfg, cache_len=S + n_gen + 1)
    tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
    start = jnp.full((B,), S, jnp.int32)
    toks, _ = steps.greedy_decode_loop(params, cache, tok, start, n_gen, cfg=cfg)
    assert toks.shape == (B, n_gen)
    assert (np.asarray(toks) >= 0).all() and (np.asarray(toks) < cfg.vocab_size).all()


def test_full_configs_match_assignment_sheet():
    spec = {
        "granite_moe_3b_a800m": dict(n_layers=32, d_model=1536, n_heads=24,
                                     n_kv_heads=8, vocab_size=49155,
                                     n_experts=40, top_k=8, moe_d_ff=512),
        "xlstm_1_3b": dict(n_layers=48, d_model=2048, n_heads=4, vocab_size=50304),
        "granite_3_8b": dict(n_layers=40, d_model=4096, n_heads=32,
                             n_kv_heads=8, d_ff=12800, vocab_size=49155),
        "gemma3_4b": dict(n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
                          d_ff=10240, vocab_size=262144),
        "deepseek_v2_lite_16b": dict(n_layers=27, d_model=2048, n_heads=16,
                                     vocab_size=102400, n_experts=64, top_k=6,
                                     moe_d_ff=1408, kv_lora_rank=512),
        "h2o_danube_3_4b": dict(n_layers=24, d_model=3840, n_heads=32,
                                n_kv_heads=8, d_ff=10240, vocab_size=32000),
        "whisper_base": dict(n_layers=6, d_model=512, n_heads=8, d_ff=2048,
                             vocab_size=51865),
        "minitron_4b": dict(n_layers=32, d_model=3072, n_heads=24,
                            n_kv_heads=8, d_ff=9216, vocab_size=256000),
        "qwen2_vl_7b": dict(n_layers=28, d_model=3584, n_heads=28,
                            n_kv_heads=4, d_ff=18944, vocab_size=152064),
        "zamba2_1_2b": dict(n_layers=38, d_model=2048, n_heads=32,
                            n_kv_heads=32, d_ff=8192, vocab_size=32000,
                            ssm_state=64),
    }
    for arch, fields in spec.items():
        cfg = registry.get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"
        assert cfg.citation


def test_long_context_skip_policy():
    """DESIGN.md skip matrix: who runs long_500k."""
    runs = {a: registry.runnable(registry.get_config(a),
                                 registry.INPUT_SHAPES["long_500k"])[0]
            for a in ARCHS}
    assert runs == {
        "granite_moe_3b_a800m": False, "xlstm_1_3b": True, "granite_3_8b": False,
        "gemma3_4b": True, "deepseek_v2_lite_16b": False, "h2o_danube_3_4b": True,
        "whisper_base": False, "minitron_4b": False, "qwen2_vl_7b": False,
        "zamba2_1_2b": True,
    }


def test_stacked_decode_variant_matches_scan_decode():
    """slot_decode_stacked (the §Perf C3 experiment) must stay correct even
    though the scan formulation is the production path."""
    import jax
    import jax.numpy as jnp
    from repro.models import blocks, lm

    cfg = registry.get_smoke_config("h2o_danube_3_4b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = make_batch(cfg, B, S, labels=False)
    _, cache = steps.prefill(params, batch, cfg=cfg, cache_len=S + 4)
    tok = batch["tokens"][:, :1]
    pos = jnp.full((B,), S, jnp.int32)
    want, _ = lm.decode_step(params, cfg, tok, pos, cache)

    # manual pass through the stacked variant
    import repro.models.modules as nn
    x = lm._embed(params, cfg, tok)
    plan = blocks.build_plan(cfg)
    for pi, phase in enumerate(plan):
        pcache = dict(cache[f"phase{pi}"])
        for g in range(phase.n_groups):
            gp = nn.layer_slice(params[f"phase{pi}"], g)
            for j, (kind, ffn) in enumerate(zip(phase.kinds, phase.ffns)):
                x, pcache[f"slot{j}"] = blocks.slot_decode_stacked(
                    jax.tree_util.tree_map(
                        lambda a: a.astype(cfg.compute_dtype)
                        if a.dtype.kind == "f" else a, gp[f"slot{j}"]),
                    x, pcache[f"slot{j}"], g, pos, cfg, kind, ffn)
    got = lm._head(jax.tree_util.tree_map(
        lambda a: a.astype(cfg.compute_dtype) if a.dtype.kind == "f" else a,
        params), cfg, x[:, 0])
    import numpy as np
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
