"""MoE routing invariants: gate normalisation, capacity semantics,
expert utilisation, aux loss range, and a dense-equivalence check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import moe

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    return registry.get_smoke_config("granite_moe_3b_a800m").replace(**kw)


def test_moe_output_shape_and_finite():
    cfg = _cfg()
    p = moe.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 16, cfg.d_model))
    y, aux = moe.moe_forward(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert 0.0 < float(aux) < 10.0 * cfg.n_experts


def test_high_capacity_equals_unlimited_dense_dispatch():
    """With cf high enough nothing is dropped: compare against a dense
    computation that runs every token through its top-k experts directly."""
    cfg = _cfg(capacity_factor=16.0)
    p = moe.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, _ = moe.moe_forward(p, x, cfg)

    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    probs = jax.nn.softmax(logits, -1)
    gates, ids = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    h_g = jnp.einsum("bsd,edf->bsef", x, p["experts_gate"])
    h_u = jnp.einsum("bsd,edf->bsef", x, p["experts_up"])
    h = jax.nn.silu(h_g) * h_u
    dense = jnp.einsum("bsef,efd->bsed", h, p["experts_down"])
    picked = jnp.take_along_axis(dense, ids[..., None], axis=2)
    want = (picked * gates[..., None]).sum(2)
    np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)


def test_capacity_drops_tokens_when_overloaded():
    cfg = _cfg(capacity_factor=0.25)
    p = moe.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))
    y_low, _ = moe.moe_forward(p, x, cfg)
    y_hi, _ = moe.moe_forward(p, x, cfg.replace(capacity_factor=16.0))
    assert float(jnp.abs(y_low - y_hi).max()) > 1e-6   # drops actually happened


def test_decode_single_token_routing():
    cfg = _cfg()
    p = moe.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, cfg.d_model))
    y, aux = moe.moe_forward(p, x, cfg)
    assert y.shape == x.shape and np.isfinite(np.asarray(y)).all()


def test_shared_experts_added():
    cfg = registry.get_smoke_config("deepseek_v2_lite_16b").replace(
        capacity_factor=16.0)
    p = moe.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y_with, _ = moe.moe_forward(p, x, cfg)
    p2 = dict(p)
    p2["shared_down"] = jnp.zeros_like(p["shared_down"])
    y_without, _ = moe.moe_forward(p2, x, cfg)
    assert float(jnp.abs(y_with - y_without).max()) > 1e-6


def test_balanced_router_aux_near_one():
    """Uniform router -> aux ~= 1 (E * E * (1/E) * (1/E))."""
    cfg = _cfg()
    p = moe.moe_init(KEY, cfg, jnp.float32)
    p["router"] = jnp.zeros_like(p["router"])   # uniform probs
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    _, aux = moe.moe_forward(p, x, cfg)
    assert 0.5 < float(aux) < 2.0


def test_expert_padding_is_function_preserving():
    """Padded (dead) experts change shapes, never outputs (perf variant)."""
    cfg = _cfg(capacity_factor=16.0)
    p0 = moe.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y0, _ = moe.moe_forward(p0, x, cfg)
    cfgp = cfg.replace(expert_pad_to=8)
    pp = moe.moe_init(KEY, cfgp, jnp.float32)
    for k in ("experts_gate", "experts_up", "experts_down"):
        pp[k] = pp[k].at[:cfg.n_experts].set(p0[k])
    pp["router"] = p0["router"]
    yp, _ = moe.moe_forward(pp, x, cfgp)
    np.testing.assert_allclose(y0, yp, rtol=1e-5, atol=1e-5)
