"""Unit tests for the multi-cloud pipeline orchestrator
(repro/pipelines/): scheduling, placement policies, outage retries,
exactly-once completion, artifact caching + cross-cloud transfers,
recurring runs, and the deploy handoff into the serving gateway."""
import dataclasses

import numpy as np
import pytest

from repro.clouds.profiles import get_profile
from repro.core.pipeline import Pipeline
from repro.pipelines import (ArtifactCache, DeploySpec, Orchestrator,
                             PipelineRuns, RetryPolicy)
from repro.serving.gateway import (AutoscalerConfig, CloudCapacity,
                                   FailureSpec, Gateway, TrafficSpec)

from conftest import AnalyticBackend

GCP = get_profile("gcp")
IBM = get_profile("ibm")


def _counted(calls):
    def fn(tag, *deps):
        calls[tag] = calls.get(tag, 0) + 1
        return [tag] + [d[0] for d in deps if isinstance(d, list)]
    return fn


def fanout_spec(n_branches=4, sim=1.0, cache=True, calls=None):
    calls = calls if calls is not None else {}
    fn = _counted(calls)
    pipe = Pipeline("fan")
    p = pipe.step(fn, 0, sim_s=0.2, name="prep", cache=cache)
    bs = [pipe.step(fn, 10 + i, p, sim_s=sim, name=f"branch{i}", cache=cache)
          for i in range(n_branches)]
    pipe.step(fn, 99, *bs, sim_s=0.1, name="merge", cache=cache)
    return pipe.compile(), calls


def test_fanout_runs_branches_in_parallel():
    spec, calls = fanout_spec()
    orch = Orchestrator({"gcp": 2, "ibm": 2})
    rec = orch.execute(spec)
    assert rec.status == "succeeded"
    assert all(r.status == "done" for r in rec.steps.values())
    # each fn ran exactly once
    assert all(v == 1 for v in calls.values())
    # branches overlap in simulated time (true parallelism)
    b = [rec.steps[f"branch{i}"] for i in range(4)]
    assert all(x.start_s == b[0].start_s for x in b)
    # work conservation: makespan never exceeds the serial sum
    serial = sum(r.duration_s for r in rec.steps.values())
    assert rec.makespan_s <= serial + 1e-9
    # and genuinely beats it on a 4-way fan-out over 4 workers
    assert serial / rec.makespan_s > 1.5


def test_cost_policy_prefers_cheap_cloud_makespan_prefers_fast():
    fast_dear = dataclasses.replace(GCP, cost_per_s=2.0 / 3600.0)
    slow_cheap = dataclasses.replace(IBM, cost_per_s=1.0 / 3600.0)
    pipe = Pipeline("one")
    pipe.step(lambda: 1, name="s", sim_s=0.5)
    spec = pipe.compile()
    rec_cost = Orchestrator({fast_dear: 1, slow_cheap: 1},
                            policy="cost").execute(spec)
    rec_mk = Orchestrator({fast_dear: 1, slow_cheap: 1},
                          policy="makespan").execute(spec)
    assert rec_cost.steps["s"].cloud == "ibm"     # cheapest first
    assert rec_mk.steps["s"].cloud == "gcp"       # startup 3s < 5s


def test_pin_forces_cloud():
    pipe = Pipeline("pinned")
    pipe.step(lambda: 1, name="s", sim_s=0.1, pin="ibm")
    rec = Orchestrator({"gcp": 1, "ibm": 1}).execute(pipe.compile())
    assert rec.steps["s"].cloud == "ibm"
    with pytest.raises(ValueError, match="unknown cloud"):
        Orchestrator({"gcp": 1}).execute(pipe.compile())


def test_outage_mid_attempt_retries_and_completes_once():
    calls = {}
    fn = _counted(calls)
    pipe = Pipeline("retry")
    pipe.step(fn, 0, sim_s=1.0, name="s")
    spec = pipe.compile()
    orch = Orchestrator({"gcp": 1}, retry=RetryPolicy(max_retries=2,
                                                      backoff_s=0.25))
    # attempt spans [0, ~4.0); the outage at 3.0 kills it
    rec = orch.execute(spec, failures=[FailureSpec("gcp", 3.0, 0.5)])
    r = rec.steps["s"]
    assert rec.status == "succeeded" and r.status == "done"
    assert calls[0] == 1                          # fn ran exactly once
    assert len(r.attempts) == 2
    assert r.attempts[0]["status"] == "outage"
    assert r.attempts[0]["end_s"] == pytest.approx(3.0)
    # retry backs off past the recovery edge, then restarts
    assert r.attempts[1]["start_s"] >= 3.5
    assert orch.log.count("pipeline:retry") == 1
    assert orch.log.count("pipeline:step") == 1
    # the failed attempt is still billed for its worker-seconds
    assert r.cost_usd > r.attempts[1]["cost_usd"]


def test_retries_exhausted_fails_step_and_skips_descendants():
    pipe = Pipeline("perm")
    a = pipe.step(lambda: 1, name="a", sim_s=1.0)
    pipe.step(lambda x: x, a, name="b", sim_s=0.1)
    spec = pipe.compile()
    orch = Orchestrator({"gcp": 1}, retry=RetryPolicy(max_retries=1,
                                                      backoff_s=0.1))
    rec = orch.execute(spec, failures=[FailureSpec("gcp", 3.0, 0.2),
                                       FailureSpec("gcp", 3.5, 0.2)])
    assert rec.status == "failed"
    assert rec.steps["a"].status == "failed"
    assert len(rec.steps["a"].attempts) == 2
    assert rec.steps["b"].status == "skipped"
    assert orch.log.count("pipeline:fail") == 1
    assert orch.log.count("pipeline:skip") == 1
    assert "b" not in rec.outputs


def test_exception_fails_fast_without_retries():
    pipe = Pipeline("boom")
    a = pipe.step(lambda: 1 / 0, name="a")
    pipe.step(lambda x: x, a, name="b")
    orch = Orchestrator({"gcp": 1})
    rec = orch.execute(pipe.compile())
    assert rec.status == "failed"
    assert rec.steps["a"].status == "failed"
    assert len(rec.steps["a"].attempts) == 1
    assert rec.steps["b"].status == "skipped"
    ev = orch.log.named("pipeline:fail")[0]
    assert ev["reason"].startswith("exception:ZeroDivisionError")


def test_cache_hits_never_reexecute_and_bypass_workers():
    spec, calls = fanout_spec(cache=True)
    orch = Orchestrator({"gcp": 2, "ibm": 2})
    rec1 = orch.execute(spec)
    n_after_first = dict(calls)
    rec2 = orch.execute(spec)
    assert calls == n_after_first                 # nothing re-ran
    assert rec2.cache_hits == len(spec.steps)
    assert all(r.cached and r.status == "done" for r in rec2.steps.values())
    assert orch.log.count("pipeline:cache_hit") == len(spec.steps)
    # a cached run is control-plane-only: no startup, tiny makespan, $0
    assert rec2.makespan_s < 0.1 and rec2.cost_usd == 0.0
    assert rec2.outputs == rec1.outputs


def test_cross_cloud_transfer_charged_once_then_resident():
    big = np.zeros(125_000_000 // 8, np.float64)   # 125 MB
    pipe = Pipeline("xfer")
    a = pipe.step(lambda: big, name="produce", sim_s=0.1, pin="gcp")
    b = pipe.step(lambda x: float(x[0]), a, name="consume", sim_s=0.1,
                  pin="ibm")
    pipe.step(lambda x, y: y, a, b, name="consume2", sim_s=0.1, pin="ibm")
    orch = Orchestrator({"gcp": 1, "ibm": 1})
    rec = orch.execute(pipe.compile())
    assert rec.status == "succeeded"
    tr = orch.log.named("pipeline:transfer")
    assert len(tr) == 1                           # second consume: resident
    assert tr[0]["src"] == "gcp" and tr[0]["dst"] == "ibm"
    assert tr[0]["bytes"] == big.nbytes
    # 125 MB over the 1.25 GB/s interconnect: ~0.1 s on the consume path
    assert rec.steps["consume"].transfer_s == pytest.approx(
        GCP.network_rtt_s + IBM.network_rtt_s + 0.1)
    assert rec.steps["consume"].transfer_cost_usd == pytest.approx(
        0.125 * GCP.egress_per_gb)
    assert rec.steps["consume2"].transfer_s == 0.0


def test_deploy_step_hands_model_to_gateway():
    pipe = Pipeline("t2s")
    model = pipe.step(lambda: {"w": 1.0}, name="train", sim_s=0.5)
    pipe.step(lambda m: AnalyticBackend("ranker", 0.01, 0.001), model,
              name="deploy", kind="deploy",
              payload=DeploySpec(
                  "ranker",
                  clouds=[CloudCapacity(GCP, 2, 1.0),
                          CloudCapacity(IBM, 4, 1.4)],
                  load_erlangs=2.0, split=True,
                  autoscaler=AutoscalerConfig(min_replicas=3, max_replicas=3,
                                              idle_window_s=np.inf),
                  max_batch=8))
    spec = pipe.compile()
    assert spec.steps[1].cache is False           # handoff is a side effect
    gw = Gateway()
    orch = Orchestrator({"gcp": 2, "ibm": 2}, policy="cost")
    rec = orch.execute(spec, gateway=gw)
    assert rec.status == "succeeded"
    out = rec.outputs["deploy"]
    # load 2.0 Erlangs / 0.7 target -> 3 replicas: gcp holds 2, ibm 1
    assert out["replicas"] == {"gcp": 2, "ibm": 1}
    assert sum(out["weights"].values()) == pytest.approx(1.0)
    assert "ranker" in gw.deployments
    assert orch.log.count("pipeline:deploy") == 1
    # the deployed model serves real traffic through the gateway
    served = gw.run([TrafficSpec("ranker", 16)], seed=0)
    assert served.per_model["ranker"].n_requests == 16


def test_failed_deploy_leaves_no_live_deployment():
    """The Gateway.deploy side effect is applied on step COMPLETION: a
    deploy step whose every attempt dies in an outage must not leave the
    model serving in the fleet."""
    pipe = Pipeline("dead-deploy")
    m = pipe.step(lambda: 1, name="train", sim_s=0.2)
    pipe.step(lambda _: AnalyticBackend("ghost", 0.01), m, name="deploy",
              kind="deploy", pin="gcp",
              payload=DeploySpec("ghost", clouds=[CloudCapacity(GCP, 4, 1.0)],
                                 load_erlangs=1.0))
    gw = Gateway()
    orch = Orchestrator({"gcp": 1}, retry=RetryPolicy(max_retries=1,
                                                      backoff_s=0.1))
    # train ends ~3.2s; both deploy attempts die inside the windows
    rec = orch.execute(pipe.compile(), gateway=gw,
                       failures=[FailureSpec("gcp", 4.0, 0.2),
                                 FailureSpec("gcp", 5.0, 3.0)])
    assert rec.steps["deploy"].status == "failed"
    assert "ghost" not in gw.deployments
    assert orch.log.count("pipeline:deploy") == 0


def test_deploy_infeasible_fails_run():
    pipe = Pipeline("nofit")
    m = pipe.step(lambda: 1, name="train", sim_s=0.1)
    pipe.step(lambda _: AnalyticBackend("m", 0.01), m, name="deploy",
              kind="deploy",
              payload=DeploySpec("m", clouds=[CloudCapacity(GCP, 0, 1.0)],
                                 load_erlangs=2.1))
    orch = Orchestrator({"gcp": 1})
    rec = orch.execute(pipe.compile(), gateway=Gateway())
    assert rec.status == "failed"
    assert orch.log.named("pipeline:fail")[0]["reason"] == "deploy_infeasible"


def test_deploy_requires_gateway_and_payload():
    pipe = Pipeline("bad")
    pipe.step(lambda: AnalyticBackend("m", 0.01), name="d", kind="deploy",
              payload=DeploySpec("m", clouds=[], load_erlangs=1.0))
    with pytest.raises(ValueError, match="gateway"):
        Orchestrator({"gcp": 1}).execute(pipe.compile())
    with pytest.raises(ValueError, match="rate / load_erlangs"):
        DeploySpec("m", clouds=[], rate=1.0, load_erlangs=1.0)
    with pytest.raises(ValueError, match="kind"):
        pipe.step(lambda: 1, kind="serve")


def test_recurring_runs_share_cache_and_catch_up():
    spec, calls = fanout_spec(cache=True)
    orch = Orchestrator({"gcp": 2, "ibm": 2})
    runs = PipelineRuns(orch)
    recs = runs.recurring(spec, every_s=100.0, runs=3)
    assert [r.run_id for r in recs] == ["fan-000", "fan-001", "fan-002"]
    assert [r.t0 for r in recs] == [0.0, 100.0, 200.0]
    assert recs[0].cache_hits == 0
    assert all(r.cache_hits == len(spec.steps) for r in recs[1:])
    assert all(v == 1 for v in calls.values())    # one real execution total
    assert orch.log.count("pipeline:recurring") == 3
    assert len(runs.history) == 3 and set(runs.summary()) == {
        "fan-000", "fan-001", "fan-002"}
    # a period shorter than the makespan catches up instead of overlapping
    orch2 = Orchestrator({"gcp": 1})
    recs2 = PipelineRuns(orch2).recurring(fanout_spec(cache=False)[0],
                                          every_s=1.0, runs=2)
    assert recs2[1].t0 >= recs2[0].finished_s


def test_cache_hits_wait_out_an_outage_on_the_resident_cloud():
    """A cached recurring run must still feel an injected outage: an
    artifact resident only on a dead cloud cannot be fetched until the
    cloud recovers."""
    spec, _ = fanout_spec(cache=True)
    orch = Orchestrator({"gcp": 1})
    first = orch.execute(spec)                    # residency: all on gcp
    rec = orch.execute(spec, t0=100.0,
                       failures=[FailureSpec("gcp", 100.0, 2.0)])
    assert rec.cache_hits == len(spec.steps)
    # nothing could be served before the recovery edge at t=102
    assert min(r.start_s for r in rec.steps.values()) >= 102.0
    assert first.makespan_s > 1.0                 # and run 1 was real work


def test_serial_cache_entry_reused_by_orchestrator(tmp_path):
    """Pipeline.run and the orchestrator share one store record shape: a
    step cached by the serial executor is a free hit for the orchestrator
    (no residency -> no cloud to bill a transfer against, by design)."""
    from repro.checkpoint.store import ArtifactStore

    def make():
        return [4, 2]

    store = ArtifactStore(str(tmp_path))
    serial = Pipeline("shared", store)
    serial.step(make)
    serial.run()
    authored = Pipeline("shared")
    authored.step(make)
    orch = Orchestrator({"gcp": 1}, cache=ArtifactCache(store))
    rec = orch.execute(authored.compile())
    r = rec.steps["make"]
    assert r.cached and r.cloud is None and r.transfer_cost_usd == 0.0
    assert rec.outputs["make"] == [4, 2]


def test_transfers_cannot_source_from_a_dead_cloud():
    """An input artifact resident only on a mid-outage cloud blocks its
    consumer (same rule as cache hits) instead of transferring bytes out
    of a dead cluster at full speed."""
    pipe = Pipeline("deadsrc")
    a = pipe.step(lambda: [1], name="produce", sim_s=0.1, pin="ibm",
                  cache=False)
    slow = pipe.step(lambda: 2, name="slow", sim_s=3.5, pin="gcp",
                     cache=False)
    pipe.step(lambda x, y: x, a, slow, name="consume", sim_s=0.1,
              pin="gcp", cache=False)
    orch = Orchestrator({"gcp": 1, "ibm": 1})
    # producer ends ~5.1 on ibm; consume becomes ready at ~6.5 (slow),
    # inside the ibm outage [6, 9): its only input source is dead
    rec = orch.execute(pipe.compile(), failures=[FailureSpec("ibm", 6.0, 3.0)])
    assert rec.status == "succeeded"
    assert rec.steps["consume"].start_s >= 9.0
    tr = orch.log.named("pipeline:transfer")
    assert len(tr) == 1 and tr[0]["src"] == "ibm" and tr[0]["t_sim"] >= 9.0


def test_cache_hit_from_retired_cluster_charges_its_rtt(tmp_path):
    """A store entry resident on a cloud outside this orchestrator's
    cluster map still charges that cloud's control-plane RTT on a hit
    (the same PROFILES fallback best_transfer uses)."""
    from repro.checkpoint.store import ArtifactStore

    def make():
        return [7]

    store = ArtifactStore(str(tmp_path))
    pipe = Pipeline("retired")
    pipe.step(make, sim_s=0.1)
    spec = pipe.compile()
    Orchestrator({"gcp": 1}, cache=ArtifactCache(store)).execute(spec)
    rec = Orchestrator({"ibm": 1}, cache=ArtifactCache(store)).execute(spec)
    r = rec.steps["make"]
    assert r.cached and r.cloud == "gcp"
    assert r.duration_s == pytest.approx(GCP.network_rtt_s)


def test_seeded_determinism_of_simulated_timeline():
    spec, _ = fanout_spec()
    fails = [FailureSpec("gcp", 3.5, 1.0)]

    def run():
        orch = Orchestrator({"gcp": 2, "ibm": 2},
                            retry=RetryPolicy(backoff_s=0.25))
        rec = orch.execute(spec, failures=fails)
        return rec.summary(), [e["name"] for e in orch.log.events]

    s1, e1 = run()
    s2, e2 = run()
    assert s1 == s2 and e1 == e2


def test_orchestrator_rejects_bad_configs():
    with pytest.raises(ValueError, match="policy"):
        Orchestrator({"gcp": 1}, policy="greedy")
    with pytest.raises(ValueError, match="worker"):
        Orchestrator({"gcp": 0})
    with pytest.raises(ValueError, match="at least one"):
        Orchestrator({})
    with pytest.raises(ValueError, match="max_retries"):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError, match="backoff_s"):
        RetryPolicy(backoff_s=0.0)


def test_artifact_cache_persists_through_store(tmp_path):
    from repro.checkpoint.store import ArtifactStore

    pipe = Pipeline("persist")
    a = pipe.step(lambda: [1, 2, 3], name="make", sim_s=0.1, pin="gcp")
    # cache=False: the consumer re-executes every run, so run 2 genuinely
    # re-consumes the artifact on ibm
    pipe.step(lambda x: list(x), a, name="use", sim_s=0.1, pin="ibm",
              cache=False)
    spec = pipe.compile()
    store = ArtifactStore(str(tmp_path))
    orch1 = Orchestrator({"gcp": 1, "ibm": 1}, cache=ArtifactCache(store))
    orch1.execute(spec)
    assert orch1.log.count("pipeline:transfer") == 1
    # a fresh process (fresh cache) reloads the JSON-able artifact AND its
    # committed residency: the gcp->ibm move paid above is not re-billed
    orch2 = Orchestrator({"gcp": 1, "ibm": 1}, cache=ArtifactCache(store))
    rec = orch2.execute(spec)
    assert rec.steps["make"].cached and not rec.steps["use"].cached
    assert rec.outputs["make"] == [1, 2, 3]
    assert orch2.log.count("pipeline:transfer") == 0
    assert rec.steps["use"].transfer_cost_usd == 0.0
