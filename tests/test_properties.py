"""Hypothesis property tests on system invariants.

Skips cleanly when hypothesis is absent (requirements-dev.txt pins it, so
the suite normally runs these)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev dep: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.checkpoint.store import tree_hash
from repro.kernels import ref
from repro.models import sharding as msh
from repro.models.attention import apply_rope
from repro.models.steps import softmax_xent
from repro.models.sharding import abstract_mesh

MESH = abstract_mesh((4, 2), ("data", "model"))


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(1, 97), min_size=1, max_size=4),
       st.integers(0, 3))
def test_fit_pspec_always_divisible(dims, which):
    """fit_pspec output must always be a legal argument sharding."""
    shape = tuple(dims)
    spec_entries = [None] * len(shape)
    spec_entries[min(which, len(shape) - 1)] = "model"
    fitted = msh.fit_pspec(shape, P(*spec_entries), MESH)
    for dim, entry in zip(shape, tuple(fitted) + (None,) * len(shape)):
        if entry is not None:
            assert dim % msh._axis_size(MESH, entry) == 0


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 64), st.integers(1, 1000))
def test_rope_preserves_norm(d2, pos):
    """RoPE is a rotation: vector norms are invariant."""
    d = d2 * 2
    x = jax.random.normal(jax.random.PRNGKey(d2), (1, 1, 1, d))
    pos_arr = jnp.full((1, 1), pos, jnp.int32)
    y = apply_rope(x, pos_arr, 10000.0)
    np.testing.assert_allclose(float(jnp.linalg.norm(y)),
                               float(jnp.linalg.norm(x)), rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 6), st.integers(3, 20), st.integers(5, 50))
def test_softmax_xent_matches_manual(b, s, v):
    key = jax.random.PRNGKey(b * 100 + s)
    logits = jax.random.normal(key, (b, s, v))
    labels = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, v)
    got = float(softmax_xent(logits, labels))
    p = jax.nn.log_softmax(logits, -1)
    want = float(-jnp.take_along_axis(p, labels[..., None], -1).mean())
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_softmax_xent_ignores_masked_labels():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 11))
    labels = jnp.full((2, 8), -1, jnp.int32)
    labels = labels.at[:, 0].set(3)
    loss = softmax_xent(logits, labels)
    only = softmax_xent(logits[:, :1], labels[:, :1])
    np.testing.assert_allclose(float(loss), float(only), rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(4, 24))
def test_attention_causality(s):
    """Output at position t must not depend on tokens after t."""
    ks = jax.random.split(jax.random.PRNGKey(s), 3)
    q = jax.random.normal(ks[0], (1, s, 2, 16))
    k = jax.random.normal(ks[1], (1, s, 2, 16))
    v = jax.random.normal(ks[2], (1, s, 2, 16))
    base = ref.flash_attention_ref(q, k, v, causal=True)
    t = s // 2
    k2 = k.at[:, t + 1:].set(999.0)
    v2 = v.at[:, t + 1:].set(-999.0)
    pert = ref.flash_attention_ref(q, k2, v2, causal=True)
    np.testing.assert_allclose(base[:, :t + 1], pert[:, :t + 1], atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 8), st.integers(1, 32))
def test_sliding_window_masks_old_tokens(w, extra):
    s = w + extra
    ks = jax.random.split(jax.random.PRNGKey(s * 7 + w), 3)
    q = jax.random.normal(ks[0], (1, s, 1, 8))
    k = jax.random.normal(ks[1], (1, s, 1, 8))
    v = jax.random.normal(ks[2], (1, s, 1, 8))
    base = ref.flash_attention_ref(q, k, v, causal=True, window=w)
    # corrupt tokens older than the window of the last position
    cutoff = s - w
    k2 = k.at[:, :cutoff].set(123.0)
    v2 = v.at[:, :cutoff].set(-123.0)
    pert = ref.flash_attention_ref(q, k2, v2, causal=True, window=w)
    np.testing.assert_allclose(base[:, -1], pert[:, -1], atol=1e-5)


def test_tree_hash_detects_changes_and_is_stable():
    t1 = {"a": np.arange(10.0), "b": {"c": np.ones((3, 3))}}
    t2 = {"a": np.arange(10.0), "b": {"c": np.ones((3, 3))}}
    assert tree_hash(t1) == tree_hash(t2)
    t2["b"]["c"][0, 0] = 2.0
    assert tree_hash(t1) != tree_hash(t2)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 64), st.integers(1, 16))
def test_decode_attention_respects_cache_len(s, valid):
    valid = min(valid, s)
    ks = jax.random.split(jax.random.PRNGKey(s + valid), 3)
    q = jax.random.normal(ks[0], (1, 2, 8))
    kc = jax.random.normal(ks[1], (1, s, 2, 8))
    vc = jax.random.normal(ks[2], (1, s, 2, 8))
    lens = jnp.array([valid], jnp.int32)
    base = ref.decode_attention_ref(q, kc, vc, lens)
    kc2 = kc.at[:, valid:].set(555.0)
    vc2 = vc.at[:, valid:].set(-555.0)
    pert = ref.decode_attention_ref(q, kc2, vc2, lens)
    np.testing.assert_allclose(base, pert, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.floats(0.0, 1.0), st.floats(0.0, 1.0), st.integers(0, 2))
def test_katib_space_roundtrip(u1, u2, cat):
    """_from_unit/_to_unit are inverses over the search space."""
    from repro.tuning import katib
    space = {"lr": katib.Double(1e-5, 1e-1, log=True),
             "w": katib.Double(-2.0, 3.0),
             "act": katib.Categorical(("a", "b", "c"))}
    params = katib._from_unit(space, np.array([u1, u2, cat / 2.0]))
    back = katib._to_unit(space, params)
    again = katib._from_unit(space, back)
    assert abs(again["lr"] - params["lr"]) / params["lr"] < 1e-6
    assert abs(again["w"] - params["w"]) < 1e-6
    assert again["act"] == params["act"]


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4096), st.floats(0.5, 4.0))
def test_moe_capacity_monotone_and_sufficient(tokens, cf):
    """Capacity covers at least top_k slots and grows with tokens/cf."""
    from repro.configs import registry
    from repro.models import moe
    cfg = registry.get_config("granite_moe_3b_a800m").replace(capacity_factor=cf)
    c = moe.capacity(cfg, tokens)
    assert c >= cfg.top_k
    assert c >= int(cf * tokens * cfg.top_k / cfg.n_experts)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 128), st.integers(1, 8))
def test_sinusoid_positions_distinct(d2, stride):
    """Distinct positions produce distinct positional encodings."""
    from repro.models.lm import sinusoid
    d = d2 * 2
    pos = jnp.asarray([[0, stride]], jnp.int32)
    enc = sinusoid(pos, d)
    assert float(jnp.abs(enc[0, 0] - enc[0, 1]).max()) > 1e-6
