"""Roofline extraction units: HLO collective parsing, fusion-modeled bytes,
term math, analytic corrections."""
import numpy as np

from repro.clouds.profiles import TPU_V5E
from repro.configs import registry
from repro.launch import roofline

HLO = """\
HloModule test

%fused_computation (param_0: f32[128,128]) -> f32[128,128] {
  %param_0 = f32[128,128]{1,0} parameter(0)
  ROOT %exp.1 = f32[128,128]{1,0} exponential(%param_0)
}

ENTRY %main (p0: f32[128,128], p1: bf16[64]) -> f32[128,128] {
  %p0 = f32[128,128]{1,0} parameter(0)
  %p1 = bf16[64]{0} parameter(1)
  %ar = f32[128,128]{1,0} all-reduce(%p0), replica_groups={}
  %ag = bf16[256]{0} all-gather(%p1), dimensions={0}
  %dot.1 = f32[128,128]{1,0} dot(%ar, %ar), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %exp = f32[128,128]{1,0} exponential(%dot.1)
  %fus = f32[128,128]{1,0} fusion(%exp), kind=kLoop, calls=%fused_computation
  ROOT %cp = f32[128,128]{1,0} collective-permute(%fus), source_target_pairs={{0,1}}
}
"""


def test_collective_bytes_parsing():
    out = roofline.collective_bytes(HLO)
    assert out["per_kind_counts"]["all-reduce"] == 1
    assert out["per_kind_counts"]["all-gather"] == 1
    assert out["per_kind_counts"]["collective-permute"] == 1
    assert out["per_kind_bytes"]["all-reduce"] == 128 * 128 * 4
    assert out["per_kind_bytes"]["all-gather"] == 256 * 2
    assert out["total_bytes"] == 128 * 128 * 4 * 2 + 512


def test_fusion_modeled_bytes_skips_elementwise_and_fusion_bodies():
    got = roofline.fusion_modeled_bytes(HLO)
    want = (128 * 128 * 4       # entry param p0
            + 64 * 2            # entry param p1
            + 128 * 128 * 4     # all-reduce
            + 256 * 2           # all-gather
            + 128 * 128 * 4     # dot
            + 128 * 128 * 4     # fusion output (single write)
            + 128 * 128 * 4)    # collective-permute
    # exponential (elementwise) and the fusion-body param are excluded
    assert got == want


def test_shape_bytes_tuple_and_dtypes():
    assert roofline._shape_bytes("(f32[2,3]{1,0}, bf16[4]{0})") == 24 + 8
    assert roofline._shape_bytes("pred[10]") == 10
    assert roofline._shape_bytes("s32[]") == 0 or roofline._shape_bytes("s32[]") == 4


def test_roofline_terms_and_dominance():
    t = roofline.roofline(flops=197e12, bytes_accessed=819e9 * 2,
                          coll_bytes=50e9 * 3, chips=256, hw=TPU_V5E)
    np.testing.assert_allclose(t.compute_s, 1.0)
    np.testing.assert_allclose(t.memory_s, 2.0)
    np.testing.assert_allclose(t.collective_s, 3.0)
    assert t.dominant == "collective"
    assert t.total_s == 3.0


def test_model_flops_train_vs_decode():
    cfg = registry.get_config("granite_3_8b")
    train = roofline.model_flops(cfg, "train", 256, 4096)
    dec = roofline.model_flops(cfg, "decode", 128, 32768)
    n = cfg.approx_active_params()
    np.testing.assert_allclose(train, 6 * n * 256 * 4096)
    np.testing.assert_allclose(dec, 2 * n * 128)   # one token per sequence


ASYNC_HLO = """\
HloModule async_test

ENTRY %main (p0: f32[128], p1: bf16[64,8]) -> f32[128] {
  %p0 = f32[128]{0} parameter(0)
  %p1 = bf16[64,8]{1,0} parameter(1)
  %ars = f32[128]{0} all-reduce-start(%p0), replica_groups={}
  %ard = f32[128]{0} all-reduce-done(%ars)
  %rs = bf16[8,8]{1,0} reduce-scatter(%p1), dimensions={0}
  %ags = (bf16[64,8]{1,0}, bf16[512,8]{1,0}) all-gather-start(%p1), dimensions={0}
  ROOT %agd = bf16[512,8]{1,0} all-gather-done(%ags)
}
"""


def test_collective_bytes_async_start_done_counted_once():
    """The async pair is ONE collective: the -start line carries the
    transfer, the -done line is a wait and must not double-count."""
    out = roofline.collective_bytes(ASYNC_HLO)
    assert out["per_kind_counts"]["all-reduce"] == 1
    assert out["per_kind_counts"]["all-gather"] == 1
    assert out["per_kind_bytes"]["all-reduce"] == 128 * 4


def test_collective_bytes_mixed_dtypes():
    """bf16 and f32 collectives in one module size by their own dtype
    widths (2 vs 4 bytes), not a shared element size."""
    out = roofline.collective_bytes(ASYNC_HLO)
    assert out["per_kind_bytes"]["reduce-scatter"] == 8 * 8 * 2
    assert out["per_kind_bytes"]["all-reduce"] == 128 * 4


def test_collective_bytes_tuple_outputs_sum_components():
    """An async -start materializes a tuple (operand alias + destination
    buffer): the parser sums every component of the tuple shape."""
    out = roofline.collective_bytes(ASYNC_HLO)
    assert out["per_kind_bytes"]["all-gather"] \
        == (64 * 8 + 512 * 8) * 2
    assert out["total_bytes"] == (128 * 4 + 8 * 8 * 2
                                  + (64 * 8 + 512 * 8) * 2)


def test_corrections_zero_when_inapplicable():
    dense = registry.get_config("granite_3_8b")
    assert roofline.slstm_correction_flops(dense, "train", 8, 128) == 0.0
    assert roofline.chunk_scan_correction_flops(dense, "train", 8, 128) == 0.0
    xl = registry.get_config("xlstm_1_3b")
    assert roofline.slstm_correction_flops(xl, "train", 8, 4096) > 0
    assert roofline.chunk_scan_correction_flops(xl, "train", 8, 4096) > 0
    assert roofline.chunk_scan_correction_flops(xl, "decode", 8, 4096) == 0.0
