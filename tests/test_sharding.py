"""Sharding rules + a real multi-device lowering smoke test (8 fake CPU
devices in a subprocess so the main test process keeps 1 device)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.models import lm, sharding as msh, steps
from repro.models.sharding import abstract_mesh

MESH = abstract_mesh((4, 2), ("data", "model"))
MESH3 = abstract_mesh((2, 2, 2), ("pod", "data", "model"))


def test_param_rules_cover_every_leaf():
    """Every param leaf of every arch resolves to a legal PartitionSpec."""
    for arch in registry.list_archs():
        cfg = registry.get_smoke_config(arch)
        spec = steps.params_spec(cfg)
        pspecs = jax.tree_util.tree_map_with_path(
            lambda path, leaf: msh.fit_pspec(
                tuple(leaf.shape),
                msh._resolve(msh.leaf_spec(path, leaf), MESH), MESH),
            spec)
        for leaf, ps in zip(jax.tree_util.tree_leaves(spec),
                            jax.tree_util.tree_leaves(pspecs, is_leaf=lambda x: isinstance(x, P))):
            for dim, entry in zip(leaf.shape, tuple(ps)):
                if entry is not None:
                    assert dim % msh._axis_size(MESH, entry) == 0, (arch, leaf.shape, ps)


def test_fit_pspec_relocates_to_divisible_dim():
    # 24 heads don't divide 16-way model axis; relocate to d_model dim
    mesh = abstract_mesh((16, 16), ("data", "model"))
    fitted = msh.fit_pspec((1536, 24, 64), P(None, "model", None), mesh)
    assert tuple(fitted) in ((("model",), None, None), ("model", None, None))


def test_fit_pspec_drops_when_nothing_fits():
    mesh = abstract_mesh((16, 16), ("data", "model"))
    fitted = msh.fit_pspec((7, 5), P("model", None), mesh)
    assert all(e is None for e in tuple(fitted) + (None,))


def test_logical_batch_axis_spans_pod_and_data():
    resolved = msh._resolve(("batch", None), MESH3)
    assert tuple(resolved)[0] == ("pod", "data")


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = msh.constrain(x, "batch", "model")
    assert (x == y).all()


def test_multidevice_lowering_subprocess():
    """End-to-end: 8 fake devices, (2,4) mesh, smoke arch train_step lowers,
    compiles, and cost analysis is extractable."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, functools
from repro.configs import registry
from repro.launch import shardings
from repro.models import sharding as msh, steps
from repro.launch.roofline import collective_bytes, cost_dict, roofline

cfg = registry.get_smoke_config("granite_3_8b").replace(dtype="bfloat16")
mesh = jax.make_mesh((2, 4), ("data", "model"))
param_spec = steps.params_spec(cfg)
param_sh = msh.param_shardings(param_spec, mesh)
opt_spec = steps.opt_state_spec(param_spec)
opt_sh = shardings.opt_shardings(opt_spec, param_spec, mesh)
bspec = steps.batch_spec(cfg, 8, 32, train=True)
batch_sh = shardings.batch_shardings(bspec, mesh)
with msh.use_mesh(mesh):
    fn = functools.partial(steps.train_step, cfg=cfg)
    lowered = jax.jit(fn, in_shardings=(param_sh, opt_sh, batch_sh),
                      out_shardings=(param_sh, opt_sh, None)).lower(
        param_spec, opt_spec, bspec)
    compiled = lowered.compile()
cost = cost_dict(compiled)
assert cost.get("flops", 0) > 0, cost
coll = collective_bytes(compiled.as_text())
assert coll["total_bytes"] > 0, coll   # data-parallel grad all-reduce must exist
terms = roofline(cost["flops"], cost.get("bytes accessed", 0.0),
                 coll["total_bytes"], 8)
assert terms.dominant in ("compute", "memory", "collective")
print("SUBPROCESS_OK", coll["per_kind_counts"])
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={**__import__("os").environ, "PYTHONPATH": "src"},
                       cwd=__import__("os").path.dirname(__import__("os").path.dirname(__file__)) or ".")
    assert "SUBPROCESS_OK" in r.stdout, r.stdout + r.stderr


def test_dp_profile_lowering_subprocess():
    """dp+zero1 profile (§Perf B1): params replicate, batch spans all axes,
    collectives shrink to gradient reductions."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, functools
from repro.configs import registry
from repro.launch import shardings
from repro.models import sharding as msh, steps
from repro.launch.roofline import collective_bytes

cfg = registry.get_smoke_config("xlstm_1_3b").replace(
    dtype="bfloat16", sharding_profile="dp", zero1=True)
mesh = jax.make_mesh((2, 4), ("data", "model"))
with msh.use_profile("dp"), msh.use_mesh(mesh):
    param_spec = steps.params_spec(cfg)
    param_sh = msh.param_shardings(param_spec, mesh)
    # dp: every param replicated
    assert all(s.spec == jax.sharding.PartitionSpec()
               or all(e is None for e in s.spec)
               for s in jax.tree_util.tree_leaves(param_sh)), "params not replicated"
    opt_spec = steps.opt_state_spec(param_spec)
    opt_sh = shardings.opt_shardings(opt_spec, param_spec, mesh, zero1=True)
    # zero1: at least one moment leaf sharded over data
    specs = [s.spec for s in jax.tree_util.tree_leaves(opt_sh["mu"])]
    assert any("data" in [a for e in sp if e for a in (e if isinstance(e, tuple) else (e,))]
               for sp in specs), "zero1 did not shard moments"
    bspec = steps.batch_spec(cfg, 8, 32, train=True)
    batch_sh = shardings.batch_shardings(bspec, mesh)
    # batch spans both axes in dp
    tok_spec = batch_sh["tokens"].spec
    assert tok_spec[0] == ("data", "model"), tok_spec
    fn = functools.partial(steps.train_step, cfg=cfg)
    compiled = jax.jit(fn, in_shardings=(param_sh, opt_sh, batch_sh),
                       out_shardings=(param_sh, opt_sh, None)).lower(
        param_spec, opt_spec, bspec).compile()
    coll = collective_bytes(compiled.as_text())
    assert coll["total_bytes"] > 0
print("DP_SUBPROCESS_OK")
"""
    import os
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(__file__)) or ".")
    assert "DP_SUBPROCESS_OK" in r.stdout, r.stdout + r.stderr
