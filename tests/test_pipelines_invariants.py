"""Property-based invariants over random pipeline DAGs, cluster shapes,
placement policies and outage windows (ISSUE 5 orchestrator suite,
mirroring tests/test_gateway_invariants.py conventions).

Invariants, checked over randomly drawn scenarios:

  1. exactly-once completion: every step ends in exactly one of done /
     failed / skipped; a done step has exactly ONE successful attempt and
     its fn ran exactly once; a failed step exhausted its RetryPolicy
     (attempts == max_retries + 1, all killed by outages); a skipped step
     has a failed ancestor; events reconcile (pipeline:step == done,
     pipeline:fail == failed, pipeline:skip == skipped, and every failed
     attempt logged either pipeline:retry or pipeline:fail);
  2. work conservation: with no outage windows every step completes and
     the parallel makespan never exceeds the serial sum of per-step
     simulated durations (the greedy scheduler never idles a worker while
     a step is ready);
  3. cache hits never re-execute: a second run on the same orchestrator
     reuses every cacheable artifact from a clean first run -- fn call
     counters do not move, records say cached;
  4. cost totals match per-step charges: run cost == sum of step costs ==
     sum over attempts of worker-seconds x the cloud's price sheet plus
     the egress dollars of every transfer (failed attempts billed too);
  5. the simulated timeline is deterministic: a rebuilt orchestrator
     replays the identical records and event-name sequence (steps carry
     analytic sim_s durations, so nothing depends on host wall clock).

The scenario space is described once (``scenario``) and driven via
hypothesis when installed (requirements-dev.txt; CI pins
--hypothesis-seed and the "ci" profile from conftest.py) and via a seeded
numpy fallback that always runs.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.pipeline import Pipeline
from repro.pipelines import Orchestrator, RetryPolicy
from repro.serving.gateway import FailureSpec

try:
    from hypothesis import given, strategies as hyp_st
    HAS_HYPOTHESIS = True
except ImportError:              # degrade to the seeded fallback only
    HAS_HYPOTHESIS = False

CLOUDS = ("gcp", "ibm")


# -- scenario space ----------------------------------------------------------

def scenario(pick_int, pick_choice, pick_float):
    """One random-but-valid DAG + cluster + failure description as plain
    data, parameterized over the drawing primitives so hypothesis and the
    numpy fallback explore the same space."""
    n = pick_int(2, 7)
    steps = []
    for i in range(n):
        n_deps = pick_int(0, min(i, 2))
        deps = sorted({pick_int(0, i - 1) for _ in range(n_deps)}) \
            if n_deps else []
        steps.append({"deps": deps,
                      "sim_ms": pick_float(1.0, 50.0),
                      "cache": pick_choice((True, False)),
                      "kb": pick_int(1, 64),      # artifact payload size
                      "pin": pick_choice((None, None, "gcp", "ibm"))})
    clusters = {"gcp": pick_int(1, 3)}
    if pick_choice((True, False)):
        clusters["ibm"] = pick_int(1, 3)
    for s in steps:                  # pins must name a cluster
        if s["pin"] is not None and s["pin"] not in clusters:
            s["pin"] = None
    failures = []
    for _ in range(pick_int(0, 2)):
        failures.append({"cloud": pick_choice(tuple(clusters)),
                         "at": pick_float(0.0, 12.0),
                         "dur": pick_float(0.3, 4.0)})
    return {"steps": steps, "clusters": clusters,
            "policy": pick_choice(("cost", "makespan")),
            "retries": pick_int(0, 2),
            "backoff": pick_float(0.1, 1.0),
            "failures": failures,
            "seed": pick_int(0, 2 ** 16)}


def build(p):
    calls: dict = {}

    def make(tag, *deps, _calls=calls, _steps=p["steps"]):
        _calls[tag] = _calls.get(tag, 0) + 1
        return np.full(_steps[tag]["kb"] * 128, float(tag))

    pipe = Pipeline("rand")
    refs = []
    for i, s in enumerate(p["steps"]):
        refs.append(pipe.step(make, i, *[refs[d] for d in s["deps"]],
                              name=f"s{i}", cache=s["cache"],
                              sim_s=s["sim_ms"] / 1e3, pin=s["pin"]))
    orch = Orchestrator(dict(p["clusters"]), policy=p["policy"],
                        retry=RetryPolicy(max_retries=p["retries"],
                                          backoff_s=p["backoff"]))
    failures = [FailureSpec(f["cloud"], f["at"], f["dur"])
                for f in p["failures"]]
    return pipe.compile(), orch, failures, calls


# -- the invariants ----------------------------------------------------------

def run_and_check(p):
    spec, orch, failures, calls = build(p)
    rec = orch.execute(spec, failures=failures)
    n = len(spec.steps)
    by_status: dict = {}
    for name, r in rec.steps.items():
        by_status.setdefault(r.status, []).append(name)

    # 1. exactly-once completion, statuses partition the DAG
    assert sum(len(v) for v in by_status.values()) == n
    assert set(by_status) <= {"done", "failed", "skipped"}
    for i, s in enumerate(spec.steps):
        r = rec.steps[s.name]
        if r.status == "done":
            ok = [a for a in r.attempts if a["status"] == "ok"]
            if r.cached:
                assert not r.attempts
            else:
                assert len(ok) == 1 and r.attempts[-1] is ok[0]
                assert calls.get(i, 0) == 1      # real work ran exactly once
            assert s.name in rec.outputs
        elif r.status == "failed":
            assert len(r.attempts) == p["retries"] + 1
            assert all(a["status"] == "outage" for a in r.attempts)
            assert s.name not in rec.outputs
        else:                                    # skipped: a bad ancestor
            assert not r.attempts
            frontier, bad = set(s.deps), False
            while frontier:
                d = frontier.pop()
                dr = rec.steps[spec.steps[d].name]
                if dr.status in ("failed", "skipped"):
                    bad = True
                    break
                frontier |= set(spec.steps[d].deps)
            assert bad, f"{s.name} skipped without a failed ancestor"
    assert rec.status == ("succeeded" if by_status.get("done", []) and
                          len(by_status["done"]) == n else "failed")

    # events reconcile with the records
    assert orch.log.count("pipeline:step") == len(by_status.get("done", []))
    assert orch.log.count("pipeline:fail") == len(by_status.get("failed", []))
    assert orch.log.count("pipeline:skip") == len(by_status.get("skipped", []))
    failed_attempts = sum(
        1 for r in rec.steps.values() for a in r.attempts
        if a["status"] == "outage")
    assert (orch.log.count("pipeline:retry")
            + orch.log.count("pipeline:fail") == failed_attempts)
    assert orch.log.count("pipeline:cache_hit") == rec.cache_hits

    # 2. work conservation (no failures => all done, makespan <= serial sum)
    if not p["failures"]:
        assert by_status.get("done", []) and len(by_status["done"]) == n
        serial = sum(r.duration_s for r in rec.steps.values())
        assert rec.makespan_s <= serial + 1e-9

    # 4. cost totals match per-step charges
    price = {c: orch.pools[c].profile.cost_per_s for c in orch.pools}
    total = 0.0
    for r in rec.steps.values():
        charge = sum((a["end_s"] - a["start_s"]) * price[a["cloud"]]
                     for a in r.attempts) + r.transfer_cost_usd
        assert r.cost_usd == pytest.approx(charge, abs=1e-12)
        assert r.cost_usd == pytest.approx(
            sum(a["cost_usd"] for a in r.attempts), abs=1e-12)
        total += r.cost_usd
    assert rec.cost_usd == pytest.approx(total, abs=1e-12)
    return rec


def run_twice_and_compare(p):
    """Invariant 5: rebuilt orchestrator => identical simulated timeline."""
    spec1, orch1, f1, _ = build(p)
    rec1 = orch1.execute(spec1, failures=f1)
    spec2, orch2, f2, _ = build(p)
    rec2 = orch2.execute(spec2, failures=f2)
    assert rec1.summary() == rec2.summary()
    assert ([dataclasses.asdict(r) for r in rec1.steps.values()]
            == [dataclasses.asdict(r) for r in rec2.steps.values()])
    assert ([e["name"] for e in orch1.log.events]
            == [e["name"] for e in orch2.log.events])


def run_cached_second_pass(p):
    """Invariant 3: on a clean (failure-free) first run, a second run on
    the same orchestrator never re-executes a cacheable step."""
    p = dict(p, failures=[])
    spec, orch, _, calls = build(p)
    orch.execute(spec)
    before = dict(calls)
    rec2 = orch.execute(spec)
    for i, s in enumerate(spec.steps):
        r = rec2.steps[s.name]
        assert r.status == "done"
        if s.cache:
            assert r.cached and calls[i] == before[i]
        else:
            assert not r.cached and calls[i] == before[i] + 1
    assert rec2.cache_hits == sum(1 for s in spec.steps if s.cache)


# -- hypothesis driver (requirements-dev.txt) --------------------------------

if HAS_HYPOTHESIS:
    @hyp_st.composite
    def scenarios(draw):
        return scenario(
            lambda lo, hi: draw(hyp_st.integers(lo, hi)),
            lambda seq: draw(hyp_st.sampled_from(list(seq))),
            lambda lo, hi: draw(hyp_st.floats(lo, hi, allow_nan=False,
                                              allow_infinity=False)))

    @given(scenarios())
    def test_orchestrator_invariants(params):
        run_and_check(params)

    @given(scenarios())
    def test_orchestrator_deterministic(params):
        run_twice_and_compare(params)

    @given(scenarios())
    def test_orchestrator_cache_never_reexecutes(params):
        run_cached_second_pass(params)
else:                            # visible skips instead of silent absence
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(pip install -r requirements-dev.txt)")
    def test_orchestrator_invariants():
        pass

    @pytest.mark.skip(reason="hypothesis not installed "
                             "(pip install -r requirements-dev.txt)")
    def test_orchestrator_deterministic():
        pass

    @pytest.mark.skip(reason="hypothesis not installed "
                             "(pip install -r requirements-dev.txt)")
    def test_orchestrator_cache_never_reexecutes():
        pass


# -- seeded numpy fallback (always runs) -------------------------------------

def params_from_seed(seed):
    rng = np.random.default_rng(seed)
    return scenario(lambda lo, hi: int(rng.integers(lo, hi + 1)),
                    lambda seq: seq[int(rng.integers(len(seq)))],
                    lambda lo, hi: float(rng.uniform(lo, hi)))


@pytest.mark.parametrize("seed", range(30))
def test_orchestrator_invariants_seeded(seed):
    run_and_check(params_from_seed(seed))


@pytest.mark.parametrize("seed", range(8))
def test_orchestrator_deterministic_seeded(seed):
    run_twice_and_compare(params_from_seed(seed + 500))


@pytest.mark.parametrize("seed", range(8))
def test_orchestrator_cache_seeded(seed):
    run_cached_second_pass(params_from_seed(seed + 900))
