"""Model-CI profiling plane units (ISSUE 10): the ModelProfile artifact
schema, the ProfileStore over the shared ArtifactCache, the orchestrator's
``kind="profile"`` commit path, the DeploySpec.profile-planned placement,
and the DriftMonitor's profile-vs-observed controller loop.

The end-to-end acceptance -- profile-planned p99 racing a hand-tuned
plan, an injected service-time shift firing ``profile:drift`` strictly
before the ``reason=profile_drift`` migrate -- lives in
``benchmarks/bench_gateway.py`` (the drift tier); this file pins the
component contracts.
"""
import pytest

from repro.clouds.profiles import get_profile
from repro.core.pipeline import Pipeline
from repro.modelci import (ModelProfile, ProfiledBackend, ProfileSpec,
                           ProfileStore, finalize, measure, roofline_fields)
from repro.pipelines import (ArtifactCache, DeploySpec, Orchestrator,
                             PipelineRuns)
from repro.serving.gateway import AutoscalerConfig, CloudCapacity, Gateway
from repro.telemetry.drift import DriftConfig, DriftMonitor
from repro.telemetry.events import EventLog
from repro.telemetry.metrics import MetricsRegistry


class FakeBackend:
    """Linear cost model: service_time(b) = b * per_request."""

    def __init__(self, name="m", per_request=0.01):
        self.name = name
        self.per_request = per_request

    def service_time(self, b: int) -> float:
        return b * self.per_request


class FakeDisaggBackend(FakeBackend):
    def prefill_time(self) -> float:
        return 0.006

    def decode_time(self) -> float:
        return 0.004


# -- ModelProfile -------------------------------------------------------------

def test_profile_validation_and_effective_service():
    with pytest.raises(ValueError):
        ModelProfile("m", "gcp", 0.0)
    with pytest.raises(ValueError):
        ModelProfile("m", "gcp", float("inf"))
    with pytest.raises(ValueError):                  # one-sided split
        ModelProfile("m", "gcp", 0.01, prefill_s=0.006)
    p = ModelProfile("m", "gcp", 0.01)
    assert p.effective_service_s == 0.01
    d = ModelProfile("m", "gcp", 0.01, prefill_s=0.006, decode_s=0.005)
    assert d.effective_service_s == pytest.approx(0.011)


def test_profile_key_is_content_hash():
    a = ModelProfile("m", "gcp", 0.01, max_batch=8)
    b = ModelProfile("m", "gcp", 0.01, max_batch=8)
    assert a.key == b.key                            # identical -> dedupe
    assert a.key.startswith("profile_")
    c = ModelProfile("m", "gcp", 0.0100001, max_batch=8)
    assert c.key != a.key                            # any change re-keys
    assert ModelProfile("m", "ibm", 0.01, max_batch=8).key != a.key


def test_profile_dict_round_trip():
    p = ModelProfile("m", "aws", 0.02, max_batch=4, prefill_s=0.015,
                     decode_s=0.005, memory_bytes=123, load_s=9.0,
                     roofline={"compute_s": 1.0}, source="measured")
    assert ModelProfile.from_dict(p.to_dict()) == p
    assert ModelProfile.from_dict(p.to_dict()).key == p.key


def test_profile_demand_bridge():
    p = ModelProfile("m", "gcp", 0.01, prefill_s=0.015, decode_s=0.005)
    with pytest.raises(ValueError):
        p.demand()                                   # exactly one of
    with pytest.raises(ValueError):
        p.demand(rate=1.0, load_erlangs=1.0)
    dem = p.demand(load_erlangs=2.0)
    assert dem.name == "m" and dem.service_time_s == 0.01
    assert dem.rate == pytest.approx(2.0 / 0.02)     # effective (pf+dc)
    assert dem.prefill_s == 0.015 and dem.decode_s == 0.005
    assert p.demand(rate=7.0).rate == 7.0


# -- measurement --------------------------------------------------------------

def test_measure_blended_and_disagg_fields():
    fields = measure(FakeBackend(per_request=0.01), max_batch=8)
    assert fields["service_time_s"] == pytest.approx(0.01)
    assert fields["max_batch"] == 8 and fields["source"] == "measured"
    assert "prefill_s" not in fields                 # no two-point model
    d = measure(FakeDisaggBackend(), max_batch=8,
                weights={"w": [1.0, 2.0]})
    assert d["prefill_s"] == 0.006 and d["decode_s"] == 0.004
    assert d["memory_bytes"] > 0


def test_roofline_fields_closed_form():
    from repro.configs.registry import get_config
    cfg = get_config("gemma3_4b")
    fields = roofline_fields(cfg)
    assert fields["source"] == "roofline"
    assert fields["service_time_s"] > 0
    assert fields["memory_bytes"] == 2 * cfg.approx_active_params()
    assert fields["roofline"]["memory_s"] > 0        # decode: bandwidth-bound


def test_finalize_stamps_cloud_constants():
    gcp = get_profile("gcp")
    mp = finalize(measure(FakeBackend(), max_batch=4), "m", gcp)
    assert mp.cloud == "gcp" and mp.load_s == gcp.model_load_s
    assert mp.source == "measured"


# -- ProfileStore -------------------------------------------------------------

def test_store_put_get_latest_and_dedupe():
    store = ProfileStore()
    a = ModelProfile("m", "gcp", 0.01)
    e1 = store.put(a)
    e2 = store.put(ModelProfile("m", "gcp", 0.01))   # identical: dedupe
    assert e1 is e2
    assert store.get("m", "gcp") == a
    newer = ModelProfile("m", "gcp", 0.02)
    store.put(newer)
    assert store.get("m", "gcp") == newer            # latest supersedes
    assert store.cache.get(a.key) is not None        # history survives
    assert store.get("m", "aws") is None
    store.put(ModelProfile("m", "ibm", 0.03))
    store.put(ModelProfile("other", "gcp", 0.5))
    assert store.clouds("m") == ["gcp", "ibm"]
    assert store.models() == ["m", "other"]


def test_store_worst_and_demand():
    store = ProfileStore()
    store.put(ModelProfile("m", "gcp", 0.01))
    store.put(ModelProfile("m", "ibm", 0.03))
    assert store.worst("m").cloud == "ibm"           # conservative pick
    assert store.worst("m", ["gcp"]).cloud == "gcp"  # restricted to plan
    dem = store.demand("m", load_erlangs=3.0)
    assert dem.service_time_s == 0.03
    with pytest.raises(KeyError):
        store.worst("m", ["aws"])                    # no artifact there
    with pytest.raises(KeyError):
        store.worst("ghost")


def test_store_pull_prices_residency_move():
    store = ProfileStore()
    p = ModelProfile("m", "gcp", 0.01, memory_bytes=10**9)
    store.put(p)
    entry, t_s, usd = store.pull("m", "gcp", get_profile("gcp"))
    assert t_s == 0.0 and usd == 0.0                 # already resident
    entry, t_s, usd = store.pull("m", "gcp", get_profile("ibm"))
    assert t_s > 0 and usd >= 0                      # priced by best_transfer
    assert "ibm" in entry.clouds                     # residency committed
    _, t2, u2 = store.pull("m", "gcp", get_profile("ibm"))
    assert t2 == 0.0 and u2 == 0.0                   # second pull is local
    with pytest.raises(KeyError):
        store.pull("m", "aws", get_profile("gcp"))


# -- ProfiledBackend ----------------------------------------------------------

def test_profiled_backend_cost_model_is_the_artifact():
    p = ModelProfile("m", "gcp", 0.01, max_batch=8)
    be = ProfiledBackend(p)
    assert be.name == "m"
    assert be.service_time(4) == pytest.approx(0.04)
    assert be.service_time(0) == pytest.approx(0.01)  # floor at one request
    assert not hasattr(be, "prefill_time")            # no split, no attrs
    split = ProfiledBackend(ModelProfile("m", "gcp", 0.01,
                                         prefill_s=0.006, decode_s=0.004))
    assert split.prefill_time() == 0.006 and split.decode_time() == 0.004


# -- orchestrator profile steps ----------------------------------------------

def _profile_pipeline(store, backend, clouds=("gcp", "ibm")):
    pipe = Pipeline("ci")
    for c in clouds:
        pipe.step(lambda: measure(backend, max_batch=8),
                  name=f"profile_{c}", kind="profile", pin=c,
                  payload=ProfileSpec("m", store, max_batch=8))
    return pipe


def test_profile_step_commits_per_cloud_artifacts():
    store, log = ProfileStore(), EventLog()
    orch = Orchestrator({"gcp": 1, "ibm": 1}, log=log)
    rec = orch.execute(_profile_pipeline(store, FakeBackend()).compile())
    assert rec.status == "succeeded"
    assert store.clouds("m") == ["gcp", "ibm"]
    # the cloud constant differentiates the artifacts per cloud
    assert store.get("m", "gcp").load_s == get_profile("gcp").model_load_s
    evs = log.named("modelci:profile")
    assert [e["cloud"] for e in evs] == ["gcp", "ibm"]
    assert all(e["key"].startswith("profile_") for e in evs)


def test_profile_step_requires_spec_payload():
    pipe = Pipeline("ci")
    pipe.step(lambda: {}, name="p", kind="profile")
    with pytest.raises(ValueError, match="ProfileSpec"):
        Orchestrator({"gcp": 1}).execute(pipe.compile())
    bad = Pipeline("ci2")
    with pytest.raises(ValueError):
        ProfileSpec("", ProfileStore())              # model must be named
    with pytest.raises(ValueError):
        ProfileSpec("m", store=object())             # store must store
    bad.step(lambda: {}, name="p", kind="profile", payload=object())
    with pytest.raises(ValueError, match="ProfileSpec"):
        Orchestrator({"gcp": 1}).execute(bad.compile())


def test_cached_recurring_profile_still_refreshes_store():
    """The second recurring firing hits the step cache, but the commit
    hook must still run: a fresh store (new process, same ArtifactStore)
    learns the latest pointers from cached completions."""
    cache = ArtifactCache()
    store = ProfileStore(cache)
    log = EventLog()
    orch = Orchestrator({"gcp": 1, "ibm": 1}, cache=cache, log=log)
    spec = _profile_pipeline(store, FakeBackend()).compile()
    recs = PipelineRuns(orch).recurring(spec, every_s=60.0, runs=2)
    assert recs[1].cache_hits == 2                   # measurements cached
    assert log.count("modelci:profile") == 4         # committed every firing
    assert store.clouds("m") == ["gcp", "ibm"]


# -- DeploySpec.profile placement ---------------------------------------------

def _deploy_spec(store):
    return DeploySpec(
        "m",
        clouds=[CloudCapacity(get_profile("gcp"), 2, 1.0),
                CloudCapacity(get_profile("ibm"), 2, 1.4)],
        load_erlangs=2.0, objective="p99", split=True,
        autoscaler=AutoscalerConfig(min_replicas=3, max_replicas=4,
                                    target_queue=8),
        max_batch=8, profile=store)


def test_profile_planned_deploy_uses_store_demand():
    store, log = ProfileStore(), EventLog()
    backend = FakeBackend(per_request=0.01)
    pipe = _profile_pipeline(store, backend)
    pipe.step(lambda: backend, name="deploy", kind="deploy",
              payload=_deploy_spec(store))
    gw = Gateway(log=log)
    rec = Orchestrator({"gcp": 1, "ibm": 1}, log=log).execute(
        pipe.compile(), gateway=gw)
    assert rec.status == "succeeded"
    out = rec.outputs["deploy"]
    assert out["profiled"] is True
    assert len(out["replicas"]) == 2                 # genuinely split
    assert "m" in gw.deployments
    # the gateway's drift monitor knows the planned-from artifact only
    # when drift detection is configured; bare gateways just deploy
    assert gw.drift is None


def test_profile_planned_deploy_infeasible_without_artifacts():
    """No committed profiles for the model on the candidate clouds is an
    infeasible deploy, not a silent fall back to hand-measured numbers."""
    store, log = ProfileStore(), EventLog()
    store.put(ModelProfile("other", "gcp", 0.01))    # wrong model
    pipe = Pipeline("ci")
    pipe.step(lambda: FakeBackend(), name="deploy", kind="deploy",
              payload=_deploy_spec(store))
    rec = Orchestrator({"gcp": 1, "ibm": 1}, log=log).execute(
        pipe.compile(), gateway=Gateway(log=log))
    assert rec.status == "failed"
    assert rec.steps["deploy"].status == "failed"
    assert rec.steps["deploy"].attempts[-1]["status"] == "infeasible"


# -- DriftMonitor -------------------------------------------------------------

def test_drift_config_validation():
    for bad in (dict(threshold=1.0), dict(threshold=0.5),
                dict(sustain=0), dict(min_n=0)):
        with pytest.raises(ValueError):
            DriftConfig(**bad)


def _fed_monitor(threshold=1.5, sustain=2, min_n=8, metrics=None):
    log = EventLog()
    mon = DriftMonitor(DriftConfig(threshold=threshold, sustain=sustain,
                                   min_n=min_n), log=log, metrics=metrics)
    mon.watch("m", ModelProfile("m", "gcp", 0.01), t=0.0)
    return mon, log


def feed(mon, t, ratio, n=10, _state={}):
    """One scrape's cumulative counters at observed ratio x profile."""
    key = id(mon)
    busy, served = _state.get(key, (0.0, 0))
    busy += ratio * 0.01 * n
    served += n
    _state[key] = (busy, served)
    mon.observe(t, "m", busy, served)


def test_drift_fires_on_sustained_out_of_band_only():
    mon, log = _fed_monitor()
    feed(mon, 1.0, ratio=1.0)
    feed(mon, 2.0, ratio=2.0)                        # 1st out-of-band
    assert not mon.is_drifting("m")                  # sustain=2
    feed(mon, 3.0, ratio=2.0)                        # 2nd: fires
    assert mon.is_drifting("m") and mon.drifting_models() == {"m"}
    evs = log.named("profile:drift")
    assert len(evs) == 1 and evs[0]["state"] == "firing"
    assert evs[0]["ratio"] == pytest.approx(2.0, abs=1e-3)
    assert mon.pop_reprofile() == {"m"}
    assert mon.pop_reprofile() == set()              # drained: armed once
    feed(mon, 4.0, ratio=2.0)                        # still firing: one edge
    assert len(log.named("profile:drift")) == 1
    assert log.count("modelci:reprofile") == 1
    feed(mon, 5.0, ratio=1.0)                        # back in band
    assert not mon.is_drifting("m")
    assert [e["state"] for e in log.named("profile:drift")] \
        == ["firing", "resolved"]


def test_drift_detects_too_fast_too():
    """A placement planned from an inflated profile over-provisions: the
    band is two-sided, ratio <= 1/threshold drifts as well."""
    mon, log = _fed_monitor(threshold=1.5)
    feed(mon, 1.0, ratio=0.5)
    feed(mon, 2.0, ratio=0.5)
    assert mon.is_drifting("m")


def test_drift_small_intervals_are_not_evidence():
    """A scrape with fewer than min_n served requests neither advances
    nor resets the streak -- quiet intervals must not mask real drift."""
    mon, log = _fed_monitor(min_n=8)
    feed(mon, 1.0, ratio=2.0, n=10)                  # streak 1
    feed(mon, 2.0, ratio=2.0, n=3)                   # below min_n: ignored
    assert not mon.is_drifting("m")
    feed(mon, 3.0, ratio=2.0, n=10)                  # streak 2: fires
    assert mon.is_drifting("m")


def test_drift_metrics_and_staleness():
    reg = MetricsRegistry()
    mon, log = _fed_monitor(metrics=reg)
    feed(mon, 7.0, ratio=2.0)
    assert reg.value("modelci_profile_staleness", model="m") == 7.0
    assert reg.value("modelci_drift_ratio", model="m") \
        == pytest.approx(2.0, abs=1e-3)
    feed(mon, 8.0, ratio=2.0)
    assert reg.total("modelci_drift_total", model="m") == 1


def test_drift_rewatch_and_reset_semantics():
    mon, log = _fed_monitor()
    feed(mon, 1.0, ratio=2.0)
    feed(mon, 2.0, ratio=2.0)
    assert mon.is_drifting("m")
    # re-watch (re-deploy after re-profile): drift state clears
    mon.watch("m", ModelProfile("m", "gcp", 0.02), t=2.0)
    assert not mon.is_drifting("m") and mon.reprofile == set()
    # reset (between gateway runs): baselines restart, watches survive
    mon.reset()
    assert not mon.active
    mon.observe(3.0, "ghost", 1.0, 100)              # unwatched: ignored
    assert not mon.drifting_models()


def test_gateway_drift_requires_scrape_clock():
    with pytest.raises(ValueError, match="scrape_every_s"):
        Gateway(drift=DriftConfig())
    with pytest.raises(ValueError, match="scrape_every_s"):
        Gateway(drift=DriftConfig(), metrics=MetricsRegistry())
    gw = Gateway(drift=DriftConfig(), metrics=MetricsRegistry(),
                 scrape_every_s=0.5)
    assert gw.drift is not None
    gw.deploy("m", FakeBackend(), get_profile("gcp"),
              planned_from=ModelProfile("m", "gcp", 0.01))
    assert "m" in gw.drift._watch
