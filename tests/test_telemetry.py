"""Unit + property tests for the observability plane (ISSUE 6):
telemetry/trace.py (span tree), telemetry/metrics.py (registry + quantile
sketch), telemetry/slo.py (burn-rate monitor), telemetry/analyze.py
(well-formedness oracle + critical paths), telemetry/events.py
(deterministic event log), and placement.replan's alert headroom.

The end-to-end reconciliation of these pieces against a live gateway run
(served + shed == offered, trace well-formedness over random fleets) lives
in test_gateway_invariants.py; this file pins the component contracts.
"""
import json
import math

import numpy as np
import pytest

from repro.clouds.profiles import get_profile
from repro.serving.gateway import CloudCapacity
from repro.serving.gateway.placement import (ModelDemand, plan_placement,
                                             replan)
from repro.telemetry.analyze import (export, request_breakdown, request_table,
                                     run_breakdown, run_critical_path,
                                     run_table, slowest_requests,
                                     validate_trace)
from repro.telemetry.events import EventLog
from repro.telemetry.metrics import (Counter, Gauge, MetricsRegistry,
                                     QuantileSketch)
from repro.telemetry.slo import BurnRateConfig, BurnRateMonitor
from repro.telemetry.trace import Tracer

try:
    from hypothesis import given, strategies as hyp_st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False


# -- QuantileSketch ----------------------------------------------------------

def exact_rank(xs_sorted, q):
    """The rank statistic the sketch approximates: the smallest sample
    whose cumulative count reaches q * n."""
    k = max(int(math.ceil(q * len(xs_sorted))), 1)
    return xs_sorted[k - 1]


def check_sketch_bound(values, sub):
    sk = QuantileSketch(sub=sub)
    for v in values:
        sk.observe(v)
    xs = sorted(values)
    assert sk.n == len(values)
    assert sk.quantile(0.0) == min(values)
    assert sk.quantile(1.0) == max(values)
    for q in (0.25, 0.5, 0.9, 0.99):
        got, want = sk.quantile(q), exact_rank(xs, q)
        assert abs(got - want) <= want / sub + 1e-12, (q, got, want)


@pytest.mark.parametrize("sub", [8, 32, 128])
@pytest.mark.parametrize("seed", range(4))
def test_sketch_relative_error_bound(sub, seed):
    rng = np.random.default_rng(seed)
    values = rng.lognormal(mean=-3.0, sigma=1.5, size=500).tolist()
    check_sketch_bound(values, sub)


def test_sketch_empty_and_edge_cases():
    sk = QuantileSketch()
    assert sk.quantile(0.5) is None and sk.mean is None
    assert sk.snapshot() == {"n": 0, "sum": 0.0, "p50": None, "p99": None}
    with pytest.raises(ValueError):
        QuantileSketch(sub=0)
    sk.observe(0.0)                      # underflow bucket: exact
    sk.observe(-2.0)
    assert sk.quantile(0.5) == -2.0 and sk.vmin == -2.0 and sk.vmax == 0.0


def test_sketch_single_value_is_exact():
    sk = QuantileSketch()
    sk.observe(0.125)
    for q in (0.0, 0.5, 0.99, 1.0):
        assert sk.quantile(q) == 0.125


def test_sketch_merge_equals_union():
    rng = np.random.default_rng(7)
    a, b = rng.exponential(0.05, 200), rng.exponential(0.5, 300)
    ska, skb, sku = QuantileSketch(), QuantileSketch(), QuantileSketch()
    for v in a:
        ska.observe(v)
        sku.observe(v)
    for v in b:
        skb.observe(v)
        sku.observe(v)
    ska.merge(skb)
    assert ska.counts == sku.counts
    assert ska.n == sku.n and ska.vmin == sku.vmin and ska.vmax == sku.vmax
    assert ska.quantile(0.99) == sku.quantile(0.99)
    with pytest.raises(ValueError):
        ska.merge(QuantileSketch(sub=8))


if HAS_HYPOTHESIS:
    @given(hyp_st.lists(hyp_st.floats(1e-9, 1e9, allow_nan=False,
                                      allow_infinity=False),
                        min_size=1, max_size=200))
    def test_sketch_bound_property(values):
        check_sketch_bound(values, 32)
else:
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(pip install -r requirements-dev.txt)")
    def test_sketch_bound_property():
        pass


# -- MetricsRegistry ---------------------------------------------------------

def test_counter_and_gauge_contracts():
    c = Counter()
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1.0)
    g = Gauge()
    g.set(4)
    assert g.snapshot() == 4.0


def test_registry_get_or_create_and_kind_guard():
    reg = MetricsRegistry()
    c1 = reg.counter("gateway_requests_total", model="m", outcome="served")
    c2 = reg.counter("gateway_requests_total", outcome="served", model="m")
    assert c1 is c2                      # label order does not matter
    with pytest.raises(ValueError):
        reg.gauge("gateway_requests_total", model="m")
    assert reg.value("nope") is None


def test_registry_total_matches_label_superset():
    reg = MetricsRegistry()
    reg.counter("req_total", model="a", outcome="served").inc(3)
    reg.counter("req_total", model="a", outcome="shed").inc(1)
    reg.counter("req_total", model="b", outcome="served").inc(5)
    assert reg.total("req_total") == 9
    assert reg.total("req_total", model="a") == 4
    assert reg.total("req_total", outcome="served") == 8
    assert reg.total("req_total", model="a", outcome="shed") == 1
    assert reg.total("req_total", model="c") == 0


def test_registry_scrape_series_and_export(tmp_path):
    reg, log = MetricsRegistry(), EventLog()
    reg.counter("hits_total", model="m").inc()
    reg.gauge("queue_depth", model="m").set(7)
    h = reg.histogram("lat_seconds", model="m")
    for v in (0.01, 0.02, 0.04):
        h.observe(v)
    reg.scrape(0.5, log)
    reg.counter("hits_total", model="m").inc()
    reg.scrape(1.5, log)
    assert [s["t_sim"] for s in reg.scrapes] == [0.5, 1.5]
    assert reg.series("hits_total", model="m") == [(0.5, 1.0), (1.5, 2.0)]
    assert log.count("metrics:scrape") == 2
    text = reg.to_prometheus()
    assert "# TYPE hits_total counter" in text
    assert "# TYPE lat_seconds summary" in text
    assert 'lat_seconds_count{model="m"} 3' in text
    assert 'quantile="0.99"' in text
    blob = json.loads(reg.to_json(str(tmp_path / "metrics.json")))
    assert blob["current"]['queue_depth{model="m"}'] == 7.0
    assert len(blob["scrapes"]) == 2


def test_prometheus_exposition_escapes_hostile_labels():
    """Exposition-format hardening (ISSUE 10 satellite): a model named
    with quotes, backslashes or newlines must produce a parseable text
    page -- label values escape backslash FIRST, then quote and newline,
    and HELP text escapes backslash and newline only."""
    reg = MetricsRegistry()
    hostile = 'mo"del\\with\nnewline'
    reg.counter("gateway_requests_total", model=hostile).inc(2)
    reg.describe("gateway_requests_total", 'requests "per" \\ model\nline2')
    text = reg.to_prometheus()
    line = next(ln for ln in text.splitlines()
                if ln.startswith("gateway_requests_total{"))
    # the raw newline never leaks into the page: one series, one line
    assert line.endswith(" 2")
    assert r'model="mo\"del\\with\nnewline"' in line
    help_line = next(ln for ln in text.splitlines()
                     if ln.startswith("# HELP gateway_requests_total"))
    assert help_line == r'# HELP gateway_requests_total requests "per" \\ ' \
                        "model\\nline2"
    assert "# TYPE gateway_requests_total counter" in text


def test_prometheus_exposition_help_type_per_family():
    """Every family gets exactly one HELP/TYPE pair, before its samples;
    histograms expose as summaries; undescribed families fall back to a
    kind-derived HELP."""
    reg = MetricsRegistry()
    reg.counter("a_total", model="x").inc()
    reg.counter("a_total", model="y").inc()
    reg.gauge("b_depth").set(3)
    reg.histogram("c_seconds").observe(0.5)
    text = reg.to_prometheus()
    lines = text.splitlines()
    assert sum(ln.startswith("# HELP a_total") for ln in lines) == 1
    assert sum(ln.startswith("# TYPE a_total") for ln in lines) == 1
    assert "# TYPE b_depth gauge" in lines
    assert "# TYPE c_seconds summary" in lines
    assert "# HELP b_depth gauge family b_depth" in lines
    # HELP/TYPE precede the family's first sample line
    assert lines.index("# TYPE a_total counter") \
        < lines.index('a_total{model="x"} 1')


# -- EventLog determinism ----------------------------------------------------

def test_eventlog_seq_and_index():
    log = EventLog()
    for i in range(5):
        log.record("a" if i % 2 else "b", 0.1, i=i)
    assert [e["seq"] for e in log.events] == list(range(5))
    assert [e["i"] for e in log.named("a")] == [1, 3]
    assert log.count("b") == 3 and log.count("zzz") == 0
    assert log.named("a")[0] is log.events[1]   # index shares the dicts


def test_eventlog_dump_is_byte_stable_without_wall(tmp_path):
    def run():
        log = EventLog()
        log.record("gateway:run", 2.5, models=["m"], wall_s=np.random.rand())
        with log.stage("serve:kserve", n=3):
            sum(range(1000))             # arbitrary wall-clock work
        log.record("pipeline:step", 0.25, step="train")
        return log

    a, b = run(), run()
    assert a.dump() == b.dump()          # wall fields stripped by default
    assert a.dump(include_wall=True) != b.dump(include_wall=True)
    d = json.loads(a.dump())
    assert "wall_s" not in d[0]
    assert d[0]["duration_s"] == 2.5     # simulated durations survive
    assert "duration_s" not in d[1]      # stage events are wall=True
    assert d[1]["wall"] is True
    p = tmp_path / "events.json"
    a.dump(str(p))
    assert p.read_text() == b.dump()


# -- Tracer + validate_trace -------------------------------------------------

def make_request_trace():
    """A tiny gateway-shaped forest: run > request > queue + serve, plus a
    foreign deploy span the request links to."""
    tr = Tracer()
    deploy = tr.start("pipeline.step", 0.0, step="deploy")
    tr.end(deploy, 1.0)
    run = tr.start("gateway.run", 0.0, seed=0)
    req = tr.start("gateway.request", 0.1, parent=run,
                   links=(deploy.span_id, None), model="m", idx=0,
                   cls="standard")
    q = tr.start("gateway.queue", 0.1, parent=req, cloud="gcp")
    tr.end(q, 0.3)
    srv = tr.start("gateway.serve", 0.3, parent=req, cloud="gcp",
                   rtt_lb_s=0.05, cold_s=0.0, service_s=0.15)
    tr.end(srv, 0.5)
    tr.end(req, 0.5, outcome="served", latency_s=0.4)
    tr.end(run, 1.0, models=["m"])
    return tr, deploy, run, req


def test_tracer_ids_links_and_reachability():
    tr, deploy, run, req = make_request_trace()
    assert [s.span_id for s in tr.spans] == list(range(5))
    assert tr.get(req.span_id) is req
    assert req.trace_id == run.span_id and req.links == (deploy.span_id,)
    assert {s.span_id for s in tr.roots()} == {deploy.span_id, run.span_id}
    kids = tr.children_index()[req.span_id]
    assert [k.name for k in kids] == ["gateway.queue", "gateway.serve"]
    # the cross-trace walk: pipeline deploy -> linking request -> children
    reach = tr.reachable(deploy.span_id)
    assert req.span_id in reach and kids[0].span_id in reach
    assert run.span_id not in reach      # links are directed


def test_tracer_json_export_records_event(tmp_path):
    tr, *_ = make_request_trace()
    log = EventLog()
    p = tmp_path / "trace.json"
    blob = json.loads(tr.to_json(str(p), log=log))
    assert len(blob) == 5 and blob[2]["name"] == "gateway.request"
    assert json.loads(p.read_text()) == blob
    assert log.named("trace:export")[0]["spans"] == 5


def test_tracer_from_json_round_trip_offline_analysis(tmp_path):
    """Offline re-analysis (ISSUE 10 satellite): a Tracer rebuilt from a
    ``to_json`` export must drive the analyzers to the exact same tables
    as the live tracer, survive a second export byte-identically, and
    reject blobs whose span ids are not the list indices."""
    tr, *_ = make_request_trace()
    blob = tr.to_json()
    back = Tracer.from_json(blob)
    assert len(back.spans) == len(tr.spans)
    assert back.to_json() == blob                        # lossless
    assert request_table(back, 3) == request_table(tr, 3)
    assert validate_trace(back) == []
    # new spans keep allocating past the imported ids (the get() contract)
    s = back.start("gateway.request", 9.0)
    assert s.span_id == len(tr.spans)
    # load() is from_json over a file written by to_json(path)
    p = tmp_path / "trace.json"
    tr.to_json(str(p))
    assert Tracer.load(str(p)).to_json() == blob
    # a reordered/id-gapped export is rejected, not silently re-keyed
    rows = json.loads(blob)
    rows[0], rows[1] = rows[1], rows[0]
    with pytest.raises(ValueError):
        Tracer.from_json(json.dumps(rows))


def test_tracer_from_json_run_tables_match(tmp_path):
    tr, run, _ = make_run_trace()
    back = Tracer.from_json(tr.to_json())
    assert run_table(back, run.span_id) == run_table(tr, run.span_id)
    assert [s.attrs["step"]
            for s in run_critical_path(back, run.span_id)] \
        == [s.attrs["step"] for s in run_critical_path(tr, run.span_id)]


def test_validate_trace_catches_malformed_spans():
    tr, deploy, run, req = make_request_trace()
    assert validate_trace(tr) == []
    open_span = tr.start("gateway.queue", 0.2, parent=req)
    assert any("open span" in v for v in validate_trace(tr))
    tr.end(open_span, 0.1)               # negative interval
    assert any("negative interval" in v for v in validate_trace(tr))
    tr.end(open_span, 9.0)               # escapes parent [0.1, 0.5]
    assert any("escapes" in v for v in validate_trace(tr))
    tr.end(open_span, 0.4)
    assert validate_trace(tr) == []
    open_span.parent_id = 99             # dangling
    assert any("dangling" in v for v in validate_trace(tr))
    open_span.parent_id = req.span_id
    open_span.trace_id = deploy.span_id  # wrong tree
    assert any("mismatch" in v for v in validate_trace(tr))
    open_span.trace_id = run.span_id
    run.trace_id = 42                    # root must own its trace id
    assert any("root" in v for v in validate_trace(tr))


# -- analyzer ----------------------------------------------------------------

def test_request_breakdown_attribution():
    tr, deploy, run, req = make_request_trace()
    # a second, slower request with a preempted first serve attempt
    r2 = tr.start("gateway.request", 0.2, parent=run, model="m", idx=1,
                  cls="latency")
    q1 = tr.start("gateway.queue", 0.2, parent=r2)
    tr.end(q1, 0.4)
    bad = tr.start("gateway.serve", 0.4, parent=r2, cloud="gcp")
    tr.end(bad, 0.6, preempted=True)
    q2 = tr.start("gateway.queue", 0.6, parent=r2, requeued=True)
    tr.end(q2, 0.7)
    srv = tr.start("gateway.serve", 0.7, parent=r2, cloud="ibm",
                   rtt_lb_s=0.1, cold_s=0.05, service_s=0.05)
    tr.end(srv, 0.9)
    tr.end(r2, 0.9, outcome="served", latency_s=0.7)
    shed = tr.start("gateway.request", 0.3, parent=run, model="m", idx=2,
                    cls="standard")
    tr.end(shed, 0.35, outcome="shed", at="enqueue")

    rows = request_breakdown(tr)
    assert len(rows) == 2                # shed requests are excluded
    r = {row["idx"]: row for row in rows}[1]
    assert r["queue_s"] == pytest.approx(0.3)
    assert r["preempted_s"] == pytest.approx(0.2)
    assert r["cold_s"] == pytest.approx(0.05)
    assert r["cloud"] == "ibm"
    assert r["total_s"] == pytest.approx(
        r["queue_s"] + r["preempted_s"] + r["rtt_lb_s"] + r["cold_s"]
        + r["service_s"])
    assert slowest_requests(tr, 1)[0]["idx"] == 1
    table = request_table(tr, k=2)
    assert "slowest requests" in table and "ibm" in table


def make_run_trace():
    """A pipeline-shaped tree: prep -> {a, b} -> join, where b finishes
    last (the critical path is prep -> b -> join)."""
    tr = Tracer()
    run = tr.start("pipeline.run", 0.0, run_id="r-000", pipeline="p")
    spans = {}
    plan = [("prep", (), 0.0, 1.0, 0.2), ("a", ("prep",), 1.0, 2.0, 0.3),
            ("b", ("prep",), 1.0, 4.0, 0.5),
            ("join", ("a", "b"), 4.0, 5.0, 0.1)]
    for name, deps, t0, t1, compute in plan:
        s = tr.start("pipeline.step", t0, parent=run, step=name,
                     deps=list(deps), cloud="gcp")
        att = tr.start("pipeline.attempt", t0, parent=s, cloud="gcp",
                       control_s=0.1, transfer_s=0.05, compute_s=compute)
        tr.end(att, t1)
        tr.end(s, t1, status="done")
        spans[name] = s
    tr.end(run, 5.0, status="succeeded")
    return tr, run, spans


def test_run_critical_path_and_breakdown():
    tr, run, spans = make_run_trace()
    assert validate_trace(tr) == []
    path = [s.attrs["step"] for s in run_critical_path(tr, run.span_id)]
    assert path == ["prep", "b", "join"]
    rows = run_breakdown(tr, run.span_id)
    b = {r["step"]: r for r in rows}["b"]
    assert b["attempts"] == 1 and b["total_s"] == pytest.approx(3.0)
    assert b["wait_s"] == pytest.approx(3.0 - 0.1 - 0.05 - 0.5)
    table = run_table(tr, run.span_id)
    assert "critical path" in table and "join" in table
    assert run_critical_path(tr, spans["prep"].span_id) == []


def test_export_writes_both_wire_formats(tmp_path):
    tr, run, _ = make_run_trace()
    reg = MetricsRegistry()
    reg.counter("pipeline_runs_total", pipeline="p").inc()
    log = EventLog()
    tpath, ppath = tmp_path / "trace.json", tmp_path / "metrics.prom"
    export(tr, reg, trace_path=str(tpath), prom_path=str(ppath), log=log)
    assert len(json.loads(tpath.read_text())) == len(tr.spans)
    assert "pipeline_runs_total" in ppath.read_text()
    assert log.count("trace:export") == 1


# -- burn-rate monitor -------------------------------------------------------

def test_burn_config_validation():
    for bad in (dict(objective=0.0), dict(objective=1.0),
                dict(short_s=2.0, long_s=1.0), dict(threshold=0.0),
                dict(min_n=0)):
        with pytest.raises(ValueError):
            BurnRateConfig(**bad)


def test_burn_monitor_fires_and_resolves():
    log, reg = EventLog(), MetricsRegistry()
    cfg = BurnRateConfig(objective=0.9, short_s=0.5, long_s=2.5,
                         threshold=2.0, min_n=8)
    mon = BurnRateMonitor(cfg, log=log, metrics=reg)
    # 7 breaches: below min_n per window, must NOT fire
    for k in range(7):
        mon.observe(0.01 * k, "m", "standard", good=False)
    assert not mon.is_burning("m") and mon.alerts == []
    # the 8th breach tips both windows past threshold (burn = 10 >= 2)
    mon.observe(0.08, "m", "standard", good=False)
    assert mon.is_burning("m") and mon.alerting_models() == {"m"}
    assert len(mon.alerts) == 1
    fire = log.named("gateway:alert")[0]
    assert fire["state"] == "firing" and fire["burn_short"] >= 2.0
    assert reg.total("gateway_slo_alerts_total", model="m") == 1
    assert mon.pressure("m", 16) == 16 and mon.pressure("other", 16) == 0
    # a good-only stream past the short window resolves the alert even
    # though the long window still remembers the breaches
    for k in range(20):
        mon.observe(1.0 + 0.01 * k, "m", "standard", good=True)
    assert not mon.is_burning("m")
    states = [e["state"] for e in log.named("gateway:alert")]
    assert states == ["firing", "resolved"]
    assert len(mon.alerts) == 1          # alert history survives resolution
    mon.reset()
    assert mon.alerts and not mon.active


def test_burn_monitor_ages_out_on_the_clock():
    """An alert must resolve by TIME alone: after the last observation of
    a run there is no further observe() call, so a firing alert would
    otherwise pin pressure() forever and the gateway's scale-up /
    idle-retire cycle never terminates (the seed-517 livelock, ISSUE 7)."""
    log = EventLog()
    cfg = BurnRateConfig(objective=0.9, short_s=0.5, long_s=2.5,
                         threshold=2.0, min_n=8)
    mon = BurnRateMonitor(cfg, log=log)
    for k in range(8):
        mon.observe(0.01 * k, "m", "latency", good=False)
    assert mon.is_burning("m") and mon.pressure("m", 16) == 16
    mon.age(0.2)                        # within the short window: still firing
    assert mon.is_burning("m")
    mon.age(5.0)                        # both windows empty: must resolve
    assert not mon.is_burning("m") and mon.pressure("m", 16) == 0
    assert [e["state"] for e in log.named("gateway:alert")] \
        == ["firing", "resolved"]
    mon.age(6.0)                        # idempotent on empty windows
    assert not mon.active


def test_burn_monitor_needs_sustained_breach():
    """A single bad observation among good ones never pages (the long
    window gates on significance)."""
    mon = BurnRateMonitor(BurnRateConfig(objective=0.9, min_n=4))
    for k in range(40):
        mon.observe(0.05 * k, "m", "latency", good=(k != 20))
    assert not mon.is_burning("m") and mon.alerts == []


# -- placement.replan with alert headroom ------------------------------------

class _Obs:
    def __init__(self, rate):
        self.observed = {"rate_rps": rate, "service_time_s": 0.02, "shed": 0}


class _Result:
    def __init__(self, rate):
        self.per_model = {"m": _Obs(rate)}


def test_replan_alert_headroom_overprovisions():
    clouds = [CloudCapacity(get_profile("gcp"), 8, 1.0),
              CloudCapacity(get_profile("ibm"), 8, 1.4)]
    plan = plan_placement([ModelDemand("m", 40.0, 0.02)], clouds)
    res = _Result(40.0)                  # load 0.8 Erlang as observed
    base = replan(plan, res, clouds=clouds)
    hot = replan(plan, res, clouds=clouds, alerts={"m"}, alert_headroom=2.0)
    n_base = sum(base.assignments[0].shares.values())
    n_hot = sum(hot.assignments[0].shares.values())
    assert n_hot > n_base                # alerts inflate observed demand
    cold = replan(plan, res, clouds=clouds, alerts={"other"},
                  alert_headroom=2.0)
    assert sum(cold.assignments[0].shares.values()) == n_base
    with pytest.raises(ValueError):
        replan(plan, res, clouds=clouds, alerts={"m"}, alert_headroom=0.5)
