"""Active-active multi-cloud serving (ISSUE 3): weighted traffic splits,
the split-aware placement planner, MigrationPlan diffs applied live
mid-run, cost-aware autoscaling against the CloudProfile price sheet, and
simulated dollar accounting in results."""
import math

import pytest

from repro.clouds.profiles import get_profile
from repro.serving.gateway import (Autoscaler, AutoscalerConfig,
                                   CloudCapacity, FailureSpec, Gateway,
                                   MigrationSpec, MigrationStep, ModelDemand,
                                   PoolView, ReplanConfig, RoutingConfig,
                                   TrafficSpec, diff_plans, plan_placement,
                                   replan, replicas_needed)
from repro.telemetry.events import EventLog

from conftest import AnalyticBackend


def warm_config(**kw):
    return AutoscalerConfig(min_replicas=kw.pop("min_replicas", 1),
                            idle_window_s=kw.pop("idle_window_s", math.inf),
                            **kw)


def split_gcp_ibm(f_ibm):
    return {get_profile("gcp"): 1.0 - f_ibm, get_profile("ibm"): f_ibm}


# -- split routing ------------------------------------------------------------

def test_split_routes_by_weight_and_charges_per_cloud():
    # policy="weights" pins the pure weighted-draw contract this test is
    # about; the queue-aware blend's share behavior is covered by
    # tests/test_admission.py
    gw = Gateway(record_batches=True, routing=RoutingConfig("weights"))
    gw.deploy("m", AnalyticBackend("m"), split=split_gcp_ibm(0.3),
              autoscaler=warm_config(min_replicas=2), max_batch=4)
    out = gw.run([TrafficSpec("m", 400, arrival="poisson", rate=300.0)],
                 seed=3)
    res = out.per_model["m"]
    assert res.n_requests == 400
    assert all(l > 0 for l in res.latencies_s)
    by_cloud: dict = {}
    for rec in gw.batch_log:
        assert not rec["preempted"]
        by_cloud[rec["cloud"]] = by_cloud.get(rec["cloud"], 0) \
            + len(rec["idx"])
    assert sum(by_cloud.values()) == 400
    assert 0.2 < by_cloud["ibm"] / 400 < 0.4     # ~the declared 30% share
    assert abs(sum(gw.final_weights["m"].values()) - 1.0) < 1e-9


def test_split_weights_must_sum_to_one():
    gw = Gateway()
    with pytest.raises(ValueError, match="sum to 1"):
        gw.deploy("m", AnalyticBackend("m"),
                  split={get_profile("gcp"): 0.5, get_profile("ibm"): 0.2})
    with pytest.raises(ValueError, match="profile or a split"):
        gw.deploy("m", AnalyticBackend("m"))
    with pytest.raises(ValueError, match="standby"):
        gw.deploy("m", AnalyticBackend("m"), split=split_gcp_ibm(0.5),
                  standby=get_profile("ibm"))


def test_split_min_replicas_apportioned_across_pools():
    """min_replicas=2 over a 50/50 split: one warm floor replica per cloud,
    and the shared capacity baseline counts both."""
    gw = Gateway(capacity={"gcp": 1, "ibm": 1}, record_batches=True)
    gw.deploy("m", AnalyticBackend("m"), split=split_gcp_ibm(0.5),
              autoscaler=warm_config(min_replicas=2, max_replicas=2),
              max_batch=4)
    out = gw.run([TrafficSpec("m", 16)], seed=0)
    assert out.per_model["m"].n_requests == 16
    assert {rec["cloud"] for rec in gw.batch_log} == {"gcp", "ibm"}

    over = Gateway(capacity={"gcp": 1, "ibm": 0})
    over.deploy("m", AnalyticBackend("m"), split=split_gcp_ibm(0.5),
                autoscaler=warm_config(min_replicas=2))
    with pytest.raises(ValueError, match="capacity"):
        over.run([TrafficSpec("m", 2)])


def test_failover_is_degenerate_split():
    """An outage on one side of an active-active split zeroes that cloud's
    weight (no standby machinery): survivors absorb everything, recovery
    restores the declared split, nothing is lost or doubled."""
    log = EventLog()
    gw = Gateway(log=log, record_batches=True)
    gw.deploy("m", AnalyticBackend("m", base_s=0.01),
              split=split_gcp_ibm(0.5),
              autoscaler=warm_config(min_replicas=2, max_replicas=2,
                                     scale_up_delay_s=0.02), max_batch=4)
    out = gw.run([TrafficSpec("m", 300, arrival="poisson", rate=600.0)],
                 seed=0, failures=[FailureSpec("gcp", at_s=0.1,
                                               duration_s=0.2)])
    assert out.per_model["m"].n_requests == 300
    splits = log.named("gateway:split")
    assert splits, "outage edges must emit gateway:split"
    during = [e for e in splits if e["reason"] == "fail"]
    assert during and during[0]["weights"]["gcp"] == 0.0
    assert during[0]["weights"]["ibm"] == 1.0
    # src is the cloud that LOST share (the dead one), dst the absorber --
    # a renormalizing survivor is a real destination, not drain-in-place
    fo = log.named("gateway:failover")
    assert fo and fo[0]["src"] == "gcp" and fo[0]["dst"] == "ibm"
    # recovery restores the nominal 50/50
    assert gw.final_weights["m"] == {"gcp": 0.5, "ibm": 0.5}
    for rec in gw.batch_log:             # dead cloud serves nothing inside
        if rec["cloud"] == "gcp":        # the window
            assert not (0.1 <= rec["start_s"] < 0.3)


# -- live migration -----------------------------------------------------------

def test_explicit_migration_shifts_mid_run():
    log = EventLog()
    gw = Gateway(log=log, record_batches=True)
    gw.deploy("m", AnalyticBackend("m", base_s=0.01), get_profile("gcp"),
              autoscaler=warm_config(max_replicas=2, scale_up_delay_s=0.02),
              max_batch=4)
    out = gw.run([TrafficSpec("m", 200, arrival="poisson", rate=400.0)],
                 seed=0,
                 migrations=[MigrationSpec(0.2, {"m": {"ibm": 1.0}})])
    assert out.per_model["m"].n_requests == 200
    assert log.count("gateway:migrate") == 1
    assert log.named("gateway:migrate")[0]["reason"] == "plan"
    clouds = {rec["cloud"] for rec in gw.batch_log}
    assert clouds == {"gcp", "ibm"}
    # drain-and-shift: no gcp batch STARTS after the migration fired, but
    # nothing is reclaimed either (in-flight work completes where it ran)
    assert all(rec["start_s"] < 0.2 for rec in gw.batch_log
               if rec["cloud"] == "gcp")
    assert not any(rec["preempted"] for rec in gw.batch_log)
    assert gw.final_weights["m"] == {"gcp": 0.0, "ibm": 1.0}


def test_replan_config_validated():
    with pytest.raises(ValueError, match="check_every_s"):
        ReplanConfig(check_every_s=0.0)
    with pytest.raises(ValueError, match="shift"):
        ReplanConfig(shift=0.0)
    with pytest.raises(ValueError, match="sustain"):
        ReplanConfig(sustain=0)
    with pytest.raises(ValueError, match="min_window_n"):
        ReplanConfig(min_window_n=0)   # would divide by zero in the probe


def test_migration_weights_validated():
    gw = Gateway()
    gw.deploy("m", AnalyticBackend("m"), get_profile("gcp"),
              autoscaler=warm_config())
    with pytest.raises(ValueError, match="sum to 1"):
        gw.run([TrafficSpec("m", 4)],
               migrations=[MigrationSpec(0.1, {"m": {"gcp": 0.4}})])
    with pytest.raises(KeyError):
        gw.run([TrafficSpec("m", 4)],
               migrations=[MigrationSpec(0.1, {"ghost": {"gcp": 1.0}})])
    with pytest.raises(ValueError):
        MigrationSpec(-1.0, {})
    with pytest.raises(ValueError, match="sum to 1"):
        MigrationStep("m", {"gcp": 0.5}, {}, {"gcp": get_profile("gcp")})


def test_plan_diff_round_trips_through_the_router():
    """plan -> diff -> run(migrations=[...]): the router lands on the new
    plan's split, opening pools for clouds it had never served from."""
    clouds = [CloudCapacity(get_profile("gcp"), 2, 1.0),
              CloudCapacity(get_profile("ibm"), 8, 1.4)]
    d_lo = [ModelDemand("m", rate=10.0, service_time_s=0.1)]    # 2 replicas
    d_hi = [ModelDemand("m", rate=25.0, service_time_s=0.1)]    # 4 replicas
    plan_lo = plan_placement(d_lo, clouds, objective="cost", split=True)
    plan_hi = plan_placement(d_hi, clouds, objective="cost", split=True)
    assert plan_lo.assignments[0].shares == {"gcp": 2}
    assert plan_hi.assignments[0].shares == {"gcp": 2, "ibm": 2}
    mig = diff_plans(plan_lo, plan_hi)
    assert mig.models == ["m"]

    gw = Gateway(record_batches=True)
    gw.deploy("m", AnalyticBackend("m", base_s=0.01), get_profile("gcp"),
              autoscaler=warm_config(min_replicas=2, max_replicas=4,
                                     scale_up_delay_s=0.02), max_batch=4)
    out = gw.run([TrafficSpec("m", 300, arrival="poisson", rate=500.0)],
                 seed=1, migrations=[MigrationSpec(0.15, mig)])
    assert out.per_model["m"].n_requests == 300
    assert gw.final_weights["m"] == plan_hi.assignments[0].weights
    assert {rec["cloud"] for rec in gw.batch_log} == {"gcp", "ibm"}


def test_unchanged_plan_diffs_to_no_steps():
    clouds = [CloudCapacity(get_profile("gcp"), 8, 1.0)]
    models = [ModelDemand("m", rate=10.0, service_time_s=0.1)]
    a = plan_placement(models, clouds, split=True)
    b = plan_placement(models, clouds, split=True)
    assert diff_plans(a, b).steps == []


def test_split_total_never_exceeds_deployment_budget():
    """Regression: per-pool ceil-share caps sum over max_replicas (ceil(3/2)
    twice = 4); the deployment-wide budget must still bound elastic
    scale-up across the split."""
    gw = Gateway()
    gw.deploy("m", AnalyticBackend("m", base_s=0.02),
              split=split_gcp_ibm(0.5),
              autoscaler=AutoscalerConfig(min_replicas=2, max_replicas=3,
                                          target_queue=1,
                                          scale_up_delay_s=0.01,
                                          idle_window_s=math.inf),
              max_batch=1)
    out = gw.run([TrafficSpec("m", 400, arrival="poisson", rate=800.0)],
                 seed=2)
    assert out.per_model["m"].n_requests == 400
    assert max(r for _, r in out.per_model["m"].replica_trace) <= 3


def test_scale_from_zero_budget_breach_is_loud():
    """A pool whose queued work cannot be served anywhere else may breach
    the deployment budget (the run must complete) -- but loudly."""
    log = EventLog()
    gw = Gateway(log=log)
    gw.deploy("m", AnalyticBackend("m", base_s=0.3),
              split=split_gcp_ibm(0.5),
              autoscaler=AutoscalerConfig(min_replicas=0, max_replicas=1,
                                          scale_up_delay_s=0.01,
                                          idle_window_s=math.inf),
              max_batch=8)
    out = gw.run([TrafficSpec("m", 32)], seed=0)
    assert out.per_model["m"].n_requests == 32
    assert log.count("gateway:budget_exceeded") >= 1


def test_migration_relaunches_working_set_despite_busy_source():
    """Regression: when every source replica is mid-batch at the shift, the
    destination must still relaunch the working set (transient surge) --
    the deployment budget must not count the soft-draining source pool."""
    log = EventLog()
    gw = Gateway(log=log)
    gw.deploy("m", AnalyticBackend("m", base_s=0.3), get_profile("gcp"),
              autoscaler=warm_config(min_replicas=2, max_replicas=2,
                                     scale_up_delay_s=0.02), max_batch=4)
    out = gw.run([TrafficSpec("m", 8), TrafficSpec("m", 8, start_s=1.0)],
                 seed=0,
                 migrations=[MigrationSpec(0.1, {"m": {"ibm": 1.0}})])
    ups = [e for e in log.named("gateway:scale_up") if e["cloud"] == "ibm"]
    assert len(ups) >= 2, "destination floor never launched"
    assert out.per_model["m"].n_requests == 16
    assert gw.final_weights["m"] == {"gcp": 0.0, "ibm": 1.0}


def test_probe_shift_during_outage_preserves_dead_clouds_nominal():
    """Regression: an auto-replan shift fired DURING an outage must not
    erase the dead cloud's nominal share -- recovery still restores it."""
    log = EventLog()
    gw = Gateway(log=log,
                 replan=ReplanConfig(check_every_s=0.05, sustain=2,
                                     overload_factor=1.0, consolidate=False))
    gw.deploy("m", AnalyticBackend("m", base_s=0.1),
              split=split_gcp_ibm(0.5), standby=get_profile("k8s"),
              autoscaler=warm_config(min_replicas=2, max_replicas=2,
                                     target_queue=1, scale_up_delay_s=0.01),
              max_batch=1)
    out = gw.run([TrafficSpec("m", 40, start_s=0.02)], seed=0,
                 failures=[FailureSpec("gcp", at_s=0.01, duration_s=1.0)])
    assert out.per_model["m"].n_requests == 40
    migs = [e for e in log.named("gateway:migrate")
            if e["reason"] in ("overload", "miss_rate")]
    assert migs and migs[0]["src"] == "ibm" and migs[0]["dst"] == "k8s"
    assert log.count("gateway:recover") >= 1
    final = gw.final_weights["m"]
    assert final["gcp"] == pytest.approx(0.5)    # the outage gave it back
    assert abs(sum(final.values()) - 1.0) < 1e-9


# -- continuous re-planning ---------------------------------------------------

def test_auto_replan_shifts_overload_to_cheapest_headroom():
    """A pool that is overloaded and out of room sheds weight toward the
    cheapest declared cloud with headroom (here: the zero-weight standby
    pool on gcp, which is also the cheaper price-sheet entry)."""
    log = EventLog()
    gw = Gateway(log=log, record_batches=True,
                 replan=ReplanConfig(check_every_s=0.05, sustain=2,
                                     overload_factor=1.0))
    gw.deploy("m", AnalyticBackend("m", base_s=0.2), get_profile("ibm"),
              standby=get_profile("gcp"),
              autoscaler=warm_config(max_replicas=1, target_queue=1),
              max_batch=1)
    out = gw.run([TrafficSpec("m", 40)], seed=0)
    assert out.per_model["m"].n_requests == 40
    migs = log.named("gateway:migrate")
    assert migs and migs[0]["reason"] == "overload"
    assert migs[0]["src"] == "ibm" and migs[0]["dst"] == "gcp"
    assert {rec["cloud"] for rec in gw.batch_log} == {"gcp", "ibm"}


def test_auto_replan_consolidates_idle_fleet_off_expensive_cloud():
    """Cost-aware scale-down: an idle 50/50 split folds the expensive ibm
    pool into gcp (retire-most-expensive-first), and the stranded ibm
    replica idles out to zero."""
    log = EventLog()
    gw = Gateway(log=log,
                 replan=ReplanConfig(check_every_s=0.1, sustain=2))
    gw.deploy("m", AnalyticBackend("m", base_s=0.005),
              split=split_gcp_ibm(0.5),
              autoscaler=AutoscalerConfig(min_replicas=2, max_replicas=2,
                                          idle_window_s=1.5), max_batch=8)
    out = gw.run([TrafficSpec("m", 16)], seed=0)
    assert out.per_model["m"].n_requests == 16
    migs = [e for e in log.named("gateway:migrate") if e["reason"] == "cost"]
    assert migs and migs[0]["src"] == "ibm" and migs[0]["dst"] == "gcp"
    assert gw.final_weights["m"] == {"gcp": 1.0, "ibm": 0.0}
    downs = [e for e in log.named("gateway:scale_down")
             if e["cloud"] == "ibm"]
    assert downs, "the expensive replica must retire after consolidation"


# -- cost-aware policy units --------------------------------------------------

def test_relaunch_pool_respects_destination_headroom():
    """ISSUE 3 bugfix: migration relaunches size against the DESTINATION
    pool's capacity, not just the global max_replicas."""
    asc = Autoscaler(AutoscalerConfig(min_replicas=0, max_replicas=4))
    assert asc.relaunch_pool(3, 10) == 3                 # legacy: global cap
    assert asc.relaunch_pool(3, 10, headroom=2) == 2     # destination-bound
    assert asc.relaunch_pool(3, 10, headroom=0) == 1     # from-zero, loudly
    assert asc.relaunch_pool(3, 0, headroom=0) == 0      # nothing queued
    assert asc.relaunch_pool(9, 10, headroom=9) == 4     # still <= max


def test_pick_scale_up_and_retire_rank_by_price_sheet():
    pools = [PoolView("ibm", 1.4 / 3600, replicas=2, headroom=2),
             PoolView("gcp", 1.0 / 3600, replicas=1, headroom=1),
             PoolView("k8s", 1.1 / 3600, replicas=0, headroom=0)]
    assert Autoscaler.pick_scale_up(pools).cloud == "gcp"   # cheapest open
    assert Autoscaler.pick_retire(pools).cloud == "ibm"     # costliest held
    assert Autoscaler.pick_scale_up([]) is None
    assert Autoscaler.pick_retire(
        [PoolView("gcp", 1.0, replicas=0, headroom=3)]) is None


# -- split-aware planner ------------------------------------------------------

def _clouds(gcp=(8, 1.0), ibm=(8, 1.4)):
    return [CloudCapacity(get_profile("gcp"), gcp[0], gcp[1]),
            CloudCapacity(get_profile("ibm"), ibm[0], ibm[1])]


def test_split_plan_spills_when_best_cloud_is_full():
    d = ModelDemand("m", rate=50.0, service_time_s=0.1)   # needs 8 replicas
    clouds = _clouds(gcp=(5, 1.0), ibm=(8, 1.4))
    single = plan_placement([d], clouds, objective="cost")
    split = plan_placement([d], clouds, objective="cost", split=True)
    assert single.assignments[0].shares == {"ibm": 8}     # gcp cannot fit it
    a = split.assignments[0]
    assert a.shares == {"gcp": 5, "ibm": 3}               # cheap first, spill
    assert abs(sum(a.weights.values()) - 1.0) < 1e-9
    assert a.weights["gcp"] == 5 / 8
    assert split.total_cost_hr < single.total_cost_hr     # the point
    assert split.capacity_map() == {"gcp": 5, "ibm": 3}
    assert a.cloud == "gcp"                               # primary = max w


def test_split_plan_feasible_where_single_cloud_is_not():
    d = ModelDemand("m", rate=30.0, service_time_s=0.1)   # needs 5
    clouds = _clouds(gcp=(3, 1.0), ibm=(2, 1.4))
    assert not plan_placement([d], clouds).feasible
    split = plan_placement([d], clouds, split=True)
    assert split.feasible
    assert split.assignments[0].shares == {"gcp": 3, "ibm": 2}


def test_split_weights_always_sum_to_one_or_unplaced():
    models = [ModelDemand(f"m{i}", rate=10.0 + 7 * i, service_time_s=0.08)
              for i in range(4)]
    plan = plan_placement(models, _clouds(gcp=(4, 1.0), ibm=(5, 1.4)),
                          split=True)
    for a in plan.assignments:
        if a.shares:
            assert abs(sum(a.weights.values()) - 1.0) < 1e-9
            assert all(w > 0 for w in a.weights.values())
        else:
            assert a.weights == {} and a.saturated


def test_cloud_capacity_price_defaults_to_profile_sheet():
    c = CloudCapacity(get_profile("ibm"), 4)
    assert c.replica_cost_hr == pytest.approx(1.4)
    c2 = CloudCapacity(get_profile("ibm"), 4, 9.0)
    assert c2.replica_cost_hr == 9.0


# -- split replan round-trip (ISSUE 3 satellite) ------------------------------

def test_replan_round_trip_under_split_assignments():
    """plan -> run -> replan with splits: untrafficked models keep their
    reserved shares, every placed assignment's weights sum to 1, and the
    revised capacity map stays within the cloud budgets."""
    demands = [ModelDemand("busy", rate=5.0, service_time_s=0.01),
               ModelDemand("quiet", rate=60.0, service_time_s=0.1)]
    clouds = _clouds(gcp=(5, 1.0), ibm=(8, 1.4))
    plan = plan_placement(demands, clouds, objective="cost", split=True)
    assert plan.feasible
    quiet0 = next(a for a in plan.assignments if a.model == "quiet")
    assert len(quiet0.shares) == 2       # the big model genuinely splits

    gw = Gateway(capacity=plan.capacity_map())
    gw.deploy("busy", AnalyticBackend("busy", base_s=0.01),
              get_profile("gcp"),
              autoscaler=AutoscalerConfig(min_replicas=1, max_replicas=2,
                                          idle_window_s=math.inf))
    gw.deploy("quiet", AnalyticBackend("quiet", base_s=0.01),
              split={get_profile(c): w for c, w in quiet0.weights.items()},
              autoscaler=AutoscalerConfig(min_replicas=1, max_replicas=2,
                                          idle_window_s=math.inf))
    out = gw.run([TrafficSpec("busy", 300, arrival="poisson", rate=150.0)],
                 seed=0)
    assert "quiet" not in out.per_model  # untrafficked this window

    plan2 = replan(plan, out)
    assert plan2.split                   # split mode carries over
    by_model = {a.model: a for a in plan2.assignments}
    assert by_model["quiet"].shares == quiet0.shares
    assert by_model["quiet"].weights == quiet0.weights
    # observed busy load >> the estimate: replicas moved toward measurement
    obs = out.per_model["busy"].observed
    # n arrivals span n-1 gaps (ISSUE 4 bugfix): the measured rate must be
    # interval-based, not the n/window overestimate
    assert obs["rate_rps"] == pytest.approx(
        (obs["n"] - 1) / obs["window_s"])
    assert by_model["busy"].replicas == replicas_needed(
        ModelDemand("busy", obs["rate_rps"], obs["service_time_s"]))
    assert by_model["busy"].replicas > 1
    for a in plan2.assignments:
        if a.shares:
            assert abs(sum(a.weights.values()) - 1.0) < 1e-9
    cap_map = plan2.capacity_map()
    avail = {c.profile.name: c.max_replicas for c in clouds}
    assert all(cap_map[c] <= avail[c] for c in cap_map)


# -- simulated dollars --------------------------------------------------------

def test_cost_accounting_bills_provisioned_replica_seconds():
    gw = Gateway()
    gw.deploy("m", AnalyticBackend("m", base_s=0.05), get_profile("gcp"),
              autoscaler=warm_config(min_replicas=2, max_replicas=2),
              max_batch=8)
    out = gw.run([TrafficSpec("m", 64)], seed=0)
    res = out.per_model["m"]
    assert set(res.cost_by_cloud) == {"gcp"}
    # two always-on replicas billed to at least the last completion
    floor = 2 * out.makespan_s * get_profile("gcp").cost_per_s
    assert res.cost_usd >= floor - 1e-12
    assert out.costs["m"] == pytest.approx(res.cost_usd)
    assert out.total_cost_usd == pytest.approx(res.cost_usd)
    assert "sim_cost_usd" in out.summary()
    assert "sim_cost_usd" in res.summary()


def test_trailing_events_do_not_inflate_cost():
    """Regression: surviving replicas bill to the fleet's last completion,
    not to the last event -- an outage window on a cloud this deployment
    never touches must not change the bill."""
    def run_once(failures):
        gw = Gateway()
        gw.deploy("m", AnalyticBackend("m", base_s=0.01), get_profile("gcp"),
                  autoscaler=warm_config(), max_batch=8)
        return gw.run([TrafficSpec("m", 8)], seed=0, failures=failures)
    plain = run_once(None)
    late = run_once([FailureSpec("ibm", at_s=50.0, duration_s=50.0)])
    assert late.total_cost_usd == pytest.approx(plain.total_cost_usd)
    assert late.makespan_s == pytest.approx(plain.makespan_s)


def test_split_to_cheaper_cloud_costs_less():
    """Same fleet, same traffic: serving mostly from the cheaper price-sheet
    entry costs fewer simulated dollars than serving all-expensive."""
    def run_once(split):
        gw = Gateway()
        gw.deploy("m", AnalyticBackend("m", base_s=0.01), split=split,
                  autoscaler=warm_config(min_replicas=2, max_replicas=2),
                  max_batch=8)
        return gw.run([TrafficSpec("m", 200, arrival="poisson", rate=400.0)],
                      seed=5)
    cheap = run_once({get_profile("gcp"): 1.0})
    dear = run_once({get_profile("ibm"): 1.0})
    assert cheap.total_cost_usd < dear.total_cost_usd
