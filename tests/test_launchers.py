"""Launcher / registry / profile / report-layer tests."""
import json
import subprocess
import sys

import pytest

from repro.clouds.profiles import PROFILES, get_profile
from repro.configs import registry
from repro.launch import report


def test_registry_normalization_accepts_display_names():
    for alias in ("xlstm-1.3b", "zamba2-1.2b", "granite-moe-3b-a800m",
                  "deepseek-v2-lite-16b", "xlstm_1_3b"):
        cfg = registry.get_config(alias)
        assert cfg.n_layers > 0


def test_registry_unknown_arch_raises():
    with pytest.raises(KeyError, match="unknown arch"):
        registry.get_config("gpt-17")


def test_all_archs_have_smoke_and_full():
    for arch in registry.list_archs():
        full = registry.get_config(arch)
        smoke = registry.get_smoke_config(arch)
        assert smoke.family == full.family
        assert smoke.n_layers <= 4
        assert smoke.d_model <= 512


def test_input_shapes_match_assignment():
    s = registry.INPUT_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)
    assert s["decode_32k"].kind == "decode" and s["long_500k"].kind == "decode"


def test_cloud_profiles_cover_paper_platforms():
    assert set(PROFILES) == {"gcp", "ibm", "baremetal", "k8s"}
    gcp, ibm = get_profile("gcp"), get_profile("ibm")
    assert ibm.network_rtt_s < gcp.network_rtt_s      # paper §7(1)
    assert ibm.startup_s > gcp.startup_s              # paper §7(2)
    assert gcp.hardware.peak_flops_bf16 == 197e12
    assert gcp.hardware.hbm_bw == 819e9
    assert gcp.hardware.ici_bw == 50e9


def test_report_tables_from_records(tmp_path):
    rec = {"arch": "a", "shape": "train_4k", "mesh": "single", "status": "ok",
           "chips": 256, "lower_s": 1.0, "compile_s": 2.0,
           "roofline": {"compute_s": 1.0, "memory_s": 2.0, "collective_s": 0.5,
                        "bound_s": 2.0, "dominant": "memory", "flops": 1e12,
                        "bytes_accessed": 1e12, "coll_bytes": 1e10, "chips": 256},
           "useful_flops_ratio": 0.5,
           "collectives": {"per_kind_counts": {"all-reduce": 3}}}
    skip = {"arch": "b", "shape": "long_500k", "mesh": "single",
            "status": "skipped", "reason": "pure full-attention arch"}
    (tmp_path / "a_train_4k_single.json").write_text(json.dumps(rec))
    (tmp_path / "b_long_500k_single.json").write_text(json.dumps(skip))
    recs = report.load(str(tmp_path), "single")
    assert len(recs) == 2
    table = report.roofline_table(recs)
    assert "**memory**" in table and "skipped" in table
    dtable = report.dryrun_table(recs)
    assert "256" in dtable


def test_mesh_shapes():
    from repro.launch import mesh as mesh_mod
    import jax
    m = mesh_mod.make_local_mesh()
    assert m.axis_names == ("data", "model")
    assert m.size == len(jax.devices())


@pytest.mark.parametrize("cli", [
    ["-m", "repro.launch.serve", "--arch", "whisper-base", "--requests", "6",
     "--gen-tokens", "2", "--max-batch", "4"],
])
def test_serve_cli_end_to_end(cli):
    r = subprocess.run([sys.executable] + cli, capture_output=True, text=True,
                       timeout=900,
                       env={**__import__("os").environ, "PYTHONPATH": "src"},
                       cwd=__import__("os").path.dirname(
                           __import__("os").path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-500:]
    out = json.loads(r.stdout)
    assert out["n"] == 6
