"""Property-based invariants over random gateway fleets, traffic mixes,
failure injections, active-active splits, live migrations (ISSUE 2
archetype suite, extended to active-active by ISSUE 3) and queue-aware
routing + per-class admission control (ISSUE 4) -- with the observability
plane (tracer + metrics registry + burn-rate monitor, ISSUE 6) attached to
every scenario and reconciled against the simulation.

Nine invariants, checked over randomly drawn scenarios:

  1. every request completes EXACTLY once OR is shed exactly once (with a
     matching gateway:shed event), even when preemption, cloud failover
     and mid-run live migration re-queue in-flight batches;
     served + shed == offered, and ``batch``-class work is never shed;
  2. simulated time is monotonic per replica -- batches on one replica never
     overlap (a preempted batch ends at its preemption time);
  3. shared per-cloud capacity caps are never exceeded, except the
     documented scale-from-zero breach (gateway:capacity_exceeded);
  4. a fixed seed makes Gateway.run bit-for-bit deterministic (identical
     summary dict and event-name sequence on a rebuilt gateway) under
     BOTH routing policies and with admission control on or off;
  5. split weights always normalize to 1: every gateway:split event and the
     post-run final_weights map sum to 1 per model (0 only while every
     cloud of a deployment is down);
  6. shed bookkeeping is consistent: per-class shed counts match the
     event log, shed requests are excluded from latency percentiles, and
     with admission off nothing is ever shed;
  7. the span tree is well-formed (validate_trace) and complete: one
     gateway.run root, one gateway.request span per offered request
     ending served xor shed, and a served request's latency decomposes
     exactly into queue + preempted + rtt/lb + cold + service child time;
  8. the metric plane reconciles exactly: served + shed counters equal
     offered per model, per-class histogram counts equal the served
     samples, and sketch p50/p99 sit within the 1/sub relative-error
     bound of the exact rank statistic;
  9. burn-rate alert edges strictly alternate firing/resolved per
     (model, class), each firing bumps gateway_slo_alerts_total, and
     scrape timestamps are monotone with a final post-run scrape.

Determinism (invariant 4) now also covers the plane: byte-stable
EventLog.dump(), bit-identical trace JSON, identical Prometheus text.

The scenario space is described once (``scenario``) and driven two ways:
via hypothesis when it is installed (requirements-dev.txt; CI pins
--hypothesis-seed and the deadline-free "ci" profile from conftest.py) and
via a seeded numpy fallback that always runs, so the invariants are
exercised even on a machine without the dev deps.
"""
import math

import numpy as np
import pytest

from repro.clouds.profiles import get_profile
from repro.serving.gateway import (AdmissionConfig, AutoscalerConfig,
                                   FailureSpec, Gateway, MigrationSpec,
                                   ReplanConfig, RoutingConfig, TrafficSpec)
from repro.telemetry.analyze import request_breakdown, validate_trace
from repro.telemetry.events import EventLog
from repro.telemetry.metrics import MetricsRegistry
from repro.telemetry.slo import BurnRateConfig
from repro.telemetry.trace import Tracer

from conftest import AnalyticBackend

try:
    from hypothesis import given, strategies as hyp_st
    HAS_HYPOTHESIS = True
except ImportError:              # degrade to the seeded fallback only
    HAS_HYPOTHESIS = False

CLOUDS = ("gcp", "ibm")
SLOS = ("latency", "standard", "batch")



# -- scenario space ----------------------------------------------------------

def scenario(pick_int, pick_choice, pick_float):
    """One random-but-valid fleet + traffic + failure description as plain
    data, parameterized over the drawing primitives so hypothesis and the
    numpy fallback explore the same space."""
    models, traffic = [], []
    for i in range(pick_int(1, 3)):
        m = {"name": f"m{i}", "cloud": pick_choice(CLOUDS),
             "standby": pick_choice((True, False)),
             "split": pick_choice((None, 0.25, 0.5)),  # active-active share
             "min": pick_int(0, 1), "max": pick_int(1, 3),
             "tq": pick_choice((2, 8)),
             "idle": pick_choice((0.5, None)),    # None => never idles out
             "max_batch": pick_choice((2, 8)),
             "base_ms": pick_float(1.0, 20.0),
             "per_ms": pick_float(0.5, 2.0)}
        models.append(m)
        for _ in range(pick_int(1, 2)):
            traffic.append({"model": m["name"], "n": pick_int(3, 30),
                            "slo": pick_choice(SLOS),
                            "arrival": pick_choice(("burst", "poisson")),
                            "rate": pick_float(20.0, 500.0),
                            "start": pick_float(0.0, 1.5)})
    failure = None
    if pick_choice((True, False)):
        failure = {"cloud": pick_choice(CLOUDS),
                   "at": pick_float(0.05, 1.5),
                   "dur": pick_float(0.2, 1.0)}
    migration = None
    if pick_choice((True, False)):       # mid-run live re-split of one model
        migration = {"model": pick_int(0, len(models) - 1),
                     "at": pick_float(0.05, 1.5),
                     "frac": pick_float(0.0, 1.0)}
    capacity = {"gcp": 4, "ibm": 4} if pick_choice((True, False)) else None
    return {"models": models, "traffic": traffic, "failure": failure,
            "migration": migration,
            "replan": pick_choice((True, False)),
            "routing": pick_choice(("queue_aware", "weights")),
            "admission": pick_choice((None, 1.0, 1.5)),   # shed margin
            "slo_burn": pick_choice((None, 2.0, 6.0)),    # burn threshold
            "scrape": pick_choice((None, 0.25)),          # scrape period
            "capacity": capacity, "seed": pick_int(0, 2 ** 16)}


def build(p):
    gw = Gateway(capacity=p["capacity"], log=EventLog(), record_batches=True,
                 replan=(ReplanConfig(check_every_s=0.2, sustain=2)
                         if p["replan"] else None),
                 routing=RoutingConfig(policy=p["routing"]),
                 admission=(AdmissionConfig(margin=p["admission"])
                            if p["admission"] else None),
                 # the full observability plane rides every scenario: the
                 # invariants below reconcile it against the simulation
                 tracer=Tracer(), metrics=MetricsRegistry(),
                 slo_burn=(BurnRateConfig(threshold=p["slo_burn"])
                           if p["slo_burn"] else None),
                 scrape_every_s=p["scrape"])
    for m in p["models"]:
        other = CLOUDS[1 - CLOUDS.index(m["cloud"])]
        backend = AnalyticBackend(m["name"], m["base_ms"] / 1e3,
                                  m["per_ms"] / 1e3)
        kw = dict(
            autoscaler=AutoscalerConfig(
                min_replicas=m["min"],
                max_replicas=max(m["max"], m["min"]),
                target_queue=m["tq"],
                idle_window_s=math.inf if m["idle"] is None else m["idle"]),
            max_batch=m["max_batch"])
        if m["split"] is not None:       # active-active over both clouds
            gw.deploy(m["name"], backend,
                      split={get_profile(m["cloud"]): 1.0 - m["split"],
                             get_profile(other): m["split"]}, **kw)
        else:
            gw.deploy(m["name"], backend, get_profile(m["cloud"]),
                      standby=get_profile(other) if m["standby"] else None,
                      **kw)
    traffic = [TrafficSpec(t["model"], t["n"], arrival=t["arrival"],
                           rate=t["rate"], start_s=t["start"], slo=t["slo"])
               for t in p["traffic"]]
    failures = ([FailureSpec(p["failure"]["cloud"], p["failure"]["at"],
                             p["failure"]["dur"])]
                if p["failure"] else [])
    migrations = []
    if p["migration"]:
        mi = p["migration"]
        f = mi["frac"]
        migrations.append(MigrationSpec(mi["at"], {
            p["models"][mi["model"]]["name"]:
                {"gcp": f, "ibm": 1.0 - f}}))
    return gw, traffic, failures, migrations


# -- the invariants ----------------------------------------------------------

def run_and_check(p):
    gw, traffic, failures, migrations = build(p)
    out = gw.run(traffic, seed=p["seed"], failures=failures,
                 migrations=migrations)

    want = {}
    for t in p["traffic"]:
        want[t["model"]] = want.get(t["model"], 0) + t["n"]

    # 1. + 6. every request completes exactly once OR is shed exactly once
    #    (matching gateway:shed event); served + shed == offered; batch
    #    never shed; shed excluded from percentiles but reported
    for m, n in want.items():
        res = out.per_model[m]
        assert res.n_requests == n
        shed_idx = sorted(e["idx"] for e in gw.log.named("gateway:shed")
                          if e["model"] == m)
        assert len(shed_idx) == len(set(shed_idx)), "shed more than once"
        if p["admission"] is None:
            assert shed_idx == [] and res.shed_total == 0
        assert res.shed_total == len(shed_idx)
        assert sum(res.class_shed.values()) == len(shed_idx)
        assert res.class_shed.get("batch", 0) == 0, "batch must defer"
        by_cls = {}
        for e in gw.log.named("gateway:shed"):
            if e["model"] == m:
                by_cls[e["cls"]] = by_cls.get(e["cls"], 0) + 1
        assert by_cls == res.class_shed
        assert len(res.latencies_s) == n - len(shed_idx)
        assert all(l > 0 for l in res.latencies_s)
        assert sum(res.per_version.values()) == n - len(shed_idx)
        served = sorted(i for rec in gw.batch_log
                        if rec["model"] == m and not rec["preempted"]
                        for i in rec["idx"])
        assert sorted(served + shed_idx) == list(range(n)), \
            f"{m}: served {served} shed {shed_idx}"
        pc = res.per_class()
        assert sum(st["shed"] for st in pc.values()) == len(shed_idx)
        assert sum(st["n"] for st in pc.values()) == n - len(shed_idx)

    # 2. monotonic per-replica time: completed and preempted batches on one
    #    replica never overlap
    by_replica = {}
    for rec in gw.batch_log:
        by_replica.setdefault((rec["model"], rec["rid"]), []).append(rec)
    for key, recs in by_replica.items():
        recs.sort(key=lambda r: r["start_s"])
        for a, b in zip(recs, recs[1:]):
            assert a["end_s"] >= a["start_s"] - 1e-9, (key, a)
            assert b["start_s"] >= a["end_s"] - 1e-9, (key, a, b)

    # 3. capacity caps hold except the documented scale-from-zero breach
    if p["capacity"]:
        breached = {e["cloud"]
                    for e in gw.log.named("gateway:capacity_exceeded")}
        for t, cloud, usage in gw.usage_trace:
            cap = p["capacity"].get(cloud)
            if cap is not None and cloud not in breached:
                assert usage <= cap, (t, cloud, usage, cap)

    # 4. makespan covers every completion
    assert out.makespan_s >= max(
        r.total_time_s for r in out.per_model.values()) - 1e-9

    # 5. split weights normalize to 1 (0 only while every cloud is down)
    for e in gw.log.named("gateway:split"):
        tot = sum(e["weights"].values())
        assert abs(tot - 1.0) < 1e-4 or tot == 0.0, e
    for m, w in gw.final_weights.items():
        tot = sum(w.values())
        assert abs(tot - 1.0) < 1e-4, (m, w)   # outages all end in-scenario

    # simulated dollars exist and add up for every deployed model
    assert set(gw.final_weights) == set(out.costs)
    assert all(c >= 0.0 for c in out.costs.values())
    assert abs(out.total_cost_usd - sum(out.costs.values())) < 1e-12

    # 7. (ISSUE 6) the trace is well-formed and complete: unique ids,
    #    acyclic parent edges, every child interval nested in its parent,
    #    no open spans; ONE gateway.run root; exactly one gateway.request
    #    span per offered request, each ending served xor shed; a served
    #    request's latency decomposes exactly into its child spans
    tr, reg = gw.tracer, gw.metrics
    violations = validate_trace(tr)
    assert violations == [], violations
    assert len(tr.named("gateway.run")) == 1
    req_spans: dict = {}
    for sp in tr.named("gateway.request"):
        req_spans.setdefault(sp.attrs["model"], []).append(sp)
    rows = {(r["model"], r["idx"]): r for r in request_breakdown(tr)}
    for m, n in want.items():
        res = out.per_model[m]
        spans = req_spans.get(m, [])
        assert len(spans) == n
        outcomes = [sp.attrs["outcome"] for sp in spans]
        assert all(o in ("served", "shed") for o in outcomes)
        assert sum(o == "shed" for o in outcomes) == res.shed_total
        for sp in spans:
            if sp.attrs["outcome"] != "served":
                continue
            row = rows[(m, sp.attrs["idx"])]
            parts = (row["queue_s"] + row["preempted_s"] + row["rtt_lb_s"]
                     + row["cold_s"] + row["service_s"])
            assert abs(parts - row["total_s"]) < 1e-6 + 1e-6 * row["total_s"], row

        # 8. the metric plane reconciles EXACTLY with the event log and the
        #    result: served + shed counters == offered, per-class histogram
        #    counts == served samples, and every sketch quantile is within
        #    its 1/sub relative-error bound of the exact rank statistic
        n_shed = res.shed_total
        assert reg.total("gateway_requests_total", model=m,
                         outcome="served") == n - n_shed
        assert reg.total("gateway_requests_total", model=m,
                         outcome="shed") == n_shed
        for cname, ls in res.class_latencies.items():
            snap = reg.value("gateway_request_latency_seconds",
                             model=m, cls=cname)
            if not ls:
                assert snap is None or snap["n"] == 0
                continue
            assert snap["n"] == len(ls)
            assert abs(snap["sum"] - sum(ls)) <= 1e-9 * max(sum(ls), 1.0)
            xs = sorted(ls)
            for q, got in ((0.5, snap["p50"]), (0.99, snap["p99"])):
                exact = xs[max(math.ceil(q * len(xs)), 1) - 1]
                assert abs(got - exact) <= exact / reg.sub + 1e-12, \
                    (m, cname, q, got, exact)

    # 9. burn-rate alerts are edge-consistent: firing/resolved strictly
    #    alternate per (model, cls), every firing edge bumped the alert
    #    counter, and without a monitor there are no alert events
    edges: dict = {}
    for e in gw.log.named("gateway:alert"):
        edges.setdefault((e["model"], e["cls"]), []).append(e["state"])
    if p["slo_burn"] is None:
        assert edges == {}
    for (m, cname), states in edges.items():
        assert all(s == ("firing" if i % 2 == 0 else "resolved")
                   for i, s in enumerate(states)), states
        assert reg.total("gateway_slo_alerts_total", model=m, cls=cname) \
            == sum(s == "firing" for s in states)
    if p["scrape"]:
        ts = [s["t_sim"] for s in reg.scrapes]
        assert ts == sorted(ts) and len(ts) >= 1
        assert reg.scrapes[-1]["t_sim"] >= out.makespan_s - 1e-9
    return out


def run_twice_and_compare(p):
    """Invariant 4: seed => bit-for-bit determinism on a rebuilt gateway."""
    gw1, tr1, f1, m1 = build(p)
    out1 = gw1.run(tr1, seed=p["seed"], failures=f1, migrations=m1)
    gw2, tr2, f2, m2 = build(p)
    out2 = gw2.run(tr2, seed=p["seed"], failures=f2, migrations=m2)
    assert out1.summary() == out2.summary()
    assert gw1.final_weights == gw2.final_weights
    assert ([e["name"] for e in gw1.log.events]
            == [e["name"] for e in gw2.log.events])
    # ISSUE 6: the whole observability plane is seed-deterministic too --
    # byte-stable event dump (wall fields stripped), bit-identical span
    # tree, identical Prometheus exposition
    assert gw1.log.dump() == gw2.log.dump()
    assert gw1.tracer.to_json() == gw2.tracer.to_json()
    assert gw1.metrics.to_prometheus() == gw2.metrics.to_prometheus()


# -- hypothesis driver (requirements-dev.txt) --------------------------------

if HAS_HYPOTHESIS:
    @hyp_st.composite
    def scenarios(draw):
        return scenario(
            lambda lo, hi: draw(hyp_st.integers(lo, hi)),
            lambda seq: draw(hyp_st.sampled_from(list(seq))),
            lambda lo, hi: draw(hyp_st.floats(lo, hi, allow_nan=False,
                                              allow_infinity=False)))

    @given(scenarios())
    def test_fleet_invariants(params):
        run_and_check(params)

    @given(scenarios())
    def test_seed_makes_run_deterministic(params):
        run_twice_and_compare(params)
else:                            # visible skips instead of silent absence
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(pip install -r requirements-dev.txt)")
    def test_fleet_invariants():
        pass

    @pytest.mark.skip(reason="hypothesis not installed "
                             "(pip install -r requirements-dev.txt)")
    def test_seed_makes_run_deterministic():
        pass


# -- seeded numpy fallback (always runs) -------------------------------------

def params_from_seed(seed):
    rng = np.random.default_rng(seed)
    return scenario(lambda lo, hi: int(rng.integers(lo, hi + 1)),
                    lambda seq: seq[int(rng.integers(len(seq)))],
                    lambda lo, hi: float(rng.uniform(lo, hi)))


@pytest.mark.parametrize("seed", range(30))
def test_fleet_invariants_seeded(seed):
    run_and_check(params_from_seed(seed))


@pytest.mark.parametrize("seed", range(8))
def test_seed_makes_run_deterministic_seeded(seed):
    run_twice_and_compare(params_from_seed(seed + 1000))
