"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssm_scan import ssm_scan

KEY = jax.random.PRNGKey(0)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("shape", [(4, 64), (3, 17, 128), (2, 5, 7, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    scale = (jax.random.normal(jax.random.PRNGKey(1), shape[-1:]) * 0.1).astype(dtype)
    got = rmsnorm(x, scale, block_rows=8)
    want = ref.rmsnorm_ref(x, scale)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("sq,skv,hq,hkv,d,window", [
    (64, 64, 4, 4, 32, 0),        # MHA causal
    (100, 100, 4, 2, 32, 0),      # GQA, non-divisible seq
    (64, 64, 8, 2, 64, 24),       # sliding window
    (33, 128, 4, 4, 32, 0),       # cross-length (q_offset prefill tail)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(sq, skv, hq, hkv, d, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, sq, hq, d), dtype)
    k = jax.random.normal(ks[1], (2, skv, hkv, d), dtype)
    v = jax.random.normal(ks[2], (2, skv, hkv, d), dtype)
    off = skv - sq
    got = flash_attention(q, k, v, causal=True, window=window, q_offset=off,
                          block_q=32, block_k=32)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window, q_offset=off)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("s,hq,hkv,d,block_k", [
    (128, 4, 4, 32, 64), (200, 8, 2, 64, 64), (64, 4, 1, 128, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(s, hq, hkv, d, block_k, dtype):
    ks = jax.random.split(KEY, 3)
    B = 3
    q = jax.random.normal(ks[0], (B, hq, d), dtype)
    kc = jax.random.normal(ks[1], (B, s, hkv, d), dtype)
    vc = jax.random.normal(ks[2], (B, s, hkv, d), dtype)
    lens = jnp.array([s, s // 2, 1], jnp.int32)
    got = decode_attention(q, kc, vc, lens, block_k=block_k)
    want = ref.decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("s,h,p,n,chunk", [
    (64, 2, 8, 16, 16), (96, 3, 16, 8, 32), (50, 1, 4, 4, 16),
])
def test_ssm_scan_kernel_matches_sequential_oracle(s, h, p, n, chunk):
    ks = jax.random.split(KEY, 5)
    B = 2
    x = jax.random.normal(ks[0], (B, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, s, h)))
    A = -jnp.abs(jax.random.normal(ks[2], (h,))) * 4
    Bm = jax.random.normal(ks[3], (B, s, n)) * 0.3
    Cm = jax.random.normal(ks[4], (B, s, n)) * 0.3
    y_ref, h_ref = ref.ssm_scan_ref(x, dt, A, Bm, Cm)
    y_k, h_k = ssm_scan(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(y_k, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h_k, h_ref, rtol=2e-4, atol=2e-4)
    # chunked-jnp twin agrees too (the default model path)
    y_j, h_j = ops.ssd_chunked_jnp(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(y_j, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h_j, h_ref, rtol=2e-4, atol=2e-4)


def test_ssm_scan_extreme_decay_no_nan():
    """The masked-exponent regression: strong decay must not overflow."""
    ks = jax.random.split(KEY, 5)
    B, s, h, p, n = 1, 32, 4, 8, 16
    x = jax.random.normal(ks[0], (B, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, s, h)) + 2)
    A = -jnp.linspace(1.0, 16.0, h)
    Bm = jax.random.normal(ks[3], (B, s, n))
    Cm = jax.random.normal(ks[4], (B, s, n))
    for fn in (lambda: ssm_scan(x, dt, A, Bm, Cm, chunk=16)[0],
               lambda: ops.ssd_chunked_jnp(x, dt, A, Bm, Cm, chunk=16)[0]):
        assert not np.isnan(np.asarray(fn())).any()


@pytest.mark.parametrize("sq,skv,hq,hkv,window,off", [
    (64, 64, 4, 2, 0, 0), (100, 100, 8, 2, 24, 0), (33, 128, 4, 4, 0, 95),
])
def test_flash_chunked_jnp_matches_ref(sq, skv, hq, hkv, window, off):
    """The 'fused attention' jnp twin (perf-variant model path)."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, sq, hq, 32))
    k = jax.random.normal(ks[1], (2, skv, hkv, 32))
    v = jax.random.normal(ks[2], (2, skv, hkv, 32))
    got = ops.flash_chunked_jnp(q, k, v, causal=True, window=window,
                                q_offset=off, chunk_k=32)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window,
                                   q_offset=off)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_ops_dispatch_kernel_vs_ref_paths_agree():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32))
    k = jax.random.normal(ks[1], (1, 64, 2, 32))
    v = jax.random.normal(ks[2], (1, 64, 2, 32))
    a = ops.flash_attention(q, k, v, use_kernel=True, block_q=32, block_k=32)
    b = ops.flash_attention(q, k, v, use_kernel=False)
    np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("s,h,d,chunk", [(37, 3, 8, 8), (64, 2, 16, 16),
                                         (50, 1, 32, 37)])
def test_mlstm_pallas_kernel_matches_sequential_oracle(s, h, d, chunk):
    from repro.kernels.mlstm_scan import mlstm_scan
    ks = jax.random.split(KEY, 5)
    B = 2
    q = jax.random.normal(ks[0], (B, s, h, d))
    k = jax.random.normal(ks[1], (B, s, h, d))
    v = jax.random.normal(ks[2], (B, s, h, d))
    logi = jax.random.normal(ks[3], (B, s, h)) * 0.5
    fpre = jax.random.normal(ks[4], (B, s, h)) + 2.0
    want = ref.mlstm_scan_ref(q, k, v, logi, fpre)
    got = mlstm_scan(q, k, v, logi, jax.nn.log_sigmoid(fpre), chunk=chunk)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_mlstm_forward_kernel_dispatch_matches_jnp():
    """cfg.use_kernels routes the mLSTM block through the Pallas kernel."""
    from repro.configs import registry
    from repro.models import ssm
    cfg = registry.get_smoke_config("xlstm_1_3b")
    p = ssm.mlstm_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model)) * 0.5
    a = ssm.mlstm_forward(p, x, cfg)
    b = ssm.mlstm_forward(p, x, cfg.replace(use_kernels=True))
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
