"""End-to-end system tests: the paper's E2E pipeline (Katib -> TFJob ->
KServe) on synthetic MNIST, plus the LM train job path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import ArtifactStore
from repro.clouds.profiles import get_profile
from repro.configs import registry
from repro.core.pipeline import Pipeline
from repro.core.trainjob import LMTrainJob, SupervisedTrainJob
from repro.data.mnist import Batches
from repro.models import lenet
from repro.serving.kserve import InferenceService, Predictor
from repro.tuning import katib


@pytest.fixture(scope="module")
def small_mnist():
    from repro.data.mnist import make_dataset
    return make_dataset(192, seed=0)


def test_e2e_mnist_pipeline(tmp_path, small_mnist):
    """The paper's §5.3 pipeline: tune -> train (best params) -> serve."""
    imgs, labels = small_mnist
    store = ArtifactStore(str(tmp_path))
    pipe = Pipeline("e2e-mnist", store)

    def tune_stage():
        def objective(params, report):
            job = SupervisedTrainJob(lr=params["lr"], n_steps=8, width=8)
            res = job.run(Batches(imgs, labels, 64), report=report)
            return {"loss": res["loss"]}
        exp = katib.tune(objective, {"lr": katib.Double(1e-4, 1e-2, log=True)},
                         algorithm="random", max_trials=2, store=store)
        return exp.best_trial().params

    def train_stage(best):
        job = SupervisedTrainJob(lr=best["lr"], n_steps=25, width=8, store=store)
        res = job.run(Batches(imgs, labels, 64), checkpoint_name="e2e-model")
        return {"loss": res["loss"], "accuracy": res["accuracy"],
                "params": res["params"]}

    def serve_stage(trained):
        params = trained["params"]
        predict = jax.jit(lambda x: jnp.argmax(lenet.apply(params, x), -1))
        pred = Predictor("e2e", predict, imgs[:1])
        svc = InferenceService(pred, get_profile("gcp"), "kserve")
        return svc.stress_test(32).summary()

    t = pipe.step(tune_stage, cache=False)
    m = pipe.step(train_stage, t, cache=False)
    s = pipe.step(serve_stage, m, cache=False)
    out = pipe.run()
    assert out["train_stage"]["loss"] < 2.5
    assert out["serve_stage"]["n"] == 32
    # pipeline spec exports (the minikf yaml analog)
    spec = pipe.export_yaml(str(tmp_path / "pipeline.yaml"))
    assert "e2e-mnist" in spec
    # stage timings recorded for the Tables 4/5 benchmark
    names = [e["name"] for e in pipe.log.events]
    assert {"tune_stage", "train_stage", "serve_stage"} <= set(names)


def test_lm_trainjob_loss_decreases(tmp_path):
    cfg = registry.get_smoke_config("granite_3_8b")
    job = LMTrainJob(cfg, batch_size=4, seq_len=32, n_steps=12, lr=2e-3,
                     store=ArtifactStore(str(tmp_path)))
    res = job.run(checkpoint_name="lm-smoke")
    assert len(res["history"]) == 12
    assert res["history"][-1] < res["history"][0]
    assert "checkpoint" in res


def test_trainjob_checkpoint_roundtrip(tmp_path, small_mnist):
    imgs, labels = small_mnist
    store = ArtifactStore(str(tmp_path))
    job = SupervisedTrainJob(n_steps=5, store=store)
    res = job.run(Batches(imgs, labels, 64), checkpoint_name="rt")
    like = jax.tree_util.tree_map(lambda x: np.zeros_like(x), res["params"])
    restored = store.load_tree("rt", like)
    got = lenet.apply(restored, imgs[:4])
    want = lenet.apply(res["params"], imgs[:4])
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_lm_trainjob_resume_continues_from_checkpoint(tmp_path):
    """Preemption recovery: resume restores params + optimizer state."""
    from repro.checkpoint.store import tree_hash
    cfg = registry.get_smoke_config("h2o_danube_3_4b")
    store = ArtifactStore(str(tmp_path))
    j1 = LMTrainJob(cfg, batch_size=2, seq_len=32, n_steps=6, lr=2e-3, store=store)
    r1 = j1.run(checkpoint_name="resume-test")
    # resumed job starts from r1's weights (not fresh init)
    j2 = LMTrainJob(cfg, batch_size=2, seq_len=32, n_steps=3, lr=2e-3, store=store)
    r2 = j2.run(resume_from="resume-test")
    j3 = LMTrainJob(cfg, batch_size=2, seq_len=32, n_steps=3, lr=2e-3, store=store)
    r3 = j3.run()  # fresh
    assert abs(r2["history"][0] - r3["history"][0]) > 1e-6  # different starts
    assert r2["history"][0] < r3["history"][0]              # warm start is better
