"""Prefill/decode disaggregation in the gateway (ISSUE 8 tentpole).

Covers the serving-stack surface above the batched-prefill kernel path
(tests/test_prefill_oracle.py pins the kernels themselves):

  * DisaggSpec / deploy() validation: pool kinds must name declared
    clouds, staged mode forbids "both" pools and requires a weighted
    prefill AND decode pool;
  * staged two-stage pipeline: every request is dispatched exactly once
    per stage, ``gateway:prefill`` fires per prefill batch, latency is
    charged once at decode completion and covers both phases, the KV
    ledger drains to zero, and scalar/vector engines stay bit-identical
    (EventLog.dump equality -- the ISSUE 7 equivalence rule extended);
  * unified (both-kind) disagg is a pure annotation: same served/shed
    outcome and latencies as an identical non-disagg deployment, plus
    per-dispatch ``gateway:prefill`` cost attribution;
  * cache-exhaustion shedding: a tiny block budget sheds sheddable
    classes with ``gateway:cache_shed`` paired to ``gateway:shed``
    (at="cache") while batch-class work is never dropped;
  * BatcherBackend's measured two-phase cost model over a real
    ContinuousBatcher, and the ModelDemand prefill/decode split in the
    placement planner.
"""
import math

import pytest

from repro.clouds.profiles import get_profile
from repro.serving.gateway import (AutoscalerConfig, BatcherBackend,
                                   DisaggSpec, Gateway, ModelDemand,
                                   RoutingConfig, TrafficSpec, est_p99_s,
                                   est_wait_s, plan_placement, replicas_needed,
                                   CloudCapacity)
from repro.telemetry.events import EventLog

from conftest import AnalyticBackend

GCP, IBM = get_profile("gcp"), get_profile("ibm")


def _staged_gateway(*, kv_blocks=256, shed_margin=1.0, routing="queue_aware",
                    admission=None, n=14, seed=3, engine="vector",
                    slo="standard"):
    gw = Gateway(log=EventLog(), record_batches=True,
                 routing=RoutingConfig(policy=routing), admission=admission)
    gw.deploy("llm", AnalyticBackend("llm", 0.02, 0.005),
              split={GCP: 0.5, IBM: 0.5},
              autoscaler=AutoscalerConfig(min_replicas=1, max_replicas=2),
              max_batch=4,
              disagg=DisaggSpec(kv_blocks=kv_blocks, block_size=16,
                                prompt_tokens=64, gen_tokens=16,
                                shed_margin=shed_margin,
                                pool_kind={"gcp": "prefill",
                                           "ibm": "decode"}))
    traffic = [TrafficSpec("llm", n, arrival="poisson", rate=100.0, slo=slo)]
    out = gw.run(traffic, seed=seed, engine=engine)
    return gw, out


# -- spec / deploy validation ------------------------------------------------

def test_disagg_spec_validation():
    assert DisaggSpec(prompt_tokens=64, gen_tokens=16,
                      block_size=16).blocks_per_request == 5
    assert DisaggSpec(prompt_tokens=0, gen_tokens=1,
                      block_size=16).blocks_per_request == 1
    spec = DisaggSpec(kv_blocks={"gcp": 32}, pool_kind={"gcp": "prefill"})
    assert spec.blocks_for("gcp") == 32 and spec.blocks_for("ibm") == 0
    assert spec.kind("gcp") == "prefill" and spec.kind("ibm") == "both"
    with pytest.raises(ValueError):
        DisaggSpec(block_size=0)
    with pytest.raises(ValueError):
        DisaggSpec(gen_tokens=0)
    with pytest.raises(ValueError):
        DisaggSpec(shed_margin=0.0)


def test_deploy_validation():
    def fresh():
        return Gateway(log=EventLog())

    be = AnalyticBackend("m", 0.01)
    kw = dict(autoscaler=AutoscalerConfig(max_replicas=1))
    with pytest.raises(ValueError, match="not in the placement"):
        fresh().deploy("m", be, GCP, disagg=DisaggSpec(
            pool_kind={"aws": "prefill"}), **kw)
    with pytest.raises(ValueError, match="pool_kind"):
        fresh().deploy("m", be, GCP, disagg=DisaggSpec(
            pool_kind={"gcp": "turbo"}), **kw)
    # staged mode forbids mixing in a unified pool (the zero-weight standby
    # defaults to "both" and must be assigned a side too)...
    with pytest.raises(ValueError, match="both"):
        fresh().deploy("m", be, split={GCP: 0.4, IBM: 0.6},
                       standby=get_profile("baremetal"),
                       disagg=DisaggSpec(pool_kind={"gcp": "prefill",
                                                    "ibm": "decode"}), **kw)
    # ...and each stage needs a pool that actually takes traffic
    with pytest.raises(ValueError, match="decode"):
        fresh().deploy("m", be, GCP,
                       disagg=DisaggSpec(pool_kind={"gcp": "prefill"}), **kw)
    with pytest.raises(ValueError, match="prefill"):
        fresh().deploy("m", be, split={GCP: 1.0, IBM: 0.0},
                       disagg=DisaggSpec(pool_kind={"gcp": "decode",
                                                    "ibm": "prefill"}), **kw)


# -- staged pipeline ---------------------------------------------------------

def test_staged_pipeline_two_dispatches_per_request():
    gw, out = _staged_gateway()
    res = out.per_model["llm"]
    n = res.n_requests
    assert res.shed_total == 0
    recs = [r for r in gw.batch_log if not r["preempted"]]
    by_stage = {"prefill": [], "decode": []}
    for r in recs:
        by_stage[r["stage"]].extend(r["idx"])
    # exactly once per stage, and only on the pool of that kind
    assert sorted(by_stage["prefill"]) == list(range(n))
    assert sorted(by_stage["decode"]) == list(range(n))
    assert {r["cloud"] for r in recs if r["stage"] == "prefill"} == {"gcp"}
    assert {r["cloud"] for r in recs if r["stage"] == "decode"} == {"ibm"}
    # latency charged once, at decode completion, covering both phases
    assert len(res.latencies_s) == n and all(l > 0 for l in res.latencies_s)
    for i in range(n):
        dec = [r for r in recs if r["stage"] == "decode" and i in r["idx"]]
        pre = [r for r in recs if r["stage"] == "prefill" and i in r["idx"]]
        assert dec[0]["start_s"] >= pre[0]["end_s"] - 1e-9, \
            "decode dispatched before its prefill landed"
    # one staged gateway:prefill event per prefill batch, n requests total
    pf = gw.log.named("gateway:prefill")
    assert len(pf) == sum(1 for r in recs if r["stage"] == "prefill")
    assert all(e["staged"] for e in pf)
    assert sum(e["n"] for e in pf) == n
    # the KV ledger drains: blocks are held dispatch -> free per phase
    assert gw.final_kv == {"llm": {"gcp": 0, "ibm": 0}}


def test_staged_engines_bit_identical():
    a = _staged_gateway(engine="scalar")[0].log.dump()
    b = _staged_gateway(engine="vector")[0].log.dump()
    assert a == b


def test_staged_deterministic():
    a = _staged_gateway(seed=11)[0].log.dump()
    b = _staged_gateway(seed=11)[0].log.dump()
    assert a == b


# -- unified (both-kind) disagg ----------------------------------------------

def test_unified_disagg_is_pure_annotation():
    def run(disagg):
        gw = Gateway(log=EventLog(), record_batches=True)
        gw.deploy("m", AnalyticBackend("m", 0.02, 0.005), GCP,
                  autoscaler=AutoscalerConfig(min_replicas=1, max_replicas=2),
                  max_batch=4, disagg=disagg)
        out = gw.run([TrafficSpec("m", 12, arrival="poisson", rate=150.0)],
                     seed=5)
        return gw, out.per_model["m"]

    gw_d, res_d = run(DisaggSpec(kv_blocks=10_000))
    gw_p, res_p = run(None)
    assert res_d.latencies_s == res_p.latencies_s
    assert res_d.shed_total == res_p.shed_total == 0
    # cost attribution rides along: one unstaged prefill event per dispatch
    pf = gw_d.log.named("gateway:prefill")
    n_batches = sum(1 for r in gw_d.batch_log if not r["preempted"])
    assert len(pf) == n_batches and not any(e["staged"] for e in pf)
    assert all(e["duration_s"] > 0 for e in pf)
    assert not gw_p.log.named("gateway:prefill")


# -- cache-exhaustion shedding -----------------------------------------------

def test_cache_exhaustion_sheds():
    # blocks_per_request = ceil(80/16) = 5; 6 blocks hold ONE request per
    # pool, so a poisson burst must shed on projected exhaustion
    gw, out = _staged_gateway(kv_blocks=6, n=16, slo="standard")
    res = out.per_model["llm"]
    assert res.shed_total > 0
    assert res.shed_total + len(res.latencies_s) == res.n_requests
    cache = gw.log.named("gateway:cache_shed")
    sheds = [e for e in gw.log.named("gateway:shed") if e["at"] == "cache"]
    assert len(cache) == len(sheds) == res.shed_total
    assert sorted(e["idx"] for e in cache) == sorted(e["idx"] for e in sheds)
    for e in cache:
        assert e["kv_projected"] > e["kv_total"] >= e["kv_used"]


def test_cache_shed_never_touches_batch_class():
    gw, out = _staged_gateway(kv_blocks=6, n=16, slo="batch")
    res = out.per_model["llm"]
    assert res.shed_total == 0 and len(res.latencies_s) == res.n_requests


def test_big_budget_never_cache_sheds():
    gw, out = _staged_gateway(kv_blocks=100_000, n=16)
    assert out.per_model["llm"].shed_total == 0
    assert not gw.log.named("gateway:cache_shed")


# -- measured two-phase cost model -------------------------------------------

def test_batcher_backend_cost_split():
    import jax
    from repro.configs import registry
    from repro.models import lm
    from repro.serving.continuous import ContinuousBatcher

    cfg = registry.get_smoke_config("h2o_danube_3_4b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    b = ContinuousBatcher(cfg, params, max_slots=2, max_len=64,
                          prefill_chunk=8)
    be = BatcherBackend("llm", b, prompt_len=16, gen_tokens=4)
    assert be.disaggregated
    pf, dec = be.prefill_time(16), be.decode_time(4)
    assert pf > 0 and dec > 0
    # chunked ingest of a 16-token prompt is 2 prefill calls; the
    # teacher-forced equivalent would be 16 decode steps
    assert be.prefill_time(16) == 2 * be.prefill_time(8)
    assert be.decode_time(8) == 2 * be.decode_time(4)
    assert be.service_time(1) == pytest.approx(pf + dec)
    assert be.service_time(2) == pytest.approx(2 * pf + dec)
    assert be.service_time(3) == pytest.approx(3 * pf + 2 * dec)


def test_batcher_backend_blended_fallback():
    class FakeBatcher:
        prefill_chunk = 0
        max_slots = 2
        step_count = 0

        def submit(self, prompt, max_new):
            self._work = len(prompt) + max_new

        def run(self):
            self.step_count += self._work
            return []

    be = BatcherBackend("m", FakeBatcher(), prompt_len=8, gen_tokens=4)
    assert not be.disaggregated
    # blended: prefill is priced as P teacher-forced steps
    assert be.prefill_time(8) == pytest.approx(8 * be.decode_time(1))


# -- placement demand split --------------------------------------------------

def test_model_demand_split():
    blended = ModelDemand("m", rate=10.0, service_time_s=0.3)
    split = ModelDemand("m", rate=10.0, service_time_s=0.3,
                        prefill_s=0.2, decode_s=0.1)
    assert blended.load == pytest.approx(split.load) == pytest.approx(3.0)
    assert split.prefill_load == pytest.approx(2.0)
    assert split.decode_load == pytest.approx(1.0)
    assert blended.prefill_load == 0.0
    assert blended.decode_load == pytest.approx(blended.load)
    # a heavier split raises the effective load the planner sizes against
    heavy = ModelDemand("m", rate=10.0, service_time_s=0.3,
                        prefill_s=0.4, decode_s=0.2)
    assert heavy.load > blended.load
    assert replicas_needed(heavy) >= replicas_needed(blended)
    assert est_wait_s(heavy, 12) > est_wait_s(blended, 12)
    assert est_p99_s(GCP, heavy, 12) > est_p99_s(GCP, blended, 12)


def test_plan_placement_with_split_demand():
    clouds = [CloudCapacity(GCP, 4), CloudCapacity(IBM, 4)]
    # need 6 replicas: no single cloud fits, the split path must engage
    # (and carry the prefill/decode split into each share's estimates)
    plan = plan_placement(
        [ModelDemand("llm", rate=8.0, service_time_s=0.5,
                     prefill_s=0.35, decode_s=0.15)], clouds, split=True)
    a = plan.assignments[0]
    assert sum(a.shares.values()) >= replicas_needed(
        ModelDemand("llm", 8.0, 0.5))
    assert abs(sum(a.weights.values()) - 1.0) < 1e-9
    assert math.isfinite(a.est_p99_s)
