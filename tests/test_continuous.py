"""Continuous-batching decode engine: slot reuse, admission, isolation,
and agreement with the plain batched decode path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import lm, steps
from repro.serving.continuous import ContinuousBatcher


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get_smoke_config("h2o_danube_3_4b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reference_generate(cfg, params, prompt, n_new, cache_len=64):
    """Plain prefill + greedy loop on a batch of one."""
    batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
    last, cache = steps.prefill(params, batch, cfg=cfg, cache_len=cache_len)
    tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
    start = jnp.full((1,), len(prompt), jnp.int32)
    toks, _ = steps.greedy_decode_loop(params, cache, tok, start, n_new - 1,
                                       cfg=cfg)
    return [int(tok[0, 0])] + [int(t) for t in np.asarray(toks)[0]]


def test_single_request_matches_reference(setup):
    cfg, params = setup
    prompt = [5, 17, 99, 3]
    want = _reference_generate(cfg, params, prompt, 6)
    cb = ContinuousBatcher(cfg, params, max_slots=2, max_len=64)
    req = cb.submit(prompt, max_new=6)
    cb.run()
    assert req.done
    assert req.output == want, (req.output, want)


def test_concurrent_requests_are_isolated(setup):
    """Each request's output must equal its solo run (no cross-slot leaks)."""
    cfg, params = setup
    prompts = [[5, 17, 99, 3], [200, 41], [7, 7, 7, 7, 7, 7]]
    solo = [_reference_generate(cfg, params, p, 5) for p in prompts]
    cb = ContinuousBatcher(cfg, params, max_slots=3, max_len=64)
    reqs = [cb.submit(p, max_new=5) for p in prompts]
    cb.run()
    for r, want in zip(reqs, solo):
        assert r.done and r.output == want


def test_slot_reuse_more_requests_than_slots(setup):
    cfg, params = setup
    cb = ContinuousBatcher(cfg, params, max_slots=2, max_len=64)
    reqs = [cb.submit([i + 1, i + 2], max_new=3) for i in range(5)]
    done = cb.run()
    assert len(done) == 5
    assert all(len(r.output) == 3 for r in reqs)
    # later requests were admitted after earlier ones finished
    assert max(r.admitted_step for r in reqs) > 0


def test_slot_reuse_output_independent_of_previous_occupant(setup):
    """A prompt served after slot reuse equals its solo generation."""
    cfg, params = setup
    target = [42, 43, 44]
    want = _reference_generate(cfg, params, target, 4)
    cb = ContinuousBatcher(cfg, params, max_slots=1, max_len=64)
    cb.submit([9, 8, 7, 6, 5], max_new=4)   # previous occupant
    tgt = cb.submit(target, max_new=4)
    cb.run()
    assert tgt.output == want


def test_ssm_state_reset_on_admission():
    """Recurrent-state arch: slot reuse must not inherit the carry."""
    cfg = registry.get_smoke_config("zamba2_1_2b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    target = [11, 12, 13]
    want = _reference_generate(cfg, params, target, 3)
    cb = ContinuousBatcher(cfg, params, max_slots=1, max_len=64)
    cb.submit([400, 300, 200, 100], max_new=3)
    tgt = cb.submit(target, max_new=3)
    cb.run()
    assert tgt.output == want


def test_submit_rids_stay_unique_after_admission(setup):
    """Regression: rid=len(queue) reused rids once admission popped the
    queue, corrupting run()'s seen-set; rids must be monotonic."""
    cfg, params = setup
    cb = ContinuousBatcher(cfg, params, max_slots=1, max_len=64)
    r1 = cb.submit([1, 2], max_new=2)
    cb.step()                        # admits r1 -> queue drains to empty
    r2 = cb.submit([3, 4], max_new=2)
    assert r1.rid != r2.rid
    done = cb.run()
    assert {r.rid for r in done} == {r1.rid, r2.rid}
    assert r1.done and r2.done


def test_run_returns_each_request_exactly_once(setup):
    """Repeated submit/run cycles: a request finished and returned by one
    run() must not be returned again by the next (and stops being
    tracked, so long-lived batchers don't accumulate requests)."""
    cfg, params = setup
    cb = ContinuousBatcher(cfg, params, max_slots=1, max_len=64)
    r1 = cb.submit([1, 2], max_new=2)
    assert [r.rid for r in cb.run()] == [r1.rid]
    r2 = cb.submit([3, 4], max_new=2)
    assert [r.rid for r in cb.run()] == [r2.rid]
    assert cb.requests == []


def test_max_steps_bounds_each_run_call(setup):
    """max_steps is a per-call budget: a long-lived batcher must keep
    draining on later run() calls, not die at a lifetime step cap."""
    cfg, params = setup
    cb = ContinuousBatcher(cfg, params, max_slots=1, max_len=64)
    r = cb.submit([1, 2, 3], max_new=4)          # needs 7 steps total
    assert cb.run(max_steps=4) == []             # budget exhausted mid-flight
    done = cb.run(max_steps=50)                  # fresh budget resumes
    assert [x.rid for x in done] == [r.rid] and r.done


def test_eos_frees_slot_early(setup):
    cfg, params = setup
    cb = ContinuousBatcher(cfg, params, max_slots=1, max_len=64, eos_id=None)
    r = cb.submit([1, 2, 3], max_new=50)
    # force EOS on the first generated token
    cb.eos_id = None
    cb.run(max_steps=100)
    assert r.done and len(r.output) <= 50


def test_admission_is_fifo_under_backlog(setup):
    """Submission order IS admission order: the queue is a deque popped
    from the head (the old list.pop(0) was quadratic under backlog, and
    any reordering here would starve early requests -- ISSUE 7)."""
    cfg, params = setup
    cb = ContinuousBatcher(cfg, params, max_slots=2, max_len=64)
    reqs = [cb.submit([i + 1, i + 2], max_new=2) for i in range(8)]
    done = cb.run()
    assert len(done) == 8
    admits = [r.admitted_step for r in reqs]     # indexed by rid order
    assert admits == sorted(admits)              # FIFO: never leapfrogged
    assert all(r.admitted_step >= 0 and r.finished_step >= r.admitted_step
               for r in reqs)
