"""Capacity-conservation invariants for the unified per-cloud market
(ISSUE 9, clouds/capacity.py + the orchestrator/gateway refactor).

Ledger unit contract first (refusal at the slot ceiling, youngest-first
preemption, audit-seq monotonicity, the budget planner's reserve), then
property-based end-to-end scenarios: a training pipeline and a serving
burst run through ONE CapacityMarket in either order, and the suite
asserts

  1. no cloud is ever over-committed -- the committed lease timeline's
     peak overlap stays <= the ledger's slots at every point (checked by
     the audit-replaying sweep in ``check_conservation``), and per-ledger
     audit ``seq`` values are strictly increasing;
  2. preempted training attempts complete-or-fail exactly once: every
     done step has exactly one ``ok`` attempt, every failed step has
     none, and every non-ok attempt was killed by a documented cause
     (outage / preempted / cancelled) -- preemption feeds the existing
     RetryPolicy backoff, it never forks or loses a step;
  3. serving lease requests are never starved by training holders while
     ``serving_priority`` is on: any ``gateway:scale_denied`` with
     ``reason="capacity"`` on a ledgered cloud happened at a sim time
     with ZERO training leases covering it (they would have been
     preempted first);
  4. the dormant path stays dormant: with ``shared_capacity=None``
     neither subsystem emits a single ``capacity:*`` event.

The scenario space is described once (``scenario``) and driven via
hypothesis when installed and via a seeded numpy fallback that always
runs (the same split as test_gateway_invariants.py).
"""
import math

import numpy as np
import pytest

from repro.clouds.capacity import CapacityLedger, CapacityMarket
from repro.clouds.profiles import get_profile
from repro.core.pipeline import Pipeline
from repro.pipelines import Orchestrator, RetryPolicy
from repro.serving.gateway import (AutoscalerConfig, FailureSpec, Gateway,
                                   TrafficSpec)
from repro.telemetry.events import EventLog
from repro.telemetry.metrics import MetricsRegistry

from conftest import AnalyticBackend

try:
    from hypothesis import given, strategies as hyp_st
    HAS_HYPOTHESIS = True
except ImportError:              # degrade to the seeded fallback only
    HAS_HYPOTHESIS = False

CLOUDS = ("gcp", "ibm")


# -- ledger unit contract ----------------------------------------------------

def test_ledger_refuses_overcommit():
    led = CapacityLedger("gcp", 2)
    a = led.lease("training", "t0", 0.0)
    b = led.lease("serving", "s0", 0.0)
    assert a is not None and b is not None
    assert led.lease("training", "t1", 0.0) is None     # full at t=0
    assert led.free(0.0) == 0 and led.used(0.0) == 2
    led.release(a, 1.0)
    assert led.lease("training", "t1", 1.0) is not None  # freed slot reused
    assert led.max_overlap() == 2                        # never above slots


def test_ledger_preempts_youngest():
    led = CapacityLedger("gcp", 3)
    old = led.lease("training", "old", 0.0)
    mid = led.lease("training", "mid", 1.0)
    yng = led.lease("training", "yng", 2.0)
    victim = led.preempt_youngest(3.0)
    assert victim is yng and yng.status == "preempted" and yng.t1 == 3.0
    assert not yng.covers(3.0)                  # truncation is half-open
    victim = led.preempt_youngest(3.0)
    assert victim is mid                        # next-youngest by t0
    assert old.status == "active"
    assert led.preempt_youngest(3.0, kind="serving") is None


def test_ledger_audit_is_monotonic_and_complete():
    led = CapacityLedger("gcp", 2)
    a = led.lease("training", "a", 0.0)
    b = led.lease("serving", "b", 0.5)
    led.release(b, 1.0)
    led.release(a, 1.5, status="cancelled")
    c = led.lease("training", "c", 2.0)
    led.preempt_youngest(3.0)
    ops = [(op["op"], op["lease"]) for op in led.audit]
    assert ops == [("lease", a.lease_id), ("lease", b.lease_id),
                   ("release", b.lease_id), ("cancel", a.lease_id),
                   ("lease", c.lease_id), ("preempt", c.lease_id)]
    seqs = [op["seq"] for op in led.audit]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)


def test_market_shares_one_audit_order():
    mkt = CapacityMarket({"gcp": 1, "ibm": 2})
    mkt.ledger("ibm").lease("serving", "s", 0.0)
    mkt.ledger("gcp").lease("training", "t", 0.0)
    mkt.ledger("ibm").lease("serving", "s2", 1.0)
    seqs = [op["seq"] for led in mkt.ledgers.values() for op in led.audit]
    assert sorted(seqs) == [0, 1, 2]     # one global counter, no collisions
    mkt.check_conservation()


def test_plan_budget_reserves_serving_headroom():
    mkt = CapacityMarket({"gcp": 4, "ibm": 2})
    plan = mkt.plan_budget({"gcp": 2.0}, work_s=12.0, target_util=0.7)
    assert plan["reserve"] == {"gcp": 3, "ibm": 0}      # ceil(2/0.7), capped
    assert plan["training_slots"] == {"gcp": 1, "ibm": 2}
    assert plan["est_makespan_s"] == pytest.approx(4.0)
    # training_free honors the installed reserve; unledgered clouds are open
    assert mkt.training_free("gcp", 0.0) == 1
    assert mkt.training_free("baremetal", 0.0) > 1000


def test_training_free_blocks_at_reserve():
    mkt = CapacityMarket({"gcp": 2})
    mkt.reserve = {"gcp": 2}
    assert mkt.training_free("gcp", 0.0) == 0
    assert mkt.ledger("gcp").lease("serving", "s", 0.0) is not None


# -- end-to-end scenario space -----------------------------------------------

def _pipeline(n_branches: int, tune_s: float, train_s: float):
    fns = {"prep": lambda: 1.0,
           "tune": lambda i, p: {"i": i, "loss": 1.0 / (1 + i)},
           "select": lambda *rs: min(rs, key=lambda r: r["loss"]),
           "train": lambda p, best: {"loss": best["loss"] / 2}}
    pipe = Pipeline("market-tune")
    prep = pipe.step(fns["prep"], name="prep", cache=False)
    branches = [pipe.step(fns["tune"], i, prep, name=f"tune{i}", cache=False)
                for i in range(n_branches)]
    best = pipe.step(fns["select"], *branches, name="select", cache=False)
    pipe.step(fns["train"], prep, best, name="train", cache=False)
    spec = pipe.compile()
    sims = {"prep": 0.2, "select": 0.05, "train": train_s,
            **{f"tune{i}": tune_s for i in range(n_branches)}}
    for s in spec.steps:
        s.sim_s = sims[s.name]
    return spec


def scenario(pick_int, pick_choice, pick_float):
    """One random-but-valid colocated training+serving description."""
    return {
        "slots": {c: pick_int(2, 4) for c in CLOUDS},
        "priority": pick_choice((True, True, False)),   # mostly spot mode
        "order": pick_choice(("train_first", "serve_first")),
        "workers": {c: pick_int(1, 3) for c in CLOUDS},
        "branches": pick_int(2, 6),
        "tune_s": pick_float(0.5, 2.0),
        "train_s": pick_float(0.5, 2.0),
        "retries": pick_int(2, 4),
        "outage": (pick_choice(CLOUDS), pick_float(0.5, 4.0),
                   pick_float(0.3, 1.5)) if pick_choice((True, False))
                  else None,
        "serve_cloud": pick_choice(CLOUDS),
        "min": pick_int(0, 1), "max": pick_int(2, 4),
        "n": pick_int(40, 300),
        "rate_x": pick_float(1.5, 4.0),   # x one replica's ceiling
        "base_ms": pick_float(1.0, 10.0),
        "seed": pick_int(0, 2 ** 16),
    }


def run_and_check(p):
    mkt = CapacityMarket(dict(p["slots"]), serving_priority=p["priority"])

    def run_training():
        log = EventLog()
        orch = Orchestrator(dict(p["workers"]), policy="makespan", log=log,
                            retry=RetryPolicy(max_retries=p["retries"],
                                              backoff_s=0.3),
                            shared_capacity=mkt)
        failures = ([FailureSpec(*p["outage"])] if p["outage"] else [])
        rec = orch.execute(_pipeline(p["branches"], p["tune_s"],
                                     p["train_s"]), failures=failures)
        return rec, log

    def run_serving():
        log = EventLog()
        gw = Gateway(log=log, shared_capacity=mkt)
        backend = AnalyticBackend("m", p["base_ms"] / 1e3, 1e-4)
        prof = get_profile(p["serve_cloud"])
        gw.deploy("m", backend,
                  autoscaler=AutoscalerConfig(min_replicas=p["min"],
                                              max_replicas=p["max"],
                                              target_queue=4,
                                              scale_up_delay_s=0.01,
                                              idle_window_s=math.inf),
                  profile=prof, max_batch=8)
        per_req = backend.service_time(1)
        out = gw.run([TrafficSpec("m", p["n"], arrival="poisson",
                                  rate=p["rate_x"] / per_req)],
                     seed=p["seed"])
        return out, log

    if p["order"] == "train_first":
        rec, tr_log = run_training()
        out, gw_log = run_serving()
    else:
        out, gw_log = run_serving()
        rec, tr_log = run_training()

    # 1. conservation: committed timeline never over-commits any cloud,
    #    audit seq strictly increasing per ledger
    mkt.check_conservation()
    for cloud, led in mkt.ledgers.items():
        assert led.max_overlap() <= led.slots, (cloud, led.audit)

    # 2. preempted training attempts complete-or-fail exactly once
    for name, r in rec.steps.items():
        oks = sum(1 for a in r.attempts if a["status"] == "ok")
        if r.status == "done" and not r.cached:
            assert oks == 1, (name, r.attempts)
        elif r.status in ("failed", "skipped"):
            assert oks == 0, (name, r.attempts)
        assert all(a["status"] in ("ok", "outage", "preempted", "cancelled")
                   for a in r.attempts), (name, r.attempts)

    # every serving request still completes exactly once (preemption is a
    # ledger-level fact; live replicas are never killed by the market)
    assert out.per_model["m"].n_requests == p["n"]
    assert len(out.per_model["m"].latencies_s) == p["n"]

    # 3. priority-on serving is never starved by training holders: any
    #    capacity denial happened with zero training leases covering it
    if p["priority"]:
        for e in gw_log.named("gateway:scale_denied"):
            if e.get("reason") != "capacity":
                continue
            led = mkt.ledger(e["cloud"])
            if led is not None:
                t = e["t_sim"]
                assert led.used(t, kind="training") == 0, (e, led.audit)
    else:
        # priority off: the market never preempts on the gateway's behalf
        assert gw_log.count("capacity:preempt") == 0

    # the audit trail accounts for every event the subsystems logged
    n_leases = sum(1 for led in mkt.ledgers.values()
                   for op in led.audit if op["op"] == "lease")
    assert n_leases == (tr_log.count("capacity:lease")
                        + gw_log.count("capacity:lease"))
    n_preempts = sum(1 for led in mkt.ledgers.values()
                     for op in led.audit if op["op"] == "preempt")
    assert n_preempts >= (tr_log.count("capacity:preempt")
                          + gw_log.count("capacity:preempt"))


# -- hypothesis driver (requirements-dev.txt) --------------------------------

if HAS_HYPOTHESIS:
    @hyp_st.composite
    def scenarios(draw):
        return scenario(
            lambda lo, hi: draw(hyp_st.integers(lo, hi)),
            lambda seq: draw(hyp_st.sampled_from(list(seq))),
            lambda lo, hi: draw(hyp_st.floats(lo, hi, allow_nan=False,
                                              allow_infinity=False)))

    @given(scenarios())
    def test_market_invariants(params):
        run_and_check(params)
else:                            # visible skip instead of silent absence
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(pip install -r requirements-dev.txt)")
    def test_market_invariants():
        pass


# -- seeded numpy fallback (always runs) -------------------------------------

def params_from_seed(seed):
    rng = np.random.default_rng(seed)
    return scenario(lambda lo, hi: int(rng.integers(lo, hi + 1)),
                    lambda seq: seq[int(rng.integers(len(seq)))],
                    lambda lo, hi: float(rng.uniform(lo, hi)))


@pytest.mark.parametrize("seed", range(20))
def test_market_invariants_seeded(seed):
    run_and_check(params_from_seed(seed))


# -- directed end-to-end cases -----------------------------------------------

def test_gateway_preempts_recorded_training():
    """Spot semantics, serving side: a burst on a cloud whose recorded
    timeline is full of training leases must preempt (never be denied)."""
    mkt = CapacityMarket({"gcp": 2})
    led = mkt.ledger("gcp")
    for i in range(2):
        led.lease("training", f"t{i}", 0.0)
    log = EventLog()
    gw = Gateway(log=log, shared_capacity=mkt)
    gw.deploy("m", AnalyticBackend("m", 0.005), get_profile("gcp"),
              autoscaler=AutoscalerConfig(min_replicas=1, max_replicas=2,
                                          target_queue=2,
                                          scale_up_delay_s=0.01,
                                          idle_window_s=math.inf),
              max_batch=4)
    out = gw.run([TrafficSpec("m", 60, arrival="poisson", rate=800.0)],
                 seed=0)
    assert out.per_model["m"].n_requests == 60
    assert log.count("capacity:preempt") >= 1        # the floor alone evicts
    assert not [e for e in log.named("gateway:scale_denied")
                if e["reason"] == "capacity"]
    mkt.check_conservation()


def test_training_preempted_at_serving_edge_retries():
    """Spot semantics, training side: recorded serving rise-edges that
    over-commit the cloud kill the youngest running attempt, which
    re-enters RetryPolicy backoff and still completes exactly once."""
    mkt = CapacityMarket({"gcp": 2})
    led = mkt.ledger("gcp")
    s = led.lease("serving", "pool:m", 0.0)          # floor, covers the run
    led.lease("serving", "pool:m", 5.0)              # rise-edge at t=5
    led.release(s, 60.0)
    log = EventLog()
    orch = Orchestrator({"gcp": 2}, policy="makespan", log=log,
                        retry=RetryPolicy(max_retries=3, backoff_s=0.3),
                        shared_capacity=mkt)
    rec = orch.execute(_pipeline(2, 4.0, 1.0))
    assert rec.status == "succeeded"
    assert log.count("capacity:preempt") >= 1
    retries = [e for e in log.named("pipeline:retry")
               if e.get("reason") == "preempt"]
    assert retries, log.named("pipeline:retry")
    for name, r in rec.steps.items():
        assert sum(1 for a in r.attempts if a["status"] == "ok") == 1
    mkt.check_conservation()


def test_speculative_retry_cancels_loser():
    """An outage window dooming a running attempt launches a backup on a
    second cloud; the winner completes, the loser's lease is cancelled."""
    mkt = CapacityMarket({"gcp": 2, "ibm": 2})
    log = EventLog()
    orch = Orchestrator({"gcp": 2, "ibm": 2}, policy="makespan", log=log,
                        retry=RetryPolicy(max_retries=2, backoff_s=0.3),
                        shared_capacity=mkt)
    gcp = get_profile("gcp")
    t0 = gcp.startup_s + gcp.network_rtt_s           # prep starts its compute
    rec = orch.execute(_pipeline(2, 2.0, 1.0),
                       failures=[FailureSpec("gcp", t0 + 0.1, 1.0)])
    assert rec.status == "succeeded"
    assert log.count("capacity:speculate") >= 1
    cancelled = [op for led in mkt.ledgers.values()
                 for op in led.audit if op["op"] == "cancel"]
    assert cancelled, "the losing side must be cancelled through the ledger"
    for name, r in rec.steps.items():
        assert sum(1 for a in r.attempts if a["status"] == "ok") == 1
    mkt.check_conservation()


def test_worker_gauges_exposed():
    """Satellite: cluster occupancy reaches the metrics plane as
    pipeline_workers_busy/free{cloud=...} gauges."""
    reg = MetricsRegistry()
    orch = Orchestrator({"gcp": 2, "ibm": 1}, policy="makespan", metrics=reg)
    orch.execute(_pipeline(2, 0.5, 0.5))
    for c in ("gcp", "ibm"):
        assert reg.value("pipeline_workers_busy", cloud=c) == 0  # drained
        free = reg.value("pipeline_workers_free", cloud=c)
        assert free == {"gcp": 2, "ibm": 1}[c]
    reg.scrape(0.0)
    assert any("pipeline_workers_busy" in k
               for k in reg.scrapes[-1]["series"])


def test_dormant_path_emits_no_capacity_events():
    """shared_capacity=None must leave both planes exactly as they were:
    not a single capacity:* event, no ledger anywhere."""
    log = EventLog()
    orch = Orchestrator({"gcp": 2}, policy="makespan", log=log)
    orch.execute(_pipeline(2, 0.3, 0.3))
    gw = Gateway(log=log)
    gw.deploy("m", AnalyticBackend("m", 0.005), get_profile("gcp"),
              autoscaler=AutoscalerConfig(min_replicas=1, max_replicas=2,
                                          target_queue=4,
                                          idle_window_s=math.inf),
              max_batch=4)
    gw.run([TrafficSpec("m", 40, arrival="poisson", rate=400.0)], seed=0)
    assert not [e for e in log.events if e["name"].startswith("capacity:")]
