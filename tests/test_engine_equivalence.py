"""Bit-compatibility oracle for the gateway engine cutover (ISSUE 7).

The vectorized engine (``Gateway.run(..., engine="vector")``, the default)
must reproduce the scalar per-request reference loop EXACTLY -- not
approximately -- on any seeded scenario: byte-identical EventLog dump
(event kinds, order and payloads), identical ServeResult summaries,
bit-identical latency lists and per-class percentiles, identical simulated
dollars, final weights and makespan, plus the whole observability plane
(span-tree JSON and Prometheus exposition).

The scenario space is the gateway invariant suite's (splits, outages,
admission control, live migrations, replanning, burn-rate alerts), driven
two ways like the rest of the property suites: via hypothesis when
installed, and via a seeded numpy fallback that always runs.
"""
import pytest

from test_gateway_invariants import build, params_from_seed, scenario

try:
    from hypothesis import given, strategies as hyp_st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False


def run_both_and_compare(p):
    gw_s, traffic, failures, migrations = build(p)
    out_s = gw_s.run(traffic, seed=p["seed"], failures=failures,
                     migrations=migrations, engine="scalar")
    gw_v, traffic, failures, migrations = build(p)
    out_v = gw_v.run(traffic, seed=p["seed"], failures=failures,
                     migrations=migrations, engine="vector")

    # the event log is the strictest oracle: every simulator decision that
    # matters lands here, in order, and dump() is byte-stable
    assert gw_s.log.dump() == gw_v.log.dump()
    assert [e["name"] for e in gw_s.log.events] \
        == [e["name"] for e in gw_v.log.events]

    assert out_s.summary() == out_v.summary()
    assert out_s.makespan_s == out_v.makespan_s
    assert out_s.costs == out_v.costs
    assert out_s.cold_starts == out_v.cold_starts
    assert set(out_s.per_model) == set(out_v.per_model)
    for m, rs in out_s.per_model.items():
        rv = out_v.per_model[m]
        # bit-for-bit float equality, not approx: both engines must fold
        # the same IEEE operations in the same order
        assert rs.latencies_s == rv.latencies_s
        assert rs.class_latencies == rv.class_latencies
        assert rs.class_misses == rv.class_misses
        assert rs.class_shed == rv.class_shed
        assert rs.per_class() == rv.per_class()
        assert rs.per_version == rv.per_version
        assert rs.observed == rv.observed
        assert rs.replica_trace == rv.replica_trace
        assert rs.cost_usd == rv.cost_usd
        assert rs.cost_by_cloud == rv.cost_by_cloud
        assert rs.p50 == rv.p50 and rs.p99 == rv.p99
    assert gw_s.final_weights == gw_v.final_weights
    assert gw_s.batch_log == gw_v.batch_log
    assert gw_s.usage_trace == gw_v.usage_trace
    assert gw_s.tracer.to_json() == gw_v.tracer.to_json()
    assert gw_s.metrics.to_prometheus() == gw_v.metrics.to_prometheus()
    # the vector engine exists to be faster, never different: it must
    # still account one simulated event per request
    assert gw_s.run_stats["requests"] == gw_v.run_stats["requests"]
    assert gw_s.run_stats["engine"] == "scalar"
    assert gw_v.run_stats["engine"] == "vector"


def test_unknown_engine_rejected():
    gw, traffic, failures, migrations = build(params_from_seed(0))
    with pytest.raises(ValueError, match="unknown engine"):
        gw.run(traffic, seed=0, engine="turbo")


# -- hypothesis driver (requirements-dev.txt) --------------------------------

if HAS_HYPOTHESIS:
    @hyp_st.composite
    def scenarios(draw):
        return scenario(
            lambda lo, hi: draw(hyp_st.integers(lo, hi)),
            lambda seq: draw(hyp_st.sampled_from(list(seq))),
            lambda lo, hi: draw(hyp_st.floats(lo, hi, allow_nan=False,
                                              allow_infinity=False)))

    @given(scenarios())
    def test_engines_bit_compatible(params):
        run_both_and_compare(params)
else:
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(pip install -r requirements-dev.txt)")
    def test_engines_bit_compatible():
        pass


# -- seeded numpy fallback (always runs) -------------------------------------

@pytest.mark.parametrize("seed", range(20))
def test_engines_bit_compatible_seeded(seed):
    run_both_and_compare(params_from_seed(seed + 500))


def test_equivalence_on_pure_burst():
    """The bulk same-timestamp append path: one burst, one pool."""
    p = params_from_seed(7)
    p["models"] = p["models"][:1]
    p["models"][0].update(split=None, standby=False, min=1, max=2)
    p["traffic"] = [{"model": p["models"][0]["name"], "n": 500,
                     "slo": "standard", "arrival": "burst", "rate": 0.0,
                     "start": 0.0}]
    p.update(failure=None, migration=None, admission=None, slo_burn=None)
    run_both_and_compare(p)


def test_equivalence_with_canary_split_classes():
    """Grouped bulk append: canary versions x several SLO classes must
    land in per-key queues in exactly the scalar engine's order."""
    from conftest import AnalyticBackend
    from repro.clouds.profiles import get_profile
    from repro.serving.gateway import (AutoscalerConfig, Gateway,
                                       TrafficSpec)
    from repro.telemetry.events import EventLog

    def mk():
        gw = Gateway(log=EventLog(), record_batches=True)
        gw.deploy("m", AnalyticBackend("m-v0", 0.01, 1e-4),
                  get_profile("gcp"),
                  canary=AnalyticBackend("m-v1", 0.012, 1e-4),
                  canary_fraction=0.3,
                  autoscaler=AutoscalerConfig(min_replicas=1,
                                              max_replicas=3),
                  max_batch=8)
        traffic = [TrafficSpec("m", 300, arrival="poisson", rate=900.0,
                               slo="latency"),
                   TrafficSpec("m", 300, arrival="poisson", rate=900.0,
                               slo="standard")]
        return gw, traffic

    gw_s, tr = mk()
    out_s = gw_s.run(tr, seed=11, engine="scalar")
    gw_v, tr = mk()
    out_v = gw_v.run(tr, seed=11, engine="vector")
    assert gw_s.log.dump() == gw_v.log.dump()
    assert out_s.summary() == out_v.summary()
    assert gw_s.batch_log == gw_v.batch_log
    rs, rv = out_s.per_model["m"], out_v.per_model["m"]
    assert rs.latencies_s == rv.latencies_s
    assert rs.per_version == rv.per_version
