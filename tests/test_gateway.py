"""Model-mesh gateway: multi-model routing, SLO classes with preemption,
scale-to-zero autoscaling with cold starts, shared per-cloud capacity,
simulated cloud failover, and multi-cloud placement + re-planning."""
import math

import numpy as np
import pytest

from repro.clouds.profiles import get_profile
from repro.serving.gateway import (AutoscalerConfig, BatcherBackend,
                                   CloudCapacity, FailureSpec, Gateway,
                                   ModelDemand, Predictor, SLOClass,
                                   TrafficSpec, est_p99_s, plan_placement,
                                   replan, replicas_needed)
from repro.telemetry.events import EventLog

from conftest import AnalyticBackend


def make_predictor(name="m", cost_s=0.0):
    import time

    def predict(x):
        if cost_s:
            time.sleep(cost_s)
        return x.sum(axis=tuple(range(1, x.ndim)))

    return Predictor(name, predict, np.zeros((1, 4), np.float32))



def warm_config(**kw):
    """Legacy-style pool: starts warm, never idles out."""
    return AutoscalerConfig(min_replicas=kw.pop("min_replicas", 1),
                            idle_window_s=kw.pop("idle_window_s", math.inf),
                            **kw)


def test_multi_model_all_served_exactly_once():
    gw = Gateway()
    for name in ("a", "b", "c"):
        gw.deploy(name, make_predictor(name), get_profile("gcp"),
                  autoscaler=warm_config(), max_batch=8)
    out = gw.run([TrafficSpec("a", 100),
                  TrafficSpec("b", 50, arrival="poisson", rate=200.0),
                  TrafficSpec("c", 25)], seed=0)
    assert set(out.per_model) == {"a", "b", "c"}
    for name, n in (("a", 100), ("b", 50), ("c", 25)):
        res = out.per_model[name]
        assert res.n_requests == n
        assert len(res.latencies_s) == n
        assert all(l > 0 for l in res.latencies_s)
        assert sum(res.per_version.values()) == n
    assert out.makespan_s >= max(r.total_time_s for r in out.per_model.values()) - 1e-12


def test_multiple_specs_for_one_model_concatenate():
    gw = Gateway()
    gw.deploy("a", make_predictor("a"), get_profile("gcp"),
              autoscaler=warm_config())
    out = gw.run([TrafficSpec("a", 10), TrafficSpec("a", 10, start_s=1.0)])
    assert out.per_model["a"].n_requests == 20


def test_scale_to_zero_cold_start_cycle():
    """min_replicas=0: burst -> cold start, idle out to zero, second burst
    pays a second cold start (Cox et al. serverless-inferencing behavior)."""
    log = EventLog()
    prof = get_profile("gcp")
    gw = Gateway(log=log)
    gw.deploy("m", make_predictor("m"), prof,
              autoscaler=AutoscalerConfig(min_replicas=0, max_replicas=2,
                                          scale_up_delay_s=0.5,
                                          idle_window_s=0.5))
    out = gw.run([TrafficSpec("m", 8), TrafficSpec("m", 8, start_s=10.0)])
    assert out.cold_starts["m"] == 2
    trace = out.per_model["m"].replica_trace
    assert trace[0] == (0.0, 0)
    pools = [p for _, p in trace]
    assert 0 in pools[1:]                # scaled back to zero mid-run
    names = [e["name"] for e in log.events]
    assert names.count("gateway:cold_start") == 2
    assert "gateway:scale_to_zero" in names
    # first request of each burst pays control-plane delay + model load
    lat = out.per_model["m"].latencies_s
    assert max(lat[:8]) >= 0.5 + prof.model_load_s
    assert max(lat[8:]) >= 0.5 + prof.model_load_s


def test_cold_start_penalty_matches_profile_constants():
    prof = get_profile("gcp")
    warm = Gateway()
    warm.deploy("m", make_predictor("m"), prof, autoscaler=warm_config())
    lat_warm = warm.run([TrafficSpec("m", 1)]).per_model["m"].latencies_s[0]
    cold = Gateway()
    cold.deploy("m", make_predictor("m"), prof,
                autoscaler=AutoscalerConfig(min_replicas=0,
                                            scale_up_delay_s=0.5,
                                            idle_window_s=1.0))
    lat_cold = cold.run([TrafficSpec("m", 1)]).per_model["m"].latencies_s[0]
    penalty = lat_cold - lat_warm
    assert abs(penalty - (0.5 + prof.model_load_s)) < 0.02


def test_idle_replicas_retire_back_to_min():
    gw = Gateway()
    gw.deploy("m", make_predictor("m", cost_s=0.002), get_profile("gcp"),
              autoscaler=AutoscalerConfig(min_replicas=1, max_replicas=4,
                                          target_queue=4, idle_window_s=0.5),
              max_batch=4)
    out = gw.run([TrafficSpec("m", 128)])
    trace = out.per_model["m"].replica_trace
    assert max(p for _, p in trace) > 1          # scaled up under the burst
    assert trace[-1][1] == 1                     # decayed back to min


def test_shared_cloud_capacity_is_enforced():
    log = EventLog()
    gw = Gateway(capacity={"gcp": 3}, log=log)
    for name in ("a", "b"):
        gw.deploy(name, make_predictor(name, cost_s=0.002), get_profile("gcp"),
                  autoscaler=warm_config(max_replicas=4, target_queue=2),
                  max_batch=2)
    out = gw.run([TrafficSpec("a", 64), TrafficSpec("b", 64)])
    # replay the two traces together: total pool never exceeds the cap
    merged = sorted((t, name, p) for name in ("a", "b")
                    for t, p in out.per_model[name].replica_trace)
    cur, peak = {"a": 0, "b": 0}, 0
    for _, name, p in merged:
        cur[name] = p
        peak = max(peak, cur["a"] + cur["b"])
    assert peak <= 3
    assert any(e["name"] == "gateway:scale_denied" for e in log.events)
    assert all(out.per_model[m].n_requests == 64 for m in ("a", "b"))


def test_scale_from_zero_not_starved_by_warm_pools():
    """A cloud pinned full by never-idling pools must not deadlock a
    scale-to-zero deployment: its first replica launches over budget and
    the breach is logged (gateway:capacity_exceeded)."""
    log = EventLog()
    gw = Gateway(capacity={"gcp": 1}, log=log)
    gw.deploy("warm", make_predictor("warm"), get_profile("gcp"),
              autoscaler=warm_config(min_replicas=1))
    gw.deploy("cold", make_predictor("cold"), get_profile("gcp"),
              autoscaler=AutoscalerConfig(min_replicas=0, idle_window_s=1.0))
    out = gw.run([TrafficSpec("warm", 4), TrafficSpec("cold", 4)])
    assert out.per_model["cold"].n_requests == 4
    assert all(l > 0 for l in out.per_model["cold"].latencies_s)
    assert any(e["name"] == "gateway:capacity_exceeded" for e in log.events)


def test_min_replicas_over_capacity_rejected_up_front():
    gw = Gateway(capacity={"gcp": 1})
    for name in ("a", "b"):
        gw.deploy(name, make_predictor(name), get_profile("gcp"),
                  autoscaler=warm_config(min_replicas=1))
    with pytest.raises(ValueError, match="capacity"):
        gw.run([TrafficSpec("a", 2), TrafficSpec("b", 2)])


def test_untrafficked_deployment_still_holds_capacity():
    """A deployed model that gets no traffic this run keeps its warm pool,
    which counts against the shared cloud cap (and the baseline check)."""
    log = EventLog()
    gw = Gateway(capacity={"gcp": 2}, log=log)
    gw.deploy("quiet", make_predictor("quiet"), get_profile("gcp"),
              autoscaler=warm_config(min_replicas=1))
    gw.deploy("busy", make_predictor("busy", cost_s=0.002), get_profile("gcp"),
              autoscaler=warm_config(max_replicas=4, target_queue=2),
              max_batch=2)
    out = gw.run([TrafficSpec("busy", 64)])
    assert "quiet" not in out.per_model          # no traffic -> no results
    assert max(p for _, p in out.per_model["busy"].replica_trace) == 1
    assert any(e["name"] == "gateway:scale_denied" for e in log.events)

    strict = Gateway(capacity={"gcp": 1})
    strict.deploy("quiet", make_predictor("quiet"), get_profile("gcp"),
                  autoscaler=warm_config(min_replicas=1))
    strict.deploy("busy", make_predictor("busy"), get_profile("gcp"),
                  autoscaler=warm_config(min_replicas=1))
    with pytest.raises(ValueError, match="capacity"):
        strict.run([TrafficSpec("busy", 2)])


def test_canary_split_through_gateway():
    gw = Gateway()
    gw.deploy("m", make_predictor("stable"), get_profile("gcp"),
              autoscaler=warm_config(), canary=make_predictor("canary"),
              canary_fraction=0.3)
    res = gw.run([TrafficSpec("m", 500)], seed=11).per_model["m"]
    assert sum(res.per_version.values()) == 500
    assert 0.2 < res.per_version.get("canary", 0) / 500 < 0.4


def test_unknown_model_raises():
    gw = Gateway()
    with pytest.raises(KeyError):
        gw.run([TrafficSpec("ghost", 4)])


# -- SLO classes / preemption ------------------------------------------------

def _one_replica_fleet(slo_batch, slo_lat):
    """32 batch-class requests burst at t=0 against one replica, one
    latency-class request arriving just behind them."""
    gw = Gateway(log=EventLog(), record_batches=True)
    gw.deploy("m", AnalyticBackend("m"), get_profile("gcp"),
              autoscaler=warm_config(max_replicas=1), max_batch=4)
    return gw, [TrafficSpec("m", 32, slo=slo_batch),
                TrafficSpec("m", 1, start_s=0.01, slo=slo_lat)]


def test_latency_class_beats_no_priority_baseline():
    gw, tr = _one_replica_fleet("batch", "latency")
    pri = gw.run(tr, seed=0).per_model["m"]
    # no-priority baseline: same class NAMES but uniform weight and no
    # preemption, so dispatch degenerates to FIFO-by-age while per-class
    # reporting stays comparable
    gw2, tr2 = _one_replica_fleet(SLOClass("batch", 1.0, math.inf),
                                  SLOClass("latency", 1.0, 4.0))
    base = gw2.run(tr2, seed=0).per_model["m"]
    assert pri.n_requests == base.n_requests == 33
    p_pri = pri.per_class()["latency"]["p99_s"]
    p_base = base.per_class()["latency"]["p99_s"]
    assert p_pri < p_base       # the whole point of priority dispatch
    # and priority must not lose any batch work
    assert len(pri.class_latencies["batch"]) == 32


def test_preemption_requeues_and_completes_exactly_once():
    log = EventLog()
    gw = Gateway(log=log, record_batches=True)
    gw.deploy("m", AnalyticBackend("m", base_s=0.1), get_profile("gcp"),
              autoscaler=warm_config(max_replicas=1), max_batch=8)
    out = gw.run([TrafficSpec("m", 8, slo="batch"),
                  TrafficSpec("m", 2, slo="latency", start_s=0.02)])
    assert log.count("gateway:preempt") >= 1
    res = out.per_model["m"]
    assert res.n_requests == 10
    assert sum(res.per_version.values()) == 10
    served = sorted(i for rec in gw.batch_log if not rec["preempted"]
                    for i in rec["idx"])
    assert served == list(range(10))         # exactly once, preempt included
    pc = res.per_class()
    # the preempted batch work finishes AFTER the latency work that evicted it
    assert pc["latency"]["p99_s"] < pc["batch"]["p50_s"]


def test_standard_class_never_preempts():
    log = EventLog()
    gw = Gateway(log=log)
    gw.deploy("m", AnalyticBackend("m", base_s=0.1), get_profile("gcp"),
              autoscaler=warm_config(max_replicas=1), max_batch=8)
    gw.run([TrafficSpec("m", 8, slo="batch"),
            TrafficSpec("m", 2, slo="standard", start_s=0.02)])
    assert log.count("gateway:preempt") == 0


def test_deadline_miss_rate_zero_when_deadlines_infinite():
    gw = Gateway()
    gw.deploy("m", AnalyticBackend("m"), get_profile("gcp"),
              autoscaler=warm_config(), max_batch=4)
    res = gw.run([TrafficSpec("m", 64,
                              slo=SLOClass("standard", 1.0, math.inf))])
    assert res.per_model["m"].per_class()["standard"]["miss_rate"] == 0.0
    assert res.per_class()["standard"]["miss_rate"] == 0.0


def test_deadline_miss_rate_one_when_deadline_impossible():
    gw = Gateway()
    gw.deploy("m", AnalyticBackend("m"), get_profile("gcp"),
              autoscaler=warm_config(), max_batch=4)
    res = gw.run([TrafficSpec("m", 64, slo=SLOClass("standard", 1.0, 0.0))])
    assert res.per_model["m"].per_class()["standard"]["miss_rate"] == 1.0


def test_unknown_slo_class_raises():
    gw = Gateway()
    gw.deploy("m", AnalyticBackend("m"), get_profile("gcp"),
              autoscaler=warm_config())
    with pytest.raises(ValueError, match="SLO"):
        gw.run([TrafficSpec("m", 4, slo="gold")])


def test_conflicting_slo_definitions_rejected():
    """Queues are keyed by class NAME: two different definitions under one
    name on the same model would silently share dispatch weight."""
    gw = Gateway()
    gw.deploy("m", AnalyticBackend("m"), get_profile("gcp"),
              autoscaler=warm_config())
    with pytest.raises(ValueError, match="conflicting"):
        gw.run([TrafficSpec("m", 4, slo=SLOClass("batch", 4.0, 5.0)),
                TrafficSpec("m", 4, slo="batch")])
    # the same definition twice is fine
    gw2 = Gateway()
    gw2.deploy("m", AnalyticBackend("m"), get_profile("gcp"),
               autoscaler=warm_config())
    out = gw2.run([TrafficSpec("m", 4, slo="batch"),
                   TrafficSpec("m", 4, slo="batch", start_s=0.1)])
    assert out.per_model["m"].n_requests == 8


# -- cloud failover ----------------------------------------------------------

def test_failover_to_standby_and_recover():
    log = EventLog()
    gw = Gateway(log=log, record_batches=True)
    gw.deploy("m", AnalyticBackend("m", base_s=0.01), get_profile("gcp"),
              standby=get_profile("ibm"),
              autoscaler=warm_config(max_replicas=2, scale_up_delay_s=0.02),
              max_batch=4)
    out = gw.run([TrafficSpec("m", 200, arrival="poisson", rate=400.0)],
                 seed=0,
                 failures=[FailureSpec("gcp", at_s=0.1, duration_s=0.2)])
    assert out.per_model["m"].n_requests == 200
    fo = log.named("gateway:failover")
    rec = log.named("gateway:recover")
    assert fo and fo[0]["src"] == "gcp" and fo[0]["dst"] == "ibm"
    assert rec and rec[-1]["src"] == "ibm" and rec[-1]["dst"] == "gcp"
    # migrated replicas arrive cold on BOTH transitions: control-plane delay
    # plus model_load_s, visible as cold starts on each side
    assert out.cold_starts["m"] >= 2
    clouds_used = {r["cloud"] for r in gw.batch_log}
    assert clouds_used == {"gcp", "ibm"}
    # nothing is served on gcp inside the outage window
    for r in gw.batch_log:
        if r["cloud"] == "gcp":
            assert not (0.1 <= r["start_s"] < 0.3)


def test_failover_without_standby_queues_until_recovery():
    log = EventLog()
    gw = Gateway(log=log, record_batches=True)
    gw.deploy("m", AnalyticBackend("m", base_s=0.01), get_profile("gcp"),
              autoscaler=warm_config(max_replicas=2), max_batch=4)
    out = gw.run([TrafficSpec("m", 100, arrival="poisson", rate=300.0)],
                 seed=1,
                 failures=[FailureSpec("gcp", at_s=0.05, duration_s=0.25)])
    assert out.per_model["m"].n_requests == 100     # nothing lost
    fo = log.named("gateway:failover")
    assert fo and fo[0]["dst"] is None              # nowhere to go: drain
    for r in gw.batch_log:                          # dead cloud serves nothing
        assert not (0.05 <= r["start_s"] < 0.3)
    # requests that arrived mid-outage waited for the recovery
    assert max(out.per_model["m"].latencies_s) > 0.1


def test_failover_drain_preserves_arrival_order():
    """Regression: when a whole pool drains, several in-flight batches
    reclaim into ONE queue; the merge must restore arrival order, so the
    oldest requests are re-served first on the (capacity-1) standby."""
    gw = Gateway(capacity={"ibm": 1})
    gw.deploy("m", AnalyticBackend("m", base_s=0.1), get_profile("gcp"),
              standby=get_profile("ibm"),
              autoscaler=warm_config(min_replicas=2, max_replicas=2,
                                     scale_up_delay_s=0.02), max_batch=2)
    out = gw.run([TrafficSpec("m", 4, slo="batch")],
                 failures=[FailureSpec("gcp", at_s=0.05, duration_s=10.0)])
    lat = out.per_model["m"].latencies_s
    done = [lat[i] for i in range(4)]            # burst: arr == 0 for all
    assert done == sorted(done)                  # 0,1 complete before 2,3


def test_recovery_relaunch_is_cold_even_with_warm_scale_up():
    """Regression: a pool destroyed by an outage (no standby) must relaunch
    COLD on recovery -- the pods are gone -- even for cold_scale_up=False
    deployments whose ordinary elastic scale-ups arrive warm."""
    log = EventLog()
    gw = Gateway(log=log)
    gw.deploy("m", AnalyticBackend("m", base_s=0.01), get_profile("gcp"),
              autoscaler=AutoscalerConfig(min_replicas=1, max_replicas=1,
                                          scale_up_delay_s=0.02,
                                          idle_window_s=math.inf,
                                          cold_scale_up=False),
              max_batch=4)
    out = gw.run([TrafficSpec("m", 8), TrafficSpec("m", 8, start_s=0.5)],
                 failures=[FailureSpec("gcp", at_s=0.2, duration_s=0.2)])
    assert out.per_model["m"].n_requests == 16
    assert out.cold_starts["m"] >= 1
    rec = log.named("gateway:recover")
    assert rec and rec[0]["dst"] == "gcp"


def test_failure_spec_validation():
    with pytest.raises(ValueError):
        FailureSpec("gcp", at_s=-1.0, duration_s=1.0)
    with pytest.raises(ValueError):
        FailureSpec("gcp", at_s=0.0, duration_s=0.0)
    gw = Gateway()
    with pytest.raises(ValueError, match="standby"):
        gw.deploy("m", AnalyticBackend("m"), get_profile("gcp"),
                  standby=get_profile("gcp"))


# -- placement ---------------------------------------------------------------

def _clouds(gcp_cost=1.0, ibm_cost=2.0, cap=8):
    return [CloudCapacity(get_profile("gcp"), cap, gcp_cost),
            CloudCapacity(get_profile("ibm"), cap, ibm_cost)]


def test_replicas_needed_sizing():
    assert replicas_needed(ModelDemand("m", rate=10.0, service_time_s=0.1)) == 2
    assert replicas_needed(ModelDemand("m", rate=0.01, service_time_s=0.01)) == 1


def test_placement_objective_cost_vs_p99():
    models = [ModelDemand("m", rate=20.0, service_time_s=0.05)]
    cheap = plan_placement(models, _clouds(), objective="cost")
    fast = plan_placement(models, _clouds(), objective="p99")
    assert cheap.assignments[0].cloud == "gcp"       # cheaper replicas
    assert fast.assignments[0].cloud == "ibm"        # same-VPC lower RTT
    assert cheap.total_cost_hr < fast.total_cost_hr
    assert fast.worst_p99_s < cheap.worst_p99_s


def test_placement_respects_capacity_and_flags_infeasible():
    # both models need 3 replicas; capacities 3 + 1 can only hold one
    models = [ModelDemand("big", rate=40.0, service_time_s=0.05),
              ModelDemand("big2", rate=38.0, service_time_s=0.05)]
    clouds = [CloudCapacity(get_profile("gcp"), 3, 1.0),
              CloudCapacity(get_profile("ibm"), 1, 2.0)]
    plan = plan_placement(models, clouds, objective="cost")
    assert not plan.feasible
    placed = [a for a in plan.assignments if a.cloud]
    unplaced = [a for a in plan.assignments if a.cloud is None]
    assert len(placed) == 1 and len(unplaced) == 1
    assert placed[0].model == "big"                  # heaviest placed first


def test_placement_capacity_map_feeds_gateway():
    models = [ModelDemand("a", rate=20.0, service_time_s=0.05),
              ModelDemand("b", rate=10.0, service_time_s=0.05)]
    plan = plan_placement(models, _clouds(), objective="cost")
    assert plan.feasible
    cap = plan.capacity_map()
    assert sum(cap.values()) == sum(a.replicas for a in plan.assignments)
    gw = Gateway(capacity=cap)      # planner budget enforced by the router
    assert gw.capacity == cap


def test_placement_overload_estimate_is_inf():
    d = ModelDemand("m", rate=100.0, service_time_s=0.1)   # 10 Erlangs
    assert est_p99_s(get_profile("gcp"), d, 5) == math.inf


def test_saturated_estimates_never_finite():
    """Regression (ISSUE 2 bugfix): utilization >= 1 or an empty replica
    set has no finite tail, and an infeasible plan must not report the
    finite worst_p99_s of whatever happened to fit."""
    d = ModelDemand("m", rate=100.0, service_time_s=0.1)
    assert est_p99_s(get_profile("gcp"), d, 0) == math.inf    # no replicas
    assert est_p99_s(get_profile("gcp"), d, 10) == math.inf   # rho == 1.0
    assert est_p99_s(get_profile("gcp"), d, 11) < math.inf    # rho < 1
    models = [ModelDemand("big", rate=40.0, service_time_s=0.05),
              ModelDemand("big2", rate=38.0, service_time_s=0.05)]
    clouds = [CloudCapacity(get_profile("gcp"), 3, 1.0),
              CloudCapacity(get_profile("ibm"), 1, 2.0)]
    plan = plan_placement(models, clouds, objective="cost")
    assert not plan.feasible
    assert plan.worst_p99_s == math.inf          # was: finite max over placed
    s = plan.summary()
    assert s["worst_p99_s"] == "inf"
    unplaced = [a for a in plan.assignments if a.cloud is None]
    assert unplaced and all(a.saturated for a in unplaced)
    placed = [a for a in plan.assignments if a.cloud]
    assert all(not a.saturated for a in placed)


# -- observed-load re-planning ----------------------------------------------

def test_replan_moves_toward_observed_load():
    """Round trip: plan from a (deliberately wrong) demand estimate, run
    the real traffic, re-plan from the measured result.  Revised replica
    counts must move toward the observed load and the new capacity map
    must stay within the clouds' budgets."""
    est = ModelDemand("m", rate=5.0, service_time_s=0.01)   # 10x underrated
    clouds = _clouds(cap=8)
    plan = plan_placement([est], clouds, objective="cost")
    n0 = plan.assignments[0].replicas
    assert n0 == 1

    gw = Gateway(capacity=plan.capacity_map())
    gw.deploy("m", AnalyticBackend("m", base_s=0.01),
              get_profile(plan.assignments[0].cloud),
              autoscaler=AutoscalerConfig(min_replicas=1, max_replicas=n0,
                                          idle_window_s=math.inf),
              max_batch=1)
    out = gw.run([TrafficSpec("m", 400, arrival="poisson", rate=150.0)],
                 seed=0)
    obs = out.per_model["m"].observed
    assert obs["n"] == 400 and obs["service_time_s"] > 0

    plan2 = replan(plan, out)           # clouds + objective carried over
    assert plan2.objective == plan.objective
    n1 = plan2.assignments[0].replicas
    assert n1 > n0                      # moved toward the observed load
    assert n1 == replicas_needed(
        ModelDemand("m", obs["rate_rps"], obs["service_time_s"]))
    assert plan2.feasible
    cap_map = plan2.capacity_map()
    avail = {c.profile.name: c.max_replicas for c in clouds}
    assert all(cap_map[c] <= avail[c] for c in cap_map)


def test_replan_keeps_untrafficked_models_reserved():
    """A model that saw no traffic this window keeps its prior assignment
    and its replicas stay reserved in the revised capacity map."""
    demands = [ModelDemand("busy", rate=5.0, service_time_s=0.01),
               ModelDemand("quiet", rate=10.0, service_time_s=0.05)]
    plan = plan_placement(demands, _clouds(cap=8), objective="cost")
    assert plan.feasible
    quiet0 = next(a for a in plan.assignments if a.model == "quiet")

    gw = Gateway(capacity=plan.capacity_map())
    for name in ("busy", "quiet"):
        gw.deploy(name, AnalyticBackend(name, base_s=0.01), get_profile("gcp"),
                  autoscaler=AutoscalerConfig(min_replicas=1, max_replicas=1,
                                              idle_window_s=math.inf))
    out = gw.run([TrafficSpec("busy", 50, arrival="poisson", rate=40.0)],
                 seed=0)
    assert "quiet" not in out.per_model          # untrafficked this window

    plan2 = replan(plan, out)
    by_model = {a.model: a for a in plan2.assignments}
    assert by_model["quiet"].cloud == quiet0.cloud
    assert by_model["quiet"].replicas == quiet0.replicas
    assert plan2.feasible
    assert plan2.capacity_map()[quiet0.cloud] >= quiet0.replicas


def test_replan_requires_clouds_and_observed_stats():
    plan = plan_placement([ModelDemand("m", 5.0, 0.01)], _clouds())
    bare = plan_placement([ModelDemand("m", 5.0, 0.01)], _clouds())
    bare.clouds = []
    from repro.serving.gateway import GatewayResult, ServeResult
    fake = GatewayResult(
        {"m": ServeResult("gateway:m", 1, 1.0, [1.0])}, {"m": 0}, 1.0)
    with pytest.raises(ValueError, match="clouds"):
        replan(bare, fake)
    with pytest.raises(ValueError, match="observed"):
        replan(plan, fake)              # result lacks observed stats


# -- LLM backend behind the router ------------------------------------------

def test_batcher_backend_service_time_and_generation():
    import jax
    from repro.configs import registry
    from repro.models import lm
    from repro.serving.continuous import ContinuousBatcher

    cfg = registry.get_smoke_config("h2o_danube_3_4b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    cb = ContinuousBatcher(cfg, params, max_slots=2, max_len=64)
    be = BatcherBackend("llm", cb, prompt_len=4, gen_tokens=3)
    t1 = be.service_time(2)          # one slot wave
    t2 = be.service_time(3)          # two waves
    assert t1 > 0
    assert abs(t2 / t1 - 2.0) < 1e-6
    outs = be.generate([[5, 17, 99], [7, 7]], max_new=3)
    assert len(outs) == 2 and all(len(o) == 3 for o in outs)

    gw = Gateway()
    gw.deploy("llm", be, get_profile("ibm"), autoscaler=warm_config(),
              max_batch=4)
    res = gw.run([TrafficSpec("llm", 12)]).per_model["llm"]
    assert res.n_requests == 12 and res.p99 > 0
