"""Model-mesh gateway: multi-model routing, scale-to-zero autoscaling with
cold starts, shared per-cloud capacity, and multi-cloud placement."""
import math

import numpy as np
import pytest

from repro.clouds.profiles import get_profile
from repro.serving.gateway import (AutoscalerConfig, BatcherBackend,
                                   CloudCapacity, Gateway, ModelDemand,
                                   Predictor, TrafficSpec, plan_placement,
                                   replicas_needed)
from repro.telemetry.events import EventLog


def make_predictor(name="m", cost_s=0.0):
    import time

    def predict(x):
        if cost_s:
            time.sleep(cost_s)
        return x.sum(axis=tuple(range(1, x.ndim)))

    return Predictor(name, predict, np.zeros((1, 4), np.float32))


def warm_config(**kw):
    """Legacy-style pool: starts warm, never idles out."""
    return AutoscalerConfig(min_replicas=kw.pop("min_replicas", 1),
                            idle_window_s=kw.pop("idle_window_s", math.inf),
                            **kw)


def test_multi_model_all_served_exactly_once():
    gw = Gateway()
    for name in ("a", "b", "c"):
        gw.deploy(name, make_predictor(name), get_profile("gcp"),
                  autoscaler=warm_config(), max_batch=8)
    out = gw.run([TrafficSpec("a", 100),
                  TrafficSpec("b", 50, arrival="poisson", rate=200.0),
                  TrafficSpec("c", 25)], seed=0)
    assert set(out.per_model) == {"a", "b", "c"}
    for name, n in (("a", 100), ("b", 50), ("c", 25)):
        res = out.per_model[name]
        assert res.n_requests == n
        assert len(res.latencies_s) == n
        assert all(l > 0 for l in res.latencies_s)
        assert sum(res.per_version.values()) == n
    assert out.makespan_s >= max(r.total_time_s for r in out.per_model.values()) - 1e-12


def test_multiple_specs_for_one_model_concatenate():
    gw = Gateway()
    gw.deploy("a", make_predictor("a"), get_profile("gcp"),
              autoscaler=warm_config())
    out = gw.run([TrafficSpec("a", 10), TrafficSpec("a", 10, start_s=1.0)])
    assert out.per_model["a"].n_requests == 20


def test_scale_to_zero_cold_start_cycle():
    """min_replicas=0: burst -> cold start, idle out to zero, second burst
    pays a second cold start (Cox et al. serverless-inferencing behavior)."""
    log = EventLog()
    prof = get_profile("gcp")
    gw = Gateway(log=log)
    gw.deploy("m", make_predictor("m"), prof,
              autoscaler=AutoscalerConfig(min_replicas=0, max_replicas=2,
                                          scale_up_delay_s=0.5,
                                          idle_window_s=0.5))
    out = gw.run([TrafficSpec("m", 8), TrafficSpec("m", 8, start_s=10.0)])
    assert out.cold_starts["m"] == 2
    trace = out.per_model["m"].replica_trace
    assert trace[0] == (0.0, 0)
    pools = [p for _, p in trace]
    assert 0 in pools[1:]                # scaled back to zero mid-run
    names = [e["name"] for e in log.events]
    assert names.count("gateway:cold_start") == 2
    assert "gateway:scale_to_zero" in names
    # first request of each burst pays control-plane delay + model load
    lat = out.per_model["m"].latencies_s
    assert max(lat[:8]) >= 0.5 + prof.model_load_s
    assert max(lat[8:]) >= 0.5 + prof.model_load_s


def test_cold_start_penalty_matches_profile_constants():
    prof = get_profile("gcp")
    warm = Gateway()
    warm.deploy("m", make_predictor("m"), prof, autoscaler=warm_config())
    lat_warm = warm.run([TrafficSpec("m", 1)]).per_model["m"].latencies_s[0]
    cold = Gateway()
    cold.deploy("m", make_predictor("m"), prof,
                autoscaler=AutoscalerConfig(min_replicas=0,
                                            scale_up_delay_s=0.5,
                                            idle_window_s=1.0))
    lat_cold = cold.run([TrafficSpec("m", 1)]).per_model["m"].latencies_s[0]
    penalty = lat_cold - lat_warm
    assert abs(penalty - (0.5 + prof.model_load_s)) < 0.02


def test_idle_replicas_retire_back_to_min():
    gw = Gateway()
    gw.deploy("m", make_predictor("m", cost_s=0.002), get_profile("gcp"),
              autoscaler=AutoscalerConfig(min_replicas=1, max_replicas=4,
                                          target_queue=4, idle_window_s=0.5),
              max_batch=4)
    out = gw.run([TrafficSpec("m", 128)])
    trace = out.per_model["m"].replica_trace
    assert max(p for _, p in trace) > 1          # scaled up under the burst
    assert trace[-1][1] == 1                     # decayed back to min


def test_shared_cloud_capacity_is_enforced():
    log = EventLog()
    gw = Gateway(capacity={"gcp": 3}, log=log)
    for name in ("a", "b"):
        gw.deploy(name, make_predictor(name, cost_s=0.002), get_profile("gcp"),
                  autoscaler=warm_config(max_replicas=4, target_queue=2),
                  max_batch=2)
    out = gw.run([TrafficSpec("a", 64), TrafficSpec("b", 64)])
    # replay the two traces together: total pool never exceeds the cap
    merged = sorted((t, name, p) for name in ("a", "b")
                    for t, p in out.per_model[name].replica_trace)
    cur, peak = {"a": 0, "b": 0}, 0
    for _, name, p in merged:
        cur[name] = p
        peak = max(peak, cur["a"] + cur["b"])
    assert peak <= 3
    assert any(e["name"] == "gateway:scale_denied" for e in log.events)
    assert all(out.per_model[m].n_requests == 64 for m in ("a", "b"))


def test_scale_from_zero_not_starved_by_warm_pools():
    """A cloud pinned full by never-idling pools must not deadlock a
    scale-to-zero deployment: its first replica launches over budget and
    the breach is logged (gateway:capacity_exceeded)."""
    log = EventLog()
    gw = Gateway(capacity={"gcp": 1}, log=log)
    gw.deploy("warm", make_predictor("warm"), get_profile("gcp"),
              autoscaler=warm_config(min_replicas=1))
    gw.deploy("cold", make_predictor("cold"), get_profile("gcp"),
              autoscaler=AutoscalerConfig(min_replicas=0, idle_window_s=1.0))
    out = gw.run([TrafficSpec("warm", 4), TrafficSpec("cold", 4)])
    assert out.per_model["cold"].n_requests == 4
    assert all(l > 0 for l in out.per_model["cold"].latencies_s)
    assert any(e["name"] == "gateway:capacity_exceeded" for e in log.events)


def test_min_replicas_over_capacity_rejected_up_front():
    gw = Gateway(capacity={"gcp": 1})
    for name in ("a", "b"):
        gw.deploy(name, make_predictor(name), get_profile("gcp"),
                  autoscaler=warm_config(min_replicas=1))
    with pytest.raises(ValueError, match="capacity"):
        gw.run([TrafficSpec("a", 2), TrafficSpec("b", 2)])


def test_untrafficked_deployment_still_holds_capacity():
    """A deployed model that gets no traffic this run keeps its warm pool,
    which counts against the shared cloud cap (and the baseline check)."""
    log = EventLog()
    gw = Gateway(capacity={"gcp": 2}, log=log)
    gw.deploy("quiet", make_predictor("quiet"), get_profile("gcp"),
              autoscaler=warm_config(min_replicas=1))
    gw.deploy("busy", make_predictor("busy", cost_s=0.002), get_profile("gcp"),
              autoscaler=warm_config(max_replicas=4, target_queue=2),
              max_batch=2)
    out = gw.run([TrafficSpec("busy", 64)])
    assert "quiet" not in out.per_model          # no traffic -> no results
    assert max(p for _, p in out.per_model["busy"].replica_trace) == 1
    assert any(e["name"] == "gateway:scale_denied" for e in log.events)

    strict = Gateway(capacity={"gcp": 1})
    strict.deploy("quiet", make_predictor("quiet"), get_profile("gcp"),
                  autoscaler=warm_config(min_replicas=1))
    strict.deploy("busy", make_predictor("busy"), get_profile("gcp"),
                  autoscaler=warm_config(min_replicas=1))
    with pytest.raises(ValueError, match="capacity"):
        strict.run([TrafficSpec("busy", 2)])


def test_canary_split_through_gateway():
    gw = Gateway()
    gw.deploy("m", make_predictor("stable"), get_profile("gcp"),
              autoscaler=warm_config(), canary=make_predictor("canary"),
              canary_fraction=0.3)
    res = gw.run([TrafficSpec("m", 500)], seed=11).per_model["m"]
    assert sum(res.per_version.values()) == 500
    assert 0.2 < res.per_version.get("canary", 0) / 500 < 0.4


def test_unknown_model_raises():
    gw = Gateway()
    with pytest.raises(KeyError):
        gw.run([TrafficSpec("ghost", 4)])


# -- placement ---------------------------------------------------------------

def _clouds(gcp_cost=1.0, ibm_cost=2.0, cap=8):
    return [CloudCapacity(get_profile("gcp"), cap, gcp_cost),
            CloudCapacity(get_profile("ibm"), cap, ibm_cost)]


def test_replicas_needed_sizing():
    assert replicas_needed(ModelDemand("m", rate=10.0, service_time_s=0.1)) == 2
    assert replicas_needed(ModelDemand("m", rate=0.01, service_time_s=0.01)) == 1


def test_placement_objective_cost_vs_p99():
    models = [ModelDemand("m", rate=20.0, service_time_s=0.05)]
    cheap = plan_placement(models, _clouds(), objective="cost")
    fast = plan_placement(models, _clouds(), objective="p99")
    assert cheap.assignments[0].cloud == "gcp"       # cheaper replicas
    assert fast.assignments[0].cloud == "ibm"        # same-VPC lower RTT
    assert cheap.total_cost_hr < fast.total_cost_hr
    assert fast.worst_p99_s < cheap.worst_p99_s


def test_placement_respects_capacity_and_flags_infeasible():
    # both models need 3 replicas; capacities 3 + 1 can only hold one
    models = [ModelDemand("big", rate=40.0, service_time_s=0.05),
              ModelDemand("big2", rate=38.0, service_time_s=0.05)]
    clouds = [CloudCapacity(get_profile("gcp"), 3, 1.0),
              CloudCapacity(get_profile("ibm"), 1, 2.0)]
    plan = plan_placement(models, clouds, objective="cost")
    assert not plan.feasible
    placed = [a for a in plan.assignments if a.cloud]
    unplaced = [a for a in plan.assignments if a.cloud is None]
    assert len(placed) == 1 and len(unplaced) == 1
    assert placed[0].model == "big"                  # heaviest placed first


def test_placement_capacity_map_feeds_gateway():
    models = [ModelDemand("a", rate=20.0, service_time_s=0.05),
              ModelDemand("b", rate=10.0, service_time_s=0.05)]
    plan = plan_placement(models, _clouds(), objective="cost")
    assert plan.feasible
    cap = plan.capacity_map()
    assert sum(cap.values()) == sum(a.replicas for a in plan.assignments)
    gw = Gateway(capacity=cap)      # planner budget enforced by the router
    assert gw.capacity == cap


def test_placement_overload_estimate_is_inf():
    from repro.serving.gateway import est_p99_s
    d = ModelDemand("m", rate=100.0, service_time_s=0.1)   # 10 Erlangs
    assert est_p99_s(get_profile("gcp"), d, 5) == math.inf


# -- LLM backend behind the router ------------------------------------------

def test_batcher_backend_service_time_and_generation():
    import jax
    from repro.configs import registry
    from repro.models import lm
    from repro.serving.continuous import ContinuousBatcher

    cfg = registry.get_smoke_config("h2o_danube_3_4b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    cb = ContinuousBatcher(cfg, params, max_slots=2, max_len=64)
    be = BatcherBackend("llm", cb, prompt_len=4, gen_tokens=3)
    t1 = be.service_time(2)          # one slot wave
    t2 = be.service_time(3)          # two waves
    assert t1 > 0
    assert abs(t2 / t1 - 2.0) < 1e-6
    outs = be.generate([[5, 17, 99], [7, 7]], max_new=3)
    assert len(outs) == 2 and all(len(o) == 3 for o in outs)

    gw = Gateway()
    gw.deploy("llm", be, get_profile("ibm"), autoscaler=warm_config(),
              max_batch=4)
    res = gw.run([TrafficSpec("llm", 12)]).per_model["llm"]
    assert res.n_requests == 12 and res.p99 > 0
