"""KServe analog: strategy ordering (paper Table 3 shape), batching,
autoscaling, canary traffic split."""
import numpy as np
import pytest

from repro.clouds.profiles import get_profile
from repro.serving.kserve import InferenceService, Predictor


def make_predictor(name="v1", cost_s=0.0):
    import time

    def predict(x):
        if cost_s:
            time.sleep(cost_s)
        return x.sum(axis=tuple(range(1, x.ndim)))

    return Predictor(name, predict, np.zeros((1, 4), np.float32))


def test_strategy_ordering_matches_paper_table3():
    """baremetal >> k8s > kserve for every request count (paper's finding)."""
    pred = make_predictor()
    pred.warmup((1, 32))
    totals = {}
    for strat, prof in (("baremetal", "baremetal"), ("k8s", "k8s"),
                        ("kserve", "gcp")):
        svc = InferenceService(pred, get_profile(prof), strat)
        totals[strat] = [svc.stress_test(n).total_time_s for n in (8, 64, 256)]
    for i in range(3):
        assert totals["baremetal"][i] > totals["k8s"][i] > totals["kserve"][i]
    # the gap grows with request count (paper Fig. 21)
    assert totals["baremetal"][2] / totals["kserve"][2] > \
        totals["baremetal"][0] / totals["kserve"][0] * 0.5


def test_ibm_profile_faster_inference_than_gcp():
    """Paper §7(1): same-VPC IBM network -> lower inference time."""
    pred = make_predictor()
    gcp = InferenceService(pred, get_profile("gcp"), "kserve").stress_test(128)
    ibm = InferenceService(pred, get_profile("ibm"), "kserve").stress_test(128)
    assert ibm.total_time_s < gcp.total_time_s


def test_all_requests_served_exactly_once():
    pred = make_predictor()
    svc = InferenceService(pred, get_profile("gcp"), "kserve", max_batch=8)
    res = svc.stress_test(100)
    assert res.n_requests == 100
    assert len(res.latencies_s) == 100
    assert all(l > 0 for l in res.latencies_s)
    assert sum(res.per_version.values()) == 100


def test_autoscaler_adds_replicas_under_load():
    pred = make_predictor(cost_s=0.002)
    svc = InferenceService(pred, get_profile("gcp"), "kserve", max_batch=4,
                           min_replicas=1, max_replicas=4, target_queue=4)
    res = svc.stress_test(128)
    assert max(r for _, r in res.replica_trace) > 1
    assert max(r for _, r in res.replica_trace) <= 4


def test_canary_traffic_split():
    v1, v2 = make_predictor("v1"), make_predictor("v2")
    svc = InferenceService(v1, get_profile("gcp"), "kserve",
                           canary=v2, canary_fraction=0.25)
    res = svc.stress_test(400, seed=7)
    frac = res.per_version.get("v2", 0) / 400
    assert 0.15 < frac < 0.35


def test_batching_reduces_per_request_cost():
    pred = make_predictor(cost_s=0.001)
    small = InferenceService(pred, get_profile("gcp"), "kserve", max_batch=1)
    big = InferenceService(pred, get_profile("gcp"), "kserve", max_batch=32)
    assert big.stress_test(64).total_time_s < small.stress_test(64).total_time_s


def test_poisson_arrivals_latency_includes_queueing():
    pred = make_predictor(cost_s=0.002)
    svc = InferenceService(pred, get_profile("gcp"), "kserve", max_batch=4,
                           max_replicas=1)
    # overload: arrival rate >> service rate -> queueing delay dominates
    hot = svc.stress_test(64, arrival="poisson", rate=10000.0)
    cold = svc.stress_test(64, arrival="poisson", rate=5.0)
    assert hot.p99 > cold.p99
    assert all(l > 0 for l in hot.latencies_s)
    assert hot.n_requests == 64 and sum(hot.per_version.values()) == 64


def test_poisson_underload_latency_near_service_time():
    pred = make_predictor()
    svc = InferenceService(pred, get_profile("gcp"), "kserve", max_batch=8)
    res = svc.stress_test(32, arrival="poisson", rate=2.0)
    base = (get_profile("gcp").network_rtt_s + get_profile("gcp").lb_overhead_s
            + pred.service_time(1))
    assert res.p50 < base * 3 + 0.01


def test_burst_mode_unchanged_semantics():
    pred = make_predictor()
    a = InferenceService(pred, get_profile("gcp"), "kserve").stress_test(50)
    assert a.n_requests == 50 and len(a.latencies_s) == 50


def test_poisson_canary_split_and_accounting():
    """Canary routing under open-loop arrivals: split fraction holds and
    every request lands on exactly one version."""
    v1, v2 = make_predictor("v1"), make_predictor("v2")
    svc = InferenceService(v1, get_profile("gcp"), "kserve",
                           canary=v2, canary_fraction=0.3)
    res = svc.stress_test(300, seed=3, arrival="poisson", rate=500.0)
    assert sum(res.per_version.values()) == 300
    assert 0.2 < res.per_version.get("v2", 0) / 300 < 0.4
    assert all(l > 0 for l in res.latencies_s)


def test_canary_zero_fraction_never_routes():
    v1, v2 = make_predictor("v1"), make_predictor("v2")
    svc = InferenceService(v1, get_profile("gcp"), "kserve",
                           canary=v2, canary_fraction=0.0)
    res = svc.stress_test(64)
    assert res.per_version == {"v1": 64}


def test_canary_split_deterministic_per_seed():
    v1, v2 = make_predictor("v1"), make_predictor("v2")
    svc = InferenceService(v1, get_profile("gcp"), "kserve",
                           canary=v2, canary_fraction=0.25)
    a = svc.stress_test(200, seed=5).per_version
    b = svc.stress_test(200, seed=5).per_version
    assert a == b


def test_poisson_latency_floor_is_network_path():
    pred = make_predictor()
    prof = get_profile("gcp")
    svc = InferenceService(pred, prof, "kserve", max_batch=8)
    res = svc.stress_test(64, arrival="poisson", rate=20.0)
    floor = prof.network_rtt_s + prof.lb_overhead_s
    assert min(res.latencies_s) >= floor
    assert res.total_time_s >= max(res.latencies_s)


def test_slo_passthrough_reports_per_class():
    """stress_test(slo=...) reaches the gateway: the result carries the
    class's percentiles and deadline-miss rate."""
    pred = make_predictor()
    pred.warmup((1, 32))
    svc = InferenceService(pred, get_profile("gcp"), "kserve")
    res = svc.stress_test(64, slo="latency")
    pc = res.per_class()
    assert set(pc) == {"latency"}
    assert pc["latency"]["n"] == 64
    assert 0.0 <= pc["latency"]["miss_rate"] <= 1.0
    assert res.observed["n"] == 64


def test_stress_test_zero_requests_is_empty_result():
    """Regression: the gateway omits untrafficked models from per_model;
    stress_test(0) must return an empty result, not raise KeyError."""
    svc = InferenceService(make_predictor(), get_profile("gcp"), "kserve")
    res = svc.stress_test(0)
    assert res.n_requests == 0
    assert res.latencies_s == []
    assert res.total_time_s == 0.0
