import jax
import jax.numpy as jnp
import numpy as np
import pytest


def make_batch(cfg, B, S, key=None, labels=True):
    """Batch dict matching models.lm.forward's contract for any family."""
    key = key if key is not None else jax.random.PRNGKey(1)
    ks = jax.random.split(key, 4)
    b = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)}
    if labels:
        b["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    if cfg.use_mrope:
        b["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None], (B, 3, S))
    if cfg.family == "vlm":
        b["vision_embeds"] = jax.random.normal(
            ks[2], (B, min(cfg.n_vision_tokens, S), cfg.d_model))
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(ks[3], (B, cfg.encoder_len, cfg.d_model))
    return b


@pytest.fixture(scope="session")
def mnist_data():
    from repro.data.mnist import make_dataset
    return make_dataset(256, seed=0)
