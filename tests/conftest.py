import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    # Hypothesis profiles for the property suites (test_properties.py,
    # test_gateway_invariants.py): "dev" runs the full 200 examples,
    # "ci" is a smaller deadline-free subset so the tier-1 workflow stays
    # fast and deterministic (ci.yml pins HYPOTHESIS_PROFILE=ci and a fixed
    # --hypothesis-seed).  Both disable the per-example deadline: simulated
    # fleets are cheap but wall-clock-noisy on shared runners.
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("dev", max_examples=200, deadline=None)
    _hyp_settings.register_profile("ci", max_examples=25, deadline=None)
    _hyp_settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:                      # dev dep; suites importorskip/skip
    pass


@pytest.fixture(autouse=True)
def _no_global_log_leaks():
    """GLOBAL_LOG is a retired legacy sink (telemetry/events.py): every
    gateway / orchestrator owns a run-scoped EventLog.  Fail any test that
    records into the shared singleton -- a leak here means some code path
    silently fell back to it."""
    from repro.telemetry.events import GLOBAL_LOG
    before = len(GLOBAL_LOG.events)
    yield
    leaked = GLOBAL_LOG.events[before:]
    assert not leaked, (
        f"{len(leaked)} event(s) leaked into the legacy GLOBAL_LOG "
        f"(first: {leaked[0]['name']!r}); pass log=EventLog() instead")


def make_batch(cfg, B, S, key=None, labels=True):
    """Batch dict matching models.lm.forward's contract for any family."""
    key = key if key is not None else jax.random.PRNGKey(1)
    ks = jax.random.split(key, 4)
    b = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)}
    if labels:
        b["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    if cfg.use_mrope:
        b["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None], (B, 3, S))
    if cfg.family == "vlm":
        b["vision_embeds"] = jax.random.normal(
            ks[2], (B, min(cfg.n_vision_tokens, S), cfg.d_model))
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(ks[3], (B, cfg.encoder_len, cfg.d_model))
    return b


class AnalyticBackend:
    """Closed-form gateway backend (the router only needs .name and
    .service_time): deterministic service times with no hardware
    measurement, shared by the gateway unit and invariant suites."""

    def __init__(self, name, base_s=0.05, per_req_s=0.0):
        self.name = name
        self.base_s = base_s
        self.per_req_s = per_req_s

    def service_time(self, b):
        return self.base_s + self.per_req_s * b


@pytest.fixture(scope="session")
def mnist_data():
    from repro.data.mnist import make_dataset
    return make_dataset(256, seed=0)
