"""Pipeline DAG semantics: ordering, cycles, caching, spec export."""
import pytest
import yaml

from repro.checkpoint.store import ArtifactStore
from repro.core.pipeline import Pipeline, StepRef


def double(x):
    return x * 2


def add(a, b):
    return a + b


def seven():
    return 7


def test_topological_execution_and_outputs(tmp_path):
    p = Pipeline("t", ArtifactStore(str(tmp_path)))
    a = p.step(seven)
    b = p.step(double, a)
    c = p.step(add, a, b)
    out = p.run()
    assert out == {"seven": 7, "double": 14, "add": 21}


def test_dependency_order_independent_of_declaration(tmp_path):
    p = Pipeline("t2", ArtifactStore(str(tmp_path)))
    # declare consumer first via forward ref
    a = p.step(seven)
    c_ref = StepRef  # noqa: just to show refs are plain handles
    b = p.step(double, a)
    out = p.run()
    assert out["double"] == 14


def test_cycle_detection():
    p = Pipeline("cyc")
    a = p.step(double, StepRef("b", 1))
    b = p.step(double, StepRef("a", 0))
    with pytest.raises(ValueError, match="cycle"):
        p.run()


def test_step_caching_across_runs(tmp_path):
    store = ArtifactStore(str(tmp_path))

    def build():
        p = Pipeline("cached", store)
        a = p.step(seven)
        b = p.step(double, a)
        return p

    p1 = build()
    p1.run()
    assert [s.cached for s in p1.steps] == [False, False]
    p2 = build()
    out = p2.run()
    assert [s.cached for s in p2.steps] == [True, True]
    assert out["double"] == 14


def test_cache_invalidated_by_input_change(tmp_path):
    store = ArtifactStore(str(tmp_path))
    p1 = Pipeline("inv", store)
    p1.step(double, 3, name="d")
    assert p1.run()["d"] == 6
    p2 = Pipeline("inv", store)
    p2.step(double, 4, name="d")
    assert p2.run()["d"] == 8          # not the stale cached 6
    assert p2.steps[0].cached is False


def test_yaml_spec_roundtrip(tmp_path):
    p = Pipeline("spec-test", ArtifactStore(str(tmp_path)))
    a = p.step(seven)
    b = p.step(double, a)
    spec = yaml.safe_load(p.export_yaml())
    assert spec["kind"] == "Pipeline"
    assert spec["metadata"]["name"] == "spec-test"
    steps = spec["spec"]["steps"]
    assert steps[1]["dependencies"] == ["seven"]


def test_stage_timing_recorded(tmp_path):
    p = Pipeline("timed", ArtifactStore(str(tmp_path)))
    p.step(seven)
    p.run()
    names = [e["name"] for e in p.log.events]
    assert "seven" in names and "pipeline:timed" in names


def test_same_function_steps_get_numbered_names(tmp_path):
    """Two steps built from the same function must not share a name (the
    pre-fix {name: output} dict dropped the earlier output)."""
    p = Pipeline("dup", ArtifactStore(str(tmp_path)))
    a = p.step(seven)
    b = p.step(double, a)
    p.step(double, b)
    assert [s.name for s in p.steps] == ["seven", "double", "double_2"]
    out = p.run()
    assert out["double"] == 14 and out["double_2"] == 28


def test_step_name_suffix_never_collides_with_explicit_name(tmp_path):
    """Regression (fails pre-fix): the generated dedup suffix used the step
    COUNT without re-checking, so it could silently collide with an
    explicit name ('double_2' here) and drop an output."""
    p = Pipeline("collide", ArtifactStore(str(tmp_path)))
    p.step(seven, name="double_2")
    p.step(double, 3)
    p.step(double, 4)
    names = [s.name for s in p.steps]
    assert len(set(names)) == len(names), names
    out = p.run()
    assert len(out) == 3
    assert out["double_2"] == 7 and out["double"] == 6
    assert out["double_3"] == 8


def test_toposort_deterministic_insertion_order():
    from repro.core.pipeline import toposort

    p = Pipeline("order")
    refs = [p.step(seven, name=f"s{i}") for i in range(5)]
    p.step(add, refs[4], refs[0], name="sink")
    # independent steps run in insertion-index order, every time
    assert p._toposort() == [0, 1, 2, 3, 4, 5]
    # diamond: children unlock in insertion order (deque FIFO)
    assert toposort([[], [0], [0], [1, 2]]) == [0, 1, 2, 3]
    with pytest.raises(ValueError, match="cycle"):
        toposort([[1], [0]])


def test_compile_lowers_to_pipeline_spec():
    p = Pipeline("c")
    a = p.step(seven)
    p.step(double, a, sim_s=0.5, pin="gcp")
    spec = p.compile()
    assert [s.name for s in spec.steps] == ["seven", "double"]
    assert spec.steps[1].deps == (0,)
    assert spec.steps[1].sim_s == 0.5 and spec.steps[1].pin == "gcp"
    d = spec.to_dict()
    assert d["spec"]["steps"][1]["dependencies"] == ["seven"]


def test_serial_and_compiled_cache_keys_agree(tmp_path):
    """The serial executor and the orchestrator share step_cache_key: a
    step cached by Pipeline.run is a hit for an orchestrator run."""
    from repro.core.pipeline import step_cache_key

    store = ArtifactStore(str(tmp_path))
    p = Pipeline("shared", store)
    p.step(double, 5, name="d")
    p.run()
    spec = p.compile()
    s = spec.steps[0]
    key = step_cache_key(spec.name, s.name, s.fn, (5,), {})
    assert store.exists(key)
    assert store.load_json(key)["value"] == 10
