"""Pipeline DAG semantics: ordering, cycles, caching, spec export."""
import pytest
import yaml

from repro.checkpoint.store import ArtifactStore
from repro.core.pipeline import Pipeline, StepRef


def double(x):
    return x * 2


def add(a, b):
    return a + b


def seven():
    return 7


def test_topological_execution_and_outputs(tmp_path):
    p = Pipeline("t", ArtifactStore(str(tmp_path)))
    a = p.step(seven)
    b = p.step(double, a)
    c = p.step(add, a, b)
    out = p.run()
    assert out == {"seven": 7, "double": 14, "add": 21}


def test_dependency_order_independent_of_declaration(tmp_path):
    p = Pipeline("t2", ArtifactStore(str(tmp_path)))
    # declare consumer first via forward ref
    a = p.step(seven)
    c_ref = StepRef  # noqa: just to show refs are plain handles
    b = p.step(double, a)
    out = p.run()
    assert out["double"] == 14


def test_cycle_detection():
    p = Pipeline("cyc")
    a = p.step(double, StepRef("b", 1))
    b = p.step(double, StepRef("a", 0))
    with pytest.raises(ValueError, match="cycle"):
        p.run()


def test_step_caching_across_runs(tmp_path):
    store = ArtifactStore(str(tmp_path))

    def build():
        p = Pipeline("cached", store)
        a = p.step(seven)
        b = p.step(double, a)
        return p

    p1 = build()
    p1.run()
    assert [s.cached for s in p1.steps] == [False, False]
    p2 = build()
    out = p2.run()
    assert [s.cached for s in p2.steps] == [True, True]
    assert out["double"] == 14


def test_cache_invalidated_by_input_change(tmp_path):
    store = ArtifactStore(str(tmp_path))
    p1 = Pipeline("inv", store)
    p1.step(double, 3, name="d")
    assert p1.run()["d"] == 6
    p2 = Pipeline("inv", store)
    p2.step(double, 4, name="d")
    assert p2.run()["d"] == 8          # not the stale cached 6
    assert p2.steps[0].cached is False


def test_yaml_spec_roundtrip(tmp_path):
    p = Pipeline("spec-test", ArtifactStore(str(tmp_path)))
    a = p.step(seven)
    b = p.step(double, a)
    spec = yaml.safe_load(p.export_yaml())
    assert spec["kind"] == "Pipeline"
    assert spec["metadata"]["name"] == "spec-test"
    steps = spec["spec"]["steps"]
    assert steps[1]["dependencies"] == ["seven"]


def test_stage_timing_recorded(tmp_path):
    p = Pipeline("timed", ArtifactStore(str(tmp_path)))
    p.step(seven)
    p.run()
    names = [e["name"] for e in p.log.events]
    assert "seven" in names and "pipeline:timed" in names
