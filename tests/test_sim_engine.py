"""Shared event-engine core (sim/engine.py) + the ISSUE 7 bug sweep.

Units for the extracted primitives (EventHeap ordering and dead-tail
rule, IndexQueue FIFO semantics, Ledger columns), plus the regression
tests for the satellite fixes that rode the cutover:

- FIFO dispatch and shed-exactly-once through the gateway's IndexQueue
  pending queues (the old ``list.pop(0)`` path);
- the burst arrival-rate fallback in ``_result`` (the old window counted
  drain time, under-reporting the offered rate of a pure burst);
- ``None`` -- not 0.0 -- percentiles for empty / shed-everything pools,
  end to end through ServeResult, summary() and per_class().
"""
import math

import numpy as np
import pytest

from repro.clouds.profiles import get_profile
from repro.serving.gateway import (AdmissionConfig, AutoscalerConfig,
                                   Gateway, ServeResult, TrafficSpec)
from repro.sim import EventHeap, IndexQueue, Ledger
from repro.telemetry.events import EventLog

from conftest import AnalyticBackend


# -- EventHeap ---------------------------------------------------------------

def test_heap_orders_by_time_then_push_order():
    h = EventHeap()
    h.push(2.0, "b", "late")
    h.push(1.0, "a", "first-at-1")
    h.push(1.0, "a", "second-at-1")
    h.push(1.0, "z", "third-at-1")   # kind never participates in ordering
    assert h.peek_t() == 1.0
    got = [h.pop() for _ in range(len(h))]
    assert got == [("a", "first-at-1"), ("a", "second-at-1"),
                   ("z", "third-at-1"), ("b", "late")]
    assert not h and h.peek_t() == math.inf
    assert h.n_pushed == 4 and h.n_popped == 4


def test_heap_payloads_never_compared():
    # payloads with no ordering defined: ties resolve purely on seq
    h = EventHeap()
    h.push(0.0, "k", {"dict": 1})
    h.push(0.0, "k", {"dict": 2})
    assert h.pop() == ("k", {"dict": 1})
    assert h.pop() == ("k", {"dict": 2})


def test_heap_pop_batch_excludes_sameday_pushes():
    """Collect-then-apply: an event pushed at the batch's own t while the
    batch is being handled belongs to the NEXT batch (the orchestrator's
    historical semantics)."""
    h = EventHeap()
    h.push(1.0, "x")
    h.push(1.0, "y")
    h.push(2.0, "z")
    t, batch = h.pop_batch()
    assert t == 1.0 and batch == [("x",), ("y",)]
    h.push(2.0, "w")
    t, batch = h.pop_batch()
    assert t == 2.0 and batch == [("z",), ("w",)]


def test_heap_only_timers_dead_tail_rule():
    h = EventHeap(timer_kinds=("probe", "scrape"))
    assert h.only_timers()          # vacuously: nothing queued
    h.push(5.0, "probe")
    h.push(6.0, "scrape")
    assert h.only_timers()          # timers may NOT re-arm now
    h.push(5.5, "free", "m", ())
    assert not h.only_timers()      # real work pending again
    assert h.pop() == ("probe",)
    assert h.pop() == ("free", "m", ())
    assert h.only_timers()


# -- IndexQueue --------------------------------------------------------------

def test_index_queue_fifo_and_take():
    q = IndexQueue()
    q.extend(range(5))
    q.append(5)
    assert len(q) == 6 and bool(q)
    assert q.peek() == 0
    assert q.popleft() == 0
    assert q.take(3) == [1, 2, 3]
    assert list(q) == [4, 5]        # iteration sees only live items
    assert sorted(q) == [4, 5]
    assert q.take(99) == [4, 5]     # take past the end drains, no error
    assert len(q) == 0 and not q


def test_index_queue_compaction_preserves_order():
    q = IndexQueue(range(1000))
    out = [q.popleft() for _ in range(997)]   # many trims along the way
    assert out == list(range(997))
    q.extend([1000, 1001])
    assert list(q) == [997, 998, 999, 1000, 1001]
    assert [q.popleft() for _ in range(5)] == [997, 998, 999, 1000, 1001]


def test_index_queue_interleaved_matches_plain_list():
    rng = np.random.default_rng(3)
    q, ref = IndexQueue(), []
    for op in rng.integers(0, 3, 500):
        if op == 0 or not ref:
            x = int(rng.integers(0, 1000))
            q.append(x)
            ref.append(x)
        elif op == 1:
            assert q.popleft() == ref.pop(0)
        else:
            k = int(rng.integers(1, 4))
            assert q.take(k) == ref[:k]
            del ref[:k]
        assert list(q) == ref and len(q) == len(ref)


# -- Ledger ------------------------------------------------------------------

def test_ledger_columns_and_deadlines():
    arr = np.array([0.0, 0.5, 1.0])
    led = Ledger(arr, np.array([0, 1, 0], dtype=np.intp),
                 np.zeros(3, int), np.zeros(3))
    assert len(led) == 3
    assert (led.lat == -1.0).all() and not led.shed.any()
    mult = np.array([2.0, 10.0])
    np.testing.assert_allclose(led.deadlines(mult, 0.1), [0.2, 1.0, 0.2])


# -- FIFO dispatch + shed exactly once through the gateway -------------------

def _single_pool_gateway(admission=None):
    gw = Gateway(log=EventLog(), record_batches=True, admission=admission)
    gw.deploy("m", AnalyticBackend("m", 0.02, 1e-3), get_profile("gcp"),
              autoscaler=AutoscalerConfig(min_replicas=1, max_replicas=1),
              max_batch=4)
    return gw


def test_dispatch_is_fifo_within_class():
    """One pool, one class, one replica: requests must be served strictly
    in arrival (= ledger row) order through the IndexQueue."""
    gw = _single_pool_gateway()
    out = gw.run([TrafficSpec("m", 64, arrival="poisson", rate=800.0,
                              slo="standard")], seed=4)
    served = [i for rec in gw.batch_log for i in rec["idx"]]
    assert served == sorted(served)
    assert len(served) == 64
    assert out.per_model["m"].shed_total == 0


def test_shed_exactly_once_under_overload():
    """Admission sheds each request at most once, served and shed
    partition the offered set, and dispatch order stays FIFO."""
    gw = _single_pool_gateway(AdmissionConfig(margin=1.0))
    out = gw.run([TrafficSpec("m", 120, arrival="burst", slo="latency")],
                 seed=0)
    res = out.per_model["m"]
    shed_events = [e for e in gw.log.events if e["name"] == "gateway:shed"]
    assert res.shed_total > 0               # overload really occurred
    assert len(shed_events) == res.shed_total
    served = [i for rec in gw.batch_log for i in rec["idx"]]
    assert served == sorted(served)         # FIFO survives shedding
    assert len(served) + res.shed_total == 120
    assert len(res.latencies_s) == len(served)


# -- burst arrival-rate fallback (satellite 2) -------------------------------

def test_burst_rate_not_diluted_by_drain_time():
    """A pure burst served slowly must report the burst's intensity, not
    ``n / makespan``.  Before the fix the fallback window was the whole
    run span (arrival -> last completion), so a 120-request instantaneous
    burst that took ~2s to drain looked like a ~60 rps trickle."""
    gw = _single_pool_gateway()
    out = gw.run([TrafficSpec("m", 120, arrival="burst", slo="batch")],
                 seed=0)
    res = out.per_model["m"]
    obs = res.observed
    assert obs["window_s"] > 0
    # window = collapsed span + one mean service interval, exactly
    assert obs["window_s"] == pytest.approx(obs["service_time_s"])
    assert obs["rate_rps"] == pytest.approx(120 / obs["window_s"])
    # the old formula: n / (total - first arrival) -- must now be a strict
    # under-estimate because it includes the drain time
    old_rate = 120 / res.total_time_s
    assert obs["rate_rps"] > 10 * old_rate


def test_trickle_rate_window_unchanged():
    """The n>1 spread-arrivals branch keeps (n-1)/span semantics."""
    gw = _single_pool_gateway()
    out = gw.run([TrafficSpec("m", 50, arrival="poisson", rate=40.0,
                              slo="standard")], seed=1)
    obs = out.per_model["m"].observed
    assert obs["rate_rps"] == pytest.approx((50 - 1) / obs["window_s"])


# -- None percentiles for empty pools (satellite 3) --------------------------

def test_empty_serve_result_percentiles_are_none():
    res = ServeResult("gateway:x", 4, 1.0, [],
                      class_shed={"standard": 4})
    assert res.p50 is None and res.p99 is None
    s = res.summary()
    assert s["p50_s"] is None and s["p99_s"] is None
    assert s["shed"] == 4 and s["shed_rate"] == 1.0
    pc = res.per_class()["standard"]
    assert pc["n"] == 0
    assert pc["p50_s"] is None and pc["p99_s"] is None
    assert pc["shed"] == 4 and pc["shed_rate"] == 1.0


def test_stale_burn_alert_does_not_livelock_the_run():
    """A burst that ends inside a firing burn alert must still let the run
    terminate.  Before the fix, alerts only re-evaluated inside
    ``observe()``: with no traffic left the alert stayed firing forever,
    its pressure() kept tipping the scale-from-zero rule, every launched
    replica idled out, and the scale-up / idle-retire cycle re-armed the
    event loop without end (seed-517 livelock).  ``BurnRateMonitor.age``
    now resolves the alert on the simulated clock instead."""
    from repro.serving.gateway import ReplanConfig
    from repro.telemetry.slo import BurnRateConfig

    def mk(engine):
        gw = Gateway(log=EventLog(), replan=ReplanConfig(),
                     slo_burn=BurnRateConfig(threshold=2.0, min_n=4))
        # slow backend + tight-deadline class: every request breaches, so
        # the alert is firing when the traffic runs out; min_replicas=0 +
        # a short idle window arm the retire half of the cycle
        gw.deploy("m", AnalyticBackend("m", 0.2, 1e-3), get_profile("gcp"),
                  autoscaler=AutoscalerConfig(min_replicas=0, max_replicas=2,
                                              idle_window_s=0.5),
                  max_batch=4)
        out = gw.run([TrafficSpec("m", 16, arrival="burst", slo="latency")],
                     seed=2, engine=engine)
        return gw, out

    gw_s, out_s = mk("scalar")          # terminating at all IS the test
    states = [e["state"] for e in gw_s.log.events
              if e["name"] == "gateway:alert"]
    assert "firing" in states           # the alert really fired...
    assert states[-1] == "resolved"     # ...and aged out after the burst
    assert out_s.makespan_s < 60.0      # no runaway churn tail
    gw_v, out_v = mk("vector")
    assert gw_s.log.dump() == gw_v.log.dump()
    assert out_s.summary() == out_v.summary()


def test_shed_everything_run_reports_none_percentiles():
    """End to end: a near-zero shed margin against a 5s backend drops
    every request; the summary must say None, never a fake perfect 0.0."""
    gw = Gateway(log=EventLog(),
                 admission=AdmissionConfig(margin=0.01))
    gw.deploy("m", AnalyticBackend("m", 5.0, 0.0), get_profile("gcp"),
              autoscaler=AutoscalerConfig(min_replicas=1, max_replicas=1),
              max_batch=1)
    out = gw.run([TrafficSpec("m", 10, arrival="burst", slo="latency")],
                 seed=0)
    res = out.per_model["m"]
    if res.shed_total == 10:        # the intended regime
        assert res.latencies_s == []
        assert res.p50 is None and res.summary()["p99_s"] is None
    else:                           # shedder tuning drifted; keep honest
        pytest.skip("near-zero-margin shedder no longer drops everything")
