"""Shared BENCH_*.json schema machinery (ISSUE 9 satellite).

Both bench suites persist a schema-versioned record and gate CI on it;
the version bump + required-scenario + required-key checks were copied
between ``bench_gateway.py`` and ``bench_pipeline.py`` verbatim.  This
module is the single copy: each suite keeps its own ``BENCH_SCHEMA``
constant and per-scenario semantic checks, but the header validation and
the "record is missing key k" plumbing live here.
"""
from __future__ import annotations


def check_header(bench: dict, schema: int, require: tuple = ()) -> dict:
    """Validate the suite-version header and the required-scenario set;
    returns the ``scenarios`` dict for the caller's semantic checks."""
    if bench.get("schema") != schema:
        raise ValueError(f"schema {bench.get('schema')} != {schema}")
    sc = bench.get("scenarios", {})
    missing = [name for name in require if name not in sc]
    if missing:
        raise ValueError(f"missing scenarios: {missing}")
    return sc


def require_keys(rec: dict, keys: tuple, ctx: str) -> None:
    """Raise naming the first key absent from ``rec`` (``ctx`` locates
    the record inside the bench JSON, e.g. ``"overload.race"``)."""
    for k in keys:
        if k not in rec:
            raise ValueError(f"{ctx} missing {k}")
