"""Benchmark driver: one module per paper table + roofline + kernels.
Prints ``name,us_per_call,derived`` CSV (spec'd output format).

  python -m benchmarks.run [--only katib|inference|pipeline|roofline|kernels]
"""
from __future__ import annotations

import argparse
import sys
import time

from . import bench_gateway, bench_inference, bench_katib, bench_kernels, \
    bench_pipeline, bench_roofline

SUITES = {
    "inference": bench_inference.run,     # paper Table 3 / Fig 21
    "gateway": bench_gateway.run,         # model-mesh fleet (beyond paper)
    "pipeline": bench_pipeline.run,       # paper Tables 4+5 / Figs 22-23
    "katib": bench_katib.run,             # paper Table 2 / Fig 20
    "roofline": bench_roofline.run,       # deliverable (g)
    "kernels": bench_kernels.run,         # kernel microbench
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=list(SUITES), default=None)
    args = ap.parse_args(argv)
    suites = {args.only: SUITES[args.only]} if args.only else SUITES

    print("name,us_per_call,derived")
    for name, fn in suites.items():
        t0 = time.perf_counter()
        try:
            rows = fn()
        except Exception as e:  # keep the harness running table-per-table
            print(f"{name}_SUITE_ERROR,-1,{type(e).__name__}:{str(e)[:80]}",
                  flush=True)
            continue
        for r in rows:
            derived = str(r["derived"]).replace(",", ";")
            print(f"{r['name']},{r['us_per_call']:.2f},{derived}", flush=True)
        print(f"# suite {name} finished in {time.perf_counter() - t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
