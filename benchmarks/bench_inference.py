"""Paper Table 3 / Fig. 21: inference stress test -- total time to serve N
requests on the four platforms (baremetal / plain-k8s / kserve-gcp /
kserve-ibm).  Compute latencies are measured on this host; network/reload
constants come from the CloudProfiles (DESIGN.md simulation note)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.clouds.profiles import get_profile
from repro.data.mnist import make_dataset
from repro.models import lenet
from repro.serving.kserve import InferenceService, Predictor

REQUEST_COUNTS = (1, 4, 8, 16, 32, 64, 128, 256, 512)
PLATFORMS = (("baremetal", "baremetal"), ("k8s", "k8s"),
             ("kserve", "gcp"), ("kserve", "ibm"))


def run() -> list[dict]:
    imgs, _ = make_dataset(8, seed=0)
    params = lenet.init_params(jax.random.PRNGKey(0))
    predict = jax.jit(lambda x: jnp.argmax(lenet.apply(params, x), -1))
    pred = Predictor("lenet-v1", predict, imgs[:1])
    pred.warmup((1,))

    rows = []
    for strategy, profile in PLATFORMS:
        svc = InferenceService(pred, get_profile(profile), strategy)
        label = f"{strategy}_{profile}" if strategy == "kserve" else strategy
        totals = []
        for n in REQUEST_COUNTS:
            res = svc.stress_test(n)
            totals.append(f"{n}:{res.total_time_s:.4f}")
            rows.append({
                "name": f"inference_{label}_n{n}",
                "us_per_call": res.total_time_s * 1e6 / n,
                "derived": f"total_s={res.total_time_s:.4f};p99_s={res.p99:.4f};"
                           f"replicas={max(r for _, r in res.replica_trace)}",
            })
    return rows
