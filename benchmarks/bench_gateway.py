"""Model-mesh gateway fleet benchmark (beyond paper): >=3 models behind one
router with heterogeneous traffic (Poisson stream, burst + canary split, and
a sparse workload forcing a scale-to-zero -> cold-start cycle), plus a
placement plan across >=2 cloud profiles under both objectives, plus an
SLO/failover scenario (three traffic classes through a mid-run cloud outage
vs a no-priority baseline on the same seed), plus an active-active
split-vs-single-cloud scenario: the same capacity-constrained demand placed
single-cloud and split, raced on identical traffic -- the split must win on
at least one of {p99, simulated cost} -- plus an OVERLOAD scenario (ISSUE
4): stale split weights over unequal capacity, offered load past the
fleet's ceiling, raced queue-aware-routing-plus-shedding vs pure weighted
routing on the same seed -- queue-aware must win latency-class p99 while
reporting a nonzero, bounded shed rate (batch work never shed) -- plus two
OBSERVABILITY scenarios (ISSUE 6): the overload race re-run with the full
telemetry plane attached (burn-rate monitor + tracer + metrics), where the
SLO alert must fire no later than the first replan migrate and the trace
analyzer's per-stage latency-breakdown table is derived from the spans;
and an instrumentation-overhead race (the same stream run bare and fully
instrumented on one seed) that must keep the traced hot-loop wall within
10% of untraced while leaving the simulation outcome bit-identical --
plus a DISAGG scenario (ISSUE 8): a real ContinuousBatcher raced with
chunked batched prefill against the teacher-forced seed path on a
prefill-heavy mix, gated by an output-identity oracle leg; the
disaggregated path must clear the asserted token-throughput floor (2x
full, 1.3x smoke) without regressing the decode-step p99 -- plus a
DRIFT scenario (ISSUE 10): the profiling DAG measures the backend into
ModelProfile artifacts per cloud, a profile-planned placement (every
demand number from the store, zero hand-tuned constants) races the
hand-tuned plan within 1.1x on p99, then an injected service-time shift
must fire profile:drift strictly before the first
``gateway:migrate reason=profile_drift`` (seq-ordered, asserted), and
every bench event log carries only registered event kinds.

Every scenario also lands in ``benchmarks/BENCH_gateway.json`` (per-scenario
p50/p99, deadline-miss rates, shed rates, simulated dollars; schema
validated by ``validate_bench``) so the perf trajectory is tracked across
PRs instead of being print-only.  ``python benchmarks/bench_gateway.py
--smoke`` runs only the overload + observability scenarios + schema
validation (the CI bench-smoke step).

Compute service times are measured (jitted matmuls of three widths); the
network / cold-start / price terms come from the CloudProfiles: any dollar
or RTT figure here is a simulation output (DESIGN.md §1)."""
from __future__ import annotations

import argparse
import gc
import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from ._schema import check_header, require_keys
except ImportError:                      # run directly as a script (CI)
    from _schema import check_header, require_keys

from repro.clouds.capacity import CapacityMarket
from repro.clouds.profiles import TPU_V5E, CloudProfile, get_profile
from repro.core.pipeline import Pipeline
from repro.pipelines import Orchestrator, RetryPolicy
from repro.serving.gateway import (SLO_CLASSES, AdmissionConfig,
                                   AutoscalerConfig, CloudCapacity,
                                   FailureSpec, Gateway, ModelDemand,
                                   Predictor, ReplanConfig, RoutingConfig,
                                   SLOClass, TrafficSpec, plan_placement)
from repro.modelci import ProfileSpec, ProfileStore, finalize, measure
from repro.pipelines import DeploySpec
from repro.telemetry.analyze import request_table, slowest_requests
from repro.telemetry.drift import DriftConfig
from repro.telemetry.events import EventLog, unregistered
from repro.telemetry.metrics import MetricsRegistry
from repro.telemetry.slo import BurnRateConfig
from repro.telemetry.trace import Tracer

BENCH_JSON = pathlib.Path(__file__).resolve().parent / "BENCH_gateway.json"
# schema 8: "drift" tier (profile-planned placement vs hand-tuned +
# injected service-time shift through the DriftMonitor, ISSUE 10);
# schema 7 added the "contention" tier (training colocated with a serving
# burst on one CapacityMarket, priority on vs off, ISSUE 9)
BENCH_SCHEMA = 8

WIDTHS = {"small": 64, "medium": 128, "large": 256}
# fleet-scale offered load in Erlangs (rate derived from the measured
# service time, so the plan shape is host-independent); the simulated
# streams below are scaled-down samples of the same mix
PLANNED_LOADS = {"small": 4.0, "medium": 2.0, "large": 0.5}


def _make_predictor(name: str, width: int, seed: int = 0) -> Predictor:
    w = jax.random.normal(jax.random.PRNGKey(seed), (width, width), jnp.float32)
    predict = jax.jit(lambda v: jnp.tanh(v @ w))
    p = Predictor(name, predict, np.zeros((1, width), np.float32))
    p.warmup((1, 8, 32))
    return p


def _round(x, nd: int):
    """None-preserving round: empty pools report null percentiles."""
    return None if x is None else round(x, nd)


def _model_record(res, cold: int) -> dict:
    return {"p50_s": _round(res.p50, 6), "p99_s": _round(res.p99, 6),
            "sim_cost_usd": round(res.cost_usd, 8),
            "cold_starts": cold,
            "shed": res.shed_total,
            "shed_rate": round(res.shed_rate, 4),
            "deadline_miss": {c: s["miss_rate"]
                              for c, s in res.per_class().items()}}


def validate_bench(bench: dict, require: tuple = ()) -> None:
    """BENCH_gateway.json schema check (the CI bench-smoke gate): the
    shared header/required-scenario machinery lives in ``_schema``; the
    suite-specific semantic gates below cover every scenario present --
    including the ISSUE 4 shed-rate fields, the recorded
    queue-aware-vs-weights race and the ISSUE 9 contention ratios."""
    sc = check_header(bench, BENCH_SCHEMA, require)
    for name, rec in sc.get("fleet", {}).get("models", {}).items():
        require_keys(rec, ("p50_s", "p99_s", "sim_cost_usd", "cold_starts",
                           "shed", "shed_rate", "deadline_miss"),
                     f"fleet model {name}")
    for key in ("slo_failover", "split_cost"):
        if key in sc and not sc[key]:
            raise ValueError(f"scenario {key} is empty")
    if "overload" in sc:
        o = sc["overload"]
        require_keys(o, ("queue_aware", "weights", "race", "burn"),
                     "overload scenario")
        for side in ("queue_aware", "weights"):
            require_keys(o[side], ("per_class", "shed", "shed_rate"),
                         f"overload.{side}")
        race = o["race"]
        require_keys(race, ("winner", "latency_p99_queue_aware",
                            "latency_p99_weights", "shed_rate"),
                     "overload race")
        if not 0 < race["shed_rate"] <= 0.5:
            raise ValueError(f"shed rate {race['shed_rate']} not in (0, .5]")
        burn = o["burn"]
        require_keys(burn, ("alerts_firing", "first_alert_seq",
                            "first_migrate_seq", "scrapes", "spans",
                            "slowest_request"), "overload burn")
        if burn["alerts_firing"] < 1:
            raise ValueError("overload burn run recorded no firing alert")
        if (burn["first_migrate_seq"] is not None
                and burn["first_alert_seq"] > burn["first_migrate_seq"]):
            raise ValueError("burn alert fired after the first migrate")
    if "scale" in sc:
        s5 = sc["scale"]
        require_keys(s5, ("requests", "models", "clouds", "oracle_requests",
                          "scalar", "vector", "speedup",
                          "asserted_min_speedup"), "scale scenario")
        for side in ("scalar", "vector"):
            require_keys(s5[side], ("wall_s", "sim_events", "events_per_s",
                                    "requests_per_s"), f"scale.{side}")
        if s5["speedup"] < s5["asserted_min_speedup"]:
            raise ValueError(
                f"scale speedup {s5['speedup']}x below the asserted "
                f"{s5['asserted_min_speedup']}x floor")
        # the full tier must really be the >=10^6-request scenario
        if s5["asserted_min_speedup"] >= 50 and s5["requests"] < 10 ** 6:
            raise ValueError(f"scale tier ran only {s5['requests']} requests")
    if "disagg" in sc:
        dg = sc["disagg"]
        require_keys(dg, ("oracle_ok", "requests", "prompt_tokens",
                          "gen_tokens", "chunk", "seed", "disagg", "speedup",
                          "asserted_min_speedup"), "disagg scenario")
        if not dg["oracle_ok"]:
            raise ValueError("disagg race ran without a passing oracle leg")
        for side in ("seed", "disagg"):
            require_keys(dg[side], ("wall_s", "tokens_per_s",
                                    "decode_step_p99_s", "steps"),
                         f"disagg.{side}")
        if dg["speedup"] < dg["asserted_min_speedup"]:
            raise ValueError(
                f"disagg token-throughput speedup {dg['speedup']}x below "
                f"the asserted {dg['asserted_min_speedup']}x floor")
        if dg["disagg"]["decode_step_p99_s"] > \
                1.3 * dg["seed"]["decode_step_p99_s"]:
            raise ValueError("disagg decode-step p99 regressed past the "
                             "1.3x noise guard")
    if "observability" in sc:
        ob = sc["observability"]
        require_keys(ob, ("wall_untraced_s", "wall_traced_s",
                          "overhead_frac", "materialize_wall_s", "spans",
                          "scrapes"), "observability scenario")
        # walls are host-measured (noise can push the min-of-pairs ratio
        # slightly negative); the asserted gate is the 10% ceiling
        if not -0.5 < ob["overhead_frac"] < 0.10:
            raise ValueError(
                f"instrumentation overhead {ob['overhead_frac']} >= 10%")
    if "drift" in sc:
        dr = sc["drift"]
        require_keys(dr, ("hand_p99_s", "profile_p99_s", "p99_ratio",
                          "profiles_committed", "injected_factor", "drift",
                          "scrapes"), "drift scenario")
        if dr["p99_ratio"] > 1.1:
            raise ValueError(f"profile-planned p99 {dr['p99_ratio']}x the "
                             "hand-tuned plan (> 1.1x gate)")
        if dr["profiles_committed"] < 2:
            raise ValueError("profiling DAG committed fewer than 2 "
                             "per-cloud artifacts")
        d = dr["drift"]
        require_keys(d, ("firing", "ratio", "first_drift_seq",
                         "first_migrate_seq", "migrates_profile_drift",
                         "reprofile_armed"), "drift.drift")
        if d["firing"] < 1:
            raise ValueError("injected shift never fired profile:drift")
        if d["migrates_profile_drift"] < 1:
            raise ValueError("drift never armed a profile_drift migrate")
        if d["first_drift_seq"] > d["first_migrate_seq"]:
            raise ValueError("profile:drift fired after the first "
                             "reason=profile_drift migrate")
    if "contention" in sc:
        ct = sc["contention"]
        require_keys(ct, ("slots", "dedicated", "priority_on",
                          "priority_off", "training", "p99_ratio",
                          "makespan_ratio"), "contention scenario")
        require_keys(ct["priority_on"], ("p99_s", "preempts", "leases",
                                         "scale_denied"),
                     "contention.priority_on")
        require_keys(ct["priority_off"], ("p99_s", "preempts",
                                          "scale_denied"),
                     "contention.priority_off")
        require_keys(ct["training"], ("contended_makespan_s",
                                      "uncontended_makespan_s", "preempts",
                                      "exactly_once"), "contention.training")
        if ct["priority_on"]["preempts"] < 1:
            raise ValueError("contention priority-on leg never preempted")
        if ct["priority_on"]["scale_denied"] != 0:
            raise ValueError("priority-on serving was starved by training")
        if ct["priority_off"]["scale_denied"] < 1:
            raise ValueError("priority-off leg never hit a capacity denial")
        if ct["p99_ratio"] > 1.3:
            raise ValueError(f"contended serving p99 {ct['p99_ratio']}x the "
                             "dedicated baseline (> 1.3x gate)")
        if ct["makespan_ratio"] > 2.0:
            raise ValueError("contended training makespan "
                             f"{ct['makespan_ratio']}x uncontended (> 2x)")
        if not ct["training"]["exactly_once"]:
            raise ValueError("preempted training attempts broke exactly-once")


def run() -> list[dict]:
    preds = {n: _make_predictor(n, w) for n, w in WIDTHS.items()}
    bench: dict = {"schema": BENCH_SCHEMA, "scenarios": {}}

    # -- placement: both objectives over gcp/ibm ---------------------------
    demands = [ModelDemand(n, PLANNED_LOADS[n] / (preds[n].service_time(8) / 8),
                           preds[n].service_time(8) / 8)
               for n in WIDTHS]
    # gcp is cheaper but capacity-constrained, so the cost plan itself must
    # spill part of the fleet onto ibm (a genuinely multi-cloud placement)
    clouds = [CloudCapacity(get_profile("gcp"), 8, 1.0),
              CloudCapacity(get_profile("ibm"), 16, 1.4)]
    plans = {obj: plan_placement(demands, clouds, objective=obj)
             for obj in ("cost", "p99")}
    plan = plans["cost"]
    # measured service times vary by host: an unplaceable model would give
    # cloud=None below, so fail with the plan rather than a KeyError
    assert plan.feasible, plan.summary()
    cloud_of = {a.model: a.cloud for a in plan.assignments}

    # -- fleet simulation on the cost plan ---------------------------------
    log = EventLog()
    gw = Gateway(capacity=plan.capacity_map(), log=log)
    replicas = {a.model: a.replicas for a in plan.assignments}
    gw.deploy("small", preds["small"], get_profile(cloud_of["small"]),
              autoscaler=AutoscalerConfig(
                  min_replicas=1, max_replicas=replicas["small"],
                  target_queue=8, idle_window_s=2.0), max_batch=16)
    gw.deploy("medium", preds["medium"], get_profile(cloud_of["medium"]),
              autoscaler=AutoscalerConfig(
                  min_replicas=1, max_replicas=replicas["medium"],
                  target_queue=8, idle_window_s=2.0), max_batch=16,
              canary=_make_predictor("medium-canary", WIDTHS["medium"], seed=1),
              canary_fraction=0.2)
    gw.deploy("large", preds["large"], get_profile(cloud_of["large"]),
              autoscaler=AutoscalerConfig(
                  min_replicas=0, max_replicas=max(replicas["large"], 1),
                  scale_up_delay_s=0.5, idle_window_s=1.0), max_batch=8)
    out = gw.run([
        TrafficSpec("small", 600, arrival="poisson", rate=2000.0),
        TrafficSpec("medium", 256),                      # burst + canary
        TrafficSpec("large", 8),                         # cold start #1
        TrafficSpec("large", 8, start_s=6.0),            # idle -> cold #2
    ], seed=0)

    rows = []
    for name, res in out.per_model.items():
        trace = res.replica_trace
        rows.append({
            "name": f"gateway_{name}",
            "us_per_call": res.p50 * 1e6,
            "derived": f"cloud={cloud_of[name]};p50_s={res.p50:.5f};"
                       f"p99_s={res.p99:.5f};replicas_max="
                       f"{max(r for _, r in trace)};"
                       f"cold_starts={out.cold_starts[name]};"
                       f"hit_zero={any(r == 0 for _, r in trace[1:])}",
        })
    bench["scenarios"]["fleet"] = {
        "models": {m: _model_record(r, out.cold_starts[m])
                   for m, r in out.per_model.items()},
        "sim_cost_usd": round(out.total_cost_usd, 8),
        "makespan_s": round(out.makespan_s, 6)}
    for obj, pl in plans.items():
        s = pl.summary()
        assign = ";".join(f"{m}->{a['cloud']}x{a['replicas']}"
                          for m, a in s["assignments"].items())
        rows.append({
            "name": f"gateway_placement_{obj}",
            "us_per_call": float(pl.worst_p99_s) * 1e6,
            "derived": f"feasible={s['feasible']};"
                       f"cost_hr={s['total_cost_hr']};{assign}",
        })
    rows.append({
        "name": "gateway_events",
        "us_per_call": out.makespan_s * 1e6,
        "derived": f"cold_start={log.count('gateway:cold_start')};"
                   f"scale_up={log.count('gateway:scale_up')};"
                   f"scale_down={log.count('gateway:scale_down')};"
                   f"scale_to_zero={log.count('gateway:scale_to_zero')}",
    })
    # acceptance: the large model must complete a scale-to-zero -> cold-start
    # cycle (zero pool between its two bursts, a cold start on each)
    assert out.cold_starts["large"] >= 2, out.cold_starts
    assert any(r == 0 for _, r in out.per_model["large"].replica_trace[1:])
    rows.extend(_slo_failover_scenario(preds["large"], bench))
    rows.extend(_split_cost_scenario(preds["medium"], bench))
    rows.extend(_overload_shed_scenario(preds["small"], bench))
    rows.extend(_observability_scenario(preds["small"], bench))
    rows.extend(_drift_scenario(preds["small"], bench))
    rows.extend(_contention_scenario(preds["small"], bench))
    rows.extend(_scale_scenario(bench))
    rows.extend(_disagg_scenario(bench))
    validate_bench(bench, require=("fleet", "slo_failover", "split_cost",
                                   "overload", "observability", "drift",
                                   "contention", "scale", "disagg"))
    BENCH_JSON.write_text(json.dumps(bench, indent=1, sort_keys=True))
    print(f"wrote {BENCH_JSON}", file=sys.stderr)
    return rows


def _slo_failover_scenario(pred: Predictor, bench: dict) -> list[dict]:
    """Three SLO classes on one two-replica fleet, a mid-run gcp outage with
    ibm standby, against a no-priority baseline (uniform class weights, no
    preemption -- same class NAMES so the per-class tables line up) on the
    same seed.  Timing is derived from the measured batch service time so
    the backlog shape is host-independent."""
    prof = get_profile("gcp")
    t8 = pred.service_time(8)
    per_batch = prof.network_rtt_s + prof.lb_overhead_s + t8
    n_batch = 480
    drain_s = (n_batch / 8) * per_batch / 2      # backlog of the batch burst
    window_s = 2.0 * drain_s
    outage = FailureSpec("gcp", at_s=0.3 * drain_s,
                         duration_s=max(0.4 * drain_s, 0.25))

    def classes(priority: bool) -> dict:
        if priority:
            return {c: SLO_CLASSES[c] for c in ("latency", "standard",
                                                "batch")}
        return {c: SLOClass(c, 1.0, SLO_CLASSES[c].deadline_mult)
                for c in ("latency", "standard", "batch")}

    def run_once(priority: bool):
        cls = classes(priority)
        log = EventLog()
        gw = Gateway(log=log)
        gw.deploy("fleet", pred, prof, standby=get_profile("ibm"),
                  autoscaler=AutoscalerConfig(
                      min_replicas=2, max_replicas=2,
                      scale_up_delay_s=0.005, idle_window_s=np.inf),
                  max_batch=8)
        out = gw.run([
            TrafficSpec("fleet", n_batch, slo=cls["batch"]),
            TrafficSpec("fleet", 120, slo=cls["standard"],
                        arrival="poisson", rate=120 / window_s),
            TrafficSpec("fleet", 80, slo=cls["latency"],
                        arrival="poisson", rate=80 / window_s),
        ], seed=0, failures=[outage])
        return out, log

    pri, pri_log = run_once(priority=True)
    base, _ = run_once(priority=False)
    pc, bc = pri.per_class(), base.per_class()

    print("per-class p99 (priority dispatch vs no-priority baseline, "
          "same seed + same gcp outage):", file=sys.stderr)
    print(f"  {'class':<10}{'p99_s':>12}{'baseline':>12}{'miss_rate':>12}",
          file=sys.stderr)
    for c in ("latency", "standard", "batch"):
        print(f"  {c:<10}{pc[c]['p99_s']:>12.5f}{bc[c]['p99_s']:>12.5f}"
              f"{pc[c]['miss_rate']:>12.4f}", file=sys.stderr)

    # acceptance: priority dispatch must strictly beat the baseline for the
    # latency class, and the outage must actually have moved the fleet
    assert pc["latency"]["p99_s"] < bc["latency"]["p99_s"], (pc, bc)
    assert pri_log.count("gateway:failover") >= 1
    assert pri_log.count("gateway:recover") >= 1

    bench["scenarios"]["slo_failover"] = {
        "classes": pc,
        "baseline": bc,
        "sim_cost_usd": round(pri.total_cost_usd, 8),
        "events": {k: pri_log.count(f"gateway:{k}")
                   for k in ("failover", "recover", "preempt", "cold_start",
                             "split")}}
    rows = [{"name": f"gateway_slo_{c}",
             "us_per_call": pc[c]["p99_s"] * 1e6,
             "derived": f"p50_s={pc[c]['p50_s']:.5f};"
                        f"p99_s={pc[c]['p99_s']:.5f};"
                        f"baseline_p99_s={bc[c]['p99_s']:.5f};"
                        f"miss_rate={pc[c]['miss_rate']}"}
            for c in ("latency", "standard", "batch")]
    rows.append({
        "name": "gateway_slo_failover",
        "us_per_call": pri.makespan_s * 1e6,
        "derived": f"outage_at_s={outage.at_s:.4f};"
                   f"outage_s={outage.duration_s:.4f};"
                   f"failover={pri_log.count('gateway:failover')};"
                   f"recover={pri_log.count('gateway:recover')};"
                   f"preempt={pri_log.count('gateway:preempt')};"
                   f"cold_start={pri_log.count('gateway:cold_start')}",
    })
    return rows


def _split_cost_scenario(pred: Predictor, bench: dict) -> list[dict]:
    """Active-active acceptance (ISSUE 3): one demand that needs more
    replicas than the cheap cloud can hold, placed two ways on the SAME
    measured service time and raced on the SAME traffic/seed --
    single-cloud (forced all-expensive by capacity) vs split (cheap first,
    spill the remainder).  The split must beat single-cloud on p99 or
    simulated cost.  The traffic is open-loop UNDERLOAD -- both fleets are
    provisioned for the window, so the makespan is pinned by the arrival
    stream and the split's cheaper replica-seconds (2x gcp@1.0 + 2x
    ibm@1.4 vs 4x ibm@1.4) decide the bill."""
    t1 = pred.service_time(8) / 8        # per-request service, batched
    need = 4
    demand = ModelDemand("ranker", rate=0.7 * need / t1, service_time_s=t1)
    clouds = [CloudCapacity(get_profile("gcp"), 2, 1.0),   # cheap, small
              CloudCapacity(get_profile("ibm"), 8, 1.4)]   # fast, dear
    single = plan_placement([demand], clouds, objective="cost")
    split = plan_placement([demand], clouds, objective="cost", split=True)
    assert single.assignments[0].shares == {"ibm": need}   # gcp can't fit it
    assert split.assignments[0].shares == {"gcp": 2, "ibm": 2}

    # ~60% of the SLOWER pool's throughput share (gcp per-batch path is the
    # long pole), derived from measured+profile terms so any host lands in
    # the same utilization regime
    prof = get_profile("gcp")
    per_batch = prof.network_rtt_s + prof.lb_overhead_s + pred.service_time(8)
    n = 600
    traffic = [TrafficSpec("ranker", n, arrival="poisson",
                           rate=19.2 / per_batch)]

    def run_once(assignment):
        gw = Gateway()
        gw.deploy("ranker", pred,
                  split={get_profile(c): w
                         for c, w in assignment.weights.items()},
                  autoscaler=AutoscalerConfig(min_replicas=need,
                                              max_replicas=need,
                                              idle_window_s=np.inf),
                  max_batch=8)
        return gw.run(traffic, seed=0)

    out_single = run_once(single.assignments[0])
    out_split = run_once(split.assignments[0])
    r_single = out_single.per_model["ranker"]
    r_split = out_split.per_model["ranker"]
    wins = []
    if r_split.p99 < r_single.p99:
        wins.append("p99")
    if out_split.total_cost_usd < out_single.total_cost_usd:
        wins.append("cost")
    print(f"split vs single-cloud: p99 {r_split.p99:.5f} vs "
          f"{r_single.p99:.5f}, sim $ {out_split.total_cost_usd:.6f} vs "
          f"{out_single.total_cost_usd:.6f} -> wins={wins}", file=sys.stderr)
    # acceptance: active-active must beat single-cloud on at least one axis
    assert wins, (r_split.p99, r_single.p99, out_split.total_cost_usd,
                  out_single.total_cost_usd)

    bench["scenarios"]["split_cost"] = {
        "single": {"p50_s": round(r_single.p50, 6),
                   "p99_s": round(r_single.p99, 6),
                   "sim_cost_usd": round(out_single.total_cost_usd, 8),
                   "plan": single.summary()["assignments"]},
        "split": {"p50_s": round(r_split.p50, 6),
                  "p99_s": round(r_split.p99, 6),
                  "sim_cost_usd": round(out_split.total_cost_usd, 8),
                  "plan": split.summary()["assignments"]},
        "wins": wins}
    return [{
        "name": "gateway_split_vs_single",
        "us_per_call": r_split.p99 * 1e6,
        "derived": f"wins={'+'.join(wins)};"
                   f"split_p99_s={r_split.p99:.5f};"
                   f"single_p99_s={r_single.p99:.5f};"
                   f"split_cost={out_split.total_cost_usd:.6f};"
                   f"single_cost={out_single.total_cost_usd:.6f}",
    }]


def _overload_shed_scenario(pred: Predictor, bench: dict) -> list[dict]:
    """Overload acceptance (ISSUE 4): a STALE 50/50 split over unequal
    capacity (ibm is capacity-pinned at one replica, gcp can grow to two)
    under offered load past the whole fleet's ceiling, raced two ways on
    the same seed and traffic:

      weights      pure weighted routing, no admission control -- half of
                   everything piles onto the one ibm replica;
      queue_aware  the ISSUE 4 blend -- requests join the best expected
                   queue, deadline-hopeless latency/standard work is shed
                   (exactly once, batch only deferred), and shed-pressure
                   still drives scale-up.

    queue-aware + shedding must win latency-class p99 while reporting a
    NONZERO but BOUNDED (<= 0.5) shed rate.  Timing derives from the
    measured batch service time so every host lands in the same
    utilization regime."""
    t8 = pred.service_time(8)
    prof = get_profile("gcp")
    per_batch = prof.network_rtt_s + prof.lb_overhead_s + t8
    cap_rps = 3 * 8 / per_batch          # 3-replica fleet ceiling
    window_s = 40 * per_batch
    n_batch = int(0.5 * cap_rps * window_s)      # burst backlog, never shed
    n_std = int(0.6 * cap_rps * window_s)
    n_lat = int(0.3 * cap_rps * window_s)
    traffic = [
        TrafficSpec("m", n_batch, slo="batch"),
        TrafficSpec("m", n_std, arrival="poisson", rate=n_std / window_s),
        TrafficSpec("m", n_lat, slo="latency",
                    arrival="poisson", rate=n_lat / window_s),
    ]

    def run_once(queue_aware: bool, burn: bool = False):
        log = EventLog()
        extra: dict = {}
        if burn:
            # full telemetry plane: burn-rate monitor (windows derived
            # from the measured batch time so alerts land in the same sim
            # regime on any host) + replan it can arm + tracer + scrapes
            extra = dict(
                replan=ReplanConfig(check_every_s=4 * per_batch,
                                    sustain=3, shift=0.25),
                slo_burn=BurnRateConfig(short_s=4 * per_batch,
                                        long_s=12 * per_batch),
                tracer=Tracer(), metrics=MetricsRegistry(),
                scrape_every_s=5 * per_batch)
        gw = Gateway(capacity={"gcp": 3, "ibm": 1}, log=log,
                     routing=RoutingConfig(
                         "queue_aware" if queue_aware else "weights"),
                     admission=AdmissionConfig() if queue_aware else None,
                     **extra)
        gw.deploy("m", pred,
                  split={get_profile("gcp"): 0.5, get_profile("ibm"): 0.5},
                  autoscaler=AutoscalerConfig(min_replicas=2, max_replicas=4,
                                              target_queue=8,
                                              scale_up_delay_s=0.01,
                                              idle_window_s=np.inf),
                  max_batch=8)
        return gw.run(traffic, seed=0), log, gw

    out_q, log_q, _ = run_once(queue_aware=True)
    out_w, _, _ = run_once(queue_aware=False)
    res_q, res_w = out_q.per_model["m"], out_w.per_model["m"]
    pc_q, pc_w = res_q.per_class(), res_w.per_class()
    # a fully shed class reports p99_s=None; fail with the scenario stats
    # rather than a TypeError in the table / comparison below
    assert all(pc[c]["n"] > 0 for pc in (pc_q, pc_w)
               for c in ("latency", "standard", "batch")), \
        f"a class was fully shed -- retune the overload regime: {pc_q}"

    print("overload race (queue-aware + shedding vs pure weights, "
          "same seed, 50/50 split over 2:1 capacity):", file=sys.stderr)
    print(f"  {'class':<10}{'qa_p99_s':>12}{'w_p99_s':>12}{'qa_shed':>9}",
          file=sys.stderr)
    for c in ("latency", "standard", "batch"):
        print(f"  {c:<10}{pc_q[c]['p99_s']:>12.5f}{pc_w[c]['p99_s']:>12.5f}"
              f"{pc_q[c]['shed']:>9}", file=sys.stderr)
    print(f"  shed rate {res_q.shed_rate:.4f} "
          f"({res_q.shed_total}/{res_q.n_requests})", file=sys.stderr)

    # acceptance: queue-aware + shedding beats pure weights on the latency
    # class tail; the shed rate is nonzero but bounded; batch is intact
    assert pc_q["latency"]["p99_s"] < pc_w["latency"]["p99_s"], (pc_q, pc_w)
    assert 0 < res_q.shed_rate <= 0.5, res_q.shed_rate
    assert res_q.class_shed.get("batch", 0) == 0
    assert len(res_q.class_latencies["batch"]) == n_batch
    assert res_w.shed_total == 0         # baseline admits everything
    # shedding must not mask the overload from the autoscaler
    assert log_q.count("gateway:scale_up") >= 1

    # third run (ISSUE 6): same traffic with the burn-rate monitor, replan
    # and the tracer/metrics plane attached -- the SLO alert must lead (or
    # tie with) the first replan migrate in event order, and the slowest
    # request's stage breakdown is derived from the span tree
    _, log_b, gw_b = run_once(queue_aware=True, burn=True)
    alerts = [e for e in log_b.named("gateway:alert")
              if e["state"] == "firing"]
    migrates = log_b.named("gateway:migrate")
    assert alerts, "burn monitor never fired under sustained overload"
    if migrates:
        assert alerts[0]["seq"] <= migrates[0]["seq"], (alerts[0],
                                                        migrates[0])
    print(request_table(gw_b.tracer, 3), file=sys.stderr)
    slow = slowest_requests(gw_b.tracer, 1)[0]

    bench["scenarios"]["overload"] = {
        "burn": {
            "alerts_firing": len(alerts),
            "first_alert_seq": alerts[0]["seq"],
            "first_migrate_seq": (migrates[0]["seq"] if migrates
                                  else None),
            "migrates": len(migrates),
            "scrapes": log_b.count("metrics:scrape"),
            "spans": len(gw_b.tracer.spans),
            "slowest_request": {
                k: round(v, 6) if isinstance(v, float) else v
                for k, v in slow.items()}},
        "queue_aware": {"per_class": pc_q, "shed": res_q.shed_total,
                        "shed_rate": round(res_q.shed_rate, 4),
                        "sim_cost_usd": round(out_q.total_cost_usd, 8)},
        "weights": {"per_class": pc_w, "shed": res_w.shed_total,
                    "shed_rate": 0.0,
                    "sim_cost_usd": round(out_w.total_cost_usd, 8)},
        "race": {"winner": "queue_aware",
                 "latency_p99_queue_aware": pc_q["latency"]["p99_s"],
                 "latency_p99_weights": pc_w["latency"]["p99_s"],
                 "shed_rate": round(res_q.shed_rate, 4),
                 "scale_ups_queue_aware":
                     log_q.count("gateway:scale_up")}}
    return [{
        "name": "gateway_overload_race",
        "us_per_call": pc_q["latency"]["p99_s"] * 1e6,
        "derived": f"qa_latency_p99_s={pc_q['latency']['p99_s']:.5f};"
                   f"w_latency_p99_s={pc_w['latency']['p99_s']:.5f};"
                   f"shed_rate={res_q.shed_rate:.4f};"
                   f"shed={res_q.shed_total};"
                   f"batch_shed={res_q.class_shed.get('batch', 0)}",
    }, {
        "name": "gateway_burn_alerts",
        "us_per_call": slow["total_s"] * 1e6,
        "derived": f"alerts_firing={len(alerts)};"
                   f"first_alert_seq={alerts[0]['seq']};"
                   f"migrates={len(migrates)};"
                   f"spans={len(gw_b.tracer.spans)};"
                   f"scrapes={log_b.count('metrics:scrape')}",
    }]


def _observability_scenario(pred: Predictor, bench: dict) -> list[dict]:
    """Instrumentation-overhead acceptance (ISSUE 6): the SAME mixed-class
    stream through the same queue-aware fleet, run bare and run with the
    full passive telemetry plane (tracer + metrics + periodic scrapes), on
    one seed.  Telemetry must be an OBSERVER: the two simulations must
    produce identical summaries, and the instrumented hot loop must stay
    within 10% of the bare wall.  Both walls are the min over interleaved
    pairs (back-to-back runs share the box's thermal state, so the ratio
    of mins is the noise-robust estimator) with the cyclic GC held off
    during the timed loop (the instrumented side allocates more young
    objects, so free-running gen-0 pauses land asymmetrically and can
    double the apparent overhead); the deferred span materialization --
    the collector flush that happens AFTER the event loop, like an async
    span processor draining -- is reported separately as
    materialize_wall_s, not charged to the hot loop."""
    t8 = pred.service_time(8)
    prof = get_profile("gcp")
    per_batch = prof.network_rtt_s + prof.lb_overhead_s + t8
    # dense ~85% utilization of a 7-replica ceiling: per-request loop work
    # dominates, so the fixed per-scrape fold cost amortizes the way a
    # production gateway's would
    window_s = 60 * per_batch
    cap_rps = 7 * 8 / per_batch
    n_std = int(0.6 * cap_rps * window_s)
    n_bat = int(0.25 * cap_rps * window_s)
    traffic = [
        TrafficSpec("m", n_std, arrival="poisson", rate=n_std / window_s),
        TrafficSpec("m", n_bat, slo="batch",
                    arrival="poisson", rate=n_bat / window_s),
    ]
    # the makespan runs ~2x the arrival window (the batch backlog drains
    # after the streams end), so this yields a handful of scrapes per run
    # -- the Prometheus-like regime where scrape cost amortizes
    scrape_s = window_s / 2
    # the drift monitor rides the scrape loop, so its per-scrape observe
    # cost belongs inside the same overhead gate (no replan is armed, so
    # the plane stays a pure observer)
    profile = finalize(measure(pred, max_batch=8), "m", get_profile("gcp"))

    def run_once(instrumented: bool):
        log = EventLog()
        gw = Gateway(capacity={"gcp": 4, "ibm": 3}, log=log,
                     routing=RoutingConfig("queue_aware"),
                     admission=AdmissionConfig(),
                     tracer=Tracer() if instrumented else None,
                     metrics=MetricsRegistry() if instrumented else None,
                     scrape_every_s=scrape_s if instrumented else None,
                     drift=DriftConfig() if instrumented else None)
        gw.deploy("m", pred,
                  split={get_profile("gcp"): 0.6, get_profile("ibm"): 0.4},
                  autoscaler=AutoscalerConfig(min_replicas=2, max_replicas=6,
                                              target_queue=8,
                                              idle_window_s=np.inf),
                  max_batch=8, planned_from=profile if instrumented else None)
        gc.collect()
        gc.disable()
        try:
            out = gw.run(traffic, seed=0)
        finally:
            gc.enable()
        return gw, out, log.named("gateway:run")[0]["wall_s"]

    wall_u = wall_t = float("inf")
    for _ in range(7):
        _, out_u, wu = run_once(instrumented=False)
        gw_t, out_t, wt = run_once(instrumented=True)
        # the plane is passive: same sim outcome to the last digit
        assert out_u.summary() == out_t.summary(), \
            "telemetry perturbed the simulation"
        wall_u, wall_t = min(wall_u, wu), min(wall_t, wt)
    overhead = wall_t / wall_u - 1.0
    mat = gw_t.log.named("trace:materialize")[0]["wall_s"]
    scrapes = gw_t.log.count("metrics:scrape")
    print(f"instrumentation overhead: untraced {wall_u * 1e3:.2f}ms "
          f"traced {wall_t * 1e3:.2f}ms ({overhead:+.1%}); span "
          f"materialization (off-loop) {mat * 1e3:.2f}ms, "
          f"{len(gw_t.tracer.spans)} spans, {scrapes} scrapes",
          file=sys.stderr)
    print(request_table(gw_t.tracer, 3), file=sys.stderr)
    # acceptance: the traced hot loop stays within 10% of untraced
    assert overhead < 0.10, f"instrumentation overhead {overhead:.1%}"

    bench["scenarios"]["observability"] = {
        "wall_untraced_s": round(wall_u, 6),
        "wall_traced_s": round(wall_t, 6),
        "overhead_frac": round(overhead, 4),
        "materialize_wall_s": round(mat, 6),
        "spans": len(gw_t.tracer.spans),
        "scrapes": scrapes,
        "requests": n_std + n_bat}
    return [{
        "name": "gateway_observability_overhead",
        "us_per_call": (wall_t - wall_u) / (n_std + n_bat) * 1e6,
        "derived": f"overhead_frac={overhead:.4f};"
                   f"wall_untraced_s={wall_u:.5f};"
                   f"wall_traced_s={wall_t:.5f};"
                   f"materialize_wall_s={mat:.5f};"
                   f"spans={len(gw_t.tracer.spans)};scrapes={scrapes}",
    }]


# -- model-CI drift tier (ISSUE 10): profile-planned placement + drift ------

class _ShiftBackend:
    """Serving backend whose cost model can be shifted BETWEEN runs (the
    drift injection).  The gateway samples service times once at run()
    start, so a mid-run mutation would be invisible; two runs sharing one
    EventLog keep the drift-before-migrate seq ordering assertable."""

    def __init__(self, inner, name: str):
        self.inner = inner
        self.name = name
        self.factor = 1.0

    def service_time(self, b: int) -> float:
        return self.factor * self.inner.service_time(b)


def _drift_scenario(pred: Predictor, bench: dict) -> list[dict]:
    """Model-CI acceptance (ISSUE 10), two legs on one shared EventLog:

    race   the profiling DAG (two pinned ``kind="profile"`` steps measuring
           the same backend, one per cloud) commits ModelProfile artifacts
           into a ProfileStore, a ``DeploySpec(profile=store)`` deploy step
           plans the placement with EVERY demand number read from the
           store, and the resulting fleet races the hand-tuned plan (same
           measured service time entered as a constant) on identical
           traffic/seed: profile-planned p99 must stay within 1.1x.

    drift  the same deployment re-runs with the backend's service time
           shifted 1.6x (the profile is now stale).  The DriftMonitor must
           fire ``profile:drift`` (sustained out-of-band ratio at the
           scrape cadence), arm a re-profile (``modelci:reprofile``), and
           the replan probe must then migrate with reason=profile_drift --
           strictly AFTER the drift edge in event order (asserted on seq).

    Every event recorded across both legs must be registered vocabulary
    (``events.unregistered``)."""
    t8 = pred.service_time(8)
    svc = t8 / 8
    prof_g, prof_i = get_profile("gcp"), get_profile("ibm")
    per_batch = prof_g.network_rtt_s + prof_g.lb_overhead_s + t8
    clouds = [CloudCapacity(prof_g, 2, 1.0), CloudCapacity(prof_i, 2, 1.4)]
    load = 2.0          # planned fleet-scale Erlangs (3 replicas, 2 clouds)
    factor = 1.6        # injected shift; the stream below is sized so the
    # shifted fleet stays underloaded (~80% of the 3-replica per-batch
    # ceiling): ONLY the drift trigger may arm the probe -- no
    # overload/miss/shed signal competes for the migrate reason
    window_s = 60 * per_batch
    rate = 0.5 * 3 * 8 / per_batch       # 50% of the measured ceiling
    n = int(rate * window_s)
    traffic = [TrafficSpec("ranker", n, arrival="poisson", rate=rate)]
    asc = AutoscalerConfig(min_replicas=3, max_replicas=4, target_queue=8,
                           scale_up_delay_s=0.01, idle_window_s=np.inf)

    # hand-tuned leg: the same measured number, entered as a constant
    demand = ModelDemand("ranker", rate=load / svc, service_time_s=svc)
    hand = plan_placement([demand], clouds, objective="p99", split=True)
    ah = hand.assignments[0]
    assert hand.feasible and len(ah.shares) == 2, hand.summary()
    gw_h = Gateway(log=EventLog())
    gw_h.deploy("ranker", pred,
                split={get_profile(c): w for c, w in ah.weights.items()},
                autoscaler=asc, max_batch=8, queue_hint=dict(ah.est_wait_s))
    out_h = gw_h.run(traffic, seed=0)
    r_h = out_h.per_model["ranker"]

    # profile-planned leg: profiling DAG -> store -> deploy, one shared log
    store = ProfileStore()
    serve = _ShiftBackend(pred, "ranker")
    log = EventLog()
    pipe = Pipeline("model-ci")
    profs = [pipe.step(lambda: measure(pred, max_batch=8),
                       name=f"profile_{c}", cache=False, kind="profile",
                       pin=c, payload=ProfileSpec("ranker", store,
                                                  max_batch=8))
             for c in ("gcp", "ibm")]
    pipe.step(lambda *_: serve, *profs, name="deploy", cache=False,
              kind="deploy",
              payload=DeploySpec("ranker", clouds, load_erlangs=load,
                                 objective="p99", split=True,
                                 autoscaler=asc, max_batch=8,
                                 profile=store))
    gw_p = Gateway(log=log,
                   replan=ReplanConfig(check_every_s=4 * per_batch,
                                       sustain=2, shift=0.25,
                                       consolidate=False),
                   metrics=MetricsRegistry(),
                   drift=DriftConfig(threshold=1.3, sustain=2, min_n=8),
                   scrape_every_s=3 * per_batch)
    orch = Orchestrator({"gcp": 1, "ibm": 1}, log=log)
    rec = orch.execute(pipe.compile(), gateway=gw_p)
    assert rec.status == "succeeded", rec.steps
    assert log.count("modelci:profile") >= 2
    assert rec.outputs["deploy"]["profiled"] is True
    worst = store.worst("ranker")
    out_p = gw_p.run(traffic, seed=0)
    r_p = out_p.per_model["ranker"]
    ratio = r_p.p99 / r_h.p99

    # drift leg: shift the backend, re-run on the SAME gateway + log
    serve.factor = factor
    gw_p.run(traffic, seed=1)
    drifts = [e for e in log.named("profile:drift")
              if e["state"] == "firing"]
    migs = [e for e in log.named("gateway:migrate")
            if e["reason"] == "profile_drift"]
    reprof = sorted(gw_p.drift.pop_reprofile())

    print(f"drift tier: profile-planned p99 {r_p.p99:.5f}s vs hand-tuned "
          f"{r_h.p99:.5f}s ({ratio:.3f}x); shift {factor}x -> "
          f"{len(drifts)} drift edge(s), {len(migs)} profile_drift "
          f"migrate(s), reprofile armed for {reprof}", file=sys.stderr)

    # acceptance: the measured-artifact plan matches hand-tuning; the
    # injected shift is detected and ACTED on, detection strictly first
    assert ratio <= 1.1, (r_p.p99, r_h.p99)
    assert drifts, "injected shift never fired profile:drift"
    assert migs, "drift never armed a reason=profile_drift migrate"
    assert drifts[0]["seq"] <= migs[0]["seq"], (drifts[0], migs[0])
    assert log.count("modelci:reprofile") >= 1 and reprof == ["ranker"]
    # every bench event is registered vocabulary (ISSUE 10 satellite)
    for lg in (log, gw_h.log):
        assert not unregistered(lg), unregistered(lg)

    bench["scenarios"]["drift"] = {
        "hand_p99_s": _round(r_h.p99, 6),
        "profile_p99_s": _round(r_p.p99, 6),
        "p99_ratio": round(ratio, 4),
        "profiles_committed": log.count("modelci:profile"),
        "profile": {"cloud": worst.cloud, "key": worst.key,
                    "service_time_s": round(worst.service_time_s, 9),
                    "source": worst.source},
        "planned": rec.outputs["deploy"],
        "injected_factor": factor,
        "drift": {"firing": len(drifts),
                  "ratio": drifts[0]["ratio"],
                  "first_drift_seq": drifts[0]["seq"],
                  "first_migrate_seq": migs[0]["seq"],
                  "migrates_profile_drift": len(migs),
                  "reprofile_armed": reprof},
        "scrapes": log.count("metrics:scrape")}
    return [{
        "name": "gateway_drift_race",
        "us_per_call": r_p.p99 * 1e6,
        "derived": f"p99_ratio={ratio:.4f};"
                   f"profiles={log.count('modelci:profile')};"
                   f"drift_firing={len(drifts)};"
                   f"drift_seq={drifts[0]['seq']};"
                   f"migrate_seq={migs[0]['seq']};"
                   f"injected_factor={factor}",
    }]


# -- contention tier (ISSUE 9): one CapacityMarket under both planes --------

def _contention_pipeline():
    """The training side of the contention race: a prep -> 4-branch tune
    fan-out -> select -> train DAG with fixed sim_s durations (analytic,
    so the makespan ratio is host-independent; the serving side keeps its
    measured Predictor)."""
    fns = {"prep": lambda: 1.0,
           "tune": lambda i, p: {"lr": 0.01 * (1 + i), "loss": 1.0 / (1 + i)},
           "select": lambda *rs: min(rs, key=lambda r: r["loss"]),
           "train": lambda p, best: {"loss": best["loss"] / 2}}
    pipe = Pipeline("contend-tune")
    prep = pipe.step(fns["prep"], name="prep", cache=False)
    branches = [pipe.step(fns["tune"], i, prep, name=f"tune{i}", cache=False)
                for i in range(4)]
    best = pipe.step(fns["select"], *branches, name="select", cache=False)
    pipe.step(fns["train"], prep, best, name="train", cache=False)
    spec = pipe.compile()
    sims = {"prep": 0.3, "select": 0.05, "train": 1.5,
            **{f"tune{i}": 1.2 for i in range(4)}}
    for s in spec.steps:
        s.sim_s = sims[s.name]
    return spec


def _contention_scenario(pred: Predictor, bench: dict) -> list[dict]:
    """ISSUE 9 acceptance: training and serving colocated on ONE 4-slot
    gcp CapacityMarket, raced four ways.

      dedicated     the serving burst alone, no market -- the baseline the
                    1.3x p99 gate compares against;
      priority_on   training leases recorded first, then the same burst:
                    elastic scale-ups preempt the youngest recorded
                    training lease (spot semantics), so serving is never
                    denied -- p99 must stay within 1.3x dedicated;
      priority_off  same layout, serving_priority=False: the contended
                    scale-up is DENIED (gateway:scale_denied capacity),
                    zero preempts -- the counterfactual that shows the
                    priority class doing the work;
      training      the mirror image on a fresh market: the serving burst
                    recorded first, then the orchestrator runs through the
                    recorded rise-edges -- its youngest attempt is killed
                    at the over-committing edge, re-enters RetryPolicy
                    backoff (exactly-once asserted), and the contended
                    makespan must stay <= 2x the uncontended run.  The
                    budget planner reserves serving headroom on this leg
                    (plan_budget), so training also waits rather than
                    crowding the reserve.

    Every leg ends with ``check_conservation()``: no cloud's committed
    lease timeline ever exceeds its slots."""
    slots = 4
    t8 = pred.service_time(8)
    prof = get_profile("gcp")
    per_batch = prof.network_rtt_s + prof.lb_overhead_s + t8
    n = 480
    rate = 3.0 * 8 / per_batch           # 3x a single replica's ceiling

    def run_serving(market):
        log = EventLog()
        gw = Gateway(log=log, shared_capacity=market)
        gw.deploy("m", pred, prof,
                  autoscaler=AutoscalerConfig(min_replicas=1,
                                              max_replicas=slots,
                                              target_queue=8,
                                              scale_up_delay_s=0.01,
                                              idle_window_s=np.inf),
                  max_batch=8)
        out = gw.run([TrafficSpec("m", n, arrival="poisson", rate=rate)],
                     seed=0)
        denied = sum(1 for e in log.named("gateway:scale_denied")
                     if e["reason"] == "capacity")
        return out, log, denied

    def run_training(market, log=None):
        orch = Orchestrator({"gcp": 3}, policy="makespan",
                            log=log or EventLog(),
                            retry=RetryPolicy(max_retries=3, backoff_s=0.3),
                            shared_capacity=market)
        return orch.execute(_contention_pipeline()), orch

    # dedicated baseline: the burst with the cluster to itself
    out_d, _, _ = run_serving(None)
    p99_d = out_d.per_model["m"].p99

    # priority on: recorded training, then the burst preempts its way up
    mkt_on = CapacityMarket({"gcp": slots})
    run_training(mkt_on)
    out_on, log_on, denied_on = run_serving(mkt_on)
    mkt_on.check_conservation()
    p99_on = out_on.per_model["m"].p99
    preempts_on = log_on.count("capacity:preempt")

    # priority off: the same layout must deny the contended scale-up
    mkt_off = CapacityMarket({"gcp": slots}, serving_priority=False)
    run_training(mkt_off)
    out_off, log_off, denied_off = run_serving(mkt_off)
    mkt_off.check_conservation()
    p99_off = out_off.per_model["m"].p99

    # training leg: serving recorded first, orchestrator rides the edges
    mkt_tr = CapacityMarket({"gcp": slots})
    budget = mkt_tr.plan_budget({"gcp": 1.0}, work_s=0.3 + 4 * 1.2 + 1.55)
    run_serving(mkt_tr)
    tr_log = EventLog()
    rec_c, _ = run_training(mkt_tr, log=tr_log)
    mkt_tr.check_conservation()
    rec_u, _ = run_training(None)        # uncontended makespan baseline
    exactly_once = all(
        r.status == "done"
        and sum(1 for a in r.attempts if a["status"] == "ok") == 1
        and all(a["status"] in ("ok", "outage", "preempted", "cancelled")
                for a in r.attempts)
        for r in rec_c.steps.values())
    mk_ratio = rec_c.makespan_s / rec_u.makespan_s
    p99_ratio = p99_on / p99_d

    print(f"contention (4-slot gcp market, burst {n} reqs @ 3x one-replica "
          "ceiling vs the tune fan-out):", file=sys.stderr)
    print(f"  serving p99: dedicated {p99_d:.5f}s | priority-on {p99_on:.5f}s"
          f" ({p99_ratio:.2f}x, {preempts_on} preempts) | priority-off "
          f"{p99_off:.5f}s ({denied_off} denied)", file=sys.stderr)
    print(f"  training makespan: uncontended {rec_u.makespan_s:.2f}s | "
          f"contended {rec_c.makespan_s:.2f}s ({mk_ratio:.2f}x, "
          f"{tr_log.count('capacity:preempt')} preempts, reserve "
          f"{budget['reserve']})", file=sys.stderr)

    # acceptance: priority keeps serving whole (preempt, never deny) within
    # 1.3x dedicated; no-priority shows the denial; preempted training
    # stays exactly-once and <= 2x uncontended
    assert preempts_on >= 1 and denied_on == 0, (preempts_on, denied_on)
    assert denied_off >= 1 and log_off.count("capacity:preempt") == 0
    assert p99_ratio <= 1.3, (p99_on, p99_d)
    assert rec_c.status == "succeeded" and exactly_once
    assert mk_ratio <= 2.0, (rec_c.makespan_s, rec_u.makespan_s)

    bench["scenarios"]["contention"] = {
        "slots": slots,
        "dedicated": {"p99_s": _round(p99_d, 6)},
        "priority_on": {"p99_s": _round(p99_on, 6),
                        "preempts": preempts_on,
                        "leases": log_on.count("capacity:lease"),
                        "scale_denied": denied_on,
                        "sim_cost_usd": round(out_on.total_cost_usd, 8)},
        "priority_off": {"p99_s": _round(p99_off, 6),
                         "preempts": log_off.count("capacity:preempt"),
                         "scale_denied": denied_off},
        "training": {"contended_makespan_s": round(rec_c.makespan_s, 4),
                     "uncontended_makespan_s": round(rec_u.makespan_s, 4),
                     "preempts": tr_log.count("capacity:preempt"),
                     "retries": tr_log.count("pipeline:retry"),
                     "exactly_once": exactly_once,
                     "budget": {"reserve": budget["reserve"],
                                "training_slots": budget["training_slots"],
                                "est_makespan_s":
                                    round(budget["est_makespan_s"], 4)}},
        "p99_ratio": round(p99_ratio, 4),
        "makespan_ratio": round(mk_ratio, 4)}
    return [{
        "name": "gateway_contention_race",
        "us_per_call": p99_on * 1e6,
        "derived": f"p99_ratio={p99_ratio:.3f};"
                   f"makespan_ratio={mk_ratio:.3f};"
                   f"preempts_on={preempts_on};denied_off={denied_off};"
                   f"training_preempts={tr_log.count('capacity:preempt')};"
                   f"exactly_once={exactly_once}",
    }]


# -- scale tier (ISSUE 7): simulator throughput, not model latency ----------

# bench-local fifth cloud so the fleet spans five providers without
# touching the repo-wide PROFILES registry (tests pin its exact key set):
# an on-prem Kubeflow analog -- LAN RTT, no LB hop, free egress, mid price
_ONPREM = CloudProfile("onprem", TPU_V5E, (4, 4),
                       network_rtt_s=0.0008, lb_overhead_s=0.0,
                       model_load_s=0.25, startup_s=0.5,
                       cost_per_s=0.95 / 3600.0,
                       egress_per_gb=0.0, interconnect_bw=0.625e9)
SCALE_CLOUDS = ("gcp", "ibm", "baremetal", "k8s", "onprem")
SCALE_MODELS = 12
SCALE_BATCH = 2048


class _SimBackend:
    """Analytic backend for the scale tier.  The tier measures SIMULATOR
    throughput (events/sec through the engine), so the compute term must
    be O(1) per batch and identical on every host -- a jitted predict
    here would benchmark the accelerator, not the event loop.  The
    latency/dollar scenarios above keep their measured Predictors."""

    def __init__(self, name: str, base_s: float, per_req_s: float):
        self.name = name
        self.base_s = base_s
        self.per_req_s = per_req_s

    def service_time(self, b: int) -> float:
        return self.base_s + self.per_req_s * b


def _build_scale_fleet(n_per_model: int, seed: int = 0):
    """A dozen single-cloud models over five clouds, every pool pinned at
    two replicas and offered ~1.3x its ceiling (sustained overload is the
    regime the vector engine must win: queues never drain, so whole
    arrival spans fold between batch completions).  Model 0 carries a
    standby and takes a mid-run outage on its primary cloud, so the
    failover/recover control path runs inside the measured loop.  All
    classes are non-preempting ("standard" / "batch") -- preemption would
    pin the engines to per-arrival stepping and belongs to the latency
    scenarios above, not the throughput tier."""
    profs = {c: get_profile(c) for c in SCALE_CLOUDS if c != "onprem"}
    profs["onprem"] = _ONPREM
    gw = Gateway(log=EventLog())
    traffic = []
    outage_cloud = SCALE_CLOUDS[0]
    window_s = 0.0
    for i in range(SCALE_MODELS):
        cloud = SCALE_CLOUDS[i % len(SCALE_CLOUDS)]
        prof = profs[cloud]
        backend = _SimBackend(f"scale{i}", 2e-3, 2e-5)
        per_batch = (prof.network_rtt_s + prof.lb_overhead_s
                     + backend.service_time(SCALE_BATCH))
        cap_rps = 2 * SCALE_BATCH / per_batch        # 2-replica ceiling
        # every model on the outage cloud carries a standby: a pool-less
        # model logs scale_denied per TIMESTEP, which is exactly the
        # regime the vector engine cannot (and must not) skip -- the
        # throughput tier measures failover, not blackholed traffic
        gw.deploy(f"scale{i}", backend, prof,
                  standby=(profs[SCALE_CLOUDS[(i + 1) % len(SCALE_CLOUDS)]]
                           if cloud == outage_cloud else None),
                  autoscaler=AutoscalerConfig(min_replicas=2,
                                              max_replicas=2,
                                              idle_window_s=np.inf),
                  max_batch=SCALE_BATCH)
        rate = 1.3 * cap_rps
        window_s = max(window_s, n_per_model / rate)
        # two non-preempting streams per model: distinct per-class queues
        # exercise the grouped bulk-append path, not just one extend
        traffic.append(TrafficSpec(f"scale{i}", (2 * n_per_model) // 3,
                                   arrival="poisson", rate=rate * 2 / 3,
                                   slo="standard"))
        traffic.append(TrafficSpec(f"scale{i}", n_per_model // 3,
                                   arrival="poisson", rate=rate / 3,
                                   slo="batch"))
    failures = [FailureSpec(outage_cloud, at_s=0.35 * window_s,
                            duration_s=0.2 * window_s)]
    return gw, traffic, failures


def _run_scale(n_per_model: int, engine: str, seed: int = 0):
    gw, traffic, failures = _build_scale_fleet(n_per_model, seed)
    out = gw.run(traffic, seed=seed, failures=failures, engine=engine)
    return gw, out


def _scale_scenario(bench: dict, *, smoke: bool = False) -> list[dict]:
    """ISSUE 7 acceptance: >=10^6 requests end-to-end through the gateway
    with events/sec recorded, the vectorized engine >=50x the scalar
    per-request loop on the same scenario (>=10x on the reduced CI smoke
    cut), gated by the bit-compatibility oracle on a small seed."""
    # oracle leg: the engines must agree EXACTLY before speed means
    # anything (the hypothesis suite covers the wide scenario space;
    # this pins the bench's own fleet shape, outage included)
    n_oracle = 400
    gw_s, out_s = _run_scale(n_oracle, "scalar")
    gw_v, out_v = _run_scale(n_oracle, "vector")
    assert gw_s.log.dump() == gw_v.log.dump(), \
        "scale oracle: EventLog diverged between engines"
    assert {m: r.summary() for m, r in out_s.per_model.items()} \
        == {m: r.summary() for m, r in out_v.per_model.items()}
    assert out_s.costs == out_v.costs and out_s.makespan_s == out_v.makespan_s
    n_oracle_total = gw_v.run_stats["requests"]

    n_per_model = 14_000 if smoke else 90_000
    min_speedup = 10.0 if smoke else 50.0
    gw_sc, out_sc = _run_scale(n_per_model, "scalar")
    gw_vec, out_vec = _run_scale(n_per_model, "vector")
    sc, vec = gw_sc.run_stats, gw_vec.run_stats
    # same scenario, same outcome -- the speed claim is apples-to-apples
    assert {m: r.summary() for m, r in out_sc.per_model.items()} \
        == {m: r.summary() for m, r in out_vec.per_model.items()}
    speedup = sc["wall_s"] / vec["wall_s"]

    print(f"scale tier: {vec['requests']} requests / {SCALE_MODELS} models "
          f"/ {len(SCALE_CLOUDS)} clouds", file=sys.stderr)
    print(f"  scalar {sc['wall_s']:.2f}s "
          f"({sc['events_per_s']:,.0f} ev/s, "
          f"{sc['requests_per_s']:,.0f} req/s)", file=sys.stderr)
    print(f"  vector {vec['wall_s']:.2f}s "
          f"({vec['events_per_s']:,.0f} ev/s, "
          f"{vec['requests_per_s']:,.0f} req/s)  ->  "
          f"{speedup:.1f}x", file=sys.stderr)

    # acceptance: the vectorized engine clears the asserted floor
    assert speedup >= min_speedup, \
        f"scale speedup {speedup:.1f}x < {min_speedup}x"

    def side(stats):
        return {"wall_s": round(stats["wall_s"], 4),
                "sim_events": stats["sim_events"],
                "events_per_s": round(stats["events_per_s"], 1),
                "requests_per_s": round(stats["requests_per_s"], 1)}

    bench["scenarios"]["scale"] = {
        "requests": vec["requests"],
        "models": SCALE_MODELS,
        "clouds": len(SCALE_CLOUDS),
        "oracle_requests": n_oracle_total,
        "scalar": side(sc),
        "vector": side(vec),
        "speedup": round(speedup, 2),
        "asserted_min_speedup": min_speedup,
        "shed": sum(r.shed_total for r in out_vec.per_model.values()),
        "failovers": gw_vec.log.count("gateway:failover"),
        "sim_cost_usd": round(out_vec.total_cost_usd, 8)}
    return [{
        "name": "gateway_scale_vector",
        "us_per_call": 1e6 / vec["requests_per_s"],
        "derived": f"requests={vec['requests']};speedup={speedup:.1f}x;"
                   f"events_per_s={vec['events_per_s']:.0f};"
                   f"requests_per_s={vec['requests_per_s']:.0f};"
                   f"scalar_wall_s={sc['wall_s']:.3f};"
                   f"vector_wall_s={vec['wall_s']:.3f}",
    }]


# -- disagg tier (ISSUE 8): chunked prefill vs teacher-forced decode --------

def _disagg_scenario(bench: dict, *, smoke: bool = False) -> list[dict]:
    """ISSUE 8 acceptance: the SAME prefill-heavy request mix drained
    through a real ContinuousBatcher two ways on one host -- the
    teacher-forced seed path (prefill_chunk=0: a P-token prompt costs P
    full decode steps across the whole slot pool) and the disaggregated
    path (prefill_chunk=C: the prompt runs through the batched
    flash-attention prefill in ceil(P/C) calls and enters the decode pool
    with its first token emitted).

    The ORACLE leg runs first: both paths must emit identical output
    tokens for every request (the bit-level logits oracle lives in
    tests/test_prefill_oracle.py; this pins the bench's own mix) -- a
    throughput number from a diverged model is meaningless.  The timed
    race then drives step() manually: steps in which admission ran a
    prompt prefill are excluded from the DECODE-step latency sample (they
    are prefill cost, already paid inside the wall), so the p99 guard
    compares pure decode steps against pure decode steps.  Acceptance:
    >=2x token throughput (>=1.3x on the reduced smoke cut) and a
    decode-step p99 within the 1.3x noise guard of the seed path."""
    from repro.configs import registry
    from repro.models import lm
    from repro.serving.continuous import ContinuousBatcher

    P, G = (48, 4) if smoke else (96, 4)
    n_req = 6 if smoke else 8
    chunk = 8 if smoke else 32       # P % chunk == 0: one prefill shape
    slots = 2 if smoke else 4
    min_speedup = 1.3 if smoke else 2.0
    arch = "h2o_danube_3_4b"
    cfg = registry.get_smoke_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, P).tolist()
               for _ in range(n_req)]

    def make(pc: int) -> ContinuousBatcher:
        return ContinuousBatcher(cfg, params, max_slots=slots,
                                 max_len=P + G + 4, prefill_chunk=pc)

    # oracle leg: identical outputs before any timing means anything
    outs = {}
    for pc in (0, chunk):
        b = make(pc)
        reqs = [b.submit(list(p), G) for p in prompts]
        b.run()
        outs[pc] = [r.output for r in reqs]
    oracle_ok = outs[0] == outs[chunk]
    assert oracle_ok, "disagg race oracle: outputs diverged from seed"

    def timed_once(pc: int) -> dict:
        b = make(pc)
        b.submit(list(prompts[0]), G)
        b.run()                          # warmup: compile both phase shapes
        for p in prompts:
            b.submit(list(p), G)
        decode_walls, admit_steps = [], 0
        t0 = time.perf_counter()
        while b.queue or b.active:
            pf0 = b.prefill_stats["requests"] if pc else 0
            s0 = time.perf_counter()
            b.step()
            w = time.perf_counter() - s0
            if pc and b.prefill_stats["requests"] > pf0:
                admit_steps += 1         # prompt ingest ran inside this step
            else:
                decode_walls.append(w)
        wall = time.perf_counter() - t0
        toks = n_req * (P + G)
        return {"wall_s": wall, "tokens_per_s": toks / wall,
                "decode_step_p99_s": float(np.percentile(decode_walls, 99)),
                "steps": len(decode_walls) + admit_steps,
                "prefill_steps": admit_steps}

    def timed(pc: int) -> dict:
        # min-of-reps, like the observability race: back-to-back reps share
        # the box's thermal/GC state, so min wall is the noise-robust
        # estimator for a fixed amount of work
        return min((timed_once(pc) for _ in range(3)),
                   key=lambda s: s["wall_s"])

    seed_side = timed(0)
    dis_side = timed(chunk)
    speedup = dis_side["tokens_per_s"] / seed_side["tokens_per_s"]

    print(f"disagg race ({arch} smoke config, {n_req} reqs x "
          f"P={P} G={G}, chunk={chunk}, slots={slots}):", file=sys.stderr)
    for tag, s in (("seed", seed_side), ("disagg", dis_side)):
        print(f"  {tag:<8}{s['tokens_per_s']:>10.0f} tok/s  "
              f"wall {s['wall_s'] * 1e3:8.1f}ms  steps {s['steps']:>4}  "
              f"decode_p99 {s['decode_step_p99_s'] * 1e3:.2f}ms",
              file=sys.stderr)
    print(f"  -> {speedup:.2f}x token throughput", file=sys.stderr)

    # acceptance: throughput floor + decode-tail non-regression
    assert speedup >= min_speedup, \
        f"disagg speedup {speedup:.2f}x < {min_speedup}x"
    assert dis_side["decode_step_p99_s"] <= \
        1.3 * seed_side["decode_step_p99_s"], \
        (dis_side["decode_step_p99_s"], seed_side["decode_step_p99_s"])

    def side(s):
        return {"wall_s": round(s["wall_s"], 6),
                "tokens_per_s": round(s["tokens_per_s"], 1),
                "decode_step_p99_s": round(s["decode_step_p99_s"], 6),
                "steps": s["steps"],
                "prefill_steps": s["prefill_steps"]}

    bench["scenarios"]["disagg"] = {
        "oracle_ok": oracle_ok,
        "arch": arch,
        "requests": n_req,
        "prompt_tokens": P,
        "gen_tokens": G,
        "chunk": chunk,
        "slots": slots,
        "seed": side(seed_side),
        "disagg": side(dis_side),
        "speedup": round(speedup, 2),
        "asserted_min_speedup": min_speedup}
    return [{
        "name": "gateway_disagg_race",
        "us_per_call": 1e6 / dis_side["tokens_per_s"],
        "derived": f"speedup={speedup:.2f}x;"
                   f"disagg_tok_s={dis_side['tokens_per_s']:.0f};"
                   f"seed_tok_s={seed_side['tokens_per_s']:.0f};"
                   f"decode_p99_ms={dis_side['decode_step_p99_s'] * 1e3:.3f};"
                   f"P={P};G={G};chunk={chunk}",
    }]


def smoke() -> None:
    """CI bench-smoke: run the overload scenario (with its burn-rate
    telemetry leg), the instrumentation-overhead race, the contention
    race (ISSUE 9: training + serving burst through one CapacityMarket,
    priority on vs off), the model-CI drift tier (ISSUE 10:
    profile-planned placement + injected shift -> profile:drift before
    the profile_drift migrate), the reduced scale tier (engine oracle +
    >=10x vector-over-scalar on a smaller request count) and the reduced
    disagg tier (output oracle + >=1.3x chunked-prefill token
    throughput), then validate both the freshly produced record and
    (when present) the committed BENCH_gateway.json against the schema
    -- including the shed-rate fields, the alert-before-migrate
    ordering, the <10% overhead gate, the drift seq ordering, the
    contention ratios and the recorded scale / disagg speedups."""
    pred = _make_predictor("small", WIDTHS["small"])
    bench: dict = {"schema": BENCH_SCHEMA, "scenarios": {}}
    _overload_shed_scenario(pred, bench)
    _observability_scenario(pred, bench)
    _drift_scenario(pred, bench)
    _contention_scenario(pred, bench)
    _scale_scenario(bench, smoke=True)
    _disagg_scenario(bench, smoke=True)
    validate_bench(bench, require=("overload", "observability", "drift",
                                   "contention", "scale", "disagg"))
    if BENCH_JSON.exists():
        validate_bench(json.loads(BENCH_JSON.read_text()),
                       require=("fleet", "slo_failover", "split_cost",
                                "overload", "observability", "drift",
                                "contention", "scale", "disagg"))
        print(f"validated {BENCH_JSON}", file=sys.stderr)
    print("overload race:",
          json.dumps(bench["scenarios"]["overload"]["race"]),
          file=sys.stderr)
    ct = bench["scenarios"]["contention"]
    print("contention:", json.dumps({"p99_ratio": ct["p99_ratio"],
                                     "makespan_ratio": ct["makespan_ratio"]}),
          file=sys.stderr)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="overload scenario + schema validation only (CI)")
    if ap.parse_args().smoke:
        smoke()
    else:
        print("name,us_per_call,derived")
        for r in run():
            print(f"{r['name']},{r['us_per_call']:.3f},{r['derived']}")
