"""Model-mesh gateway fleet benchmark (beyond paper): >=3 models behind one
router with heterogeneous traffic (Poisson stream, burst + canary split, and
a sparse workload forcing a scale-to-zero -> cold-start cycle), plus a
placement plan across >=2 cloud profiles under both objectives.

Compute service times are measured (jitted matmuls of three widths); the
network / cold-start terms come from the CloudProfiles (DESIGN.md)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.clouds.profiles import get_profile
from repro.serving.gateway import (AutoscalerConfig, CloudCapacity, Gateway,
                                   ModelDemand, Predictor, TrafficSpec,
                                   plan_placement)
from repro.telemetry.events import EventLog

WIDTHS = {"small": 64, "medium": 128, "large": 256}
# fleet-scale offered load in Erlangs (rate derived from the measured
# service time, so the plan shape is host-independent); the simulated
# streams below are scaled-down samples of the same mix
PLANNED_LOADS = {"small": 4.0, "medium": 2.0, "large": 0.5}


def _make_predictor(name: str, width: int, seed: int = 0) -> Predictor:
    w = jax.random.normal(jax.random.PRNGKey(seed), (width, width), jnp.float32)
    predict = jax.jit(lambda v: jnp.tanh(v @ w))
    p = Predictor(name, predict, np.zeros((1, width), np.float32))
    p.warmup((1, 8, 32))
    return p


def run() -> list[dict]:
    preds = {n: _make_predictor(n, w) for n, w in WIDTHS.items()}

    # -- placement: both objectives over gcp/ibm ---------------------------
    demands = [ModelDemand(n, PLANNED_LOADS[n] / (preds[n].service_time(8) / 8),
                           preds[n].service_time(8) / 8)
               for n in WIDTHS]
    # gcp is cheaper but capacity-constrained, so the cost plan itself must
    # spill part of the fleet onto ibm (a genuinely multi-cloud placement)
    clouds = [CloudCapacity(get_profile("gcp"), 8, 1.0),
              CloudCapacity(get_profile("ibm"), 16, 1.4)]
    plans = {obj: plan_placement(demands, clouds, objective=obj)
             for obj in ("cost", "p99")}
    plan = plans["cost"]
    # measured service times vary by host: an unplaceable model would give
    # cloud=None below, so fail with the plan rather than a KeyError
    assert plan.feasible, plan.summary()
    cloud_of = {a.model: a.cloud for a in plan.assignments}

    # -- fleet simulation on the cost plan ---------------------------------
    log = EventLog()
    gw = Gateway(capacity=plan.capacity_map(), log=log)
    replicas = {a.model: a.replicas for a in plan.assignments}
    gw.deploy("small", preds["small"], get_profile(cloud_of["small"]),
              autoscaler=AutoscalerConfig(
                  min_replicas=1, max_replicas=replicas["small"],
                  target_queue=8, idle_window_s=2.0), max_batch=16)
    gw.deploy("medium", preds["medium"], get_profile(cloud_of["medium"]),
              autoscaler=AutoscalerConfig(
                  min_replicas=1, max_replicas=replicas["medium"],
                  target_queue=8, idle_window_s=2.0), max_batch=16,
              canary=_make_predictor("medium-canary", WIDTHS["medium"], seed=1),
              canary_fraction=0.2)
    gw.deploy("large", preds["large"], get_profile(cloud_of["large"]),
              autoscaler=AutoscalerConfig(
                  min_replicas=0, max_replicas=max(replicas["large"], 1),
                  scale_up_delay_s=0.5, idle_window_s=1.0), max_batch=8)
    out = gw.run([
        TrafficSpec("small", 600, arrival="poisson", rate=2000.0),
        TrafficSpec("medium", 256),                      # burst + canary
        TrafficSpec("large", 8),                         # cold start #1
        TrafficSpec("large", 8, start_s=6.0),            # idle -> cold #2
    ], seed=0)

    rows = []
    for name, res in out.per_model.items():
        trace = res.replica_trace
        rows.append({
            "name": f"gateway_{name}",
            "us_per_call": res.p50 * 1e6,
            "derived": f"cloud={cloud_of[name]};p50_s={res.p50:.5f};"
                       f"p99_s={res.p99:.5f};replicas_max="
                       f"{max(r for _, r in trace)};"
                       f"cold_starts={out.cold_starts[name]};"
                       f"hit_zero={any(r == 0 for _, r in trace[1:])}",
        })
    for obj, pl in plans.items():
        s = pl.summary()
        assign = ";".join(f"{m}->{a['cloud']}x{a['replicas']}"
                          for m, a in s["assignments"].items())
        rows.append({
            "name": f"gateway_placement_{obj}",
            "us_per_call": float(pl.worst_p99_s) * 1e6,
            "derived": f"feasible={s['feasible']};"
                       f"cost_hr={s['total_cost_hr']};{assign}",
        })
    events = [e["name"] for e in log.events]
    rows.append({
        "name": "gateway_events",
        "us_per_call": out.makespan_s * 1e6,
        "derived": f"cold_start={events.count('gateway:cold_start')};"
                   f"scale_up={events.count('gateway:scale_up')};"
                   f"scale_down={events.count('gateway:scale_down')};"
                   f"scale_to_zero={events.count('gateway:scale_to_zero')}",
    })
    # acceptance: the large model must complete a scale-to-zero -> cold-start
    # cycle (zero pool between its two bursts, a cold start on each)
    assert out.cold_starts["large"] >= 2, out.cold_starts
    assert any(r == 0 for _, r in out.per_model["large"].replica_trace[1:])
    return rows
