"""Kernel microbench: jnp reference path wall time per call on this host
(the TPU kernels are validated in interpret mode by tests/; wall numbers
here are the CPU reference path, 'derived' reports achieved GFLOP/s).

Also carries the serving-layer prompt-ingest race (ISSUE 8): tokens/s
ingesting a P-token prompt through the chunked batched prefill path vs
the 1-token-per-step teacher-forced reference, at P in {128, 512, 2048}
(P=128 only under --smoke)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def _prefill_rows(smoke: bool = False) -> list[dict]:
    """Prompt-ingest tokens/s: chunked batched prefill vs teacher forcing.

    One request, one slot -- this isolates the per-token ingest cost from
    the batcher's slot scheduling (the batched race with a full slot pool
    is bench_gateway's disagg scenario).  Both sides drain the same prompt
    on the same host after a warmup drain that compiles both phase shapes;
    outputs are asserted identical before the timed leg."""
    from repro.configs import registry
    from repro.models import lm
    from repro.serving.continuous import ContinuousBatcher

    cfg = registry.get_smoke_config("h2o_danube_3_4b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    G, chunk = 4, 32
    rows = []
    for P in (128,) if smoke else (128, 512, 2048):
        prompt = rng.integers(1, cfg.vocab_size, P).tolist()

        def drain(b: ContinuousBatcher) -> tuple[list[int], float]:
            req = b.submit(list(prompt), G)
            t0 = time.perf_counter()
            b.run()
            return req.output, time.perf_counter() - t0

        per = {}
        for pc in (0, chunk):
            # one batcher per side: its jitted phase programs compile on
            # the warmup drain and stay cached for the timed reps
            b = ContinuousBatcher(cfg, params, max_slots=1,
                                  max_len=P + G + 4, prefill_chunk=pc)
            out_w, _ = drain(b)                   # warmup / compile
            out_t, wall = min((drain(b) for _ in range(2)),
                              key=lambda r: r[1])
            assert out_w == out_t, "prefill microbench: nondeterministic"
            per[pc] = {"out": out_t, "tok_s": P / wall}
        assert per[0]["out"] == per[chunk]["out"], \
            f"prefill oracle diverged at P={P}"
        rows.append({
            "name": f"serving_prefill_p{P}",
            "us_per_call": P / per[chunk]["tok_s"] * 1e6,
            "derived": f"prefill_tok_s={per[chunk]['tok_s']:.0f};"
                       f"teacher_tok_s={per[0]['tok_s']:.0f};"
                       f"speedup={per[chunk]['tok_s'] / per[0]['tok_s']:.2f}x;"
                       f"chunk={chunk}",
        })
    return rows


def run(smoke: bool = False) -> list[dict]:
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 6)
    rows = []

    B, S, Hq, Hkv, D = 2, 512, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    kk = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    fn = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v))
    t = _time(fn, q, kk, v)
    flops = 4 * B * Hq * S * S * D
    rows.append({"name": "kernel_flash_attention_ref", "us_per_call": t * 1e6,
                 "derived": f"gflops={flops / t / 1e9:.2f}"})

    qd = jax.random.normal(ks[3], (B, Hq, D))
    lens = jnp.full((B,), S, jnp.int32)
    fn = jax.jit(lambda q, k, v, l: ops.decode_attention(q, k, v, l))
    t = _time(fn, qd, kk, v, lens)
    rows.append({"name": "kernel_decode_attention_ref", "us_per_call": t * 1e6,
                 "derived": f"cache_tokens_per_s={B * S / t:.0f}"})

    x = jax.random.normal(ks[4], (B, S, 1024))
    sc = jnp.zeros((1024,))
    fn = jax.jit(lambda x, s: ops.rmsnorm(x, s))
    t = _time(fn, x, sc)
    rows.append({"name": "kernel_rmsnorm_ref", "us_per_call": t * 1e6,
                 "derived": f"gbps={x.size * 8 / t / 1e9:.2f}"})

    H, P, N = 4, 32, 64
    xs = jax.random.normal(ks[5], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, H)))
    A = -jnp.linspace(1, 8, H)
    Bm = jax.random.normal(ks[1], (B, S, N)) * 0.3
    Cm = jax.random.normal(ks[2], (B, S, N)) * 0.3
    fn = jax.jit(lambda *a: ops.ssm_scan(*a, chunk=128)[0])
    t = _time(fn, xs, dt, A, Bm, Cm)
    rows.append({"name": "kernel_ssm_scan_ref", "us_per_call": t * 1e6,
                 "derived": f"tokens_per_s={B * S / t:.0f}"})

    from repro.models.ssm import _mlstm_chunked
    q2 = jax.random.normal(ks[0], (B, S, H, 64))
    k2 = jax.random.normal(ks[1], (B, S, H, 64))
    v2 = jax.random.normal(ks[2], (B, S, H, 64))
    li = jax.random.normal(ks[3], (B, S, H)) * 0.5
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, H)) + 2.0)
    fn = jax.jit(lambda *a: _mlstm_chunked(*a, 128)[0])
    t = _time(fn, q2, k2, v2, li, lf)
    rows.append({"name": "kernel_mlstm_scan_ref", "us_per_call": t * 1e6,
                 "derived": f"tokens_per_s={B * S / t:.0f}"})
    rows.extend(_prefill_rows(smoke=smoke))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="P=128 prefill row only (CI tier)")
    args = ap.parse_args()
    for row in run(smoke=args.smoke):
        print(f"{row['name']},{row['us_per_call']:.3f},{row['derived']}")
