"""Kernel microbench: jnp reference path wall time per call on this host
(the TPU kernels are validated in interpret mode by tests/; wall numbers
here are the CPU reference path, 'derived' reports achieved GFLOP/s)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run() -> list[dict]:
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 6)
    rows = []

    B, S, Hq, Hkv, D = 2, 512, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    kk = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    fn = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v))
    t = _time(fn, q, kk, v)
    flops = 4 * B * Hq * S * S * D
    rows.append({"name": "kernel_flash_attention_ref", "us_per_call": t * 1e6,
                 "derived": f"gflops={flops / t / 1e9:.2f}"})

    qd = jax.random.normal(ks[3], (B, Hq, D))
    lens = jnp.full((B,), S, jnp.int32)
    fn = jax.jit(lambda q, k, v, l: ops.decode_attention(q, k, v, l))
    t = _time(fn, qd, kk, v, lens)
    rows.append({"name": "kernel_decode_attention_ref", "us_per_call": t * 1e6,
                 "derived": f"cache_tokens_per_s={B * S / t:.0f}"})

    x = jax.random.normal(ks[4], (B, S, 1024))
    sc = jnp.zeros((1024,))
    fn = jax.jit(lambda x, s: ops.rmsnorm(x, s))
    t = _time(fn, x, sc)
    rows.append({"name": "kernel_rmsnorm_ref", "us_per_call": t * 1e6,
                 "derived": f"gbps={x.size * 8 / t / 1e9:.2f}"})

    H, P, N = 4, 32, 64
    xs = jax.random.normal(ks[5], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, H)))
    A = -jnp.linspace(1, 8, H)
    Bm = jax.random.normal(ks[1], (B, S, N)) * 0.3
    Cm = jax.random.normal(ks[2], (B, S, N)) * 0.3
    fn = jax.jit(lambda *a: ops.ssm_scan(*a, chunk=128)[0])
    t = _time(fn, xs, dt, A, Bm, Cm)
    rows.append({"name": "kernel_ssm_scan_ref", "us_per_call": t * 1e6,
                 "derived": f"tokens_per_s={B * S / t:.0f}"})

    from repro.models.ssm import _mlstm_chunked
    q2 = jax.random.normal(ks[0], (B, S, H, 64))
    k2 = jax.random.normal(ks[1], (B, S, H, 64))
    v2 = jax.random.normal(ks[2], (B, S, H, 64))
    li = jax.random.normal(ks[3], (B, S, H)) * 0.5
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, H)) + 2.0)
    fn = jax.jit(lambda *a: _mlstm_chunked(*a, 128)[0])
    t = _time(fn, q2, k2, v2, li, lf)
    rows.append({"name": "kernel_mlstm_scan_ref", "us_per_call": t * 1e6,
                 "derived": f"tokens_per_s={B * S / t:.0f}"})
    return rows
