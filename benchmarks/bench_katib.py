"""Paper Table 2 / Fig. 20: average Katib wall time for grid / random /
bayesian across max_trials budgets, on the paper's workload (LeNet/MNIST
hyperparameter tuning: learning rate + batch size)."""
from __future__ import annotations

import time

from repro.core.trainjob import SupervisedTrainJob
from repro.data.mnist import Batches, make_dataset
from repro.tuning import katib

TRIAL_BUDGETS = (3, 5, 8)     # paper used 5/10/15 on cloud; scaled for 1-core CPU
ALGOS = ("random", "bayesian", "grid")


def run(n_examples: int = 256, n_steps: int = 8) -> list[dict]:
    imgs, labels = make_dataset(n_examples, seed=0)
    # paper §5.3: lr in [0.01, 0.05], batch in [80, 100]; batch rounded to
    # pow2-ish buckets to bound jit retraces on CPU
    space = {"lr": katib.Double(0.01, 0.05),
             "batch_size": katib.Categorical((64, 80, 96))}

    def objective(params, report):
        job = SupervisedTrainJob(lr=params["lr"], n_steps=n_steps, width=8)
        res = job.run(Batches(imgs, labels, int(params["batch_size"])),
                      report=report)
        return {"loss": res["loss"]}

    rows = []
    for algo in ALGOS:
        for budget in TRIAL_BUDGETS:
            t0 = time.perf_counter()
            exp = katib.tune(objective, space, algorithm=algo,
                             max_trials=budget, seed=0,
                             early_stopping=katib.MedianStop())
            wall = time.perf_counter() - t0
            best = exp.best_trial()
            rows.append({
                "name": f"katib_{algo}_trials{budget}",
                "us_per_call": wall * 1e6 / budget,
                "derived": f"best_loss={exp.objective(best):.4f};"
                           f"total_s={wall:.2f};"
                           f"early_stopped={sum(t.status == 'early_stopped' for t in exp.trials)}",
            })
    return rows
