"""Roofline table (deliverable g): read the dry-run artifacts from
experiments/dryrun and emit one row per (arch x shape x mesh) with the three
terms, the bottleneck, and the useful-flops ratio."""
from __future__ import annotations

import glob
import json
import os


def run(dryrun_dir: str = "experiments/dryrun") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        name = f"roofline_{rec['arch']}_{rec['shape']}_{rec['mesh']}"
        if rec.get("tag"):
            name += f"_{rec['tag']}"
        if rec["status"] == "skipped":
            rows.append({"name": name, "us_per_call": 0.0,
                         "derived": f"skipped:{rec['reason'][:60]}"})
            continue
        if rec["status"] != "ok":
            rows.append({"name": name, "us_per_call": -1.0,
                         "derived": f"error:{rec.get('error', '?')[:60]}"})
            continue
        r = rec["roofline"]
        rows.append({
            "name": name,
            "us_per_call": r["bound_s"] * 1e6,
            "derived": (f"dominant={r['dominant']};compute_s={r['compute_s']:.4g};"
                        f"memory_s={r['memory_s']:.4g};"
                        f"collective_s={r['collective_s']:.4g};"
                        f"useful_ratio={rec.get('useful_flops_ratio', 0) or 0:.3g}"),
        })
    return rows
