"""Diff two BENCH_*.json records with a relative tolerance (ISSUE 10
satellite): the perf-trajectory companion to ``validate_bench``.

The bench suites already persist per-scenario numbers (p50/p99, shed
rates, simulated dollars, speedups) precisely so the trajectory is
tracked across PRs -- but until now "tracked" meant a human eyeballing
the JSON diff.  ``compare()`` walks the ``scenarios`` tree of an old and
a new record and reports every shared numeric leaf whose relative change
exceeds the tolerance, in either direction: a regression AND a
too-good-to-be-true improvement both deserve a look before merge.

Wall-clock-derived leaves move with the host, so the default tolerance
is generous (25%); CI runs this as a NON-BLOCKING step against the
committed record from the main branch (``continue-on-error``) -- the
output is a review aid, not a merge gate, because a hosted runner's
timings drift far more than a pinned box's.

CLI::

    python benchmarks/compare.py OLD.json NEW.json [--tol 0.25]

Exit status 1 when any leaf drifted past tolerance (so the CI step
annotates), 0 otherwise.  Schema version changes are reported and the
scenarios common to both records are still compared.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys


def _leaves(node, path: str, out: dict) -> None:
    """Flatten nested dicts to {dotted.path: numeric leaf}; bools are
    config flags, not measurements, and strings/lists carry labels."""
    if isinstance(node, dict):
        for k in sorted(node):
            _leaves(node[k], f"{path}.{k}" if path else str(k), out)
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        out[path] = float(node)


def compare(old: dict, new: dict, *, tol: float = 0.25) -> list:
    """Every shared numeric leaf under ``scenarios`` whose relative
    change exceeds ``tol``, as dicts: {path, old, new, rel}.  ``rel`` is
    (new - old) / |old|; a leaf appearing or disappearing is not drift
    (schema evolution adds scenarios -- ``validate_bench`` owns presence),
    and an old value of exactly 0 flags any nonzero new value."""
    if tol < 0:
        raise ValueError("tol must be >= 0")
    a, b = {}, {}
    _leaves(old.get("scenarios", {}), "", a)
    _leaves(new.get("scenarios", {}), "", b)
    drifted = []
    for path in sorted(set(a) & set(b)):
        va, vb = a[path], b[path]
        if va == vb:
            continue
        rel = (vb - va) / abs(va) if va != 0 else float("inf")
        if abs(rel) > tol:
            drifted.append({"path": path, "old": va, "new": vb,
                            "rel": rel})
    return drifted


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH_*.json records with a tolerance")
    ap.add_argument("old", type=pathlib.Path,
                    help="committed record (the baseline)")
    ap.add_argument("new", type=pathlib.Path,
                    help="freshly produced record")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="relative tolerance per numeric leaf "
                         "(default 0.25)")
    args = ap.parse_args(argv)
    old = json.loads(args.old.read_text())
    new = json.loads(args.new.read_text())
    if old.get("schema") != new.get("schema"):
        print(f"schema {old.get('schema')} -> {new.get('schema')} "
              "(comparing shared scenarios only)")
    drifted = compare(old, new, tol=args.tol)
    if not drifted:
        print(f"no drift beyond {args.tol:.0%} "
              f"({args.old.name} -> {args.new.name})")
        return 0
    width = max(len(d["path"]) for d in drifted)
    for d in drifted:
        rel = "new!=0" if d["rel"] == float("inf") else f"{d['rel']:+.1%}"
        print(f"{d['path']:<{width}}  {d['old']:>12.6g} -> "
              f"{d['new']:>12.6g}  ({rel})")
    print(f"{len(drifted)} leaf/leaves drifted beyond {args.tol:.0%}")
    return 1


if __name__ == "__main__":
    sys.exit(main())
