"""Paper Tables 4+5 / Figs. 22-23: E2E pipeline stage timing (Katib ->
TFJob -> Model Serving) on the gcp vs ibm CloudProfiles, plus the custom
digit-recognizer pipeline (Table 4: total pipeline vs model time), plus the
ISSUE 5 orchestrator scenarios:

  race       a fan-out tuning DAG (6 branches) run two ways on the SAME
             measured per-step compute: serially through Pipeline.run
             (stage wall + per-step startup/rtt, the pre-orchestrator
             accounting) vs scheduled by the multi-cloud orchestrator
             (pipelines/scheduler.py) onto {gcp: 3, ibm: 3} worker slots
             with a mid-run gcp outage injected into the tuning wave.  The
             orchestrator must recover every killed attempt by retry
             (exactly-once asserted) and still beat the serial makespan by
             >= 1.5x;
  recurring  the paper's Recurring Runs concept: the same pipeline fired
             twice through PipelineRuns -- the second run must be all
             cache hits (no re-execution) and collapse to control-plane
             time.

Every scenario lands in ``benchmarks/BENCH_pipelines.json`` (schema
validated by ``validate_bench``) so the perf trajectory is tracked across
PRs.  ``python benchmarks/bench_pipeline.py --smoke`` runs an ANALYTIC
race + recurring pass (fixed sim_s durations, no jax training -- fast and
bit-for-bit deterministic) and validates both the fresh record and the
committed JSON: the CI bench-smoke step.

Stage compute is measured; the per-profile control-plane constant
(profile.startup_s, the paper's cluster spin-up / resource-contention
delta) is added per stage start, reproducing the paper's "GCP pipelines run
faster, IBM control plane is slower" finding as a simulation input."""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

import jax
import jax.numpy as jnp

try:
    from ._schema import check_header, require_keys
except ImportError:                      # run directly as a script (CI)
    from _schema import check_header, require_keys

from repro.checkpoint.store import ArtifactStore
from repro.clouds.profiles import get_profile
from repro.core.pipeline import Pipeline
from repro.core.trainjob import SupervisedTrainJob
from repro.data.mnist import Batches, make_dataset
from repro.models import lenet
from repro.pipelines import Orchestrator, PipelineRuns, RetryPolicy
from repro.serving.gateway import FailureSpec
from repro.serving.kserve import InferenceService, Predictor
from repro.telemetry.analyze import run_breakdown, run_table
from repro.telemetry.trace import Tracer
from repro.tuning import katib

BENCH_JSON = pathlib.Path(__file__).resolve().parent / "BENCH_pipelines.json"
# schema 3: header validation moved onto the shared benchmarks/_schema.py
# helper (ISSUE 9); schema 2 added the race/recurring orchestrator tiers
BENCH_SCHEMA = 3
N_BRANCHES = 6


def validate_bench(bench: dict, require: tuple = ()) -> None:
    """BENCH_pipelines.json schema check (the CI bench-smoke gate); the
    header/required-scenario machinery is shared with the gateway suite
    via ``_schema``."""
    sc = check_header(bench, BENCH_SCHEMA, require)
    for prof, rec in sc.get("stage_timing", {}).items():
        require_keys(rec, ("katib_s", "tfjob_s", "serving_s", "total_s"),
                     f"stage_timing {prof}")
    if "race" in sc:
        r = sc["race"]
        require_keys(r, ("serial_s", "orchestrated_s", "speedup", "retries",
                         "exactly_once", "sim_cost_usd", "branches",
                         "critical_path"), "race")
        if r["speedup"] < 1.5:
            raise ValueError(f"race speedup {r['speedup']} < 1.5")
        if r["retries"] < 1 or not r["exactly_once"]:
            raise ValueError(f"race must recover injected failures: {r}")
        cp = r["critical_path"]
        if not cp or cp[-1]["step"] != "train":
            raise ValueError(f"race critical path must end at train: {cp}")
        for row in cp:
            require_keys(row, ("step", "cloud", "total_s", "control_s",
                               "transfer_s", "compute_s", "wait_s"),
                         "critical path row")
    if "recurring" in sc:
        r = sc["recurring"]
        require_keys(r, ("runs", "first_run_s", "cached_run_s", "cache_hits",
                         "sim_cost_usd"), "recurring")
        if r["cache_hits"] < 1 or r["cached_run_s"] > r["first_run_s"]:
            raise ValueError(f"recurring run did not cache: {r}")


# -- paper stage timing (Tables 4/5) -----------------------------------------

def _e2e(profile_name: str, store: ArtifactStore) -> dict:
    prof = get_profile(profile_name)
    imgs, labels = make_dataset(256, seed=0)
    pipe = Pipeline(f"e2e-{profile_name}", store, enable_cache=False)

    def katib_stage():
        def objective(params, report):
            job = SupervisedTrainJob(lr=params["lr"], n_steps=6, width=8)
            return {"loss": job.run(Batches(imgs, labels, 64), report=report)["loss"]}
        exp = katib.tune(objective, {"lr": katib.Double(0.01, 0.05)},
                         algorithm="random", max_trials=3, seed=0)
        return exp.best_trial().params

    def tfjob_stage(best):
        job = SupervisedTrainJob(lr=best["lr"], n_steps=20, width=8, store=store)
        res = job.run(Batches(imgs, labels, 64),
                      checkpoint_name=f"e2e-{profile_name}")
        return res["params"]

    def serving_stage(params):
        predict = jax.jit(lambda x: jnp.argmax(lenet.apply(params, x), -1))
        pred = Predictor("e2e", predict, imgs[:1])
        svc = InferenceService(pred, prof, "kserve")
        return svc.stress_test(64).total_time_s

    k = pipe.step(katib_stage, cache=False)
    t = pipe.step(tfjob_stage, k, cache=False)
    pipe.step(serving_stage, t, cache=False)
    pipe.run()
    stage_s = {e["name"]: e["duration_s"] for e in pipe.log.events}
    # control-plane constant per stage (paper's cluster spin-up delta)
    n_stages = 3
    total = stage_s[f"pipeline:e2e-{profile_name}"] + n_stages * prof.startup_s
    return {
        "katib_s": stage_s["katib_stage"] + prof.startup_s,
        "tfjob_s": stage_s["tfjob_stage"] + prof.startup_s,
        "serving_s": stage_s["serving_stage"] + prof.startup_s,
        "total_s": total,
    }


def _digit_recognizer(profile_name: str) -> dict:
    """Table 4: the custom-model pipeline (train only, no katib)."""
    prof = get_profile(profile_name)
    imgs, labels = make_dataset(256, seed=1)
    job = SupervisedTrainJob(lr=0.002, n_steps=30, width=8)
    res = job.run(Batches(imgs, labels, 64))
    model_s = res["wall_s"]
    return {"model_s": model_s, "total_s": model_s + 2 * prof.startup_s}


# -- orchestrator race (ISSUE 5 acceptance) ----------------------------------

def _tuning_pipeline(fns: dict) -> Pipeline:
    """The fan-out DAG both sides of the race run: prep -> N_BRANCHES
    tuning branches -> select -> final train."""
    pipe = Pipeline("tune-fanout")
    prep = pipe.step(fns["prep"], name="prep", cache=False)
    branches = [pipe.step(fns["tune"], i, prep, name=f"tune{i}", cache=False)
                for i in range(N_BRANCHES)]
    best = pipe.step(fns["select"], *branches, name="select", cache=False)
    pipe.step(fns["train"], prep, best, name="train", cache=False)
    return pipe


def _mnist_fns() -> dict:
    """Real measured components: LeNet tuning branches over a small lr
    grid (the katib fan-out the paper runs sequentially)."""
    imgs, labels = make_dataset(256, seed=0)

    def prep():
        return float(imgs.mean())        # touch the data; tiny artifact

    def tune(i, _prep):
        lr = 0.005 * (1 + i)
        job = SupervisedTrainJob(lr=lr, n_steps=8, width=8)
        res = job.run(Batches(imgs, labels, 64))
        return {"lr": lr, "loss": float(res["loss"])}

    def select(*results):
        return min(results, key=lambda r: r["loss"])

    def train(_prep, best):
        job = SupervisedTrainJob(lr=best["lr"], n_steps=20, width=8)
        return {"loss": float(job.run(Batches(imgs, labels, 64))["loss"])}

    return {"prep": prep, "tune": tune, "select": select, "train": train}


def _analytic_fns() -> tuple:
    """Synthetic components + fixed sim_s durations for the --smoke race:
    deterministic on every host, no jax work."""
    fns = {"prep": lambda: 1.0,
           "tune": lambda i, p: {"lr": 0.005 * (1 + i), "loss": 1.0 / (1 + i)},
           "select": lambda *rs: min(rs, key=lambda r: r["loss"]),
           "train": lambda p, best: {"loss": best["loss"] / 2}}
    sims = {"prep": 0.3, "select": 0.05, "train": 1.5,
            **{f"tune{i}": 1.2 for i in range(N_BRANCHES)}}
    return fns, sims


def _race(bench: dict, *, analytic: bool) -> list:
    gcp = get_profile("gcp")
    per_step = gcp.startup_s + gcp.network_rtt_s
    if analytic:
        fns, sims = _analytic_fns()
        pipe = _tuning_pipeline(fns)
        durations = sims
        serial_s = sum(per_step + d for d in durations.values())
    else:
        fns = _mnist_fns()
        pipe = _tuning_pipeline(fns)
        pipe.run()                       # the serial baseline, measured
        durations = {s.name: s.duration_s for s in pipe.steps}
        serial_s = sum(per_step + s.duration_s for s in pipe.steps)
    spec = _tuning_pipeline(fns).compile()
    for s in spec.steps:                 # replay the measured compute
        s.sim_s = durations[s.name]      # through the simulated clusters

    # outage: kill the gcp tuning wave shortly after its compute starts
    # (the schedule is deterministic given the replayed durations)
    tune_d = [durations[f"tune{i}"] for i in range(N_BRANCHES)]
    prep_end = per_step + durations["prep"]
    outage = FailureSpec("gcp", prep_end + gcp.startup_s
                         + 0.2 * min(tune_d), 1.0)

    tracer = Tracer()
    orch = Orchestrator({"gcp": 3, "ibm": 3}, policy="makespan",
                        retry=RetryPolicy(max_retries=2, backoff_s=0.3),
                        tracer=tracer)
    rec = orch.execute(spec, failures=[outage])

    assert rec.status == "succeeded", rec.summary()
    # trace-derived per-stage attribution (the paper's Tables 4/5 as an
    # analyzer output): the chain bounding the makespan must run through
    # the terminal train step
    cpath = run_breakdown(tracer, rec.span_id)
    assert cpath and cpath[-1]["step"] == "train", cpath
    print(run_table(tracer, rec.span_id), file=sys.stderr)
    retries = orch.log.count("pipeline:retry")
    assert retries >= 1, "the outage must have killed at least one attempt"
    # exactly-once through the failures: every step done with ONE
    # successful attempt, every other attempt killed by the outage
    exactly_once = all(
        r.status == "done"
        and sum(1 for a in r.attempts if a["status"] == "ok") == 1
        and all(a["status"] in ("ok", "outage") for a in r.attempts)
        for r in rec.steps.values())
    assert exactly_once
    speedup = serial_s / rec.makespan_s
    assert speedup >= 1.5, (serial_s, rec.makespan_s)

    print(f"race ({'analytic' if analytic else 'measured'}): serial "
          f"{serial_s:.2f}s vs orchestrated {rec.makespan_s:.2f}s "
          f"(speedup {speedup:.2f}x, {retries} retries through the gcp "
          f"outage, sim ${rec.cost_usd:.6f})", file=sys.stderr)

    bench["scenarios"]["race"] = {
        "mode": "analytic" if analytic else "measured",
        "branches": N_BRANCHES,
        "serial_s": round(serial_s, 4),
        "orchestrated_s": round(rec.makespan_s, 4),
        "speedup": round(speedup, 4),
        "retries": retries,
        "exactly_once": exactly_once,
        "outage": {"cloud": outage.cloud, "at_s": round(outage.at_s, 4),
                   "duration_s": outage.duration_s},
        "critical_path": [
            {k: round(v, 4) if isinstance(v, float) else v
             for k, v in row.items()} for row in cpath],
        "sim_cost_usd": round(rec.cost_usd, 8),
        "steps": {n: {"cloud": r.cloud, "sim_s": round(r.duration_s, 4),
                      "attempts": len(r.attempts)}
                  for n, r in rec.steps.items()}}
    return [{
        "name": "pipeline_orchestrator_race",
        "us_per_call": rec.makespan_s * 1e6,
        "derived": f"speedup={speedup:.2f};serial_s={serial_s:.2f};"
                   f"orchestrated_s={rec.makespan_s:.2f};retries={retries};"
                   f"exactly_once={exactly_once}",
    }]


def _recurring(bench: dict, *, analytic: bool) -> list:
    """Recurring Runs: the second firing must be pure cache hits."""
    if analytic:
        fns, sims = _analytic_fns()
    else:
        fns = _mnist_fns()
        sims = None
    pipe = Pipeline("recurring-tune")
    prep = pipe.step(fns["prep"], name="prep")
    branches = [pipe.step(fns["tune"], i, prep, name=f"tune{i}")
                for i in range(2)]
    pipe.step(fns["select"], *branches, name="select")
    spec = pipe.compile()
    if sims is not None:
        for s in spec.steps:
            s.sim_s = sims.get(s.name, 0.1)
    orch = Orchestrator({"gcp": 2, "ibm": 2}, policy="cost")
    runs = PipelineRuns(orch)
    recs = runs.recurring(spec, every_s=120.0, runs=2)
    first, second = recs
    assert second.cache_hits == len(spec.steps), second.summary()
    assert second.makespan_s <= first.makespan_s
    print(f"recurring: first run {first.makespan_s:.2f}s -> cached run "
          f"{second.makespan_s:.4f}s ({second.cache_hits} cache hits)",
          file=sys.stderr)
    bench["scenarios"]["recurring"] = {
        "runs": len(recs),
        "first_run_s": round(first.makespan_s, 4),
        "cached_run_s": round(second.makespan_s, 6),
        "cache_hits": second.cache_hits,
        "sim_cost_usd": round(sum(r.cost_usd for r in recs), 8)}
    return [{
        "name": "pipeline_recurring_cached",
        "us_per_call": second.makespan_s * 1e6,
        "derived": f"first_s={first.makespan_s:.3f};"
                   f"cached_s={second.makespan_s:.5f};"
                   f"cache_hits={second.cache_hits}",
    }]


def run(store_dir: str = "experiments/artifacts") -> list[dict]:
    store = ArtifactStore(store_dir)
    bench: dict = {"schema": BENCH_SCHEMA, "scenarios": {"stage_timing": {}}}
    rows = []
    for profile in ("gcp", "ibm"):
        e2e = _e2e(profile, store)
        bench["scenarios"]["stage_timing"][profile] = {
            k: round(v, 4) for k, v in e2e.items()}
        for stage in ("katib_s", "tfjob_s", "serving_s", "total_s"):
            rows.append({
                "name": f"pipeline_e2e_{profile}_{stage[:-2]}",
                "us_per_call": e2e[stage] * 1e6,
                "derived": f"seconds={e2e[stage]:.2f}",
            })
        dr = _digit_recognizer(profile)
        rows.append({
            "name": f"pipeline_digit_recognizer_{profile}",
            "us_per_call": dr["total_s"] * 1e6,
            "derived": f"total_s={dr['total_s']:.2f};model_s={dr['model_s']:.2f}",
        })
    rows.extend(_race(bench, analytic=False))
    rows.extend(_recurring(bench, analytic=False))
    validate_bench(bench, require=("stage_timing", "race", "recurring"))
    BENCH_JSON.write_text(json.dumps(bench, indent=1, sort_keys=True))
    print(f"wrote {BENCH_JSON}", file=sys.stderr)
    return rows


def smoke() -> None:
    """CI bench-smoke: the analytic race + recurring scenarios (fixed
    sim_s durations, deterministic on any host), then validate both the
    fresh record and (when present) the committed BENCH_pipelines.json."""
    bench: dict = {"schema": BENCH_SCHEMA, "scenarios": {}}
    _race(bench, analytic=True)
    _recurring(bench, analytic=True)
    validate_bench(bench, require=("race", "recurring"))
    if BENCH_JSON.exists():
        validate_bench(json.loads(BENCH_JSON.read_text()),
                       require=("stage_timing", "race", "recurring"))
        print(f"validated {BENCH_JSON}", file=sys.stderr)
    print("race:", json.dumps(bench["scenarios"]["race"]["speedup"]),
          "recurring cache hits:",
          bench["scenarios"]["recurring"]["cache_hits"], file=sys.stderr)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="analytic race + schema validation only (CI)")
    if ap.parse_args().smoke:
        smoke()
    else:
        print("name,us_per_call,derived")
        for r in run():
            print(f"{r['name']},{r['us_per_call']:.3f},{r['derived']}")
