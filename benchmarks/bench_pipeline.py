"""Paper Tables 4+5 / Figs. 22-23: E2E pipeline stage timing (Katib ->
TFJob -> Model Serving) on the gcp vs ibm CloudProfiles, plus the custom
digit-recognizer pipeline (Table 4: total pipeline vs model time).

Stage compute is measured; the per-profile control-plane constant
(profile.startup_s, the paper's cluster spin-up / resource-contention
delta) is added per stage start, reproducing the paper's "GCP pipelines run
faster, IBM control plane is slower" finding as a simulation input."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.checkpoint.store import ArtifactStore
from repro.clouds.profiles import get_profile
from repro.core.pipeline import Pipeline
from repro.core.trainjob import SupervisedTrainJob
from repro.data.mnist import Batches, make_dataset
from repro.models import lenet
from repro.serving.kserve import InferenceService, Predictor
from repro.tuning import katib


def _e2e(profile_name: str, store: ArtifactStore) -> dict:
    prof = get_profile(profile_name)
    imgs, labels = make_dataset(256, seed=0)
    pipe = Pipeline(f"e2e-{profile_name}", store, enable_cache=False)

    def katib_stage():
        def objective(params, report):
            job = SupervisedTrainJob(lr=params["lr"], n_steps=6, width=8)
            return {"loss": job.run(Batches(imgs, labels, 64), report=report)["loss"]}
        exp = katib.tune(objective, {"lr": katib.Double(0.01, 0.05)},
                         algorithm="random", max_trials=3, seed=0)
        return exp.best_trial().params

    def tfjob_stage(best):
        job = SupervisedTrainJob(lr=best["lr"], n_steps=20, width=8, store=store)
        res = job.run(Batches(imgs, labels, 64),
                      checkpoint_name=f"e2e-{profile_name}")
        return res["params"]

    def serving_stage(params):
        predict = jax.jit(lambda x: jnp.argmax(lenet.apply(params, x), -1))
        pred = Predictor("e2e", predict, imgs[:1])
        svc = InferenceService(pred, prof, "kserve")
        return svc.stress_test(64).total_time_s

    k = pipe.step(katib_stage, cache=False)
    t = pipe.step(tfjob_stage, k, cache=False)
    pipe.step(serving_stage, t, cache=False)
    pipe.run()
    stage_s = {e["name"]: e["duration_s"] for e in pipe.log.events}
    # control-plane constant per stage (paper's cluster spin-up delta)
    n_stages = 3
    total = stage_s[f"pipeline:e2e-{profile_name}"] + n_stages * prof.startup_s
    return {
        "katib_s": stage_s["katib_stage"] + prof.startup_s,
        "tfjob_s": stage_s["tfjob_stage"] + prof.startup_s,
        "serving_s": stage_s["serving_stage"] + prof.startup_s,
        "total_s": total,
    }


def _digit_recognizer(profile_name: str) -> dict:
    """Table 4: the custom-model pipeline (train only, no katib)."""
    prof = get_profile(profile_name)
    imgs, labels = make_dataset(256, seed=1)
    job = SupervisedTrainJob(lr=0.002, n_steps=30, width=8)
    res = job.run(Batches(imgs, labels, 64))
    model_s = res["wall_s"]
    return {"model_s": model_s, "total_s": model_s + 2 * prof.startup_s}


def run(store_dir: str = "experiments/artifacts") -> list[dict]:
    store = ArtifactStore(store_dir)
    rows = []
    for profile in ("gcp", "ibm"):
        e2e = _e2e(profile, store)
        for stage in ("katib_s", "tfjob_s", "serving_s", "total_s"):
            rows.append({
                "name": f"pipeline_e2e_{profile}_{stage[:-2]}",
                "us_per_call": e2e[stage] * 1e6,
                "derived": f"seconds={e2e[stage]:.2f}",
            })
        dr = _digit_recognizer(profile)
        rows.append({
            "name": f"pipeline_digit_recognizer_{profile}",
            "us_per_call": dr["total_s"] * 1e6,
            "derived": f"total_s={dr['total_s']:.2f};model_s={dr['model_s']:.2f}",
        })
    return rows
