"""Distributed-training driver: train a reduced config of any assigned
architecture on the synthetic token pipeline via the TFJob analog.

    PYTHONPATH=src python examples/train_llm.py --arch gemma3-4b --steps 30

(The FULL configs target the 256/512-chip dry-run mesh; on this CPU host
the reduced config demonstrates the same code path end to end, including
checkpointing and stage telemetry.)
"""
import argparse
import json

from repro.checkpoint.store import ArtifactStore
from repro.configs import registry
from repro.core.trainjob import LMTrainJob
from repro.telemetry.events import EventLog


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch)
    log = EventLog()
    job = LMTrainJob(cfg, batch_size=args.batch, seq_len=args.seq,
                     n_steps=args.steps, lr=1e-3,
                     store=ArtifactStore("experiments/artifacts"), log=log)
    res = job.run(checkpoint_name=f"{cfg.name}-example")
    print(json.dumps({
        "arch": cfg.name,
        "loss_first": round(res["history"][0], 4),
        "loss_last": round(res["loss"], 4),
        "checkpoint": res.get("checkpoint"),
        "stages_s": {k: round(v, 2) for k, v in log.totals().items()},
    }, indent=1))
    if args.steps >= 20:    # short runs are too noisy for a strict check
        assert min(res["history"]) < res["history"][0], "loss should decrease"


if __name__ == "__main__":
    main()
