"""Quickstart: build and run a 3-step pipeline on the framework.

    PYTHONPATH=src python examples/quickstart.py

Mirrors the paper's 'lightweight components' flow (its Fig. 14): plain
python functions become pipeline steps; the framework adds ordering,
caching, artifact storage, stage timing, and a YAML spec export.
"""
import jax
import jax.numpy as jnp

from repro.checkpoint.store import ArtifactStore
from repro.core.pipeline import Pipeline, component
from repro.core.trainjob import SupervisedTrainJob
from repro.data.mnist import Batches, make_dataset
from repro.models import lenet


@component
def load_data():
    imgs, labels = make_dataset(512, seed=0)
    return {"n": len(labels)}


@component
def train(data_info):
    imgs, labels = make_dataset(data_info["n"], seed=0)
    job = SupervisedTrainJob(lr=2e-3, n_steps=40, width=8)
    res = job.run(Batches(imgs, labels, 64))
    return {"loss": res["loss"], "accuracy": res["accuracy"],
            "params": res["params"]}


@component
def evaluate(trained):
    imgs, labels = make_dataset(128, seed=7)
    logits = lenet.apply(trained["params"], imgs)
    acc = float(jnp.mean((jnp.argmax(logits, -1) == labels)))
    return {"holdout_accuracy": acc}


def main():
    store = ArtifactStore("experiments/artifacts")
    pipe = Pipeline("quickstart", store)
    d = pipe.step(load_data, cache=False)
    t = pipe.step(train, d, cache=False)
    e = pipe.step(evaluate, t, cache=False)
    out = pipe.run(verbose=True)
    print("\npipeline spec:\n" + pipe.export_yaml())
    print("results:", {k: v for k, v in out["evaluate"].items()})
    assert out["evaluate"]["holdout_accuracy"] > 0.5


if __name__ == "__main__":
    main()
