"""The paper's Table 4/5 story end to end, on the ISSUE 5 orchestrator:
a RECURRING pipeline (Kubeflow Runs / Recurring Runs) that tunes and
trains MNIST on the cheapest simulated cloud, hands the trained model to
the serving gateway through a terminal ``deploy`` step with a SPLIT
placement plan (gcp capacity-pinned, spill to ibm), then stress-tests the
deployed model -- pipeline -> placement -> live serving in one run.

The second recurring firing reuses every training artifact from the
cross-run cache (only the deploy step re-executes: the handoff is a side
effect), so the run collapses to control-plane time -- the Kubeflow
step-caching headline, now under the orchestrator's simulated clusters.

One Tracer is shared by the orchestrator and the gateway (ISSUE 6), so
the pipeline's span tree and the serving spans form a SINGLE connected
trace: every served request's span links back to the deploy step that
produced the model, and the analyzer derives the run critical path and
the slowest-request stage breakdown from the spans alone.

One CapacityMarket is ALSO shared by both planes (ISSUE 9): training and
serving lease the same per-cloud slots, so the burst's gcp floor finds
the recorded training leases in its way and preempts the youngest (spot
semantics, logged capacity:preempt) -- the colocated-cluster economics
the paper's single-cluster deployments imply.

The placement itself is planned from MEASURED Model-CI artifacts
(ISSUE 10): two pinned ``kind="profile"`` steps measure the trained
backend into per-cloud ``ModelProfile`` artifacts (committed into a
ProfileStore over the orchestrator's own ArtifactCache), and the deploy
step's ``DeploySpec(profile=store)`` derives every ``ModelDemand``
number from the store -- no hand-entered service-time constant.  A
second pipeline then profiles a REGISTRY model (gemma3-4b) analytically
(``roofline_fields``: every term a closed form of the ArchConfig + the
HardwareSpec constants) and deploys it behind a ``ProfiledBackend`` --
an end-to-end deployment with zero hand-tuned numbers anywhere.

Per DESIGN.md §1: stage compute and backend service times are MEASURED on
this host; startup / RTT / transfer / dollar figures derive from the
CloudProfile constants and are simulation outputs.

    PYTHONPATH=src python examples/e2e_train_to_serve.py
"""
import json

import jax
import jax.numpy as jnp

from repro.clouds.capacity import CapacityMarket
from repro.clouds.profiles import get_profile
from repro.configs.registry import get_config
from repro.core.pipeline import Pipeline
from repro.core.trainjob import SupervisedTrainJob
from repro.data.mnist import Batches, make_dataset
from repro.models import lenet
from repro.modelci import (ProfiledBackend, ProfileSpec, ProfileStore,
                           measure, roofline_fields)
from repro.pipelines import (ArtifactCache, DeploySpec, Orchestrator,
                             PipelineRuns)
from repro.serving.gateway import (AutoscalerConfig, CloudCapacity, Gateway,
                                   Predictor, TrafficSpec)
from repro.telemetry.analyze import (request_table, run_table,
                                     validate_trace)
from repro.telemetry.events import EventLog
from repro.telemetry.metrics import MetricsRegistry
from repro.telemetry.trace import Tracer
from repro.tuning import katib


def main():
    imgs, labels = make_dataset(256, seed=0)
    gcp, ibm = get_profile("gcp"), get_profile("ibm")

    def tune():
        def objective(params, report):
            job = SupervisedTrainJob(lr=params["lr"], n_steps=8, width=8)
            return {"loss": job.run(Batches(imgs, labels, 64),
                                    report=report)["loss"]}
        exp = katib.tune(objective, {"lr": katib.Double(0.01, 0.05)},
                         algorithm="random", max_trials=3, seed=0)
        return exp.best_trial().params

    def train(best):
        job = SupervisedTrainJob(lr=best["lr"], n_steps=30, width=8)
        res = job.run(Batches(imgs, labels, 64))
        print(f"  train: lr={best['lr']:.4f} loss={res['loss']:.4f} "
              f"acc={res['accuracy']:.3f}")
        return res["params"]

    def make_backend(params):
        predict = jax.jit(lambda x: jnp.argmax(lenet.apply(params, x), -1))
        pred = Predictor("mnist", predict, imgs[:1])
        pred.warmup((1, 8, 16))
        return pred

    def profile(params):
        # cloud-agnostic measurement of the trained backend; the
        # orchestrator stamps the per-cloud load_s constant at commit
        return measure(make_backend(params), max_batch=16, weights=params)

    def deploy_backend(params, *_profiles):
        return make_backend(params)

    # the profile artifacts live in the SAME ArtifactCache the step
    # artifacts do: one residency/egress rule set for both
    cache = ArtifactCache()
    store = ProfileStore(cache)

    # authoring: the serial front-end DAG, compiled for the orchestrator.
    # gcp holds only 2 replicas, so the 2.0-Erlang demand (3 replicas at
    # 0.7 target utilization) forces a genuinely split placement --
    # planned ENTIRELY from the committed profile artifacts
    # (DeploySpec.profile): no service-time constant appears below.
    pipe = Pipeline("train-to-serve")
    best = pipe.step(tune)
    model = pipe.step(train, best)
    profs = [pipe.step(profile, model, name=f"profile_{c}", kind="profile",
                       pin=c,
                       payload=ProfileSpec("mnist", store, max_batch=16))
             for c in ("gcp", "ibm")]
    pipe.step(deploy_backend, model, *profs, name="deploy", kind="deploy",
              payload=DeploySpec(
                  "mnist",
                  clouds=[CloudCapacity(gcp, 2, 1.0),
                          CloudCapacity(ibm, 4, 1.4)],
                  load_erlangs=2.0, objective="cost", split=True,
                  autoscaler=AutoscalerConfig(min_replicas=3, max_replicas=4,
                                              target_queue=8,
                                              idle_window_s=2.0),
                  max_batch=16, profile=store))
    spec = pipe.compile()

    log = EventLog()
    tracer = Tracer()                    # ONE tracer spans train AND serve
    registry = MetricsRegistry()
    # ONE capacity market under both planes: gcp is tight (2 slots, the
    # same ceiling the placement pin models), so the serving floor must
    # preempt the recorded training leases to come up
    market = CapacityMarket({"gcp": 2, "ibm": 4})
    gw = Gateway(log=log, tracer=tracer, metrics=registry,
                 shared_capacity=market)
    # cost policy: tuning + training land on the CHEAPEST simulated cloud
    orch = Orchestrator({"gcp": 2, "ibm": 2}, policy="cost", log=log,
                        tracer=tracer, cache=cache, shared_capacity=market)
    runs = PipelineRuns(orch)
    recs = runs.recurring(spec, every_s=300.0, runs=2, gateway=gw)

    print("\nper-stage timing (simulated seconds, per run):")
    for rec in recs:
        print(f" {rec.run_id} [{rec.status}] makespan {rec.makespan_s:.2f}s "
              f"sim ${rec.cost_usd:.6f} cache_hits={rec.cache_hits}")
        for name, r in rec.steps.items():
            print(f"   {name:10s} {r.cloud or '-':4s} {r.duration_s:8.3f}s "
                  f"{'cached' if r.cached else f'x{len(r.attempts)}'}")
    deploy_out = recs[-1].outputs["deploy"]
    print("deploy placement:", json.dumps(deploy_out["weights"]),
          "replicas:", json.dumps(deploy_out["replicas"]))
    planned = store.worst("mnist")
    print(f"planned from profile {planned.key} ({planned.cloud}, "
          f"{planned.service_time_s * 1e6:.1f}us/req at "
          f"batch {planned.max_batch}, {planned.memory_bytes} weight bytes)")

    # the paper's serving stage: stress the model the pipeline deployed
    backend = gw.deployments["mnist"].backend
    rate = 0.5 * 3 * 16 / backend.service_time(16)   # ~50% of fleet ceiling
    served = gw.run([TrafficSpec("mnist", 512, arrival="poisson",
                                 rate=rate)], seed=0)
    res = served.per_model["mnist"]
    print(f"stress test: 512 reqs p50 {res.p50 * 1e3:.2f}ms "
          f"p99 {res.p99 * 1e3:.2f}ms sim ${served.total_cost_usd:.6f}")

    total = sum(r.cost_usd for r in recs) + served.total_cost_usd
    print(f"total simulated cost (2 pipeline runs + serving): ${total:.6f} "
          "(price-sheet output, not a measurement)")

    # trace-derived tables (the paper's per-stage attribution, computed
    # from the span tree instead of hand-kept timers)
    print()
    print(run_table(tracer, recs[0].span_id))
    print()
    print(request_table(tracer, 3, model="mnist"))
    n_served = registry.total("gateway_requests_total", outcome="served")
    print(f"\nmetrics: served={n_served:.0f} "
          f"misses={registry.total('gateway_deadline_miss_total'):.0f} "
          f"spans={len(tracer.spans)}")

    # acceptance: cheapest-cloud training, profile-planned split deploy,
    # cached rerun, and the deployed model actually served the traffic
    assert all(r.status == "succeeded" for r in recs)
    assert all(recs[0].steps[n].cloud in (None, "gcp")
               for n in ("tune", "train")
               if not recs[0].steps[n].cached), \
        "cost policy must train on the cheap cloud"
    assert len(deploy_out["replicas"]) == 2          # genuinely split
    assert abs(sum(deploy_out["weights"].values()) - 1.0) < 1e-6
    assert deploy_out["profiled"], "demand must come from the ProfileStore"
    assert store.clouds("mnist") == ["gcp", "ibm"]   # one artifact per cloud
    assert planned.source == "measured"
    # tune + train + both profile measurements cached; a cache-hit profile
    # firing still refreshes the store's latest pointer
    assert recs[1].cache_hits == 4
    assert log.count("modelci:profile") == 4         # committed every firing
    assert not recs[1].steps["deploy"].cached        # handoff re-executes
    assert res.n_requests == 512 and len(res.latencies_s) == 512
    assert log.count("pipeline:deploy") == 2
    assert served.makespan_s > 0
    # ISSUE 6 acceptance: the pipeline trace and the serving trace are ONE
    # connected component -- walking from the second recurring run's root
    # (its deploy step produced the served model) reaches every served
    # request span through the deploy-step link
    # ISSUE 9 acceptance: the shared market actually contended -- the
    # serving floor preempted at least one recorded training lease on the
    # tight cloud, and no cloud's committed timeline was over-committed
    assert log.count("capacity:preempt") >= 1
    assert log.count("capacity:lease") >= 1
    market.check_conservation()
    print(f"capacity market: {log.count('capacity:lease')} leases, "
          f"{log.count('capacity:preempt')} preempt(s) during the burst")
    assert not validate_trace(tracer)
    linked = tracer.reachable(recs[1].span_id)
    request_roots = [s for s in tracer.named("gateway.request")
                     if s.attrs.get("outcome") == "served"]
    assert request_roots
    assert all(s.span_id in linked for s in request_roots)
    assert n_served == len(request_roots) == 512

    # -- registry-model leg (ISSUE 10): zero hand-tuned numbers ----------
    # profile a registry ArchConfig analytically (roofline_fields: every
    # term a closed form of the config + HardwareSpec constants), commit
    # per-cloud artifacts into the SAME store, and deploy a
    # ProfiledBackend whose cost model IS the artifact -- service time,
    # demand and placement all trace back to the config
    cfg = get_config("gemma3_4b")

    def roofline_profile():
        return roofline_fields(cfg)

    gpipe = Pipeline("profile-gemma")
    gprofs = [gpipe.step(roofline_profile, name=f"profile_{c}",
                         kind="profile", pin=c,
                         payload=ProfileSpec(cfg.name, store, max_batch=1))
              for c in ("gcp", "ibm")]
    # gcp's 2 market slots are fully held by the mnist serving floor
    # (ISSUE 9 colocation), so the registry model's candidates are the
    # big cloud only
    gpipe.step(lambda *_: ProfiledBackend(store.worst(cfg.name)), *gprofs,
               name="deploy", kind="deploy",
               payload=DeploySpec(
                   cfg.name,
                   clouds=[CloudCapacity(ibm, 4, 1.4)],
                   load_erlangs=1.0, objective="cost", split=True,
                   autoscaler=AutoscalerConfig(min_replicas=2,
                                               max_replicas=3,
                                               target_queue=4,
                                               idle_window_s=2.0),
                   max_batch=1, profile=store))
    grec = orch.execute(gpipe.compile(), gateway=gw)
    gout = grec.outputs["deploy"]
    gprof = store.worst(cfg.name)
    print(f"\nregistry model {cfg.name}: roofline profile {gprof.key} "
          f"({gprof.service_time_s * 1e3:.1f}ms/req, "
          f"{gprof.memory_bytes / 1e9:.1f}GB weights) -> placement "
          f"{json.dumps(gout['weights'])}")
    gserved = gw.run([TrafficSpec(cfg.name, 24, arrival="poisson",
                                  rate=0.5 / gprof.service_time_s)], seed=0)
    gres = gserved.per_model[cfg.name]
    print(f"registry stress test: 24 reqs p50 {gres.p50:.3f}s "
          f"p99 {gres.p99:.3f}s sim ${gserved.total_cost_usd:.6f}")
    assert grec.status == "succeeded"
    assert gout["profiled"] and gprof.source == "roofline"
    assert gres.n_requests == 24
    # the analytic profile is derived, not typed in: its terms reproduce
    # from the config alone
    assert gprof.roofline is not None and gprof.memory_bytes == \
        2 * cfg.approx_active_params()


if __name__ == "__main__":
    main()
