"""The paper's headline experiment, end to end (its §5.3):

  Katib hyperparameter tuning -> TFJob training with the best params ->
  KServe serving -> stress test, on BOTH cloud profiles (gcp, ibm),
  exporting the generated pipeline YAML (the minikf_generated_gcp.yaml
  analog) and the per-stage timing table (paper Tables 4/5).

    PYTHONPATH=src python examples/e2e_mnist_pipeline.py
"""
import json

import jax
import jax.numpy as jnp

from repro.checkpoint.store import ArtifactStore
from repro.clouds.profiles import get_profile
from repro.core.pipeline import Pipeline
from repro.core.trainjob import SupervisedTrainJob
from repro.data.mnist import Batches, make_dataset
from repro.models import lenet
from repro.serving.kserve import InferenceService, Predictor
from repro.tuning import katib


def run_cloud(profile_name: str, store: ArtifactStore) -> dict:
    prof = get_profile(profile_name)
    imgs, labels = make_dataset(384, seed=0)
    pipe = Pipeline(f"e2e-mnist-{profile_name}", store, enable_cache=False)

    def katib_tuning():
        """Paper: random search over lr [0.01,0.05], batch [80,100]."""
        def objective(params, report):
            job = SupervisedTrainJob(lr=params["lr"], n_steps=10, width=8)
            res = job.run(Batches(imgs, labels, int(params["batch_size"])),
                          report=report)
            return {"loss": res["loss"]}
        exp = katib.tune(
            objective,
            {"lr": katib.Double(0.01, 0.05),
             "batch_size": katib.Categorical((80, 96))},
            algorithm="random", max_trials=3, seed=0,
            early_stopping=katib.MedianStop(), store=store,
            name=f"mnist-{profile_name}")
        best = exp.best_trial()
        print(f"  katib best: {best.params} loss={exp.objective(best):.4f}")
        return best.params

    def tfjob_training(best):
        job = SupervisedTrainJob(lr=best["lr"], n_steps=60, width=8, store=store)
        res = job.run(Batches(imgs, labels, int(best["batch_size"])),
                      checkpoint_name=f"mnist-{profile_name}")
        print(f"  tfjob: loss={res['loss']:.4f} acc={res['accuracy']:.3f}")
        return res["params"]

    def kserve_serving(params):
        predict = jax.jit(lambda x: jnp.argmax(lenet.apply(params, x), -1))
        pred = Predictor(f"mnist-{profile_name}", predict, imgs[:1])
        svc = InferenceService(pred, prof, "kserve", max_batch=32,
                               max_replicas=4)
        res = svc.stress_test(128)
        print(f"  kserve: 128 reqs in {res.total_time_s:.3f}s "
              f"(p99 {res.p99 * 1e3:.1f}ms)")
        return res.summary()

    k = pipe.step(katib_tuning, cache=False)
    t = pipe.step(tfjob_training, k, cache=False)
    s = pipe.step(kserve_serving, t, cache=False)
    out = pipe.run()
    yaml_path = f"experiments/artifacts/pipeline_{profile_name}.yaml"
    pipe.export_yaml(yaml_path)
    stages = {e["name"]: round(e["duration_s"] + prof.startup_s, 2)
              for e in pipe.log.events if not e["name"].startswith("pipeline")}
    return {"stages_s": stages, "serving": out["kserve_serving"],
            "pipeline_yaml": yaml_path}


def main():
    store = ArtifactStore("experiments/artifacts")
    results = {}
    for profile in ("gcp", "ibm"):
        print(f"== cloud profile: {profile} ==")
        results[profile] = run_cloud(profile, store)
    print(json.dumps(results, indent=1, default=str))


if __name__ == "__main__":
    main()
