"""Serve a heterogeneous model fleet through the model-mesh gateway:
a LeNet classifier, a synthetic embedding model, and a continuous-batched
LLM behind one router -- with a canary split, a scale-to-zero cold-start
cycle, a SPLIT-aware multi-cloud placement plan (a model may serve
active-active from several clouds at once), and the simulated dollar bill
for the run (CloudProfile price sheet; a simulation output, DESIGN.md §1).

    PYTHONPATH=src python examples/multi_model_serving.py [--arch h2o_danube_3_4b]
"""
import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.clouds.profiles import get_profile
from repro.configs import registry
from repro.data.mnist import make_dataset
from repro.models import lenet, lm
from repro.serving.continuous import ContinuousBatcher
from repro.serving.gateway import (AutoscalerConfig, BatcherBackend,
                                   CloudCapacity, Gateway, ModelDemand,
                                   Predictor, TrafficSpec, plan_placement)
from repro.telemetry.events import EventLog


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o_danube_3_4b")
    args = ap.parse_args()

    # -- three very different backends -------------------------------------
    imgs, _ = make_dataset(8, seed=0)
    lp = lenet.init_params(jax.random.PRNGKey(0))
    classifier = Predictor(
        "lenet", jax.jit(lambda x: jnp.argmax(lenet.apply(lp, x), -1)),
        imgs[:1])
    classifier.warmup((1, 8))

    w = jax.random.normal(jax.random.PRNGKey(1), (128, 128), jnp.float32)
    embedder = Predictor("embed", jax.jit(lambda v: jnp.tanh(v @ w)),
                         np.zeros((1, 128), np.float32))
    embedder.warmup((1, 8))
    embedder_v2 = Predictor("embed-v2", jax.jit(lambda v: jnp.tanh(v @ w.T)),
                            np.zeros((1, 128), np.float32))

    cfg = registry.get_smoke_config(args.arch)
    llm = BatcherBackend(
        "llm", ContinuousBatcher(cfg, lm.init_params(jax.random.PRNGKey(0), cfg),
                                 max_slots=2, max_len=64),
        prompt_len=4, gen_tokens=4)

    # -- place the fleet over gcp/ibm, then simulate it ---------------------
    # demands in fixed Erlangs (rate = load / measured service time) so the
    # plan is the same shape on any host, however slow the measurement
    t_lenet = classifier.service_time(8) / 8
    t_embed = embedder.service_time(8) / 8
    t_llm = llm.service_time(2)
    demands = [ModelDemand("lenet", 3.0 / t_lenet, t_lenet),
               ModelDemand("embed", 1.0 / t_embed, t_embed),
               ModelDemand("llm", 0.5 / t_llm, t_llm)]
    # gcp is cheap but small: the heaviest model cannot fit there whole, so
    # the split planner serves it ACTIVE-ACTIVE from both clouds at once
    clouds = [CloudCapacity(get_profile("gcp"), 4, 1.0),
              CloudCapacity(get_profile("ibm"), 10, 1.4)]
    plan = plan_placement(demands, clouds, objective="cost", split=True)
    print("placement (cost, split-aware):",
          json.dumps(plan.summary(), indent=1))
    assert plan.feasible, "fleet does not fit the configured clouds"
    split_of = {a.model: {get_profile(c): w for c, w in a.weights.items()}
                for a in plan.assignments}
    # the plan's expected-queue hints seed queue-aware routing (the
    # default policy) before any real queue signal exists
    hint_of = {a.model: dict(a.est_wait_s) for a in plan.assignments}

    log = EventLog()
    gw = Gateway(capacity=plan.capacity_map(), log=log)
    gw.deploy("lenet", classifier, split=split_of["lenet"],
              autoscaler=AutoscalerConfig(min_replicas=1, max_replicas=4,
                                          target_queue=8, idle_window_s=2.0),
              max_batch=8, queue_hint=hint_of["lenet"])
    gw.deploy("embed", embedder, split=split_of["embed"],
              autoscaler=AutoscalerConfig(min_replicas=1, max_replicas=4,
                                          target_queue=8, idle_window_s=2.0),
              max_batch=16, canary=embedder_v2, canary_fraction=0.25,
              queue_hint=hint_of["embed"])
    gw.deploy("llm", llm, split=split_of["llm"],
              autoscaler=AutoscalerConfig(min_replicas=0, max_replicas=2,
                                          scale_up_delay_s=0.5,
                                          idle_window_s=1.0), max_batch=4,
              queue_hint=hint_of["llm"])
    out = gw.run([
        TrafficSpec("lenet", 200, arrival="poisson", rate=1000.0),
        TrafficSpec("embed", 128),                 # burst, 25% canary
        TrafficSpec("llm", 4),                     # cold start
        TrafficSpec("llm", 4, start_s=8.0),        # scale-to-zero -> cold again
    ], seed=0)
    print("fleet:", json.dumps(out.summary(), indent=1))
    print("final split weights:", json.dumps(gw.final_weights, indent=1))
    print(f"simulated run cost: ${out.total_cost_usd:.6f} "
          "(price-sheet output, not a measurement)")
    print("llm replica trace (scale-to-zero cycle):",
          [(round(t, 3), p) for t, p in out.per_model["llm"].replica_trace])

    # the LLM backend is real: generate through the same batcher
    outputs = llm.generate([[5, 17, 99], [7, 8, 9]], max_new=4)
    print("llm generations:", outputs)

    assert out.cold_starts["llm"] >= 2
    assert sum(out.per_model["embed"].per_version.values()) == 128


if __name__ == "__main__":
    main()
