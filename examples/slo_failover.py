"""SLO-aware serving through a cloud outage: three traffic classes
(latency / standard / batch) on one fleet, a mid-run gcp failure with ibm
standby, an observed-load re-plan afterwards -- and the revised plan
applied LIVE to a second window via a MigrationPlan (drain-and-shift, no
requests dropped), then the same outage window replayed WITH per-class
admission control (deadline-hopeless latency/standard work is shed
exactly once, batch work only deferred).

The run shows the full loop: class-weighted dispatch + preemption keeps
the latency class fast while the batch class absorbs the queueing; the
outage zeroes gcp's split weight (failover is a degenerate split) and
restores it on recovery; ``placement.replan`` rebuilds the plan from what
the gateway MEASURED rather than what we guessed, and ``diff_plans``
turns the delta into a mid-run migration.

    PYTHONPATH=src python examples/slo_failover.py
"""
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.clouds.profiles import get_profile
from repro.serving.gateway import (AdmissionConfig, AutoscalerConfig,
                                   CloudCapacity, FailureSpec, Gateway,
                                   MigrationSpec, ModelDemand, Predictor,
                                   TrafficSpec, plan_placement, replan)
from repro.telemetry.events import EventLog


def main():
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 256), jnp.float32)
    pred = Predictor("ranker", jax.jit(lambda v: jnp.tanh(v @ w)),
                     np.zeros((1, 256), np.float32))
    pred.warmup((1, 8))

    # plan from a deliberately rough demand guess; the replan at the end
    # corrects it from measurement
    guess = ModelDemand("ranker", rate=50.0,
                        service_time_s=pred.service_time(8) / 8)
    clouds = [CloudCapacity(get_profile("gcp"), 8, 1.0),
              CloudCapacity(get_profile("ibm"), 8, 1.4)]
    plan = plan_placement([guess], clouds, objective="p99")
    print("initial plan:", json.dumps(plan.summary(), indent=1))

    log = EventLog()
    gw = Gateway(capacity=plan.capacity_map(), log=log)
    gw.deploy("ranker", pred, get_profile("gcp"), standby=get_profile("ibm"),
              autoscaler=AutoscalerConfig(min_replicas=1, max_replicas=2,
                                          scale_up_delay_s=0.02,
                                          idle_window_s=np.inf),
              max_batch=8)

    # size the workload from the MEASURED batch time so the backlog shape is
    # host-independent: the batch burst keeps both replicas saturated for
    # ~drain seconds, the latency stream lands inside that backlog (forcing
    # preemptions), and the outage hits while the queue is still deep
    t8 = pred.service_time(8)
    per_batch = get_profile("gcp").network_rtt_s + t8
    drain = (640 / 8) * per_batch / 2
    traffic = [
        TrafficSpec("ranker", 640, slo="batch"),              # bulk backlog
        TrafficSpec("ranker", 96, slo="standard",
                    arrival="poisson", rate=96 / drain),
        TrafficSpec("ranker", 64, slo="latency",
                    arrival="poisson", rate=64 / (0.4 * drain)),
    ]
    outage = FailureSpec("gcp", at_s=0.6 * drain, duration_s=0.5 * drain)
    out = gw.run(traffic, seed=0, failures=[outage])

    print("per-class latencies through the outage:")
    print(json.dumps(out.per_class(), indent=1))
    for name in ("gateway:preempt", "gateway:failover", "gateway:recover",
                 "gateway:cold_start"):
        print(f"  {name}: {log.count(name)}")

    revised = replan(plan, out)
    print("replanned from observed load:",
          json.dumps(revised.summary(), indent=1))
    print(f"simulated run cost: ${out.total_cost_usd:.6f} "
          "(price-sheet output, not a measurement)")

    assert log.count("gateway:failover") >= 1
    assert log.count("gateway:recover") >= 1
    assert log.count("gateway:preempt") >= 1
    pc = out.per_class()
    assert pc["latency"]["p99_s"] <= pc["batch"]["p99_s"]

    # apply the revised plan LIVE to a fresh window: the router shifts the
    # split mid-run (in-flight batches finish where they started, the
    # backlog re-routes, relaunches arrive cold on the destination).
    # diff_plans(plan, revised) is the general plan-to-plan form; here the
    # RUNNING placement (gcp primary) is what differs, so we migrate to the
    # revised assignment's weights directly
    target = dict(next(a for a in revised.assignments
                       if a.model == "ranker").weights)
    out2 = gw.run([TrafficSpec("ranker", 160, slo="standard",
                               arrival="poisson", rate=96 / drain)],
                  seed=1,
                  migrations=[MigrationSpec(0.3 * drain, {"ranker": target})])
    print("live migration applied mid-run ->", target)
    print("post-migration split:", gw.final_weights["ranker"],
          f"- sim cost ${out2.total_cost_usd:.6f}")
    assert log.count("gateway:migrate") >= 1
    assert out2.per_model["ranker"].n_requests == 160

    # replay the outage window WITH per-class admission control: requests
    # whose expected completion already breaks their deadline are shed at
    # the door (gateway:shed, exactly once) instead of queueing to certain
    # failure -- batch work is only deferred, and the survivors' per-class
    # tail collapses because the hopeless work no longer clogs the queues
    adm_log = EventLog()
    adm = Gateway(capacity=plan.capacity_map(), log=adm_log,
                  admission=AdmissionConfig())
    adm.deploy("ranker", pred, get_profile("gcp"), standby=get_profile("ibm"),
               autoscaler=AutoscalerConfig(min_replicas=1, max_replicas=2,
                                           scale_up_delay_s=0.02,
                                           idle_window_s=np.inf),
               max_batch=8)
    out3 = adm.run(traffic, seed=0, failures=[outage])
    res3 = out3.per_model["ranker"]
    pc3 = out3.per_class()
    print("same outage window with admission control:")
    print(json.dumps(pc3, indent=1))
    print(f"  shed {res3.shed_total}/{res3.n_requests} "
          f"(rate {res3.shed_rate:.4f}), by class {res3.class_shed}")
    assert res3.class_shed.get("batch", 0) == 0   # deferred, never shed
    assert len(res3.class_latencies["batch"]) == 640
    n_shed = adm_log.count("gateway:shed")
    assert n_shed == res3.shed_total              # exactly once, all logged
    assert len(res3.latencies_s) + n_shed == res3.n_requests


if __name__ == "__main__":
    main()
