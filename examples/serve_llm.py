"""Serve an LLM (reduced config of any assigned arch) through the KServe
analog with batched greedy generation + canary rollout between two model
versions -- then through the DISAGGREGATED gateway path (ISSUE 8): a real
ContinuousBatcher is measured by BatcherBackend to split per-request cost
into prefill/decode, and the gateway stages every request across a
prefill pool (gcp) and a decode pool (ibm) with KV-block accounting.

    PYTHONPATH=src python examples/serve_llm.py --arch zamba2-1.2b
"""
import argparse
import json

import jax

from repro.clouds.profiles import get_profile
from repro.configs import registry
from repro.launch.serve import make_lm_predictor
from repro.models import lm
from repro.serving.continuous import ContinuousBatcher
from repro.serving.gateway import (AutoscalerConfig, BatcherBackend,
                                   DisaggSpec, Gateway, RoutingConfig,
                                   TrafficSpec)
from repro.serving.kserve import InferenceService
from repro.telemetry.events import EventLog


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-1.2b")
    ap.add_argument("--requests", type=int, default=24)
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch)
    v1 = make_lm_predictor(cfg, gen_tokens=8, seed=0)
    v2 = make_lm_predictor(cfg, gen_tokens=8, seed=1)   # canary candidate
    v2.name = f"{cfg.name}-canary"

    svc = InferenceService(v1, get_profile("gcp"), "kserve", max_batch=8,
                           canary=v2, canary_fraction=0.2)
    res = svc.stress_test(args.requests)
    out = {"kserve_canary": res.summary()}
    assert sum(res.per_version.values()) == args.requests

    # disaggregated leg: measure a real batcher, stage prefill on gcp and
    # decode on ibm, KV budget sized so nothing sheds at this load
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batcher = ContinuousBatcher(cfg, params, max_slots=2, max_len=64,
                                prefill_chunk=8)
    backend = BatcherBackend(cfg.name, batcher, prompt_len=16, gen_tokens=4)
    gw = Gateway(log=EventLog(), routing=RoutingConfig(policy="queue_aware"))
    gw.deploy(cfg.name, backend,
              split={get_profile("gcp"): 0.5, get_profile("ibm"): 0.5},
              autoscaler=AutoscalerConfig(min_replicas=1, max_replicas=2),
              max_batch=4,
              disagg=DisaggSpec(kv_blocks=256, block_size=16,
                                prompt_tokens=16, gen_tokens=4,
                                pool_kind={"gcp": "prefill",
                                           "ibm": "decode"}))
    run = gw.run([TrafficSpec(cfg.name, args.requests, arrival="poisson",
                              rate=50.0)], seed=0)
    r = run.per_model[cfg.name]
    out["disagg_gateway"] = {
        "served": r.n_requests - r.shed_total,
        "shed": r.shed_total,
        "p50_s": round(r.p50, 5),
        "p99_s": round(r.p99, 5),
        "prefill_batches": len(gw.log.named("gateway:prefill")),
        "cache_sheds": len(gw.log.named("gateway:cache_shed")),
        "measured_prefill_s_per_chunk": round(backend.prefill_time(8), 6),
        "measured_decode_s_per_step": round(backend.decode_time(1), 6),
        "kv_blocks_leaked": sum(run_kv for run_kv
                                in gw.final_kv[cfg.name].values()),
    }
    assert out["disagg_gateway"]["served"] + r.shed_total == args.requests
    assert out["disagg_gateway"]["kv_blocks_leaked"] == 0
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
