"""Serve an LLM (reduced config of any assigned arch) through the KServe
analog with batched greedy generation + canary rollout between two model
versions.

    PYTHONPATH=src python examples/serve_llm.py --arch zamba2-1.2b
"""
import argparse
import json

from repro.clouds.profiles import get_profile
from repro.configs import registry
from repro.launch.serve import make_lm_predictor
from repro.serving.kserve import InferenceService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-1.2b")
    ap.add_argument("--requests", type=int, default=24)
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch)
    v1 = make_lm_predictor(cfg, gen_tokens=8, seed=0)
    v2 = make_lm_predictor(cfg, gen_tokens=8, seed=1)   # canary candidate
    v2.name = f"{cfg.name}-canary"

    svc = InferenceService(v1, get_profile("gcp"), "kserve", max_batch=8,
                           canary=v2, canary_fraction=0.2)
    res = svc.stress_test(args.requests)
    print(json.dumps(res.summary(), indent=1))
    assert sum(res.per_version.values()) == args.requests


if __name__ == "__main__":
    main()
