"""Continuous-batching LLM serving: more requests than decode slots, with
admission into freed slots mid-flight (vLLM-style scheduling on the same
decode path the dry-run lowers).

Prompts now ingest through the disaggregated batched-prefill path by
default (--prefill-chunk C: ceil(P/C) flash-attention prefill calls write
the KV rows directly, then the request enters the decode slot pool); a
teacher-forced reference leg (--prefill-chunk 0) drains the same mix and
the outputs are asserted identical -- the oracle contract of
tests/test_prefill_oracle.py, demonstrated end to end.

    PYTHONPATH=src python examples/continuous_batching.py --arch h2o-danube-3-4b
"""
import argparse
import json
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import lm
from repro.serving.continuous import ContinuousBatcher


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b")
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="0 = teacher-forced seed path only")
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, args.prompt_len).tolist()
               for _ in range(args.requests)]

    def drain(pc):
        cb = ContinuousBatcher(cfg, params, max_slots=args.slots,
                               max_len=96, prefill_chunk=pc)
        reqs = [cb.submit(list(p), max_new=args.max_new) for p in prompts]
        t0 = time.perf_counter()
        done = cb.run()
        assert len(done) == args.requests
        return cb, reqs, time.perf_counter() - t0

    cb, reqs, wall = drain(args.prefill_chunk)
    summary = {
        "arch": cfg.name,
        "requests": args.requests,
        "slots": args.slots,
        "prefill_chunk": args.prefill_chunk,
        "prefill_stats": dict(cb.prefill_stats) if args.prefill_chunk else None,
        "engine_steps": cb.step_count,
        "wall_s": round(wall, 2),
        "tokens_generated": sum(len(r.output) for r in reqs),
        "admission_steps": [r.admitted_step for r in reqs],
        "sample_output": reqs[0].output,
    }
    if args.prefill_chunk:
        # oracle leg: the seed path must emit the exact same tokens
        _, ref, ref_wall = drain(0)
        assert [r.output for r in ref] == [r.output for r in reqs], \
            "disaggregated prefill diverged from teacher-forced reference"
        summary["oracle_ok"] = True
        summary["teacher_forced_wall_s"] = round(ref_wall, 2)
    print(json.dumps(summary, indent=1))


if __name__ == "__main__":
    main()
