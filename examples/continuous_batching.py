"""Continuous-batching LLM serving: more requests than decode slots, with
admission into freed slots mid-flight (vLLM-style scheduling on the same
decode path the dry-run lowers).

    PYTHONPATH=src python examples/continuous_batching.py --arch h2o-danube-3-4b
"""
import argparse
import json
import time

import jax

from repro.configs import registry
from repro.models import lm
from repro.serving.continuous import ContinuousBatcher


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b")
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=6)
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    cb = ContinuousBatcher(cfg, params, max_slots=args.slots, max_len=96)
    reqs = [cb.submit([10 + i, 20 + i, 30 + i], max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = cb.run()
    wall = time.perf_counter() - t0
    print(json.dumps({
        "arch": cfg.name,
        "requests": args.requests,
        "slots": args.slots,
        "engine_steps": cb.step_count,
        "wall_s": round(wall, 2),
        "tokens_generated": sum(len(r.output) for r in reqs),
        "admission_steps": [r.admitted_step for r in reqs],
        "sample_output": reqs[0].output,
    }, indent=1))
    assert len(done) == args.requests


if __name__ == "__main__":
    main()
