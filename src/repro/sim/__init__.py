"""Shared discrete-event simulation core (DESIGN.md S6)."""
from .engine import EventHeap, IndexQueue, Ledger

__all__ = ["EventHeap", "IndexQueue", "Ledger"]
