"""Shared discrete-event simulation core (DESIGN.md S6).

Both event loops in the repo -- the serving gateway
(serving/gateway/router.py) and the pipeline orchestrator
(pipelines/scheduler.py) -- run on the one ``EventHeap`` here instead of
each hand-rolling ``heapq`` + ``itertools.count``.  The contract:

- every event is a ``(t, seq, kind, *payload)`` tuple ordered by
  ``(t, seq)``; ``seq`` is a per-heap monotonic counter drawn at push
  time, so ties at the same simulated timestamp resolve in PUSH order
  and no payload is ever compared;
- same-timestamp batching: all events sharing the earliest ``t`` form
  one logical step.  ``pop()`` + ``peek_t()`` supports the gateway's
  interleaved style (an event processed at ``t`` may push another event
  at ``t`` into the SAME step); ``pop_batch()`` supports the
  orchestrator's collect-then-apply style (a same-``t`` push lands in
  the NEXT step).  Each caller keeps its historical semantics exactly;
- timer kinds: self-rescheduling periodic events (the gateway's
  ``probe`` / ``scrape``).  ``only_timers()`` is the dead-tail rule
  from the observability PR: once no work is left and only timer kinds
  remain queued, the timers must stop re-arming -- re-pushing while
  "the heap is non-empty" would let two timers sustain each other
  through an unbounded tail after the last request completes;
- determinism: no RNG is consumed here, ``seq`` is stable under a fixed
  push order, and ``n_pushed`` / ``n_popped`` count simulator events for
  the scale bench (events/sec) without touching the hot-path tuples.

``Ledger`` is the struct-of-arrays request ledger the vectorized gateway
engine folds over (arrival / class / version / routing-uniform /
latency / shed columns, one row per offered request), and ``IndexQueue``
the O(1)-amortized FIFO of ledger row indices that replaced the
quadratic ``list.pop(0)`` pending queues.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Iterable

import numpy as np

_INF = float("inf")


class EventHeap:
    """Min-heap of ``(t, seq, kind, *payload)`` with same-timestamp
    batching and the timer dead-tail rule (module docstring)."""

    __slots__ = ("_heap", "_seq", "timer_kinds", "n_pushed", "n_popped")

    def __init__(self, timer_kinds: Iterable[str] = ()):
        self._heap: list = []
        self._seq = itertools.count()
        self.timer_kinds = frozenset(timer_kinds)
        self.n_pushed = 0
        self.n_popped = 0

    def __bool__(self) -> bool:
        return bool(self._heap)

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, t: float, kind: str, *payload) -> None:
        """Schedule ``kind`` at simulated time ``t``; ties at ``t``
        process in push order (the drawn ``seq`` is unique, so payloads
        never compare)."""
        heapq.heappush(self._heap,
                       (float(t), next(self._seq), kind) + payload)
        self.n_pushed += 1

    def peek_t(self) -> float:
        """Earliest scheduled time; +inf when empty."""
        return self._heap[0][0] if self._heap else _INF

    def pop(self) -> tuple:
        """Pop the earliest event as ``(kind, *payload)``."""
        self.n_popped += 1
        return heapq.heappop(self._heap)[2:]

    def pop_batch(self) -> tuple[float, list]:
        """Pop EVERY event at the earliest time: ``(t, [(kind, *payload),
        ...])`` in seq order.  Events pushed while the batch is processed
        -- even at the same ``t`` -- belong to the next batch."""
        t = self._heap[0][0]
        batch = []
        while self._heap and self._heap[0][0] == t:
            batch.append(self.pop())
        return t, batch

    def only_timers(self) -> bool:
        """True when nothing but self-rescheduling timer kinds remain --
        the signal for periodic timers to stop re-arming (dead-tail
        rule)."""
        kinds = self.timer_kinds
        return all(e[2] in kinds for e in self._heap)


class Ledger:
    """Struct-of-arrays request ledger for one model's offered traffic.

    One row per request, columns as parallel numpy arrays: ``arr``
    (arrival time, sorted ascending -- row index IS arrival order),
    ``cls_code`` (int code into the owner's SLO-class list), ``ver``
    (backend version: 0 primary / 1 canary), ``route_u`` (the pre-drawn
    routing uniform), ``lat`` (realized latency, -1 until served) and
    ``shed`` (admission-control drop flag, set exactly once).  The
    vectorized engine appends/folds whole index ranges against these
    columns; the scalar engine addresses single rows -- both see the
    same memory, which is what makes bit-compatibility checkable.
    """

    __slots__ = ("arr", "cls_code", "ver", "route_u", "lat", "shed")

    def __init__(self, arr: np.ndarray, cls_code: np.ndarray,
                 ver: np.ndarray, route_u: np.ndarray):
        n = len(arr)
        self.arr = arr
        self.cls_code = cls_code
        self.ver = ver
        self.route_u = route_u
        self.lat = np.full(n, -1.0)
        self.shed = np.zeros(n, bool)

    def __len__(self) -> int:
        return len(self.arr)

    def deadlines(self, mult_by_code: np.ndarray, base: float) -> np.ndarray:
        """Per-request deadline column: class deadline multiple x a warm
        single-request base path (seconds)."""
        return mult_by_code[self.cls_code] * base


class IndexQueue:
    """FIFO of ledger row indices: a list plus a head cursor.

    Replaces the ``list.pop(0)`` pending queues that went quadratic
    under backlog: append/extend are amortized O(1), ``take(n)`` is one
    C-level slice, and the consumed prefix is compacted away once it
    outgrows the live tail.  Iteration and ``sorted()`` see only the
    live items, so drain/merge paths (preemption reclaim, weight-shift
    re-routing) behave exactly like the old plain list."""

    __slots__ = ("buf", "head")

    def __init__(self, items: Iterable = ()):
        self.buf = list(items)
        self.head = 0

    def __len__(self) -> int:
        return len(self.buf) - self.head

    def __bool__(self) -> bool:
        return len(self.buf) > self.head

    def __iter__(self):
        return iter(self.buf[self.head:])

    def peek(self):
        return self.buf[self.head]

    def append(self, i) -> None:
        self.buf.append(i)

    def extend(self, items) -> None:
        self.buf.extend(items)

    def popleft(self):
        i = self.buf[self.head]
        self.head += 1
        self._trim()
        return i

    def take(self, n: int) -> list:
        """Pop and return up to ``n`` items from the front (FIFO order)."""
        h = self.head
        j = min(h + n, len(self.buf))
        out = self.buf[h:j]
        self.head = j
        self._trim()
        return out

    def _trim(self) -> None:
        if self.head * 2 >= len(self.buf):
            del self.buf[:self.head]
            self.head = 0
