"""KServe analog: InferenceService with four deployment strategies.

Strategies (paper Table 3 columns):
  baremetal -- model re-loaded on EVERY request, no batching, sequential
               (the paper's linserv Flask app: "loading a new PyTorch model
               everytime a new request comes in")
  k8s       -- persistent model behind a LoadBalancer, batch=1, sequential
  kserve    -- dynamic batching + queue-depth autoscaling + optional canary
               traffic split, on a CloudProfile (gcp / ibm)

Compute latencies are REAL (measured from the jitted predictor on this
host, per batch size, cached); network RTT / model-load / LB constants come
from the CloudProfile (simulated, calibrated to the paper's ratios --
DESIGN.md records this as the hardware-gate simulation).

The discrete-event machinery now lives in serving/gateway/router.py (the
multi-model fleet layer); InferenceService is its single-model client:
one Deployment, legacy KPA knobs (min_replicas >= 1, no idle scale-down,
warm scale-up).  Predictor / ServeResult are re-exported from there for
backward compatibility.
"""
from __future__ import annotations

import math
from typing import Optional

import numpy as np

from ..clouds.profiles import CloudProfile
from ..telemetry.events import EventLog
from .gateway.autoscaler import AutoscalerConfig
from .gateway.router import (AdmissionConfig, Gateway,  # noqa: F401
                             Predictor, ServeResult, TrafficSpec, _pow2,
                             jax_block)


class InferenceService:
    def __init__(self, predictor: Predictor, profile: CloudProfile,
                 strategy: str = "kserve", *, max_batch: int = 32,
                 min_replicas: int = 1, max_replicas: int = 4,
                 target_queue: int = 16, scale_up_delay_s: float = 0.5,
                 canary: Optional[Predictor] = None, canary_fraction: float = 0.0,
                 admission: Optional[AdmissionConfig] = None,
                 log: Optional[EventLog] = None,
                 tracer=None, metrics=None):
        assert strategy in ("baremetal", "k8s", "kserve")
        self.predictor = predictor
        self.profile = profile
        self.strategy = strategy
        self.max_batch = max_batch
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.target_queue = target_queue
        self.scale_up_delay_s = scale_up_delay_s
        self.canary = canary
        self.canary_fraction = canary_fraction
        self.admission = admission       # pass-through: deadline-hopeless
        # requests shed at the gateway (kserve strategy only; the
        # sequential baselines admit everything by construction)
        self.log = log or EventLog()
        self.tracer = tracer             # telemetry pass-through: the
        self.metrics = metrics           # kserve-strategy gateway records
        # request spans / metric series into these (observability plane,
        # DESIGN.md S5); the sequential baselines have no event loop to
        # instrument

    # -- the paper's stress test -------------------------------------------
    def stress_test(self, n_requests: int, seed: int = 0, *,
                    arrival: str = "burst", rate: float = 0.0,
                    slo="standard") -> ServeResult:
        """arrival="burst": all requests at t=0 (the paper's test).
        arrival="poisson": open-loop Poisson arrivals at `rate` req/s
        (beyond-paper: measures queueing latency, not just throughput).
        slo: SLO class (name or SLOClass) stamped on every request --
        passed straight through to the gateway, so per-class percentiles
        and deadline-miss rates come back in the result."""
        with self.log.stage(f"serve:{self.strategy}", n=n_requests):
            if self.strategy == "baremetal":
                return self._sequential(n_requests, reload_each=True)
            if self.strategy == "k8s":
                return self._sequential(n_requests, reload_each=False)
            arrivals = None
            if arrival == "poisson":
                rng = np.random.default_rng(seed + 1)
                gaps = rng.exponential(1.0 / max(rate, 1e-9), n_requests)
                arrivals = np.cumsum(gaps)
            return self._kserve_sim(n_requests, seed=seed, arrivals=arrivals,
                                    slo=slo)

    def _sequential(self, n: int, *, reload_each: bool) -> ServeResult:
        p = self.profile
        t_req = self.predictor.service_time(1)
        lat, clock = [], 0.0
        for _ in range(n):
            l = p.network_rtt_s + p.lb_overhead_s + t_req
            if reload_each:
                l += p.model_load_s
            clock += l
            lat.append(l)
        # one always-on replica billed for the whole run (simulated $,
        # profile price sheet -- DESIGN.md §1)
        cost = clock * p.cost_per_s
        return ServeResult(self.strategy, n, clock, lat, [(0.0, 1)],
                           cost_usd=cost, cost_by_cloud={p.name: cost})

    def _kserve_sim(self, n: int, seed: int = 0, arrivals=None,
                    slo="standard") -> ServeResult:
        """One-model gateway run with the legacy KPA semantics: replicas
        never idle out (idle_window=inf) and scale-ups arrive warm (the
        scale-up delay stands in for scheduling + load, as pre-gateway)."""
        if n == 0:                       # untrafficked models report nothing
            return ServeResult(self.strategy, 0, 0.0, [], [(0.0, 1)])
        cfg = AutoscalerConfig(min_replicas=self.min_replicas,
                               max_replicas=self.max_replicas,
                               target_queue=self.target_queue,
                               scale_up_delay_s=self.scale_up_delay_s,
                               idle_window_s=math.inf, cold_scale_up=False)
        gw = Gateway(log=self.log, admission=self.admission,
                     tracer=self.tracer, metrics=self.metrics)
        gw.deploy(self.predictor.name, self.predictor, self.profile,
                  autoscaler=cfg, max_batch=self.max_batch,
                  canary=self.canary, canary_fraction=self.canary_fraction)
        res = gw.run([TrafficSpec(self.predictor.name, n, arrivals=arrivals,
                                  slo=slo)],
                     seed=seed).per_model[self.predictor.name]
        return ServeResult(self.strategy, n, res.total_time_s,
                           res.latencies_s, res.replica_trace,
                           per_version=res.per_version,
                           class_latencies=res.class_latencies,
                           class_misses=res.class_misses,
                           class_shed=res.class_shed,
                           observed=res.observed,
                           cost_usd=res.cost_usd,
                           cost_by_cloud=res.cost_by_cloud)
