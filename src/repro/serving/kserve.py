"""KServe analog: InferenceService with four deployment strategies and a
discrete-event serving simulator.

Strategies (paper Table 3 columns):
  baremetal -- model re-loaded on EVERY request, no batching, sequential
               (the paper's linserv Flask app: "loading a new PyTorch model
               everytime a new request comes in")
  k8s       -- persistent model behind a LoadBalancer, batch=1, sequential
  kserve    -- dynamic batching + queue-depth autoscaling + optional canary
               traffic split, on a CloudProfile (gcp / ibm)

Compute latencies are REAL (measured from the jitted predictor on this
host, per batch size, cached); network RTT / model-load / LB constants come
from the CloudProfile (simulated, calibrated to the paper's ratios --
DESIGN.md records this as the hardware-gate simulation).  The autoscaler is
a queue-depth rule evaluated at batch completions (KServe/KPA-style).
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Callable, Optional

import numpy as np

from ..clouds.profiles import CloudProfile
from ..telemetry.events import EventLog


@dataclasses.dataclass
class ServeResult:
    strategy: str
    n_requests: int
    total_time_s: float
    latencies_s: list
    replica_trace: list = dataclasses.field(default_factory=list)
    per_version: dict = dataclasses.field(default_factory=dict)

    @property
    def p50(self):
        return float(np.percentile(self.latencies_s, 50))

    @property
    def p99(self):
        return float(np.percentile(self.latencies_s, 99))

    def summary(self) -> dict:
        return {"strategy": self.strategy, "n": self.n_requests,
                "total_s": round(self.total_time_s, 4),
                "p50_s": round(self.p50, 4), "p99_s": round(self.p99, 4),
                "replicas_max": max([r for _, r in self.replica_trace], default=1),
                **({"per_version": self.per_version} if self.per_version else {})}


class Predictor:
    """A deployable model version: jitted predict over a batch of inputs."""

    def __init__(self, name: str, predict_fn: Callable, example_input: Any):
        self.name = name
        self.predict_fn = predict_fn
        self.example_input = example_input
        self._lat_cache: dict[int, float] = {}

    def _batch_of(self, b: int):
        x = self.example_input
        reps = [b] + [1] * (np.ndim(x) - 1)
        return np.tile(x[:1], reps)

    def warmup(self, batch_sizes=(1,)):
        for b in batch_sizes:
            self.service_time(b)

    def service_time(self, b: int) -> float:
        """Measured wall latency of a size-b predict on this host."""
        if b not in self._lat_cache:
            x = self._batch_of(b)
            out = self.predict_fn(x)
            jax_block(out)                       # compile
            t0 = time.perf_counter()
            for _ in range(3):
                jax_block(self.predict_fn(x))
            self._lat_cache[b] = (time.perf_counter() - t0) / 3
        return self._lat_cache[b]

    def predict(self, x):
        return self.predict_fn(x)


def jax_block(x):
    try:
        import jax
        jax.block_until_ready(x)
    except Exception:
        pass


class InferenceService:
    def __init__(self, predictor: Predictor, profile: CloudProfile,
                 strategy: str = "kserve", *, max_batch: int = 32,
                 min_replicas: int = 1, max_replicas: int = 4,
                 target_queue: int = 16, scale_up_delay_s: float = 0.5,
                 canary: Optional[Predictor] = None, canary_fraction: float = 0.0,
                 log: Optional[EventLog] = None):
        assert strategy in ("baremetal", "k8s", "kserve")
        self.predictor = predictor
        self.profile = profile
        self.strategy = strategy
        self.max_batch = max_batch
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.target_queue = target_queue
        self.scale_up_delay_s = scale_up_delay_s
        self.canary = canary
        self.canary_fraction = canary_fraction
        self.log = log or EventLog()

    # -- the paper's stress test -------------------------------------------
    def stress_test(self, n_requests: int, seed: int = 0, *,
                    arrival: str = "burst", rate: float = 0.0) -> ServeResult:
        """arrival="burst": all requests at t=0 (the paper's test).
        arrival="poisson": open-loop Poisson arrivals at `rate` req/s
        (beyond-paper: measures queueing latency, not just throughput)."""
        with self.log.stage(f"serve:{self.strategy}", n=n_requests):
            if self.strategy == "baremetal":
                return self._sequential(n_requests, reload_each=True)
            if self.strategy == "k8s":
                return self._sequential(n_requests, reload_each=False)
            arrivals = None
            if arrival == "poisson":
                rng = np.random.default_rng(seed + 1)
                gaps = rng.exponential(1.0 / max(rate, 1e-9), n_requests)
                arrivals = np.cumsum(gaps)
            return self._kserve_sim(n_requests, seed=seed, arrivals=arrivals)

    def _sequential(self, n: int, *, reload_each: bool) -> ServeResult:
        p = self.profile
        t_req = self.predictor.service_time(1)
        lat, clock = [], 0.0
        for _ in range(n):
            l = p.network_rtt_s + p.lb_overhead_s + t_req
            if reload_each:
                l += p.model_load_s
            clock += l
            lat.append(l)
        return ServeResult(self.strategy, n, clock, lat, [(0.0, 1)])

    # -- discrete-event simulation of batched, autoscaled serving ----------
    def _kserve_sim(self, n: int, seed: int = 0, arrivals=None) -> ServeResult:
        """arrivals: None = burst at t=0 (paper); else per-request arrival
        times.  Latency = completion - arrival (queueing included)."""
        p = self.profile
        rng = np.random.default_rng(seed)
        # request -> version routing (canary split)
        versions = np.full(n, 0)
        if self.canary is not None and self.canary_fraction > 0:
            versions = (rng.random(n) < self.canary_fraction).astype(int)
        preds = [self.predictor] + ([self.canary] if self.canary else [])
        arr = np.zeros(n) if arrivals is None else np.asarray(arrivals)
        order = np.argsort(arr)
        pending: dict = {v: [] for v in range(len(preds))}
        next_arrival = 0                       # index into `order`
        lat = [0.0] * n
        trace = [(0.0, self.min_replicas)]
        free_at = [0.0] * self.min_replicas    # replica availability times
        heapq.heapify(free_at)
        per_version: dict[str, int] = {}
        served = 0
        while served < n:
            t_free = free_at[0]
            # admit every request that has arrived by the replica-free time;
            # if none pending, fast-forward to the next arrival
            while next_arrival < len(order) and \
                    (arr[order[next_arrival]] <= t_free
                     or not any(pending.values())):
                i = int(order[next_arrival])
                pending[int(versions[i])].append(i)
                next_arrival += 1
            clock = max(heapq.heappop(free_at),
                        min((arr[i] for q in pending.values() for i in q),
                            default=0.0))
            v = max(pending, key=lambda k: len(pending[k]))
            take = pending[v][:self.max_batch]
            pending[v] = pending[v][len(take):]
            if not take:
                heapq.heappush(free_at, clock)
                continue
            b = len(take)
            service = preds[v].service_time(_pow2(b))
            done = clock + p.network_rtt_s + p.lb_overhead_s + service
            for i in take:
                lat[i] = done - arr[i]
            served += b
            per_version[preds[v].name] = per_version.get(preds[v].name, 0) + b
            heapq.heappush(free_at, done)
            queue_len = sum(len(q) for q in pending.values())
            # KPA-style scale-up on queue depth
            if queue_len > self.target_queue * len(free_at) and \
               len(free_at) < self.max_replicas:
                heapq.heappush(free_at, clock + self.scale_up_delay_s)
                trace.append((clock, len(free_at)))
        total = max(arr[i] + lat[i] for i in range(n)) if n else 0.0
        return ServeResult(self.strategy, n, total, lat, trace,
                           per_version=per_version)


def _pow2(b: int) -> int:
    """Measure service times on pow2 batch buckets (jit retrace control)."""
    n = 1
    while n < b:
        n *= 2
    return n
