"""Continuous-batching decode engine (beyond-paper serving feature).

KServe's request-level batching (kserve.py) wastes decode slots when
sequences finish at different times.  This engine keeps a fixed-width slot
pool over ONE shared KV cache and admits queued prompts into freed slots
between steps -- the vLLM-style scheduling pattern, built on the same
models.lm decode path used by the dry-run (per-sequence positions).

Mechanics: every step advances ALL slots by one token through
lm.decode_step.  With ``prefill_chunk=0`` (the teacher-forced reference
path) a newly admitted prompt is "caught up" by teacher-forcing its prompt
tokens through the decode path (one per step) before switching to
generation -- a P-token prompt costs P full decode steps across the entire
slot pool.  With ``prefill_chunk=C`` the prompt instead runs through the
batched prefill path (lm.prefill_chunk -> the flash-attention style masked
chunk attention) in O(P/C) calls on a standalone one-row cache, the KV rows
are scattered into the slot's cache row, and the sequence enters the decode
pool with its first generated token already emitted.  The oracle suite
(tests/test_prefill_oracle.py) pins the two paths to each other.  Idle
slots process a pad token whose writes land in their own cache rows, never
leaking across slots (cache rows are per-sequence).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import lm

PAD = 0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list          # token ids
    max_new: int
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    admitted_step: int = -1
    finished_step: int = -1


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0              # next cache position for this row
    remaining_prompt: int = 0  # tokens still being teacher-forced


class ContinuousBatcher:
    def __init__(self, cfg: ArchConfig, params, *, max_slots: int = 4,
                 max_len: int = 128, eos_id: Optional[int] = None,
                 prefill_chunk: int = 0):
        assert cfg.family not in ("audio",), "enc-dec admission not supported"
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.prefill_chunk = int(prefill_chunk)
        self.cache = lm.init_cache(cfg, max_slots, max_len)
        self.slots = [_Slot() for _ in range(max_slots)]
        # deque: admission pops the head every step -- a plain list's
        # pop(0) is O(n) and went quadratic under backlog (ISSUE 7)
        self.queue: collections.deque[Request] = collections.deque()
        self.requests: list[Request] = []   # submitted, not yet run()-returned
        self.step_count = 0
        self._next_rid = 0
        self._decode = jax.jit(
            lambda p, c, t, pos: lm.decode_step(p, cfg, t, pos, c))
        if self.prefill_chunk > 0:
            self._prefill = jax.jit(
                lambda p, c, t, pos: lm.prefill_chunk(p, cfg, t, pos, c))
            self._row_cache_zeros = lm.init_cache(cfg, 1, max_len)
            # per-phase counters the disaggregated cost model reads
            self.prefill_stats = {"requests": 0, "chunks": 0, "tokens": 0}

    # -- client API ---------------------------------------------------------
    def submit(self, prompt: list, max_new: int) -> Request:
        # A prompt must leave room for at least one generated token: the
        # done-check fires at pos >= max_len - 1 only once output exists, so
        # an unbounded prompt used to walk pos past the cache bound with its
        # KV writes silently dropped (out-of-range scatter) -- reject here.
        if len(prompt) >= self.max_len:
            raise ValueError(
                f"prompt length {len(prompt)} >= max_len {self.max_len}: "
                "no room in the KV cache to generate")
        # rid must be monotonic, not len(queue): admission pops the queue, so
        # a later submit would reuse a live rid and corrupt run()'s seen-set.
        req = Request(rid=self._next_rid, prompt=list(prompt), max_new=max_new)
        self._next_rid += 1
        self.queue.append(req)
        self.requests.append(req)
        return req

    @property
    def active(self) -> int:
        return sum(s.req is not None for s in self.slots)

    def _reset_row(self, i: int):
        """Zero cache row i: KV rows would be masked by eff_len anyway, but
        recurrent state (SSM/mLSTM carries) PERSISTS across occupants and
        must be cleared at re-admission."""
        def zero_row(a):
            if a.ndim >= 2 and a.shape[1] == self.max_slots:
                return a.at[:, i].set(jnp.zeros_like(a[:, i]))
            return a
        self.cache = {
            k: (jax.tree_util.tree_map(zero_row, v) if k.startswith("phase")
                else v)
            for k, v in self.cache.items()
        }

    def _admit(self):
        for i, s in enumerate(self.slots):
            # loop: a prefilled request can finish instantly (max_new=1 /
            # eos / cache bound), freeing the slot for the next in queue
            while s.req is None and self.queue:
                req = self.queue.popleft()
                req.admitted_step = self.step_count
                s.req = req
                self._reset_row(i)
                if self.prefill_chunk > 0 and req.prompt:
                    first = self._prefill_into(i, req)
                    req.output.append(first)
                    s.pos = len(req.prompt)
                    s.remaining_prompt = 0
                    self._maybe_finish(s)
                else:
                    s.pos = 0
                    s.remaining_prompt = len(req.prompt)

    def _maybe_finish(self, s: _Slot):
        """Same termination predicate the decode loop applies each step."""
        req = s.req
        hit_eos = self.eos_id is not None and req.output \
            and req.output[-1] == self.eos_id
        if req.output and (len(req.output) >= req.max_new or hit_eos
                           or s.pos >= self.max_len - 1):
            req.done = True
            req.finished_step = self.step_count
            s.req = None

    def _prefill_into(self, i: int, req: Request) -> int:
        """Run the prompt through lm.prefill_chunk on a one-row cache, then
        scatter the produced cache rows into slot i.  Returns the first
        generated token (argmax of the last prompt position's logits)."""
        prompt = np.asarray(req.prompt, np.int32)
        n = len(prompt)
        cache = self._row_cache_zeros
        t0 = 0
        logits = None
        while t0 < n:
            c = min(self.prefill_chunk, n - t0)
            tok = jnp.asarray(prompt[t0:t0 + c], jnp.int32)[None]
            pos = jnp.arange(t0, t0 + c, dtype=jnp.int32)[None]
            if self.cfg.use_mrope:
                pos = jnp.broadcast_to(pos[:, None], (1, 3, c))
            logits, cache = self._prefill(self.params, cache, tok, pos)
            self.prefill_stats["chunks"] += 1
            t0 += c
        self.prefill_stats["tokens"] += n
        self.prefill_stats["requests"] += 1
        self._scatter_row(i, cache)
        return int(np.asarray(jnp.argmax(logits[:, -1], axis=-1))[0])

    def _scatter_row(self, i: int, row_cache):
        """Copy a one-row prefill cache into row i of the shared cache."""
        def put(dst, src):
            if dst.ndim >= 2 and dst.shape[1] == self.max_slots:
                return dst.at[:, i].set(src[:, 0].astype(dst.dtype))
            return dst
        self.cache = {
            k: (jax.tree_util.tree_map(put, v, row_cache[k])
                if k.startswith("phase") else v)
            for k, v in self.cache.items()
        }

    # -- engine -------------------------------------------------------------
    def step(self):
        """Advance every slot one token; admit queued work into free slots."""
        self._admit()
        tokens, positions = [], []
        for s in self.slots:
            if s.req is None:
                tokens.append(PAD)
                positions.append(s.pos)
                continue
            if s.remaining_prompt > 0:     # teacher-force the prompt
                idx = len(s.req.prompt) - s.remaining_prompt
                tokens.append(s.req.prompt[idx])
            else:                          # feed back last generated token
                tokens.append(s.req.output[-1] if s.req.output
                              else s.req.prompt[-1])
            positions.append(s.pos)
        tok = jnp.asarray(tokens, jnp.int32)[:, None]
        pos = jnp.asarray(positions, jnp.int32)
        if self.cfg.use_mrope:
            pos = jnp.broadcast_to(pos[:, None], (self.max_slots, 3))
        logits, self.cache = self._decode(self.params, self.cache, tok, pos)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))

        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            s.pos += 1
            if s.remaining_prompt > 0:
                s.remaining_prompt -= 1
                if s.remaining_prompt == 0:
                    s.req.output.append(int(nxt[i]))   # first generated token
            else:
                s.req.output.append(int(nxt[i]))
            hit_eos = self.eos_id is not None and s.req.output \
                and s.req.output[-1] == self.eos_id
            if s.req.output and (len(s.req.output) >= s.req.max_new or hit_eos
                                 or s.pos >= self.max_len - 1):
                s.req.done = True
                s.req.finished_step = self.step_count
                s.req = None               # free the slot for admission
        self.step_count += 1

    def run(self, max_steps: int = 10_000) -> list:
        """Drain the queue; returns requests finished since the last run()
        (each request is returned exactly once across repeated
        submit/run cycles, and handed-back requests stop being tracked)."""
        finished: list[Request] = []

        def collect():
            done = [r for r in self.requests if r.done]
            if done:
                finished.extend(done)
                self.requests = [r for r in self.requests if not r.done]

        collect()                      # finished via manual step()s pre-run
        start = self.step_count        # max_steps bounds THIS call, not the
        while (self.queue or self.active) \
                and self.step_count - start < max_steps:   # batcher lifetime
            self.step()
            collect()
        return finished
