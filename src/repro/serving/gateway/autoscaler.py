"""KPA-style autoscaling policy with scale-to-zero (Cox et al.,
arXiv:2007.07366: serverless inferencing makes idle scale-down + cold-start
the defining production behaviors).

The policy is pure decision logic so it unit-tests without the simulator:
the router observes queue depth / idle time and asks the policy what to do,
then executes the decision inside the discrete-event loop (router.py).

Scale-up     queue_len > target_queue * pool  (KServe KPA queue-depth rule,
             same rule InferenceService used pre-gateway).
Scale-down   a replica idle for idle_window_s is retired, never below
             min_replicas.  min_replicas=0 enables scale-to-zero.
Cold start   a replica created after t=0 holds no weights: its first batch
             pays CloudProfile.model_load_s (cold_scale_up=False restores
             the legacy InferenceService behavior where the scale-up delay
             was the whole cost).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class AutoscalerConfig:
    min_replicas: int = 0            # 0 => scale-to-zero allowed
    max_replicas: int = 4
    target_queue: int = 16           # per-replica queue-depth target
    scale_up_delay_s: float = 0.5    # control-plane: pod scheduling + start
    idle_window_s: float = 1.0       # retire a replica idle this long
    cold_scale_up: bool = True       # new replicas pay model_load_s


class Autoscaler:
    """Stateless policy over an AutoscalerConfig (per-deployment instance)."""

    def __init__(self, config: AutoscalerConfig | None = None):
        self.cfg = config or AutoscalerConfig()

    def scale_up_needed(self, queue_len: int, pool: int) -> bool:
        """pool counts live replicas plus ones already scheduled to start."""
        return (queue_len > self.cfg.target_queue * max(pool, 1)
                and pool < self.cfg.max_replicas)

    def can_remove(self, pool: int) -> bool:
        return pool > self.cfg.min_replicas

    def relaunch_pool(self, pool_before: int, queue_len: int) -> int:
        """Replicas to start on the new cloud after a failover/fail-back:
        preserve the working-set size (the old pool was sized by observed
        load), keep at least min_replicas, and start one even from an empty
        pool when work is already queued.  Bounded by max_replicas so a
        migration cannot out-scale the policy."""
        want = max(pool_before, self.cfg.min_replicas,
                   1 if queue_len > 0 else 0)
        return min(want, max(self.cfg.max_replicas, self.cfg.min_replicas))

    @property
    def tracks_idle(self) -> bool:
        return math.isfinite(self.cfg.idle_window_s)
