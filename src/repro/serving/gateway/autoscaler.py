"""KPA-style autoscaling policy with scale-to-zero (Cox et al.,
arXiv:2007.07366: serverless inferencing makes idle scale-down + cold-start
the defining production behaviors), extended with cost awareness over the
CloudProfile price sheet (ISSUE 3: active-active splits make "which cloud"
a per-replica decision, not a per-deployment one).

The policy is pure decision logic so it unit-tests without the simulator:
the router observes queue depth / idle time and asks the policy what to do,
then executes the decision inside the discrete-event loop (router.py).

Scale-up     queue_len > target_queue * pool  (KServe KPA queue-depth rule,
             same rule InferenceService used pre-gateway), evaluated PER
             POOL now that a deployment may hold one pool per cloud.
             ``effective_queue`` folds the router's shed-pressure (requests
             admission control dropped since the last launch/probe) into
             the queue term: shed demand is still demand, so shedding
             triggers scale-up instead of masking the overload (ISSUE 4).
Scale-down   a replica idle for idle_window_s is retired, never below the
             pool's floor (its apportioned share of min_replicas).
             min_replicas=0 enables scale-to-zero.
Cost         pick_scale_up prefers the cheapest cloud with headroom;
             pick_retire prefers the most expensive cloud first.  Both rank
             against CloudProfile.cost_per_s (a simulated price sheet,
             DESIGN.md §1).
Cold start   a replica created after t=0 holds no weights: its first batch
             pays CloudProfile.model_load_s (cold_scale_up=False restores
             the legacy InferenceService behavior where the scale-up delay
             was the whole cost).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class AutoscalerConfig:
    min_replicas: int = 0            # 0 => scale-to-zero allowed
    max_replicas: int = 4
    target_queue: int = 16           # per-replica queue-depth target
    scale_up_delay_s: float = 0.5    # control-plane: pod scheduling + start
    idle_window_s: float = 1.0       # retire a replica idle this long
    cold_scale_up: bool = True       # new replicas pay model_load_s


@dataclasses.dataclass(frozen=True)
class PoolView:
    """What the policy sees of one per-cloud replica pool: enough to rank
    clouds by price and room, nothing about the simulator's internals."""
    cloud: str
    cost_per_s: float                # CloudProfile price sheet entry
    replicas: int                    # live + scheduled
    headroom: int                    # replicas the cloud/pool can still add


class Autoscaler:
    """Stateless policy over an AutoscalerConfig (per-deployment instance)."""

    def __init__(self, config: AutoscalerConfig | None = None):
        self.cfg = config or AutoscalerConfig()

    def scale_up_needed(self, queue_len: int, pool: int) -> bool:
        """pool counts live replicas plus ones already scheduled to start.
        The max_replicas bound is enforced by the caller per pool share
        (router.py) -- this is the pure queue-pressure rule."""
        return (queue_len > self.cfg.target_queue * max(pool, 1)
                and pool < self.cfg.max_replicas)

    @staticmethod
    def effective_queue(queue_len: int, shed_pressure: int,
                        alert_pressure: int = 0) -> int:
        """Queue depth as the scaling policy should see it: the real queue
        plus the requests admission control shed since the last launch or
        probe window, plus ``alert_pressure`` from an active SLO burn-rate
        alert (telemetry/slo.py BurnRateMonitor.pressure: a model burning
        its error budget scales up BEFORE the queue alone would tip the
        rule).  Shedding keeps queues short by design; without these terms
        an overloaded, hard-shedding pool would never scale up."""
        return queue_len + shed_pressure + alert_pressure

    def can_remove(self, pool: int, floor: Optional[int] = None) -> bool:
        """``floor`` is the pool's apportioned share of min_replicas; a
        single-pool deployment's floor IS min_replicas (legacy behavior)."""
        return pool > (self.cfg.min_replicas if floor is None else floor)

    def relaunch_pool(self, pool_before: int, queue_len: int,
                      headroom: Optional[int] = None) -> int:
        """Replicas to start on the destination cloud after a migration /
        failover: preserve the working-set size (the old pool was sized by
        observed load), keep at least min_replicas, and start one even from
        an empty pool when work is already queued.  Bounded by max_replicas
        so a migration cannot out-scale the policy, AND by the destination
        pool's capacity headroom when known (ISSUE 3 bugfix: the old global
        bound over-asked on a smaller destination cloud, burning launches
        on gateway:scale_denied) -- except the one guaranteed from-zero
        launch, which may breach the budget loudly."""
        want = max(pool_before, self.cfg.min_replicas,
                   1 if queue_len > 0 else 0)
        want = min(want, max(self.cfg.max_replicas, self.cfg.min_replicas))
        if headroom is not None:
            want = min(want, max(headroom, 1 if queue_len > 0 else 0))
        return want

    # -- cost awareness (CloudProfile.cost_per_s price sheet) ---------------
    @staticmethod
    def pick_scale_up(pools: list) -> Optional[PoolView]:
        """Cheapest cloud that can still grow; ties prefer the most
        headroom, then the cloud name (deterministic)."""
        open_ = [p for p in pools if p.headroom > 0]
        if not open_:
            return None
        return min(open_, key=lambda p: (p.cost_per_s, -p.headroom, p.cloud))

    @staticmethod
    def pick_retire(pools: list) -> Optional[PoolView]:
        """Most expensive cloud holding replicas retires first; ties prefer
        the most replicas, then the cloud name (deterministic)."""
        held = [p for p in pools if p.replicas > 0]
        if not held:
            return None
        return max(held, key=lambda p: (p.cost_per_s, p.replicas,
                                        p.cloud))

    @property
    def tracks_idle(self) -> bool:
        return math.isfinite(self.cfg.idle_window_s)
