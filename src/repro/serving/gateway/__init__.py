"""Model-mesh serving gateway: multi-model routing with SLO classes,
preemption and cloud failover (router.py), scale-to-zero autoscaling
(autoscaler.py), multi-cloud placement + observed-load re-planning
(placement.py).  See DESIGN.md §Gateway."""
from .autoscaler import Autoscaler, AutoscalerConfig
from .placement import (Assignment, CloudCapacity, ModelDemand, PlacementPlan,
                        est_p99_s, plan_placement, replan, replicas_needed)
from .router import (SLO_CLASSES, BatcherBackend, Deployment, FailureSpec,
                     Gateway, GatewayResult, Predictor, ServeResult, SLOClass,
                     TrafficSpec, resolve_slo)

__all__ = [
    "Autoscaler", "AutoscalerConfig",
    "Assignment", "CloudCapacity", "ModelDemand", "PlacementPlan",
    "est_p99_s", "plan_placement", "replan", "replicas_needed",
    "BatcherBackend", "Deployment", "FailureSpec", "Gateway", "GatewayResult",
    "Predictor", "ServeResult", "SLOClass", "SLO_CLASSES", "TrafficSpec",
    "resolve_slo",
]
