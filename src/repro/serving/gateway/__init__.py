"""Model-mesh serving gateway: multi-model routing (router.py),
scale-to-zero autoscaling (autoscaler.py), multi-cloud placement
(placement.py).  See DESIGN.md §Gateway."""
from .autoscaler import Autoscaler, AutoscalerConfig
from .placement import (Assignment, CloudCapacity, ModelDemand, PlacementPlan,
                        est_p99_s, plan_placement, replicas_needed)
from .router import (BatcherBackend, Deployment, Gateway, GatewayResult,
                     Predictor, ServeResult, TrafficSpec)

__all__ = [
    "Autoscaler", "AutoscalerConfig",
    "Assignment", "CloudCapacity", "ModelDemand", "PlacementPlan",
    "est_p99_s", "plan_placement", "replicas_needed",
    "BatcherBackend", "Deployment", "Gateway", "GatewayResult",
    "Predictor", "ServeResult", "TrafficSpec",
]
