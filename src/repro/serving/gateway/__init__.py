"""Model-mesh serving gateway: multi-model routing with SLO classes,
preemption, active-active multi-cloud splits, queue-aware weighted-JSQ
routing + per-class admission control (load shedding) and live migration
(router.py), cost-aware scale-to-zero autoscaling (autoscaler.py),
split-aware multi-cloud placement + expected-queue hints + observed-load
re-planning + plan diffs (placement.py).  See DESIGN.md §Gateway."""
from .autoscaler import Autoscaler, AutoscalerConfig, PoolView
from .placement import (Assignment, CloudCapacity, MigrationPlan,
                        MigrationStep, ModelDemand, PlacementPlan, diff_plans,
                        est_p99_s, est_wait_s, plan_placement, replan,
                        replicas_needed)
from .router import (SLO_CLASSES, AdmissionConfig, BatcherBackend, Deployment,
                     DisaggSpec, FailureSpec, Gateway, GatewayResult,
                     MigrationSpec, Predictor, ReplanConfig, RoutingConfig,
                     ServeResult, SLOClass, TrafficSpec, resolve_slo)

__all__ = [
    "Autoscaler", "AutoscalerConfig", "PoolView",
    "Assignment", "CloudCapacity", "MigrationPlan", "MigrationStep",
    "ModelDemand", "PlacementPlan", "diff_plans", "est_p99_s", "est_wait_s",
    "plan_placement", "replan", "replicas_needed",
    "AdmissionConfig", "BatcherBackend", "Deployment", "DisaggSpec",
    "FailureSpec", "Gateway", "GatewayResult", "MigrationSpec", "Predictor",
    "ReplanConfig", "RoutingConfig", "ServeResult", "SLOClass",
    "SLO_CLASSES", "TrafficSpec", "resolve_slo",
]
