"""Model-mesh serving gateway: multi-model routing with SLO classes,
preemption, active-active multi-cloud splits and live migration
(router.py), cost-aware scale-to-zero autoscaling (autoscaler.py),
split-aware multi-cloud placement + observed-load re-planning + plan
diffs (placement.py).  See DESIGN.md §Gateway."""
from .autoscaler import Autoscaler, AutoscalerConfig, PoolView
from .placement import (Assignment, CloudCapacity, MigrationPlan,
                        MigrationStep, ModelDemand, PlacementPlan, diff_plans,
                        est_p99_s, plan_placement, replan, replicas_needed)
from .router import (SLO_CLASSES, BatcherBackend, Deployment, FailureSpec,
                     Gateway, GatewayResult, MigrationSpec, Predictor,
                     ReplanConfig, ServeResult, SLOClass, TrafficSpec,
                     resolve_slo)

__all__ = [
    "Autoscaler", "AutoscalerConfig", "PoolView",
    "Assignment", "CloudCapacity", "MigrationPlan", "MigrationStep",
    "ModelDemand", "PlacementPlan", "diff_plans", "est_p99_s",
    "plan_placement", "replan", "replicas_needed",
    "BatcherBackend", "Deployment", "FailureSpec", "Gateway", "GatewayResult",
    "MigrationSpec", "Predictor", "ReplanConfig", "ServeResult", "SLOClass",
    "SLO_CLASSES", "TrafficSpec", "resolve_slo",
]
