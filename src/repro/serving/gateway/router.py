"""Model-mesh serving gateway: one router fronting MANY models.

The pre-gateway repo could stress-test a single InferenceService; this
package is the fleet layer (ROADMAP north star: "heavy traffic from
millions of users").  A Gateway owns per-model Deployments -- each a
backend (Predictor or BatcherBackend), a CloudProfile, a replica pool and
an Autoscaler -- and runs a mixed multi-model workload (per-model burst /
Poisson TrafficSpecs) through ONE discrete-event simulation with shared
per-cloud replica capacity.

The simulation contract is the repo-wide hardware gate (DESIGN.md):
compute service times are MEASURED on this host (jitted predict per pow2
batch bucket, or real decode steps for the LLM backend); network RTT /
load-balancer / model-load constants are SIMULATED from the CloudProfile.
InferenceService (serving/kserve.py) is now a single-model client of this
router, so the paper's Table-3 stress test and the fleet simulation share
one event loop.

Event kinds: "arr" request arrival, "up" replica joins the pool after the
control-plane delay, "free" replica finishes a batch, "idle" idle-window
expiry check (scale-down / scale-to-zero, autoscaler.py).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import time
from typing import Any, Callable, Optional

import numpy as np

from ...clouds.profiles import CloudProfile
from ...telemetry.events import EventLog
from .autoscaler import Autoscaler, AutoscalerConfig


# -- results / backends (moved from kserve.py; it re-exports them) ----------

@dataclasses.dataclass
class ServeResult:
    strategy: str
    n_requests: int
    total_time_s: float
    latencies_s: list
    replica_trace: list = dataclasses.field(default_factory=list)
    per_version: dict = dataclasses.field(default_factory=dict)

    @property
    def p50(self):
        return float(np.percentile(self.latencies_s, 50))

    @property
    def p99(self):
        return float(np.percentile(self.latencies_s, 99))

    def summary(self) -> dict:
        return {"strategy": self.strategy, "n": self.n_requests,
                "total_s": round(self.total_time_s, 4),
                "p50_s": round(self.p50, 4), "p99_s": round(self.p99, 4),
                "replicas_max": max([r for _, r in self.replica_trace], default=1),
                **({"per_version": self.per_version} if self.per_version else {})}


class Predictor:
    """A deployable model version: jitted predict over a batch of inputs."""

    def __init__(self, name: str, predict_fn: Callable, example_input: Any):
        self.name = name
        self.predict_fn = predict_fn
        self.example_input = example_input
        self._lat_cache: dict[int, float] = {}

    def _batch_of(self, b: int):
        x = self.example_input
        reps = [b] + [1] * (np.ndim(x) - 1)
        return np.tile(x[:1], reps)

    def warmup(self, batch_sizes=(1,)):
        for b in batch_sizes:
            self.service_time(b)

    def service_time(self, b: int) -> float:
        """Measured wall latency of a predict on this host, at b rounded up
        to its pow2 bucket (jit retrace control lives HERE, not in the
        router: analytic backends like BatcherBackend price exact b)."""
        b = _pow2(b)
        if b not in self._lat_cache:
            x = self._batch_of(b)
            out = self.predict_fn(x)
            jax_block(out)                       # compile
            t0 = time.perf_counter()
            for _ in range(3):
                jax_block(self.predict_fn(x))
            self._lat_cache[b] = (time.perf_counter() - t0) / 3
        return self._lat_cache[b]

    def predict(self, x):
        return self.predict_fn(x)


class BatcherBackend:
    """Adapt a ContinuousBatcher (serving/continuous.py) as a router backend.

    An LLM's unit of work is decode steps, not one jitted call: a request
    costs ``prompt_len + gen_tokens`` steps (teacher-forced catch-up, then
    generation), and b concurrent requests run in ``ceil(b / max_slots)``
    slot waves.  Per-step wall time is measured once by draining a real
    request through the batcher (after a jit warmup drain), keeping the
    compute term hardware-true like Predictor.service_time.
    """

    def __init__(self, name: str, batcher, *, prompt_len: int = 8,
                 gen_tokens: int = 8):
        self.name = name
        self.batcher = batcher
        self.prompt_len = prompt_len
        self.gen_tokens = gen_tokens
        self._step_time: Optional[float] = None

    def _measure(self) -> float:
        prompt = [1 + (i % 97) for i in range(self.prompt_len)]
        self.batcher.submit(prompt, self.gen_tokens)
        self.batcher.run()                       # warmup: jit compile
        steps0 = self.batcher.step_count
        self.batcher.submit(prompt, self.gen_tokens)
        t0 = time.perf_counter()
        self.batcher.run()
        dt = time.perf_counter() - t0
        return dt / max(self.batcher.step_count - steps0, 1)

    def service_time(self, b: int) -> float:
        if self._step_time is None:
            self._step_time = self._measure()
        waves = math.ceil(b / self.batcher.max_slots)
        return waves * (self.prompt_len + self.gen_tokens) * self._step_time

    def generate(self, prompts: list, max_new: int) -> list:
        """Real generation passthrough (not simulated)."""
        reqs = [self.batcher.submit(list(p), max_new) for p in prompts]
        self.batcher.run()
        return [r.output for r in reqs]


def jax_block(x):
    try:
        import jax
        jax.block_until_ready(x)
    except Exception:
        pass


def _pow2(b: int) -> int:
    """Measure service times on pow2 batch buckets (jit retrace control)."""
    n = 1
    while n < b:
        n *= 2
    return n


# -- workload / deployment ---------------------------------------------------

@dataclasses.dataclass
class TrafficSpec:
    """One arrival stream for one model.  Several specs may target the same
    model (e.g. two bursts separated by more than the idle window to force
    a scale-to-zero -> cold-start cycle)."""
    model: str
    n: int
    arrival: str = "burst"               # "burst" | "poisson"
    rate: float = 0.0                    # poisson req/s
    start_s: float = 0.0
    arrivals: Optional[Any] = None       # explicit times override generation

    def gen(self, rng) -> np.ndarray:
        if self.arrivals is not None:
            return np.asarray(self.arrivals, float)
        if self.arrival == "burst":
            return np.full(self.n, float(self.start_s))
        if self.arrival == "poisson":
            gaps = rng.exponential(1.0 / max(self.rate, 1e-9), self.n)
            return self.start_s + np.cumsum(gaps)
        raise ValueError(f"unknown arrival kind {self.arrival!r}")


@dataclasses.dataclass
class Deployment:
    name: str
    backend: Any                         # .name + .service_time(b) -> s
    profile: CloudProfile
    autoscaler: Autoscaler
    max_batch: int = 32
    canary: Any = None
    canary_fraction: float = 0.0

    @property
    def backends(self) -> list:
        return [self.backend] + ([self.canary] if self.canary is not None
                                 else [])


@dataclasses.dataclass
class _Replica:
    rid: int
    warm: bool                           # cold replicas pay model_load_s once
    busy: bool = False
    last_active: float = 0.0


class _ModelState:
    def __init__(self, dep: Deployment, arr: np.ndarray, ver: np.ndarray):
        self.dep = dep
        self.arr = arr
        self.ver = ver
        self.lat = np.full(len(arr), -1.0)
        self.pending: dict[int, list] = {v: [] for v in range(len(dep.backends))}
        self.replicas: dict[int, _Replica] = {}
        self.scheduled_up = 0
        self.next_rid = 0
        self.trace: list = []
        self.cold_starts = 0
        self.per_version: dict[str, int] = {}
        self.served = 0

    @property
    def pool(self) -> int:
        return len(self.replicas) + self.scheduled_up

    def queue_len(self) -> int:
        return sum(len(q) for q in self.pending.values())


@dataclasses.dataclass
class GatewayResult:
    per_model: dict                      # name -> ServeResult
    cold_starts: dict                    # name -> int
    makespan_s: float

    def summary(self) -> dict:
        return {"makespan_s": round(self.makespan_s, 4),
                "cold_starts": dict(self.cold_starts),
                "models": {m: r.summary() for m, r in self.per_model.items()}}


# -- the router --------------------------------------------------------------

class Gateway:
    """Routes a mixed multi-model workload to per-model replica pools.

    capacity: optional {cloud_name: max_total_replicas} shared across every
    deployment placed on that cloud -- the knob the placement planner
    (placement.py) sizes against.  The cap bounds ELASTIC scale-up
    (over-budget requests are denied and logged gateway:scale_denied);
    run() rejects a configuration whose baseline min_replicas pools
    already exceed it, and a scale-from-zero launch that would otherwise
    starve forever proceeds over budget with a gateway:capacity_exceeded
    event (the K8s analog: the pod pends, then preempts -- we choose
    serve-and-log so the simulation always completes).
    """

    def __init__(self, *, capacity: Optional[dict] = None,
                 log: Optional[EventLog] = None):
        self.deployments: dict[str, Deployment] = {}
        self.capacity = dict(capacity or {})
        self.log = log or EventLog()

    def deploy(self, name: str, backend, profile: CloudProfile, *,
               autoscaler=None, max_batch: int = 32,
               canary=None, canary_fraction: float = 0.0) -> Deployment:
        if isinstance(autoscaler, AutoscalerConfig):
            autoscaler = Autoscaler(autoscaler)
        dep = Deployment(name, backend, profile, autoscaler or Autoscaler(),
                         max_batch, canary, canary_fraction)
        self.deployments[name] = dep
        return dep

    # -- discrete-event loop ------------------------------------------------
    def run(self, traffic: list, seed: int = 0) -> GatewayResult:
        rng = np.random.default_rng(seed)
        by_model: dict[str, list] = {}
        for spec in traffic:
            if spec.model not in self.deployments:
                raise KeyError(f"no deployment named {spec.model!r}")
            by_model.setdefault(spec.model, []).append(spec)

        base: dict[str, int] = {}        # cloud -> baseline min_replicas,
        for dep in self.deployments.values():   # over EVERY deployment: an
            base[dep.profile.name] = (base.get(dep.profile.name, 0)  # idle
                                      + dep.autoscaler.cfg.min_replicas)
        for cloud, n in base.items():    # pool still holds cloud capacity
            cap = self.capacity.get(cloud)
            if cap is not None and n > cap:
                raise ValueError(
                    f"min_replicas on {cloud!r} total {n} > capacity {cap}")

        events: list = []                # (t, seq, kind, model, payload)
        seq = itertools.count()
        st: dict[str, _ModelState] = {}
        for m, dep in self.deployments.items():
            specs = by_model.get(m, [])
            arr = (np.sort(np.concatenate([s.gen(rng) for s in specs]))
                   if specs else np.zeros(0))
            ver = np.zeros(len(arr), int)
            if dep.canary is not None and dep.canary_fraction > 0:
                ver = (rng.random(len(arr)) < dep.canary_fraction).astype(int)
            s = st[m] = _ModelState(dep, arr, ver)
            for _ in range(dep.autoscaler.cfg.min_replicas):
                s.replicas[s.next_rid] = _Replica(s.next_rid, warm=True)
                s.next_rid += 1
            s.trace.append((0.0, len(s.replicas)))
            for i, t in enumerate(arr):
                heapq.heappush(events, (float(t), next(seq), "arr", m, i))

        with self.log.stage("gateway:run", models=sorted(by_model),
                            n=int(sum(len(x.arr) for x in st.values()))):
            while events:
                t = events[0][0]
                touched, idle_checks = set(), []
                # apply every state change at time t before dispatching so a
                # burst admits as full batches (pre-gateway sim semantics);
                # idle expiries run last so a coincident arrival wins the
                # replica instead of forcing a retire + cold start
                while events and events[0][0] == t:
                    _, _, kind, m, data = heapq.heappop(events)
                    s = st[m]
                    if kind == "arr":
                        s.pending[int(s.ver[data])].append(data)
                        touched.add(m)
                    elif kind == "up":
                        s.scheduled_up -= 1
                        warm = not s.dep.autoscaler.cfg.cold_scale_up
                        s.replicas[s.next_rid] = _Replica(
                            s.next_rid, warm=warm, last_active=t)
                        if s.dep.autoscaler.tracks_idle:
                            # a replica that joins after the queue drained
                            # would otherwise never get an idle check
                            heapq.heappush(events, (
                                t + s.dep.autoscaler.cfg.idle_window_s,
                                next(seq), "idle", m, (s.next_rid, t)))
                        s.next_rid += 1
                        touched.add(m)
                    elif kind == "free":
                        r = s.replicas.get(data)
                        if r is not None:
                            r.busy = False
                            r.last_active = t
                            if s.dep.autoscaler.tracks_idle:
                                heapq.heappush(events, (
                                    t + s.dep.autoscaler.cfg.idle_window_s,
                                    next(seq), "idle", m, (data, t)))
                            touched.add(m)
                    else:                # "idle"
                        idle_checks.append((m, data))
                for m in touched:
                    self._dispatch(st[m], t, events, seq)
                    self._autoscale(st[m], t, events, seq, st)
                for m, payload in idle_checks:
                    self._maybe_retire(st[m], t, payload)

        results, cold, makespan = {}, {}, 0.0
        for m, s in st.items():
            if not len(s.arr):           # deployed but untrafficked: holds
                continue                 # capacity, reports no results
            if s.served < len(s.arr):
                raise RuntimeError(
                    f"gateway stalled: {m} served {s.served}/{len(s.arr)}")
            total = max((float(s.arr[i] + s.lat[i]) for i in range(len(s.arr))),
                        default=0.0)
            makespan = max(makespan, total)
            results[m] = ServeResult(f"gateway:{m}", len(s.arr), total,
                                     s.lat.tolist(), s.trace,
                                     per_version=s.per_version)
            cold[m] = s.cold_starts
        return GatewayResult(results, cold, makespan)

    def _dispatch(self, s: _ModelState, t: float, events, seq) -> None:
        dep = s.dep
        while True:
            idle = [r for r in s.replicas.values() if not r.busy]
            if not idle:
                return
            v = max(s.pending, key=lambda k: len(s.pending[k]))
            take = s.pending[v][:dep.max_batch]
            if not take:
                return
            s.pending[v] = s.pending[v][len(take):]
            r = min(idle, key=lambda x: x.rid)
            cold = 0.0
            if not r.warm:
                cold = dep.profile.model_load_s
                r.warm = True
                s.cold_starts += 1
                self.log.record("gateway:cold_start", cold, model=dep.name,
                                t_sim=round(t, 6))
            backend = dep.backends[v]
            b = len(take)
            done = (t + dep.profile.network_rtt_s + dep.profile.lb_overhead_s
                    + cold + backend.service_time(b))
            for i in take:
                s.lat[i] = done - s.arr[i]
            s.served += b
            s.per_version[backend.name] = s.per_version.get(backend.name, 0) + b
            r.busy = True
            r.last_active = done
            heapq.heappush(events, (done, next(seq), "free", dep.name, r.rid))

    def _autoscale(self, s: _ModelState, t: float, events, seq, st) -> None:
        q = s.queue_len()
        if q > 0 and s.pool == 0:        # scale from zero: spin up one
            self._launch(s, t, events, seq, st, from_zero=True)
            return
        # at most ONE launch per evaluation (KPA rate-limits scale-up; also
        # the pre-gateway sim's cadence of one replica per batch completion,
        # which the legacy InferenceService path depends on)
        if s.dep.autoscaler.scale_up_needed(q, s.pool):
            self._launch(s, t, events, seq, st)

    def _cloud_usage(self, st, cloud: str) -> int:
        return sum(x.pool for x in st.values()
                   if x.dep.profile.name == cloud)

    def _launch(self, s: _ModelState, t: float, events, seq, st, *,
                from_zero: bool = False) -> bool:
        cloud = s.dep.profile.name
        cap = self.capacity.get(cloud)
        if cap is not None and self._cloud_usage(st, cloud) >= cap:
            if not from_zero:
                self.log.record("gateway:scale_denied", 0.0, model=s.dep.name,
                                cloud=cloud, t_sim=round(t, 6))
                return False
            # a deployment at pool 0 would starve forever if every other
            # pool on this cloud is warm-pinned: serve over budget, loudly
            self.log.record("gateway:capacity_exceeded", 0.0,
                            model=s.dep.name, cloud=cloud, t_sim=round(t, 6))
        delay = s.dep.autoscaler.cfg.scale_up_delay_s
        s.scheduled_up += 1
        s.trace.append((t, s.pool))
        heapq.heappush(events, (t + delay, next(seq), "up", s.dep.name, None))
        self.log.record("gateway:scale_up", delay, model=s.dep.name,
                        t_sim=round(t, 6), pool=s.pool, from_zero=from_zero)
        return True

    def _maybe_retire(self, s: _ModelState, t: float, payload) -> None:
        rid, stamp = payload
        r = s.replicas.get(rid)
        if r is None or r.busy or r.last_active > stamp:
            return                       # reused since the check was scheduled
        if not s.dep.autoscaler.can_remove(s.pool):
            return
        del s.replicas[rid]
        s.trace.append((t, s.pool))
        self.log.record("gateway:scale_down", 0.0, model=s.dep.name,
                        t_sim=round(t, 6), pool=s.pool)
        if s.pool == 0:
            self.log.record("gateway:scale_to_zero", 0.0, model=s.dep.name,
                            t_sim=round(t, 6))
