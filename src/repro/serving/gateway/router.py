"""Model-mesh serving gateway: one router fronting MANY models, each of
which may be ACTIVE-ACTIVE across several clouds at once.

The pre-gateway repo could stress-test a single InferenceService; this
package is the fleet layer (ROADMAP north star: "heavy traffic from
millions of users").  A Gateway owns per-model Deployments -- each a
backend (Predictor or BatcherBackend), one replica pool PER CLOUD
(``{cloud: _Pool}``), a weighted traffic split over those pools, and an
Autoscaler -- and runs a mixed multi-model workload (per-model burst /
Poisson TrafficSpecs) through ONE discrete-event simulation with shared
per-cloud replica capacity.

The simulation contract is the repo-wide hardware gate (DESIGN.md):
compute service times are MEASURED on this host (jitted predict per pow2
batch bucket, or real decode steps for the LLM backend); network RTT /
load-balancer / model-load constants are SIMULATED from the CloudProfile.
InferenceService (serving/kserve.py) is now a single-model client of this
router, so the paper's Table-3 stress test and the fleet simulation share
one event loop.

Splits (DESIGN.md S3): a Deployment carries per-cloud traffic weights
(``deploy(split={profile: weight})``; a plain ``profile`` is the
degenerate one-entry split).  Each arrival is routed to a pool by a
seeded uniform draw against the LIVE weights, each pool keeps its own
queues / replicas / epochs, and every latency is charged with that
pool's cloud constants.  Weights move mid-run three ways, all through
one primitive (``_set_weights``, drain-and-shift, exactly-once):

- ``gw.run(migrations=[MigrationSpec(at_s, plan)])`` applies a
  placement.MigrationPlan live (``gateway:migrate reason=plan``);
- a ReplanConfig on the Gateway probes the fleet periodically and shifts
  weight off a pool that is overloaded-but-blocked (or missing
  deadlines) toward the CHEAPEST cloud with headroom, and consolidates
  an idle fleet off its most expensive cloud (``gateway:migrate`` with
  reason overload / miss_rate / cost);
- a FailureSpec outage is a degenerate split: the dead cloud's weight
  drops to 0 (``gateway:failover``), survivors -- or the zero-weight
  standby pool -- absorb the traffic, and recovery restores the nominal
  weights (``gateway:recover``).  There is no separate failover code
  path.

Queue-aware routing (DESIGN.md S3): with the default
``RoutingConfig(policy="queue_aware")`` the split weights only set the
BIAS.  Each arrival scores every live pool with an expected-completion
estimate (queue depth x amortized service estimate + RTT/LB + cold-start
risk + scale-from-zero delay), keeps the pools within a slack band of the
best score, and resolves the request's pre-drawn uniform against the
declared weights of that band -- weighted join-shortest-expected-queue.  A
pool drowning in backlog falls out of the band and stops receiving
traffic until it drains; with balanced queues the band holds every live
pool and routing degenerates to the pure weighted draw
(``policy="weights"``, the pre-ISSUE-4 behavior, kept for A/B racing).

Admission control (Gateway(admission=AdmissionConfig(...)), off by
default): at enqueue -- and again at dispatch -- a request whose expected
completion already exceeds ``margin x`` its class deadline (measured
against the SERVING pool's own warm path, not the primary's) is SHED
exactly once: dropped with a ``gateway:shed`` event, counted per class in
ServeResult/GatewayResult, excluded from latency percentiles.  Classes
with ``sheddable=False`` (batch) or an infinite deadline are never shed,
only deferred.  Shedding is an overload signal, not a mask: each shed
adds pool shed-pressure that the autoscaler reads as queue depth (so a
shedding pool scales up / from zero) and ReplanConfig probes treat a
window shed-rate breach like a deadline-miss breach (weight shifts away,
``gateway:migrate reason=shed_rate``).

SLO layer (DESIGN.md S3): every request carries an SLOClass
(latency / standard / batch).  Dispatch serves the queue maximizing
``weight * age-of-oldest`` instead of longest-queue; a ``latency`` batch
may preempt an in-flight ``batch`` batch (the victim re-queues,
gateway:preempt).

Event kinds: "arr" request arrival, "up" replica joins a pool after the
control-plane delay, "free" replica finishes a batch, "idle" idle-window
expiry check (scale-down / scale-to-zero, autoscaler.py), "fail"/"recover"
FailureSpec window edges, "replan" a MigrationSpec firing, "probe" an
auto-replan check.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Optional

import numpy as np

from ...clouds.profiles import CloudProfile, get_profile
from ...sim.engine import EventHeap, IndexQueue, Ledger
from ...telemetry.drift import DriftConfig, DriftMonitor
from ...telemetry.events import EventLog
from ...telemetry.metrics import MetricsRegistry
from ...telemetry.slo import BurnRateConfig, BurnRateMonitor
from ...telemetry.trace import Tracer
from .autoscaler import Autoscaler, AutoscalerConfig, PoolView
from .placement import MigrationStep


# -- SLO classes -------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SLOClass:
    """A traffic priority class.

    weight scales queue age in dispatch scoring (higher = served sooner);
    deadline_mult sets the per-request deadline as a multiple of the
    deployment's warm single-request path (rtt + lb + service_time(1)), so
    the same class means the same *relative* promise on any backend.
    ``preempts`` classes may evict an in-flight ``preemptible`` batch when
    no replica is idle.  ``sheddable=False`` work is never dropped by
    admission control, only deferred (batch: finishing late beats never).
    """
    name: str
    weight: float
    deadline_mult: float
    preempts: bool = False
    preemptible: bool = False
    sheddable: bool = True


SLO_CLASSES = {
    "latency": SLOClass("latency", weight=8.0, deadline_mult=4.0,
                        preempts=True),
    "standard": SLOClass("standard", weight=1.0, deadline_mult=20.0),
    "batch": SLOClass("batch", weight=0.25, deadline_mult=math.inf,
                      preemptible=True, sheddable=False),
}


def resolve_slo(slo) -> SLOClass:
    if isinstance(slo, SLOClass):
        return slo
    try:
        return SLO_CLASSES[slo]
    except KeyError:
        raise ValueError(f"unknown SLO class {slo!r}; "
                         f"known: {sorted(SLO_CLASSES)}") from None


@dataclasses.dataclass(frozen=True)
class RoutingConfig:
    """How `_route` picks a pool within a live split (Gateway(routing=...)).

    policy="queue_aware" (default): weighted join-shortest-expected-queue.
    Every live pool is scored with the expected-completion estimate
    (`Gateway._expected_wait`: queue depth x amortized service estimate +
    RTT/LB + cold-start risk); pools scoring within ``slack`` (relative)
    of the best stay in the candidate band, and the request's pre-drawn
    uniform resolves a weighted draw over the band -- so balanced pools
    split by the declared weights, while a backlogged or cold pool falls
    out of the band and gets no new traffic until it recovers.  Fully
    deterministic under the run seed.

    policy="weights": the pre-ISSUE-4 pure weighted draw, kept for A/B
    comparison (bench_gateway races the two) and share-exact tests.
    """
    policy: str = "queue_aware"
    slack: float = 0.25

    def __post_init__(self):
        if self.policy not in ("queue_aware", "weights"):
            raise ValueError(f"unknown routing policy {self.policy!r}")
        if self.slack < 0:
            raise ValueError("slack must be >= 0")


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Per-class admission control (Gateway(admission=...), off when None).

    A request is shed -- exactly once, `gateway:shed` -- when its expected
    completion already exceeds ``margin x`` its class deadline, measured
    against the SERVING pool's own warm path (rtt + lb + service_time(1)):
    at enqueue via the routing estimate, and (``recheck_at_dispatch``)
    again when its queue reaches a replica, using the then-known best-case
    completion.  ``sheddable=False`` classes and infinite deadlines are
    exempt: deferred, never dropped.
    """
    margin: float = 1.0
    recheck_at_dispatch: bool = True

    def __post_init__(self):
        if self.margin <= 0:
            raise ValueError("margin must be > 0")


@dataclasses.dataclass(frozen=True)
class FailureSpec:
    """A simulated cloud outage: ``cloud`` is down over
    [at_s, at_s + duration_s).  Injected via Gateway.run(failures=[...])."""
    cloud: str
    at_s: float
    duration_s: float

    def __post_init__(self):
        if self.at_s < 0 or self.duration_s <= 0:
            raise ValueError("FailureSpec needs at_s >= 0 and duration_s > 0")


@dataclasses.dataclass(frozen=True)
class MigrationSpec:
    """Apply a placement.MigrationPlan (or a raw ``{model: {cloud:
    weight}}`` dict) at simulated time ``at_s``, mid-run, without dropping
    requests.  Injected via Gateway.run(migrations=[...])."""
    at_s: float
    plan: Any

    def __post_init__(self):
        if self.at_s < 0:
            raise ValueError("MigrationSpec needs at_s >= 0")


@dataclasses.dataclass(frozen=True)
class ReplanConfig:
    """Continuous re-planning knobs (Gateway(replan=...)).  Every
    ``check_every_s`` of simulated time the router probes each model:

    - a pool whose queue (plus its shed-pressure: requests admission
      control dropped since the last probe/launch) exceeds
      ``overload_factor * target_queue * replicas`` while its cloud can no
      longer grow, or a model whose recent deadline-miss rate breaches
      ``max_miss_rate`` (over at least ``min_window_n`` completions), or
      whose window shed rate breaches ``max_shed_rate`` (shedding is an
      overload signal, never a mask), sustained for ``sustain``
      consecutive probes, shifts ``shift`` of the hottest pool's weight
      toward the cheapest cloud with headroom (gateway:migrate);
    - with ``consolidate``, a fully idle multi-cloud split sustained for
      ``sustain`` probes folds its most expensive pool into the cheapest
      one (weight -> 0, so the expensive replicas idle out first).
    """
    check_every_s: float = 0.25
    overload_factor: float = 2.0
    max_miss_rate: float = 0.5
    max_shed_rate: float = 0.1
    min_window_n: int = 8
    shift: float = 0.5
    sustain: int = 2
    consolidate: bool = True

    def __post_init__(self):
        if self.check_every_s <= 0:
            raise ValueError("check_every_s must be > 0")
        if not 0 < self.max_shed_rate <= 1:
            raise ValueError("max_shed_rate must be in (0, 1]")
        if not 0 < self.shift <= 1:
            raise ValueError("shift must be in (0, 1]")
        if self.sustain < 1:
            raise ValueError("sustain must be >= 1")
        if self.min_window_n < 1:     # also guards the miss-rate division
            raise ValueError("min_window_n must be >= 1")


# -- results / backends (moved from kserve.py; it re-exports them) ----------

def _class_stats(lats: list, misses: int, shed: int = 0) -> dict:
    """Per-class stats: percentiles/miss over SERVED requests only; shed
    requests are reported separately (shed_rate is shed / offered)."""
    n = len(lats)
    return {"n": n,
            "p50_s": round(float(np.percentile(lats, 50)), 6) if n else None,
            "p99_s": round(float(np.percentile(lats, 99)), 6) if n else None,
            "miss_rate": round(misses / n, 4) if n else 0.0,
            "shed": shed,
            "shed_rate": round(shed / (n + shed), 4) if n + shed else 0.0}


@dataclasses.dataclass
class ServeResult:
    strategy: str
    n_requests: int                      # OFFERED requests (served + shed)
    total_time_s: float
    latencies_s: list                    # served requests only (shed excluded)
    replica_trace: list = dataclasses.field(default_factory=list)
    per_version: dict = dataclasses.field(default_factory=dict)
    class_latencies: dict = dataclasses.field(default_factory=dict)
    class_misses: dict = dataclasses.field(default_factory=dict)
    class_shed: dict = dataclasses.field(default_factory=dict)
    observed: dict = dataclasses.field(default_factory=dict)
    # SIMULATED dollars (CloudProfile.cost_per_s price sheet, DESIGN.md S1):
    # replica-seconds provisioned x per-cloud price, never a measurement
    cost_usd: float = 0.0
    cost_by_cloud: dict = dataclasses.field(default_factory=dict)

    # percentiles are None -- not 0.0 -- when NO request was served (empty
    # class / shed-everything pools): 0.0 read as "perfect latency" and was
    # indistinguishable from an actually-instant pool.  _class_stats and
    # the bench schema carry the same convention.
    @property
    def p50(self) -> Optional[float]:
        return float(np.percentile(self.latencies_s, 50)) \
            if self.latencies_s else None

    @property
    def p99(self) -> Optional[float]:
        return float(np.percentile(self.latencies_s, 99)) \
            if self.latencies_s else None

    @property
    def shed_total(self) -> int:
        return int(sum(self.class_shed.values()))

    @property
    def shed_rate(self) -> float:
        """Shed / offered; 0.0 with admission control off."""
        return self.shed_total / self.n_requests if self.n_requests else 0.0

    def per_class(self) -> dict:
        """Per-SLO-class p50/p99, deadline-miss rate (SERVED requests,
        against the PRIMARY cloud's warm path -- the deployment-level
        promise, see DESIGN.md S3) and shed count/rate."""
        names = sorted(set(self.class_latencies) | set(self.class_shed))
        return {c: _class_stats(self.class_latencies.get(c, []),
                                self.class_misses.get(c, 0),
                                self.class_shed.get(c, 0))
                for c in names}

    def summary(self) -> dict:
        p50, p99 = self.p50, self.p99
        return {"strategy": self.strategy, "n": self.n_requests,
                "total_s": round(self.total_time_s, 4),
                "p50_s": round(p50, 4) if p50 is not None else None,
                "p99_s": round(p99, 4) if p99 is not None else None,
                "replicas_max": max([r for _, r in self.replica_trace], default=1),
                **({"shed": self.shed_total,
                    "shed_rate": round(self.shed_rate, 4)}
                   if self.shed_total else {}),
                **({"sim_cost_usd": round(self.cost_usd, 6)}
                   if self.cost_by_cloud else {}),
                **({"per_version": self.per_version} if self.per_version else {}),
                **({"per_class": self.per_class()}
                   if self.class_latencies or self.class_shed else {})}


class Predictor:
    """A deployable model version: jitted predict over a batch of inputs."""

    def __init__(self, name: str, predict_fn: Callable, example_input: Any):
        self.name = name
        self.predict_fn = predict_fn
        self.example_input = example_input
        self._lat_cache: dict[int, float] = {}

    def _batch_of(self, b: int):
        x = self.example_input
        reps = [b] + [1] * (np.ndim(x) - 1)
        return np.tile(x[:1], reps)

    def warmup(self, batch_sizes=(1,)):
        for b in batch_sizes:
            self.service_time(b)

    def service_time(self, b: int) -> float:
        """Measured wall latency of a predict on this host, at b rounded up
        to its pow2 bucket (jit retrace control lives HERE, not in the
        router: analytic backends like BatcherBackend price exact b)."""
        b = _pow2(b)
        if b not in self._lat_cache:
            x = self._batch_of(b)
            out = self.predict_fn(x)
            jax_block(out)                       # compile
            t0 = time.perf_counter()
            for _ in range(3):
                jax_block(self.predict_fn(x))
            self._lat_cache[b] = (time.perf_counter() - t0) / 3
        return self._lat_cache[b]

    def predict(self, x):
        return self.predict_fn(x)


class BatcherBackend:
    """Adapt a ContinuousBatcher (serving/continuous.py) as a router backend.

    An LLM's unit of work is decode steps, not one jitted call: a request
    costs ``prompt_len + gen_tokens`` steps (teacher-forced catch-up, then
    generation), and b concurrent requests run in ``ceil(b / max_slots)``
    slot waves.  Per-step wall time is measured once by draining a real
    request through the batcher (after a jit warmup drain), keeping the
    compute term hardware-true like Predictor.service_time.

    With a disaggregated batcher (``prefill_chunk > 0``) the blended
    per-step estimate splits into two MEASURED cost models (ISSUE 8):
    ``prefill_time(P)`` -- ceil(P / chunk) prefill-kernel calls at the
    measured per-chunk latency (prompt ingest is serial per request, it
    runs on a one-row cache at admission) -- and ``decode_time(steps)`` --
    per-step decode latency times steps, shared by every occupied slot.
    The two phases are separated by timing two workload points (a
    prompt_len-token prompt and a 1-token prompt, both generating
    gen_tokens) and solving the resulting 2x2 system in (chunk, step)
    counts read back from the batcher's own phase counters.
    """

    def __init__(self, name: str, batcher, *, prompt_len: int = 8,
                 gen_tokens: int = 8):
        self.name = name
        self.batcher = batcher
        self.prompt_len = prompt_len
        self.gen_tokens = gen_tokens
        self._step_time: Optional[float] = None
        self._chunk_time: Optional[float] = None

    @property
    def disaggregated(self) -> bool:
        return getattr(self.batcher, "prefill_chunk", 0) > 0

    def _timed_run(self, prompt: list) -> tuple:
        """One timed submit+drain; returns (wall_s, chunks, steps) deltas."""
        b = self.batcher
        c0 = b.prefill_stats["chunks"] if self.disaggregated else 0
        s0 = b.step_count
        b.submit(prompt, self.gen_tokens)
        t0 = time.perf_counter()
        b.run()
        dt = time.perf_counter() - t0
        c1 = b.prefill_stats["chunks"] if self.disaggregated else 0
        return dt, c1 - c0, b.step_count - s0

    def _measure(self) -> None:
        prompt = [1 + (i % 97) for i in range(self.prompt_len)]
        self.batcher.submit(prompt, self.gen_tokens)
        self.batcher.run()                       # warmup: jit compile
        if not self.disaggregated:
            dt, _, steps = self._timed_run(prompt)
            self._step_time = dt / max(steps, 1)
            self._chunk_time = self._step_time
            return
        self.batcher.submit([1], self.gen_tokens)
        self.batcher.run()                       # warm the short-chunk shape
        dt_a, ch_a, st_a = self._timed_run(prompt)
        dt_b, ch_b, st_b = self._timed_run([1])
        det = ch_a * st_b - ch_b * st_a
        if abs(det) > 1e-12:
            chunk = (dt_a * st_b - dt_b * st_a) / det
            step = (ch_a * dt_b - ch_b * dt_a) / det
        else:            # prompt fits one chunk: phases indistinguishable
            chunk = step = (dt_a + dt_b) / max(ch_a + st_a + ch_b + st_b, 1)
        self._chunk_time = max(chunk, 1e-9)
        self._step_time = max(step, 1e-9)

    def _ensure_measured(self) -> None:
        if self._step_time is None:
            self._measure()

    def prefill_time(self, prompt_tokens: Optional[int] = None) -> float:
        """Measured prompt-ingest cost for ONE request: ceil(P / chunk)
        prefill calls when disaggregated, P teacher-forced decode steps
        otherwise."""
        self._ensure_measured()
        p = self.prompt_len if prompt_tokens is None else int(prompt_tokens)
        if not self.disaggregated:
            return p * self._step_time
        chunk = max(self.batcher.prefill_chunk, 1)
        return math.ceil(p / chunk) * self._chunk_time

    def decode_time(self, steps: Optional[int] = None) -> float:
        """Measured generation cost: per-step decode latency x steps
        (every occupied slot advances together, so a wave shares this)."""
        self._ensure_measured()
        n = self.gen_tokens if steps is None else int(steps)
        return n * self._step_time

    def service_time(self, b: int) -> float:
        self._ensure_measured()
        waves = math.ceil(b / self.batcher.max_slots)
        if not self.disaggregated:
            return waves * (self.prompt_len + self.gen_tokens) \
                * self._step_time
        # prompt ingest is serial per request (one-row prefill cache at
        # admission); generation advances whole slot waves per step
        return (b * self.prefill_time(self.prompt_len)
                + waves * self.decode_time(self.gen_tokens))

    def generate(self, prompts: list, max_new: int) -> list:
        """Real generation passthrough (not simulated)."""
        reqs = [self.batcher.submit(list(p), max_new) for p in prompts]
        self.batcher.run()
        return [r.output for r in reqs]


def jax_block(x):
    try:
        import jax
        jax.block_until_ready(x)
    except Exception:
        pass


def _pow2(b: int) -> int:
    """Measure service times on pow2 batch buckets (jit retrace control)."""
    n = 1
    while n < b:
        n *= 2
    return n


# the self-rescheduling event kinds: once no work is left and only these
# remain, the periodic probe / scrape timers must stop re-arming --
# re-pushing while "events is non-empty" would let the two timers sustain
# EACH OTHER through an unbounded dead tail after the last request
# completes.  Pending "idle" checks deliberately keep the timers alive:
# they are one-shot (never re-pushed), so the tail is bounded by the idle
# window, and the probe must stay armed through it for post-traffic cost
# consolidation (idle split folds onto the cheap cloud, stragglers retire).
# The rule itself lives in the shared sim core (EventHeap.only_timers);
# this is the gateway's timer vocabulary.
_TIMER_KINDS = frozenset(("probe", "scrape"))

_INF = float("inf")


class _Step:
    """Mutable per-timestep scratch shared by both engines: which models
    saw state changes (dispatch/autoscale run once per touched model at
    the end of the step), deferred idle checks, and due timer flags."""

    __slots__ = ("touched", "idle_checks", "probe_due", "scrape_due")

    def __init__(self):
        self.touched: set = set()
        self.idle_checks: list = []
        self.probe_due = False
        self.scrape_due = False


def _apportion(total: int, weights: dict) -> dict:
    """Largest-remainder split of ``total`` replicas by weight (zero-weight
    pools get zero); deterministic tie-break by remainder, weight, name.

    Whenever ``total >= len(live)`` every live-weight pool is guaranteed at
    least one replica (ISSUE 4 bugfix: a 0.95/0.05 split at total=2 used to
    floor the 0.05 pool at ZERO replicas while routing still sent it
    traffic, parking those requests until the autoscaler noticed)."""
    live = {c: w for c, w in weights.items() if w > 0}
    out = {c: 0 for c in weights}
    if not live or total <= 0:
        return out
    s = sum(live.values())
    exact = {c: total * w / s for c, w in live.items()}
    for c in live:
        out[c] = int(math.floor(exact[c]))
    left = total - sum(out.values())
    order = sorted(live, key=lambda c: (-(exact[c] - out[c]), -live[c], c))
    for c in order[:left]:
        out[c] += 1
    if total >= len(live):               # min-1 floor for every live pool
        empty = sorted((c for c in live if out[c] == 0),
                       key=lambda c: (-live[c], c))
        for c in empty:
            donor = max((d for d in live if out[d] >= 2),
                        key=lambda d: (out[d], live[d], d))
            out[donor] -= 1
            out[c] += 1
    return out


# -- workload / deployment ---------------------------------------------------

@dataclasses.dataclass
class TrafficSpec:
    """One arrival stream for one model.  Several specs may target the same
    model (e.g. two bursts separated by more than the idle window to force
    a scale-to-zero -> cold-start cycle).  ``slo`` is an SLO_CLASSES key or
    a custom SLOClass instance applied to every request of this stream."""
    model: str
    n: int
    arrival: str = "burst"               # "burst" | "poisson"
    rate: float = 0.0                    # poisson req/s
    start_s: float = 0.0
    arrivals: Optional[Any] = None       # explicit times override generation
    slo: Any = "standard"                # str key or SLOClass

    def gen(self, rng) -> np.ndarray:
        if self.arrivals is not None:
            return np.asarray(self.arrivals, float)
        if self.arrival == "burst":
            return np.full(self.n, float(self.start_s))
        if self.arrival == "poisson":
            gaps = rng.exponential(1.0 / max(self.rate, 1e-9), self.n)
            return self.start_s + np.cumsum(gaps)
        raise ValueError(f"unknown arrival kind {self.arrival!r}")


@dataclasses.dataclass
class DisaggSpec:
    """Opt-in prefill/decode disaggregation for one deployment (ISSUE 8,
    DESIGN.md §7).  All gateway disagg machinery -- pool kinds, KV-block
    accounting, the cache-residency routing term, cache-exhaustion
    shedding -- is DORMANT unless a deployment carries one of these.

    ``kv_blocks`` budgets KV-cache blocks per pool (an int for every pool
    or {cloud: blocks}; 0 = unaccounted).  A request holds
    ``ceil((prompt_tokens + gen_tokens) / block_size)`` blocks from
    dispatch to completion of its phase.  ``pool_kind`` assigns each cloud
    "prefill" / "decode" / "both" (default "both" = unified pools).  When
    both a "prefill" and a "decode" pool exist the deployment runs STAGED:
    new arrivals route to prefill pools only, a finished prefill batch
    emits ``gateway:prefill`` and re-enqueues its requests on the best
    decode pool (the KV handoff), and request latency is charged at decode
    completion.  ``shed_margin`` scales the block budget admission sheds
    against (``gateway:cache_shed``)."""
    kv_blocks: Any = 0
    block_size: int = 16
    prompt_tokens: int = 64              # expected prompt length / request
    gen_tokens: int = 16                 # expected generated tokens / request
    pool_kind: dict = dataclasses.field(default_factory=dict)
    shed_margin: float = 1.0

    def __post_init__(self):
        if self.block_size <= 0:
            raise ValueError("block_size must be > 0")
        if self.prompt_tokens < 0 or self.gen_tokens <= 0:
            raise ValueError("prompt_tokens must be >= 0, gen_tokens > 0")
        if self.shed_margin <= 0:
            raise ValueError("shed_margin must be > 0")

    @property
    def blocks_per_request(self) -> int:
        return max(1, math.ceil((self.prompt_tokens + self.gen_tokens)
                                / self.block_size))

    def kind(self, cloud: str) -> str:
        return self.pool_kind.get(cloud, "both")

    def blocks_for(self, cloud: str) -> int:
        if isinstance(self.kv_blocks, dict):
            return int(self.kv_blocks.get(cloud, 0))
        return int(self.kv_blocks)


@dataclasses.dataclass
class Deployment:
    name: str
    backend: Any                         # .name + .service_time(b) -> s
    profile: CloudProfile                # primary cloud (deadline base)
    autoscaler: Autoscaler
    max_batch: int = 32
    canary: Any = None
    canary_fraction: float = 0.0
    standby: Optional[CloudProfile] = None   # zero-weight failover pool
    placements: list = dataclasses.field(default_factory=list)
    # [(CloudProfile, weight)]: the declared split, standby appended at 0
    queue_hint: dict = dataclasses.field(default_factory=dict)
    # {cloud: expected queueing wait s} planner prior (Assignment.est_wait_s)
    # used by queue-aware routing while a pool has no queue of its own yet
    trace_link: Optional[int] = None
    # span id of the pipeline deploy step that produced this deployment
    # (telemetry/trace.py): every request root span links to it, connecting
    # the serving trace to the training trace across their sim-time axes
    disagg: Optional[DisaggSpec] = None
    # prefill/decode disaggregation opt-in; None keeps every pre-ISSUE-8
    # code path bit-identical (the engine-equivalence suites rely on it)

    @property
    def backends(self) -> list:
        return [self.backend] + ([self.canary] if self.canary is not None
                                 else [])


@dataclasses.dataclass
class _Replica:
    rid: int
    warm: bool                           # cold replicas pay model_load_s once
    busy: bool = False
    last_active: float = 0.0
    created_s: float = 0.0               # provisioned-time start (cost sheet)
    epoch: int = 0                       # bumps per assignment/preemption;
    inflight: Optional[dict] = None      # stale "free" events check it


class _Pool:
    """One per-cloud replica pool of a deployment: its own queues, replicas,
    epochs and launch generation.  ``weight`` is the LIVE traffic share
    (failover zeroes it), ``nominal`` the configured share migrations edit
    and recovery restores, ``floor`` its apportioned slice of min_replicas.
    """

    def __init__(self, profile: CloudProfile, weight: float):
        self.profile = profile
        self.weight = float(weight)
        self.nominal = float(weight)
        self.floor = 0
        self.replicas: dict[int, _Replica] = {}
        self.pending: dict[tuple, IndexQueue] = {}   # FIFO request rows
        self.scheduled_up = 0
        self.generation = 0              # bumps on drain; stale "up" dropped
        self.replica_seconds = 0.0       # provisioned time (simulated $)
        self.shed_pressure = 0           # sheds since the last launch/probe:
        # unmet demand the autoscaler must see as queue depth, so shedding
        # triggers scale-up instead of masking the overload
        self.kind = "both"               # disagg stage(s) this pool serves
        self.handoff_load_s: Optional[float] = None  # warm-handoff cold
        # cost for relaunched replicas (market mode only; None keeps the
        # profile.model_load_s path bit-identical)
        self.handoff_n = 0               # relaunched replicas still owed it
        self.kv_total = 0                # KV block budget (0 = unaccounted)
        self.kv_used = 0                 # blocks held by in-flight batches
        self.kv_resident: dict = {}      # version -> blocks currently held
        self.kv_warm: set = set()        # versions whose cache rows are warm

    def size(self) -> int:
        return len(self.replicas) + self.scheduled_up

    def queue_len(self) -> int:
        return sum(len(q) for q in self.pending.values())


class _ModelState:
    def __init__(self, dep: Deployment, ledger: Ledger, classes: list):
        self.dep = dep
        self.ledger = ledger             # SoA request columns (sim core)
        # column aliases: the scalar engine addresses single rows, the
        # vectorized engine whole index ranges -- same memory either way
        self.arr = ledger.arr
        self.ver = ledger.ver
        self.cls_code = ledger.cls_code  # int code into ``classes``
        self.route_u = ledger.route_u    # uniform draw per request (routing)
        self.lat = ledger.lat
        self.shed = ledger.shed
        self.classes: list[SLOClass] = classes      # code order
        self.mult_by_code = np.array(
            [c.deadline_mult for c in classes]) if classes else np.zeros(0)
        self.slo_by_name: dict[str, SLOClass] = {c.name: c for c in classes}
        self.cursor = 0                  # vectorized engine: next arrival row
        self.combo_rows: Optional[dict] = None   # lazy: rows per ver x class
        self.pools: dict[str, _Pool] = {}
        for prof, w in dep.placements:
            self.pools[prof.name] = _Pool(prof, w)
        # -- disagg state (dormant unless dep.disagg is set) --
        self.staged = False              # prefill AND decode pools exist
        self.stage: Optional[np.ndarray] = None  # per-request phase (run())
        self.svc_prefill = 0.0           # per-request prompt-ingest estimate
        self.svc_decode = 0.0            # per-batch generation estimate
        self.kv_gauge_inst: dict = {}    # cloud -> cache-occupancy gauge
        if dep.disagg is not None:
            for c, pool in self.pools.items():
                pool.kind = dep.disagg.kind(c)
                pool.kv_total = dep.disagg.blocks_for(c)
            kinds = {p.kind for p in self.pools.values()}
            self.staged = "prefill" in kinds and "decode" in kinds
        self.next_rid = 0                # rids are model-global: the batch
        self.trace: list = []            # audit keys (model, rid) stay unique
        self.cold_starts = 0
        self.per_version: dict[str, int] = {}
        self.served = 0
        self.busy_s = 0.0                # realized backend service seconds
        self.deadline_base = 0.0         # warm single-request path, primary
        # per-request shed state (ledger.shed column): shed exactly once,
        # excluded from latency percentiles, counted per class
        self.class_shed: dict[str, int] = {}
        self.svc1 = 0.0                  # service_time(1), per-pool bases
        self.svc_est = 0.0               # amortized per-request service est.
        self.base_by_cloud: dict[str, float] = {}   # pool warm paths (lazy)
        self.win_n = 0                   # completions since the last probe
        self.win_miss = 0
        self.win_shed = 0                # sheds since the last probe
        self.win_epoch = 0               # bumps on probe reset: a reclaim
        self.streak = {"hot": 0, "cold": 0}   # only undoes its own window
        self.streak_why = "overload"     # what armed the hot streak
        # deferred-telemetry collector state (the sim analog of an async
        # span processor): with a Tracer attached the event loop only
        # appends per-BATCH records here (amortized ~nothing per request)
        # and the span tree is materialized in bulk after the loop; with a
        # MetricsRegistry attached, counters and latency sketches are
        # folded vectorized from the arrays below at each scrape.  All
        # None when untraced, so the bare hot path pays nothing.
        self.batch_recs: Optional[list] = None   # dispatch-order batch dicts
        self.shed_at: dict = {}          # idx -> (t, where, cloud)
        self.fold_pending: Optional[list] = None   # really-completed
        # batches awaiting the next metric fold: (idx, cls, miss threshold)
        self.fold_inst: dict = {}        # cname -> cached instruments
        self.gauge_inst: dict = {}       # cloud -> cached scrape gauges

    def slo(self, i: int) -> SLOClass:
        """The SLO class of request row ``i`` (ledger code -> class)."""
        return self.classes[self.cls_code[i]]

    def total_pool(self) -> int:
        return sum(p.size() for p in self.pools.values())

    def queue_len(self) -> int:
        return sum(p.queue_len() for p in self.pools.values())


@dataclasses.dataclass
class GatewayResult:
    per_model: dict                      # name -> ServeResult
    cold_starts: dict                    # name -> int
    makespan_s: float
    costs: dict = dataclasses.field(default_factory=dict)
    # model -> simulated $ for the run, INCLUDING untrafficked warm pools

    @property
    def total_cost_usd(self) -> float:
        """Simulated fleet dollars (price-sheet output, DESIGN.md S1)."""
        return float(sum(self.costs.values()))

    @property
    def shed_total(self) -> int:
        return sum(r.shed_total for r in self.per_model.values())

    def per_class(self) -> dict:
        """Fleet-wide per-SLO-class stats (latencies pooled across models,
        shed counts included)."""
        lats: dict[str, list] = {}
        miss: dict[str, int] = {}
        shed: dict[str, int] = {}
        for r in self.per_model.values():
            for c, ls in r.class_latencies.items():
                lats.setdefault(c, []).extend(ls)
                miss[c] = miss.get(c, 0) + r.class_misses.get(c, 0)
            for c, n in r.class_shed.items():
                shed[c] = shed.get(c, 0) + n
                lats.setdefault(c, [])
        return {c: _class_stats(ls, miss.get(c, 0), shed.get(c, 0))
                for c, ls in sorted(lats.items())}

    def summary(self) -> dict:
        out = {"makespan_s": round(self.makespan_s, 4),
               "cold_starts": dict(self.cold_starts),
               "models": {m: r.summary() for m, r in self.per_model.items()}}
        if self.costs:
            out["sim_cost_usd"] = round(self.total_cost_usd, 6)
        if self.shed_total:
            out["shed"] = self.shed_total
        pc = self.per_class()
        if pc:
            out["per_class"] = pc
        return out


# -- the router --------------------------------------------------------------

class Gateway:
    """Routes a mixed multi-model workload to per-model, per-cloud replica
    pools by split weight.

    capacity: optional {cloud_name: max_total_replicas} shared across every
    pool placed on that cloud -- the knob the placement planner
    (placement.py) sizes against.  The cap bounds ELASTIC scale-up
    (over-budget requests are denied and logged gateway:scale_denied);
    run() rejects a configuration whose baseline min_replicas floors
    already exceed it, and a scale-from-zero launch that would otherwise
    starve forever proceeds over budget with a gateway:capacity_exceeded
    event (the K8s analog: the pod pends, then preempts -- we choose
    serve-and-log so the simulation always completes).

    replan: optional ReplanConfig enabling continuous mid-run re-planning
    (periodic probes that shift split weights; see ReplanConfig).

    routing: RoutingConfig -- queue-aware weighted JSQ by default,
    policy="weights" for the pure pre-drawn weighted draw.

    admission: optional AdmissionConfig -- shed requests whose expected
    completion already exceeds their class deadline (None = admit all,
    the legacy behavior InferenceService relies on).

    tracer: optional telemetry.trace.Tracer -- every run opens a
    ``gateway.run`` root span and each request gets a ``gateway.request``
    span with ``gateway.queue`` / ``gateway.serve`` children crossing
    shed, preemption, failover and migration; request roots link to the
    deployment's ``trace_link`` (the pipeline deploy step span).

    metrics: optional telemetry.metrics.MetricsRegistry -- request /
    shed / miss counters, latency histograms (quantile sketches) and, with
    ``scrape_every_s``, periodic simulated-time scrape snapshots of queue
    depth / replicas / accrued cost gauges.

    slo_burn: optional telemetry.slo.BurnRateConfig -- a BurnRateMonitor
    (``self.burn``) watches per-(model, class) error-budget burn, emits
    ``gateway:alert`` events, arms replan probes (reason=slo_burn) and
    adds scale-up pressure via Autoscaler.effective_queue.

    drift: optional telemetry.drift.DriftConfig -- a DriftMonitor
    (``self.drift``) compares each scrape's observed per-request service
    time against the ModelProfile the deployment was planned from
    (``deploy(profile=...)``, threaded through from
    ``DeploySpec.profile``), emits ``profile:drift`` edges, arms
    re-profiling (``modelci:reprofile``) and arms replan probes
    (reason=profile_drift).  Needs ``scrape_every_s``: the scrape loop is
    the monitor's clock.

    record_batches=True keeps a per-batch audit trail (batch_log) and a
    per-cloud usage trace (usage_trace) for the invariant test suite.
    After run(), ``final_weights`` holds each model's normalized live
    split for inspection.
    """

    def __init__(self, *, capacity: Optional[dict] = None,
                 log: Optional[EventLog] = None,
                 replan: Optional[ReplanConfig] = None,
                 routing: Optional[RoutingConfig] = None,
                 admission: Optional[AdmissionConfig] = None,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 slo_burn: Optional[BurnRateConfig] = None,
                 drift: Optional[DriftConfig] = None,
                 scrape_every_s: Optional[float] = None,
                 record_batches: bool = False,
                 shared_capacity=None):
        self.deployments: dict[str, Deployment] = {}
        self.capacity = dict(capacity or {})
        # unified capacity market (clouds/capacity.py, ISSUE 9): when a
        # CapacityMarket is shared with the Orchestrator, every replica
        # holds a serving lease on its cloud's ledger, scale-ups preempt
        # the youngest training lease (serving priority) and relaunches
        # may pay a state transfer instead of a cold model load.  None
        # (the default) keeps every pre-ISSUE-9 code path bit-identical.
        self.market = shared_capacity
        if self.market is not None:
            for c, led in self.market.ledgers.items():
                self.capacity.setdefault(c, led.slots)
        self.log = log or EventLog()
        self.replan = replan
        self.routing = routing or RoutingConfig()
        self.admission = admission
        self.tracer = tracer
        self.metrics = metrics
        self.burn = (BurnRateMonitor(slo_burn, log=self.log, metrics=metrics)
                     if slo_burn is not None else None)
        if drift is not None and (scrape_every_s is None or metrics is None):
            raise ValueError("drift detection needs metrics= and "
                             "scrape_every_s=: the scrape loop is the "
                             "monitor's clock")
        self.drift = (DriftMonitor(drift, log=self.log, metrics=metrics)
                      if drift is not None else None)
        if scrape_every_s is not None and scrape_every_s <= 0:
            raise ValueError("scrape_every_s must be > 0")
        self.scrape_every_s = scrape_every_s
        self.record_batches = record_batches
        self.batch_log: list = []        # dicts, one per dispatched batch
        self.usage_trace: list = []      # (t, cloud, replicas_incl_scheduled)
        self.final_weights: dict = {}    # model -> {cloud: weight} post-run
        self.final_kv: dict = {}         # disagg models: post-run kv_used
        self.run_stats: dict = {}        # last run's engine + throughput
        self._run_span = None            # open gateway.run span during run()
        self._leases: dict = {}          # (model, cloud) -> serving Leases

    def deploy(self, name: str, backend, profile: Optional[CloudProfile] = None,
               *, split: Optional[dict] = None, autoscaler=None,
               max_batch: int = 32, canary=None, canary_fraction: float = 0.0,
               standby: Optional[CloudProfile] = None,
               queue_hint: Optional[dict] = None,
               trace_link: Optional[int] = None,
               disagg: Optional[DisaggSpec] = None,
               planned_from=None) -> Deployment:
        """``profile`` places the model on one cloud (weight 1.0);
        ``split={CloudProfile: weight}`` places it active-active (weights
        must sum to 1).  With both, ``profile`` names the primary among the
        split clouds; with only a split, the largest weight is primary.
        ``standby`` adds a zero-weight pool that failover shifts into.
        ``queue_hint`` ({cloud: expected wait s}, e.g. the placement
        plan's Assignment.est_wait_s) seeds queue-aware routing before a
        pool has any queue of its own.  ``trace_link`` is the span id of
        the pipeline deploy step that produced this model (the orchestrator
        passes it through deploy_apply): request spans link to it, so one
        train-to-serve run yields a single connected trace.
        ``planned_from`` is the modelci.ModelProfile the placement was
        sized against (DeploySpec.profile path): with ``drift`` enabled
        the DriftMonitor watches this deployment's observed service time
        against it."""
        if isinstance(autoscaler, AutoscalerConfig):
            autoscaler = Autoscaler(autoscaler)
        if split:
            placements = [(p, float(w)) for p, w in split.items()]
            names = [p.name for p, _ in placements]
            if len(set(names)) != len(names):
                raise ValueError(f"duplicate clouds in split: {names}")
            if any(w < 0 for _, w in placements):
                raise ValueError("split weights must be >= 0")
            total = sum(w for _, w in placements)
            if abs(total - 1.0) > 1e-6:
                raise ValueError(f"split weights must sum to 1, got {total}")
            if profile is None:
                profile = max(placements, key=lambda pw: pw[1])[0]
            elif profile.name not in names:
                raise ValueError("profile must be one of the split clouds")
        elif profile is not None:
            placements = [(profile, 1.0)]
        else:
            raise ValueError("deploy needs a profile or a split")
        if standby is not None:
            if standby.name in [p.name for p, _ in placements]:
                raise ValueError("standby must be a different cloud")
            placements.append((standby, 0.0))
        hint = {c: float(w) for c, w in (queue_hint or {}).items()
                if math.isfinite(w)}
        if disagg is not None:
            clouds = [p.name for p, _ in placements]
            unknown = set(disagg.pool_kind) - set(clouds)
            if unknown:
                raise ValueError(f"disagg pool_kind names clouds not in the "
                                 f"placement: {sorted(unknown)}")
            kinds = {c: disagg.kind(c) for c in clouds}
            bad = {c: k for c, k in kinds.items()
                   if k not in ("prefill", "decode", "both")}
            if bad:
                raise ValueError(f"disagg pool_kind must be prefill / "
                                 f"decode / both, got {bad}")
            vals = set(kinds.values())
            if "prefill" in vals or "decode" in vals:
                # staged mode: every pool picks a side so every queue is
                # stage-homogeneous, and both stages need a live pool
                if "both" in vals:
                    raise ValueError(
                        "staged disagg needs every pool (standby included) "
                        "assigned 'prefill' or 'decode'; got a 'both' pool: "
                        f"{kinds}")
                w_by = {p.name: w for p, w in placements}
                for side in ("prefill", "decode"):
                    if not any(kinds[c] == side and w_by[c] > 0
                               for c in clouds):
                        raise ValueError(
                            f"staged disagg needs a weighted {side} pool, "
                            f"got kinds={kinds}")
        dep = Deployment(name, backend, profile, autoscaler or Autoscaler(),
                         max_batch, canary, canary_fraction, standby,
                         placements, hint, trace_link, disagg)
        self.deployments[name] = dep
        if planned_from is not None and self.drift is not None:
            self.drift.watch(name, planned_from)
        return dep

    # -- discrete-event loop ------------------------------------------------
    def run(self, traffic: list, seed: int = 0,
            failures: Optional[list] = None,
            migrations: Optional[list] = None, *,
            engine: str = "vector") -> GatewayResult:
        """Run the workload through the discrete-event simulation.

        engine="vector" (default): the vectorized hot path -- arrivals
        stay in the sorted ledger columns (never entering the event
        heap), whole arrival spans bulk-enqueue between discrete control
        events when a conservative attention predicate proves the span
        side-effect free, and per-batch folds are numpy.  engine="scalar"
        is the per-request reference loop.  The two are BIT-COMPATIBLE:
        identical EventLog (kinds + order), ServeResult, per-class
        percentiles and simulated dollars on any seed -- enforced by the
        equivalence suite (tests/test_engine_equivalence.py) and the
        bench oracle; the vector engine falls back to scalar-style
        single-timestamp processing whenever the predicate cannot prove
        a span inert, so feature coverage is identical by construction.
        """
        if engine not in ("scalar", "vector"):
            raise ValueError(f"unknown engine {engine!r}")
        self.batch_log = []              # audit trails cover ONE run
        self.usage_trace = []
        self.final_weights = {}
        self._leases = {}                # (model, cloud) -> open Leases
        if self.burn is not None:
            self.burn.reset()            # windows are run-scoped
        if self.drift is not None:
            self.drift.reset()           # counter baselines are run-scoped
        if self.tracer is not None:
            self._run_span = self.tracer.start("gateway.run", 0.0,
                                               seed=int(seed))
        rng = np.random.default_rng(seed)
        by_model: dict[str, list] = {}
        for spec in traffic:
            if spec.model not in self.deployments:
                raise KeyError(f"no deployment named {spec.model!r}")
            by_model.setdefault(spec.model, []).append(spec)

        events = EventHeap(_TIMER_KINDS)
        down: dict[str, int] = {}        # cloud -> active failure windows
        st: dict[str, _ModelState] = {}
        for m, dep in self.deployments.items():
            specs = by_model.get(m, [])
            times, code_chunks = [], []
            classes: list = []           # SLOClass per code, first-use order
            for spec in specs:
                ts = spec.gen(rng)
                times.append(ts)
                c = resolve_slo(spec.slo)
                code = next((k for k, kc in enumerate(classes)
                             if kc.name == c.name), None)
                if code is None:
                    classes.append(c)
                    code = len(classes) - 1
                elif classes[code] != c:  # queues are keyed by name: two
                    raise ValueError(     # defs would silently share one
                        f"conflicting SLOClass definitions named {c.name!r} "
                        f"on {m!r}: {classes[code]} vs {c}")
                code_chunks.append(np.full(len(ts), code, dtype=np.intp))
            arr = np.concatenate(times) if times else np.zeros(0)
            codes = (np.concatenate(code_chunks) if code_chunks
                     else np.zeros(0, dtype=np.intp))
            order = np.argsort(arr, kind="stable")
            arr = arr[order]
            codes = codes[order]
            ver = np.zeros(len(arr), int)
            if dep.canary is not None and dep.canary_fraction > 0:
                ver = (rng.random(len(arr)) < dep.canary_fraction).astype(int)
            route_u = rng.random(len(arr))
            s = st[m] = _ModelState(dep, Ledger(arr, codes, ver, route_u),
                                    classes)
            if self.tracer is not None:
                s.batch_recs = []
            if self.metrics is not None and len(arr):
                s.fold_pending = []
                reg = self.metrics
                s.fold_inst = {cname: (
                    reg.counter("gateway_requests_total", model=m,
                                cls=cname, outcome="served"),
                    reg.counter("gateway_deadline_miss_total", model=m,
                                cls=cname),
                    reg.histogram("gateway_request_latency_seconds",
                                  model=m, cls=cname),
                    reg.counter("gateway_requests_total", model=m,
                                cls=cname, outcome="shed"),
                ) for cname in s.slo_by_name}
            floors = _apportion(dep.autoscaler.cfg.min_replicas,
                                {c: p.weight for c, p in s.pools.items()})
            for c, pool in s.pools.items():
                pool.floor = floors[c]
                for _ in range(pool.floor):
                    pool.replicas[s.next_rid] = _Replica(
                        s.next_rid, warm=True)
                    s.next_rid += 1
                    if self.market is not None:
                        # floors are the contractual serving minimum: they
                        # always win the slot, preempting recorded training
                        # leases even with serving_priority off
                        self._market_lease(m, c, 0.0, force=True)
            s.trace.append((0.0, s.total_pool()))
            s.svc1 = dep.backend.service_time(1)
            # amortized per-request service estimate for the routing /
            # admission expected-completion formula: a full batch's cost
            # split over its requests (svc(1) would overprice a batched
            # backend and over-shed)
            s.svc_est = dep.backend.service_time(dep.max_batch) / dep.max_batch
            s.deadline_base = (dep.profile.network_rtt_s
                               + dep.profile.lb_overhead_s + s.svc1)
            if dep.disagg is not None:
                spec = dep.disagg
                s.stage = np.zeros(len(arr), np.int8)
                be = dep.backend
                if hasattr(be, "prefill_time") and hasattr(be, "decode_time"):
                    # measured two-phase cost model (BatcherBackend)
                    s.svc_prefill = float(be.prefill_time(spec.prompt_tokens))
                    s.svc_decode = float(be.decode_time(spec.gen_tokens))
                else:
                    # blended backend: split the single-request estimate so
                    # the disagg machinery still prices two phases
                    s.svc_prefill = 0.5 * s.svc1
                    s.svc_decode = s.svc1 - s.svc_prefill
            if engine == "scalar":
                # the vector engine keeps arrivals in the sorted ledger
                # columns and consumes them by cursor -- they never touch
                # the heap (the whole point of the SoA hot path)
                for i, t in enumerate(arr):
                    events.push(float(t), "arr", m, i)

        base: dict[str, int] = {}        # cloud -> baseline floors, over
        for s in st.values():            # EVERY deployment: an idle pool
            for c, pool in s.pools.items():   # still holds cloud capacity
                base[c] = base.get(c, 0) + pool.floor
        for cloud, n in base.items():
            cap = self.capacity.get(cloud)
            if cap is not None and n > cap:
                raise ValueError(
                    f"min_replicas on {cloud!r} total {n} > capacity {cap}")

        for f in failures or []:
            events.push(float(f.at_s), "fail", "", f.cloud)
            events.push(float(f.at_s + f.duration_s), "recover", "", f.cloud)
        for mig in migrations or []:
            events.push(float(mig.at_s), "replan", "", mig)
        if self.replan is not None:
            events.push(float(self.replan.check_every_s), "probe", "", None)
        if self.metrics is not None and self.scrape_every_s is not None:
            events.push(float(self.scrape_every_s), "scrape", "", None)

        # gateway:run is recorded AFTER the loop with the SIMULATED makespan
        # as its duration (wall_s meta carries the real wall), mirroring
        # pipeline:run -- so dump() stays byte-stable under a fixed seed
        _wall0 = time.perf_counter()
        if engine == "vector":
            t_last = self._run_vector(st, events, down)
        else:
            t_last = self._run_scalar(st, events, down)
        _wall_s = time.perf_counter() - _wall0
        n_req = int(sum(len(x.arr) for x in st.values()))
        # vector-mode arrivals never enter the heap but are simulator
        # events all the same -- count them so events/sec is comparable
        sim_events = events.n_popped + (n_req if engine == "vector" else 0)
        self.run_stats = {
            "engine": engine, "requests": n_req, "sim_events": sim_events,
            "wall_s": _wall_s,
            "events_per_s": sim_events / _wall_s if _wall_s > 0 else 0.0,
            "requests_per_s": n_req / _wall_s if _wall_s > 0 else 0.0}

        results, cold, costs, makespan = {}, {}, {}, 0.0
        totals: dict[str, float] = {}
        for m, s in st.items():
            if not len(s.arr):           # deployed but untrafficked: holds
                continue                 # capacity, reports no results
            n_shed = int(s.shed.sum())
            if s.served + n_shed < len(s.arr):
                raise RuntimeError(
                    f"gateway stalled: {m} served {s.served} + shed "
                    f"{n_shed} of {len(s.arr)}")
            keep = ~s.shed
            totals[m] = (float((s.arr[keep] + s.lat[keep]).max())
                         if keep.any() else 0.0)
            makespan = max(makespan, totals[m])
        if self.market is not None:
            # surviving replicas occupied their slots through the fleet's
            # last completion: close the recorded serving intervals there
            for (model, cloud), leases in sorted(self._leases.items()):
                led = self.market.ledger(cloud)
                for lease in leases:
                    if lease.status == "active":
                        led.release(lease, makespan)
        self.log.record("gateway:run", makespan, models=sorted(by_model),
                        n=n_req, wall_s=_wall_s)
        if self.tracer is not None:
            # collector flush: build the request span forest in bulk from
            # the per-batch records -- off the event loop, like an async
            # span processor draining its queue.  wall_s meta reports the
            # flush cost next to gateway:run's hot-loop wall.
            _mat0 = time.perf_counter()
            self._materialize_trace(st)
            self.tracer.end(self._run_span, max(makespan, t_last),
                            models=sorted(by_model))
            self._run_span = None
            self.log.record("trace:materialize", 0.0,
                            spans=len(self.tracer.spans),
                            wall_s=time.perf_counter() - _mat0)
        for m, s in st.items():
            # bill surviving replicas to the fleet's last completion, NOT
            # to t_end: a trailing recover window or probe event on an
            # unrelated cloud must not inflate the bill (replicas retired
            # after the makespan already billed their real idle-out time)
            for pool in s.pools.values():
                for r in pool.replicas.values():
                    pool.replica_seconds += max(makespan - r.created_s, 0.0)
            costs[m] = sum(self._pool_costs(s).values())
            self.final_weights[m] = self._norm_weights(s)
            if s.dep.disagg is not None:
                # a drained run must have given every block back
                self.final_kv[m] = {c: int(p.kv_used)
                                    for c, p in s.pools.items()}
            if m in totals:
                results[m] = self._result(s, totals[m])
                cold[m] = s.cold_starts
        if self.metrics is not None:
            # closing scrape AFTER billing so the cost gauges are final
            self._scrape(st, max(makespan, t_last), live_accrual=False)
        return GatewayResult(results, cold, makespan, costs)

    def _run_scalar(self, st: dict, events: EventHeap, down: dict) -> float:
        """Per-request reference loop: every arrival is a heap event."""
        t_last = 0.0
        while events:
            t = events.peek_t()
            t_last = t
            step = _Step()
            # apply every state change at time t before dispatching so a
            # burst admits as full batches (pre-gateway sim semantics);
            # probes run after dispatch (leftover queues are real
            # pressure); idle expiries run last so a coincident arrival
            # wins the replica instead of forcing a retire + cold start
            while events and events.peek_t() == t:
                kind, m, data = events.pop()
                self._apply_event(st, t, kind, m, data, events, down, step)
            self._finish_timestep(st, t, events, down, step)
        return t_last

    def _run_vector(self, st: dict, events: EventHeap, down: dict) -> float:
        """Vectorized loop: arrivals live in the sorted ledger columns and
        are consumed by per-model cursors.  Between heap events, whole
        arrival spans bulk-append to their (single) live pool when the
        attention predicate proves every skipped timestep-end a no-op;
        any timestep the predicate cannot clear runs through the exact
        same _apply_event/_finish_timestep code as the scalar engine.

        Ordering matches the scalar engine by construction: there,
        arrivals are pushed first at init (models in deploy order, rows
        ascending), so at any shared timestamp they pop before every
        other event -- here they are processed first explicitly, in the
        same model/row order, before the heap events at that time."""
        models = [m for m, s in st.items() if len(s.arr)]
        bulk_ok = self.admission is None   # per-row admit decisions (and
        # their shed side effects) force the per-row path
        t_last = 0.0
        while True:
            t_arr = _INF
            for m in models:
                s = st[m]
                if s.cursor < len(s.arr):
                    ta = float(s.arr[s.cursor])
                    if ta < t_arr:
                        t_arr = ta
            t_ev = events.peek_t()
            t = min(t_arr, t_ev)
            if t == _INF:
                break
            if bulk_ok and self.burn is None and t_arr < t_ev:
                # silent span: every arrival strictly before t_cut only
                # appends to its queue -- dispatch, autoscale, logging all
                # provably idle until then
                t_cut = t_ev
                for m in models:
                    s = st[m]
                    if s.cursor < len(s.arr):
                        att = self._attention_time(s)
                        if att < t_cut:
                            t_cut = att
                if t_cut > t_arr:
                    for m in models:
                        s = st[m]
                        lo = s.cursor
                        if lo >= len(s.arr) or float(s.arr[lo]) >= t_cut:
                            continue
                        hi = lo + int(np.searchsorted(s.arr[lo:], t_cut,
                                                      side="left"))
                        self._bulk_append(s, lo, hi, self._route(s, lo))
                        s.cursor = hi
                    continue
            # one full timestep at t: arrivals first (scalar pop order),
            # then the heap events due now, then the shared step tail
            step = _Step()
            for m in models:
                s = st[m]
                lo, n = s.cursor, len(s.arr)
                if lo >= n or float(s.arr[lo]) != t:
                    continue
                hi = lo + int(np.searchsorted(s.arr[lo:], t, side="right"))
                live = sum(1 for p in s.pools.values() if p.weight > 0)
                if bulk_ok and live <= 1 and s.dep.disagg is None:
                    # routing is pinned (single live pool, or everything
                    # waits on the primary) and admission is off: the
                    # whole same-t burst appends in one grouped extend
                    self._bulk_append(s, lo, hi, self._route(s, lo))
                else:
                    for i in range(lo, hi):
                        self._arrive(s, i, t)
                s.cursor = hi
                step.touched.add(m)
            while events and events.peek_t() == t:
                kind, m, data = events.pop()
                self._apply_event(st, t, kind, m, data, events, down, step)
            t_last = t
            left = any(st[m].cursor < len(st[m].arr) for m in models)
            self._finish_timestep(st, t, events, down, step,
                                  arrivals_left=left)
        return t_last

    def _attention_time(self, s: _ModelState) -> float:
        """Earliest pending-arrival time at which this model might do
        anything beyond appending one request to one queue.  Conservative
        by design: flagging a harmless timestep only costs the slow path,
        while the span skip is valid exactly when every skipped
        timestep-end (dispatch + autoscale) is a no-op -- caller already
        guarantees admission and burn monitoring are off."""
        arr = s.arr
        now = float(arr[s.cursor])
        if self.market is not None:
            # market mode: scale-up decisions preempt training leases, so
            # every timestep is a potential ledger mutation -- force the
            # per-request path (same rule as disagg below)
            return now
        if s.dep.disagg is not None:
            # per-request KV accounting / cache shed / stage routing: every
            # arrival is a real decision, so the span skip never applies --
            # both engines take the identical per-request path (the disagg
            # analog of the engine-equivalence bit-compat rule)
            return now
        live = [p for p in s.pools.values() if p.weight > 0]
        if len(live) != 1:
            # multi-pool: queue-aware routing shifts per request;
            # zero-pool: autoscale logs scale_denied every timestep
            return now
        pool = live[0]
        for p in s.pools.values():
            if p is not pool and p.queue_len():
                return now               # a foreign queue could dispatch
        size = pool.size()
        if size == 0:
            return now                   # scale-from-zero fires immediately
        if any(not r.busy for r in pool.replicas.values()):
            return now                   # an idle replica would dispatch
        if any(c.preempts for c in s.classes) and any(
                r.busy for r in pool.replicas.values()):
            return now                   # arrival could preempt a batch
        cfg = s.dep.autoscaler.cfg
        budget = max(cfg.max_replicas, cfg.min_replicas)
        if (size < cfg.max_replicas and size < self._pool_cap(s, pool)
                and s.total_pool() < budget):
            # queue-pressure crossing: the k-th appended request tips
            # scale_up_needed (q0 + k > target_queue * size)
            need = math.floor(cfg.target_queue * max(size, 1)
                              - pool.queue_len()) + 1
            if need <= 0:
                return now
            k = s.cursor + need - 1
            if k < len(arr):
                return float(arr[k])
        return _INF

    def _bulk_append(self, s: _ModelState, lo: int, hi: int,
                     pool: _Pool) -> None:
        """Append ledger rows [lo, hi) to ``pool``'s pending queues in row
        (= arrival) order: the vectorized equivalent of per-row _arrive
        when routing is pinned to one pool and admission is off."""
        if hi <= lo:
            return
        nc = len(s.classes)
        if s.combo_rows is None:
            # the class/version columns are immutable after init, so the
            # per-combo row lists are computed once and every later span
            # split is two searchsorteds + one slice per combo present
            combo_full = s.ver * nc + s.cls_code
            s.combo_rows = {int(c): np.flatnonzero(combo_full == c)
                            for c in np.unique(combo_full)}
        if len(s.combo_rows) == 1:
            # the common case: one class, one version -> one extend
            code = next(iter(s.combo_rows))
            key = (code // nc, s.classes[code % nc].name)
            pool.pending.setdefault(key, IndexQueue()).extend(range(lo, hi))
            return
        # queues are created (and a mixed span appended) in first-row
        # order, so the pending-dict key order matches the scalar engine's
        present = []
        for code, rows_c in s.combo_rows.items():
            a = int(np.searchsorted(rows_c, lo))
            b = int(np.searchsorted(rows_c, hi))
            if b > a:
                present.append((int(rows_c[a]), code, rows_c, a, b))
        present.sort()
        for _, code, rows_c, a, b in present:
            key = (code // nc, s.classes[code % nc].name)
            pool.pending.setdefault(key, IndexQueue()).extend(
                rows_c[a:b].tolist())

    def _grouped_append(self, s: _ModelState, rows: np.ndarray,
                        combo: np.ndarray, pool: _Pool) -> None:
        """Append ledger ``rows`` (ascending, ``combo`` their version x
        class codes) to ``pool``'s per-key queues: rows stay in arrival
        order within each key and queues are created in first-appearance
        order, exactly like a per-row _arrive loop over the same rows."""
        codes, first = np.unique(combo, return_index=True)
        for j in np.argsort(first, kind="stable"):
            code = int(codes[j])
            sel = rows[np.nonzero(combo == code)[0]]
            key = (code // len(s.classes),
                   s.classes[code % len(s.classes)].name)
            pool.pending.setdefault(key, IndexQueue()).extend(sel.tolist())

    def _arrive(self, s: _ModelState, i: int, t: float) -> None:
        pool = self._route(s, i)
        if self._admit(s, pool, i, t):
            key = (int(s.ver[i]), s.slo(i).name)
            pool.pending.setdefault(key, IndexQueue()).append(i)

    def _apply_event(self, st: dict, t: float, kind: str, m: str, data,
                     events: EventHeap, down: dict, step: _Step) -> None:
        if kind == "fail":
            down[data] = down.get(data, 0) + 1
            if down[data] == 1:
                step.touched |= self._outage_edge(
                    st, t, down, events, reason="fail", cloud=data)
            return
        if kind == "recover":
            down[data] -= 1
            if down[data] == 0:
                del down[data]
                step.touched |= self._outage_edge(
                    st, t, down, events, reason="recover", cloud=data)
            return
        if kind == "replan":
            step.touched |= self._apply_migration(
                st, t, data.plan, events, down)
            return
        if kind == "probe":
            step.probe_due = True
            return
        if kind == "scrape":
            step.scrape_due = True
            return
        s = st[m]
        if kind == "arr":                # scalar engine only
            self._arrive(s, data, t)
            step.touched.add(m)
        elif kind == "up":
            cloud, gen, forced_cold = data
            pool = s.pools[cloud]
            if gen != pool.generation:
                return               # scheduled before a drain
            pool.scheduled_up -= 1
            warm = (not s.dep.autoscaler.cfg.cold_scale_up
                    and not forced_cold)
            pool.replicas[s.next_rid] = _Replica(
                s.next_rid, warm=warm, last_active=t, created_s=t)
            if s.dep.autoscaler.tracks_idle:
                # a replica that joins after the queue drained
                # would otherwise never get an idle check
                events.push(t + s.dep.autoscaler.cfg.idle_window_s,
                            "idle", m, (cloud, s.next_rid, t))
            s.next_rid += 1
            step.touched.add(m)
        elif kind == "free":
            cloud, rid, epoch = data
            pool = s.pools[cloud]
            r = pool.replicas.get(rid)
            if r is not None and r.epoch == epoch:
                # real completion (preempted batches bumped the
                # epoch): feed the burn monitor BEFORE the batch
                # is forgotten (spans/metrics fold off-loop)
                if r.inflight is not None:
                    fl = r.inflight
                    if fl["kv"]:            # KV blocks held dispatch->free
                        pool.kv_used -= fl["kv"]
                        pool.kv_resident[int(fl["v"])] -= fl["kv"]
                    if fl["stage"] == "prefill":
                        self._prefill_done(s, pool, fl, t)
                    else:
                        self._complete(s, pool, fl, t)
                r.busy = False
                r.inflight = None
                r.last_active = t
                if pool.weight <= 0 and pool.queue_len() == 0:
                    # drained-away pool: the last in-flight batch
                    # just finished, release the replica now
                    self._retire(s, pool, r, t, st)
                elif s.dep.autoscaler.tracks_idle:
                    events.push(t + s.dep.autoscaler.cfg.idle_window_s,
                                "idle", m, (cloud, rid, t))
                step.touched.add(m)
        else:                        # "idle"
            step.idle_checks.append((m, data))

    def _finish_timestep(self, st: dict, t: float, events: EventHeap,
                         down: dict, step: _Step,
                         arrivals_left: bool = False) -> None:
        """The shared tail of one timestep: dispatch + autoscale for every
        touched model, then due probes/scrapes, then idle expiries.
        ``arrivals_left`` keeps the periodic timers armed in vector mode,
        where pending arrivals are invisible to the heap (the dead-tail
        rule reads "no work AND only timers queued")."""
        if self.burn is not None:
            # stale alerts must resolve on the clock, not only on the next
            # observation: a post-traffic firing alert would otherwise keep
            # pressure() > 0 and sustain a scale-up/idle-retire livelock
            self.burn.age(t)
        # sorted: set order depends on PYTHONHASHSEED, and which
        # model dispatches first decides shared-capacity races --
        # invariant 4 promises cross-process determinism
        for m in sorted(step.touched):
            self._dispatch(st[m], t, events)
            self._autoscale(st[m], t, events, st, down)
        if step.probe_due:
            for m in sorted(self._probe(st, t, events, down)):
                self._dispatch(st[m], t, events)
                self._autoscale(st[m], t, events, st, down)
            if (self._work_left(st) or arrivals_left
                    or not events.only_timers()):
                events.push(t + self.replan.check_every_s,
                            "probe", "", None)
        if step.scrape_due:
            self._scrape(st, t)
            if (self._work_left(st) or arrivals_left
                    or not events.only_timers()):
                events.push(t + self.scrape_every_s, "scrape", "", None)
        for m, payload in step.idle_checks:
            self._maybe_retire(st[m], t, payload, st)

    def _complete(self, s: _ModelState, pool: _Pool, fl: dict,
                  t: float) -> None:
        """A batch really finished (its "free" matched the epoch): queue it
        for the next metric fold and feed the burn monitor with the
        per-pool deadline verdict.  The monitor is a CONTROLLER (it arms
        probes and pressures the autoscaler) so it must see completions
        live; metric series and spans are pure observers and fold off the
        hot path -- the whole per-batch cost here is one tuple append."""
        pend, burn = s.fold_pending, self.burn
        if pend is None and burn is None:
            return
        thresh = fl["slo"].deadline_mult * self._pool_base(s, pool)
        if pend is not None:
            pend.append((fl["idx"], fl["cls"], thresh))
        if burn is not None:
            m = s.dep.name
            cname = fl["cls"]
            for i in fl["idx"]:
                burn.observe(t, m, cname, float(s.lat[i]) <= thresh)

    def _prefill_done(self, s: _ModelState, pool: _Pool, fl: dict,
                      t: float) -> None:
        """A staged prefill batch finished: its KV rows hand off to the
        decode tier, the requests flip to stage 1 and re-enter routing
        (_pool_accepts narrows them to decode pools).  No latency verdict
        yet -- the clock keeps running from the ORIGINAL arrival and the
        decode completion charges the whole span."""
        take = fl["idx"]
        for i in take:
            s.stage[i] = 1
        self.log.record("gateway:prefill", fl["service_s"], model=s.dep.name,
                        cloud=pool.profile.name, n=len(take),
                        t_sim=round(t, 6), staged=True)
        key = (fl["v"], fl["cls"])
        for i in take:
            dest = self._route(s, i)
            dest.pending.setdefault(key, IndexQueue()).append(i)

    def _fold_metrics(self, st: dict, t: float) -> None:
        """Drain the really-completed batches queued by _complete into the
        request counters and latency sketches, chunked per class so the
        sketch updates are vectorized -- called at each scrape, never from
        the dispatch loop.  Completed batches are FINAL (a preemption
        invalidates its batch strictly before the completion would fire),
        so folds are incremental: total fold work is O(n) per run, and the
        closing fold reconciles exactly with ServeResult."""
        for s in st.values():
            pend = s.fold_pending
            if pend is None:
                continue
            if pend:
                byc: dict = {}
                for idx, cname, thresh in pend:
                    byc.setdefault(cname, []).append((idx, thresh))
                pend.clear()
                for cname, batches in byc.items():
                    served, missed, hist, _ = s.fold_inst[cname]
                    flat = [i for idx, _ in batches for i in idx]
                    vals = s.lat[flat]
                    thr = np.repeat([th for _, th in batches],
                                    [len(idx) for idx, _ in batches])
                    served.value += float(len(flat))
                    missed.value += float((vals > thr).sum())
                    hist.sketch.observe_many(vals)
            for cname, n_shed in s.class_shed.items():
                s.fold_inst[cname][3].value = float(n_shed)

    def _materialize_trace(self, st: dict) -> None:
        """Build each request's span tree (root > queue/serve children)
        from the batch records and shed marks the loop collected -- same
        vocabulary and attrs as if the spans had been opened live, at a
        fraction of the hot-path cost.  Creation order (models in deploy
        order, requests by index, children chronologically) is
        deterministic, so the exported trace is byte-stable per seed."""
        tracer, run = self.tracer, self._run_span
        for m, s in st.items():
            n = len(s.arr)
            if not n:
                continue
            by_req: list = [[] for _ in range(n)]
            for rec in s.batch_recs:
                for i in rec["idx"]:
                    by_req[i].append(rec)
            links = (s.dep.trace_link,) if s.dep.trace_link is not None \
                else ()
            for i in range(n):
                root = tracer.start("gateway.request", float(s.arr[i]),
                                    parent=run, links=links, model=m,
                                    idx=i, cls=s.slo(i).name)
                cursor, requeued = root.t0, False
                for rec in by_req[i]:
                    q = tracer.start("gateway.queue", cursor, parent=root,
                                     cloud=rec["cloud"])
                    if requeued:
                        q.attrs["requeued"] = True
                    q.t1 = rec["start_s"]
                    sp = tracer.start(
                        "gateway.serve", rec["start_s"], parent=root,
                        cloud=rec["cloud"], rid=rec["rid"],
                        batch=len(rec["idx"]), rtt_lb_s=rec["rtt_lb_s"],
                        cold_s=rec["cold_s"], service_s=rec["service_s"])
                    sp.t1 = rec["end_s"]
                    stage = rec.get("stage")
                    if stage is not None:
                        sp.attrs["stage"] = stage
                        if stage == "prefill" and not rec["preempted"]:
                            # handoff: the decode queue span opens when the
                            # prefill batch lands, not at arrival
                            cursor = rec["end_s"]
                    if rec["preempted"]:
                        sp.attrs["preempted"] = True
                        cursor, requeued = rec["end_s"], True
                if s.shed[i]:
                    t_shed, where, cloud = s.shed_at[i]
                    if by_req[i] or where != "enqueue":
                        # shed out of a queue (dispatch-time prune); an
                        # enqueue-time shed never queued at all
                        q = tracer.start("gateway.queue", cursor,
                                         parent=root, cloud=cloud)
                        if requeued:
                            q.attrs["requeued"] = True
                        q.t1 = t_shed
                    root.t1 = t_shed
                    root.attrs["outcome"] = "shed"
                    root.attrs["at"] = where
                else:
                    root.t1 = by_req[i][-1]["end_s"]
                    root.attrs["outcome"] = "served"
                    root.attrs["latency_s"] = float(s.lat[i])

    def _scrape(self, st: dict, t: float, *,
                live_accrual: bool = True) -> None:
        """Freeze queue-depth / replica / accrued-cost gauges and take a
        MetricsRegistry snapshot at simulated time ``t`` (the "scrape"
        event; scheduled every ``scrape_every_s`` like replan probes).
        ``live_accrual=False`` for the closing scrape: end-of-run billing
        already folded surviving replicas into replica_seconds."""
        metrics = self.metrics
        self._fold_metrics(st, t)        # counters/sketches catch up first
        for m, s in st.items():
            for c, pool in s.pools.items():
                g = s.gauge_inst.get(c)  # lazy: migration can open pools
                if g is None:
                    g = s.gauge_inst[c] = (
                        metrics.gauge("gateway_queue_depth",
                                      model=m, cloud=c),
                        metrics.gauge("gateway_replicas", model=m, cloud=c),
                        metrics.gauge("gateway_cost_usd", model=m, cloud=c))
                g[0].set(pool.queue_len())
                g[1].set(pool.size())
                accrued = pool.replica_seconds
                if live_accrual:
                    accrued += sum(max(t - r.created_s, 0.0)
                                   for r in pool.replicas.values())
                g[2].set(accrued * pool.profile.cost_per_s)
                if s.dep.disagg is not None and pool.kv_total > 0:
                    kg = s.kv_gauge_inst.get(c)
                    if kg is None:
                        kg = s.kv_gauge_inst[c] = metrics.gauge(
                            "gateway_kv_blocks_used", model=m, cloud=c)
                    kg.set(pool.kv_used)
            if self.drift is not None:
                # cumulative counters in, deltas inside the monitor -- the
                # same contract a Prometheus rate() has with a counter
                self.drift.observe(t, m, float(s.busy_s), int(s.served))
        metrics.scrape(t, self.log)

    def _result(self, s: _ModelState, total: float) -> ServeResult:
        dep = s.dep
        # REPORTED deadline base: the warm single-request path on the
        # PRIMARY cloud.  This is the deployment-level promise per_class()
        # publishes (a request served by a slower split cloud that beats
        # that cloud's own path but not the primary's still counts as a
        # miss here).  The IN-RUN accounting that drives probes and the
        # shedder is per-pool (_pool_base) so a slow-but-honest split
        # cloud cannot make replanning oscillate -- DESIGN.md S3.
        base = s.deadline_base
        # shed requests are excluded exactly once: reported via class_shed,
        # never in the latency percentiles
        keep = ~s.shed
        lats_arr = s.lat[keep]
        codes = s.cls_code[keep]
        lats = lats_arr.tolist()
        cls_lats: dict[str, list] = {}
        cls_miss: dict[str, int] = {}
        for code, c in enumerate(s.classes):
            mask = codes == code
            if not mask.any():
                continue
            vals = lats_arr[mask]
            cls_lats[c.name] = vals.tolist()
            miss = int((vals > c.deadline_mult * base).sum())
            if miss:
                cls_miss[c.name] = miss
        n = len(s.arr)
        window = float(s.arr.max() - s.arr.min()) if n > 1 else 0.0
        if window <= 1e-9:
            # pure burst: estimate the offered window as the (collapsed)
            # arrival span plus ONE mean service interval.  The old
            # fallback used the whole run span (total - arr.min()), which
            # counts drain time: a burst that ends early looked like a
            # low trickle, under-estimating rate_rps and suppressing the
            # replan probes that overload should arm (ISSUE 7 bugfix).
            gap = (s.busy_s / s.served if s.served
                   else max(total - float(s.arr.min()), 1e-9))
            window = max(window + gap, 1e-9)
            rate = n / window
        else:
            # n arrivals span n-1 inter-arrival gaps: n/window overestimates
            # the offered rate for small n and biases replan demand upward
            rate = (n - 1) / window
        observed = {"rate_rps": rate,
                    "service_time_s": s.busy_s / max(s.served, 1),
                    "window_s": window, "n": n,
                    "shed": int(s.shed.sum())}
        self.log.record("gateway:observed", 0.0, model=dep.name,
                        rate_rps=round(observed["rate_rps"], 4),
                        service_time_s=round(observed["service_time_s"], 8),
                        n=n, shed=observed["shed"])
        cost_by_cloud = self._pool_costs(s)
        return ServeResult(f"gateway:{dep.name}", n, total, lats,
                           s.trace, per_version=s.per_version,
                           class_latencies=cls_lats, class_misses=cls_miss,
                           class_shed=dict(s.class_shed),
                           observed=observed,
                           cost_usd=sum(cost_by_cloud.values()),
                           cost_by_cloud=cost_by_cloud)

    @staticmethod
    def _pool_costs(s: _ModelState) -> dict:
        """Simulated dollars per cloud: provisioned replica-seconds priced
        by the profile sheet.  The ONE formula behind both
        GatewayResult.costs and ServeResult.cost_by_cloud."""
        return {c: p.replica_seconds * p.profile.cost_per_s
                for c, p in s.pools.items() if p.replica_seconds > 0}

    # -- split routing ------------------------------------------------------
    @staticmethod
    def _norm_weights(s: _ModelState) -> dict:
        total = sum(p.weight for p in s.pools.values())
        if total <= 0:
            return {c: 0.0 for c in s.pools}
        return {c: p.weight / total for c, p in s.pools.items()}

    def _pool_base(self, s: _ModelState, pool: _Pool) -> float:
        """This POOL's warm single-request path (rtt + lb + svc(1)) -- the
        deadline base for in-run miss/shed accounting (ISSUE 4 bugfix:
        charging every pool against the PRIMARY's warm path made a slow
        split cloud look like a miss storm and replan probes oscillate).
        Cached lazily: migrations open pools mid-run."""
        cloud = pool.profile.name
        base = s.base_by_cloud.get(cloud)
        if base is None:
            base = s.base_by_cloud[cloud] = (
                pool.profile.network_rtt_s + pool.profile.lb_overhead_s
                + s.svc1)
        return base

    def _expected_wait(self, s: _ModelState, pool: _Pool) -> float:
        """Expected seconds until a request joining ``pool`` NOW completes:
        queue depth x amortized service estimate over the pool's replicas,
        plus the cloud's rtt/lb constants, plus cold-start risk: a pool
        with NO replicas must first spin one up (control-plane delay +
        model load).  A provisioned-but-cold pool is NOT penalized -- its
        model_load_s is a one-time cost the first batch amortizes, and
        charging it per decision would keep a freshly migrated-to pool
        cold forever.  A deployment-supplied queue_hint (planner prior)
        floors the wait while the pool has no queue of its own.  A coarse
        ranking estimate, deliberately -- the simulation is the ground
        truth; this only has to order pools and spot hopeless deadlines."""
        size = pool.size()
        est = s.svc_est
        if s.dep.disagg is not None and pool.kind != "both":
            # the two phases price differently (ISSUE 8): prompt ingest is
            # serial per request, decode amortizes one wave over the batch
            est = (s.svc_prefill if pool.kind == "prefill"
                   else s.svc_decode / max(s.dep.max_batch, 1)) or est
        wait = (pool.queue_len() + 1) * est / max(size, 1)
        if pool.queue_len() == 0:
            wait = max(wait, s.dep.queue_hint.get(pool.profile.name, 0.0))
        e = wait + pool.profile.network_rtt_s + pool.profile.lb_overhead_s
        if size == 0:
            e += (s.dep.autoscaler.cfg.scale_up_delay_s
                  + pool.profile.model_load_s)
        return e

    def _kv_bias(self, s: _ModelState, pool: _Pool, i: int) -> float:
        """Cache terms added to a pool's expected completion during disagg
        routing: a version whose KV rows are not resident on the pool pays
        one prompt-ingest to populate them, and a pool whose projected
        block demand exceeds its budget pays the drain time of the
        deficit.  Zero for non-disagg deployments."""
        spec = s.dep.disagg
        if spec is None:
            return 0.0
        bias = 0.0
        if int(s.ver[i]) not in pool.kv_warm:
            bias += s.svc_prefill
        if pool.kv_total > 0:
            need = spec.blocks_per_request * (pool.queue_len() + 1)
            free = pool.kv_total - pool.kv_used
            if need > free:
                per_batch = spec.blocks_per_request * max(s.dep.max_batch, 1)
                bias += s.svc_decode * math.ceil((need - free) / per_batch)
        return bias

    def _pool_accepts(self, s: _ModelState, pool: _Pool, i: int) -> bool:
        """Stage gate for staged disagg: new arrivals go to prefill pools,
        prefill-complete requests to decode pools; always True otherwise
        (so every queue stays stage-homogeneous)."""
        if not s.staged:
            return True
        want = "decode" if (s.stage is not None and s.stage[i]) else "prefill"
        return pool.kind == want

    def _route(self, s: _ModelState, i: int) -> _Pool:
        """Blended queue-aware routing (RoutingConfig): live pools within
        ``slack`` of the best expected completion form the candidate band;
        the request's pre-drawn uniform resolves a weighted draw over the
        band (declared pool order), so a fixed seed stays bit-for-bit
        deterministic however queues and weights move.  policy="weights"
        skips the band (pure weighted draw, the pre-ISSUE-4 behavior).
        With every weight at zero (full outage, no standby) requests wait
        on the primary.  Staged disagg narrows the candidates to the
        request's stage and scores carry the KV cache terms (_kv_bias)."""
        live = [(c, p) for c, p in s.pools.items()
                if p.weight > 0 and self._pool_accepts(s, p, i)]
        total = sum(p.weight for _, p in live)
        if total <= 0:
            return s.pools[s.dep.profile.name]
        if self.routing.policy == "queue_aware" and len(live) > 1:
            scored = [(self._expected_wait(s, p) + self._kv_bias(s, p, i),
                       c, p) for c, p in live]
            band = (min(e for e, _, _ in scored)
                    * (1.0 + self.routing.slack) + 1e-12)
            live = [(c, p) for e, c, p in scored if e <= band]
            total = sum(p.weight for _, p in live)
        u = float(s.route_u[i]) * total
        acc = 0.0
        for c, p in live:
            acc += p.weight
            if u < acc:
                return p
        return live[-1][1]

    # -- admission control (shedding) ---------------------------------------
    def _admit(self, s: _ModelState, pool: _Pool, i: int, t: float) -> bool:
        """Enqueue-time admission: shed the request (exactly once) when its
        expected completion already exceeds margin x the class deadline,
        measured against the SERVING pool's own warm path.  A disagg pool
        with a block budget additionally sheds on PROJECTED cache
        exhaustion -- queued demand plus in-flight blocks past
        shed_margin x the budget (``gateway:cache_shed``) -- a physical
        memory limit, so it applies even with admission control off."""
        spec = s.dep.disagg
        if spec is not None and pool.kv_total > 0:
            c = s.slo(i)
            projected = (pool.kv_used
                         + (pool.queue_len() + 1) * spec.blocks_per_request)
            if (c.sheddable
                    and projected > spec.shed_margin * pool.kv_total):
                self.log.record("gateway:cache_shed", 0.0, model=s.dep.name,
                                cloud=pool.profile.name, cls=c.name,
                                idx=int(i), t_sim=round(t, 6),
                                kv_used=int(pool.kv_used),
                                kv_projected=int(projected),
                                kv_total=int(pool.kv_total))
                self._shed(s, pool, i, t, where="cache")
                return False
        adm = self.admission
        if adm is None:
            return True
        c = s.slo(i)
        if not c.sheddable or not math.isfinite(c.deadline_mult):
            return True
        deadline = adm.margin * c.deadline_mult * self._pool_base(s, pool)
        if t + self._expected_wait(s, pool) <= float(s.arr[i]) + deadline:
            return True
        self._shed(s, pool, i, t, where="enqueue")
        return False

    def _shed(self, s: _ModelState, pool: _Pool, i: int, t: float, *,
              where: str) -> None:
        c = s.slo(i)
        s.shed[i] = True
        s.class_shed[c.name] = s.class_shed.get(c.name, 0) + 1
        s.win_shed += 1
        pool.shed_pressure += 1
        self.log.record("gateway:shed", 0.0, model=s.dep.name,
                        cloud=pool.profile.name, cls=c.name, idx=int(i),
                        t_sim=round(t, 6), at=where)
        if self.tracer is not None:      # span materialized post-run
            s.shed_at[i] = (t, where, pool.profile.name)
        if self.burn is not None:        # a shed is a budget breach
            self.burn.observe(t, s.dep.name, c.name, good=False)

    def _prune_hopeless(self, s: _ModelState, pool: _Pool, t: float) -> None:
        """Dispatch-time re-check: shed queued requests whose BEST-CASE
        completion (dispatched right now, warm, batch of one) already
        breaches margin x deadline.  Queues are FIFO by arrival, so the
        hopeless requests form a prefix."""
        adm = self.admission
        if adm is None or not adm.recheck_at_dispatch:
            return
        base = self._pool_base(s, pool)
        best = t + base                  # rtt + lb + svc(1) from now
        for key in list(pool.pending):
            q = pool.pending[key]
            if not q:
                continue
            c = s.slo_by_name[key[1]]
            if not c.sheddable or not math.isfinite(c.deadline_mult):
                continue
            deadline = adm.margin * c.deadline_mult * base
            while q and best > float(s.arr[q.peek()]) + deadline:
                self._shed(s, pool, q.popleft(), t, where="dispatch")

    # -- dispatch -----------------------------------------------------------
    def _best_queue(self, s: _ModelState, pool: _Pool, keys: list,
                    t: float) -> tuple:
        """Class-weighted age: serve the queue maximizing weight * age of
        its oldest request; ties fall to weight then earliest arrival."""
        def rank(k):
            q = pool.pending[k]
            w = s.slo_by_name[k[1]].weight
            head = q.peek()
            return (w * (t - float(s.arr[head])), w, -head)
        return max(keys, key=rank)

    def _dispatch(self, s: _ModelState, t: float, events: EventHeap) -> None:
        for pool in s.pools.values():
            if pool.queue_len():
                self._dispatch_pool(s, pool, t, events)

    def _dispatch_pool(self, s: _ModelState, pool: _Pool, t: float,
                       events: EventHeap) -> None:
        self._prune_hopeless(s, pool, t)
        while True:
            keys = [k for k, q in pool.pending.items() if q]
            if not keys:
                return
            idle = [r for r in pool.replicas.values() if not r.busy]
            if idle:
                key = self._best_queue(s, pool, keys, t)
                r = min(idle, key=lambda x: x.rid)
            else:
                pkeys = [k for k in keys if s.slo_by_name[k[1]].preempts]
                if not pkeys:
                    return
                key = self._best_queue(s, pool, pkeys, t)
                w = s.slo_by_name[key[1]].weight
                # strict weight order prevents preemption livelock (a class
                # can never evict work of its own or a higher class)
                victims = [r for r in pool.replicas.values()
                           if r.busy and r.inflight is not None
                           and r.inflight["slo"].preemptible
                           and r.inflight["slo"].weight < w]
                if not victims:
                    return
                # evict the batch with the most remaining work (least sunk)
                r = max(victims, key=lambda x: (x.inflight["done"], x.rid))
                n_back = self._reclaim(s, pool, r, t)
                self.log.record("gateway:preempt", 0.0, model=s.dep.name,
                                t_sim=round(t, 6), rid=r.rid, requeued=n_back,
                                by=key[1], cloud=pool.profile.name)
            self._assign(s, pool, r, key, t, events)

    def _assign(self, s: _ModelState, pool: _Pool, r: _Replica, key: tuple,
                t: float, events: EventHeap) -> None:
        dep = s.dep
        v, cname = key
        take = pool.pending[key].take(dep.max_batch)
        cold = 0.0
        if not r.warm:
            cold = pool.profile.model_load_s
            if pool.handoff_load_s is not None and pool.handoff_n > 0:
                # warm handoff (market mode): this relaunched replica got
                # its state over the interconnect, not a cold model load
                cold = pool.handoff_load_s
                pool.handoff_n -= 1
            r.warm = True
            s.cold_starts += 1
            self.log.record("gateway:cold_start", cold, model=dep.name,
                            cloud=pool.profile.name, t_sim=round(t, 6))
        backend = dep.backends[v]
        b = len(take)
        spec = dep.disagg
        stage = None
        if spec is not None and s.staged:
            # stage-homogeneous queues make pool.kind authoritative; the
            # head check covers the full-decode-outage fallback (a stage-1
            # request parked on the primary must not prefill again)
            stage = ("prefill" if pool.kind == "prefill"
                     and not s.stage[take[0]] else "decode")
        if stage == "prefill":
            svc = b * s.svc_prefill        # prompt ingest is serial/request
        elif stage == "decode":
            svc = s.svc_decode             # one slot wave (b <= max_batch)
        else:
            svc = backend.service_time(b)
        done = (t + pool.profile.network_rtt_s + pool.profile.lb_overhead_s
                + cold + svc)
        kv = 0
        if spec is not None:
            pool.kv_warm.add(int(v))       # cache rows resident (routing)
            if pool.kv_total > 0:
                kv = spec.blocks_per_request * b
                pool.kv_used += kv
                pool.kv_resident[int(v)] = \
                    pool.kv_resident.get(int(v), 0) + kv
            if stage is None:
                # unified pool: the prefill share is priced inside
                # service_time; surface it so the event log still splits
                self.log.record("gateway:prefill", b * s.svc_prefill,
                                model=dep.name, cloud=pool.profile.name,
                                n=b, t_sim=round(t, 6), staged=False)
        if stage != "prefill":
            # in-run miss window: charge against the SERVING pool's own
            # warm path, not the primary's (per-pool promise; the
            # primary-relative one is reported post-run in per_class) --
            # ISSUE 4 bugfix.  A staged prefill batch carries no latency
            # verdict: the request is still in flight until its decode
            # batch lands, which charges the whole arrival-to-done span.
            pool_base = self._pool_base(s, pool)
            idx = np.fromiter(take, np.intp, b)
            lats = done - s.arr[idx]
            s.lat[idx] = lats
            # the batch is single-class (queues key on class), so one
            # scalar threshold covers it; elementwise semantics match the
            # old per-row compare bit for bit (an inf deadline never
            # counts as a miss)
            s.win_miss += int((lats > s.slo_by_name[cname].deadline_mult
                               * pool_base).sum())
            s.win_n += b
            s.served += b
            s.per_version[backend.name] = \
                s.per_version.get(backend.name, 0) + b
        s.busy_s += svc
        r.busy = True
        r.last_active = done
        r.epoch += 1
        rec = None
        if self.record_batches or s.batch_recs is not None:
            # one dict per BATCH is the whole per-dispatch telemetry cost;
            # the span materializer reads rtt_lb/cold/service back out
            rec = {"model": dep.name, "rid": r.rid,
                   "cloud": pool.profile.name,
                   "cls": cname, "version": v, "idx": tuple(take),
                   "start_s": t, "end_s": done, "preempted": False,
                   "rtt_lb_s": pool.profile.network_rtt_s
                   + pool.profile.lb_overhead_s,
                   "cold_s": cold, "service_s": svc}
            if stage is not None:
                rec["stage"] = stage
            if self.record_batches:
                self.batch_log.append(rec)
            if s.batch_recs is not None:
                s.batch_recs.append(rec)
        r.inflight = {"idx": take, "v": v, "cls": cname,
                      "slo": s.slo_by_name[cname], "backend": backend.name,
                      "service_s": svc, "done": done, "record": rec,
                      "win_epoch": s.win_epoch, "stage": stage, "kv": kv}
        events.push(done, "free", dep.name,
                    (pool.profile.name, r.rid, r.epoch))

    def _reclaim(self, s: _ModelState, pool: _Pool, r: _Replica,
                 t: float) -> int:
        """Undo an in-flight batch (preemption or cloud failure): requests
        re-queue with their original arrival times, so they complete exactly
        once when re-dispatched.  Request index order IS arrival order
        (arrivals are sorted at init), so a sorted merge restores the
        queue's FIFO invariant even when several replicas reclaim into the
        same queue (e.g. a whole-pool failover drain)."""
        fl = r.inflight
        take = fl["idx"]
        key = (fl["v"], fl["cls"])
        old = pool.pending.get(key)
        pool.pending[key] = IndexQueue(
            sorted(take + (list(old) if old else [])))
        if fl["kv"]:                             # give the blocks back
            pool.kv_used -= fl["kv"]
            pool.kv_resident[int(fl["v"])] -= fl["kv"]
        if fl["stage"] != "prefill":
            # only undo window counts the batch contributed to the CURRENT
            # probe window; a pre-reset batch was already flushed with its
            # window and must not distort this one.  (A prefill batch
            # never wrote lat/window/served counters -- see _assign.)
            undo_window = fl["win_epoch"] == s.win_epoch
            pool_base = self._pool_base(s, pool)  # mirror _assign's charge
            for i in take:
                if undo_window and s.lat[i] > s.slo(i).deadline_mult \
                        * pool_base:
                    s.win_miss -= 1
                s.lat[i] = -1.0
            if undo_window:
                s.win_n -= len(take)
            s.served -= len(take)
            s.per_version[fl["backend"]] -= len(take)
        s.busy_s -= fl["service_s"]
        if fl["record"] is not None:
            # the serve attempt is abandoned: the materializer turns this
            # into a preempted serve span followed by a requeued queue span
            # (the analyzer charges preempted time separately from service)
            fl["record"]["end_s"] = t
            fl["record"]["preempted"] = True
        r.busy = False
        r.inflight = None
        r.epoch += 1                     # invalidate the scheduled "free"
        r.last_active = t
        return len(take)

    # -- weight shifts: migration, failover, recovery -----------------------
    def _desired_weights(self, s: _ModelState, down: dict) -> dict:
        """Nominal weights with down clouds zeroed; if that extinguishes
        every pool, the zero-nominal pools that are still up (the standby)
        split the traffic evenly."""
        live = {c: p.nominal for c, p in s.pools.items()
                if p.nominal > 0 and c not in down}
        if not live:
            alts = [c for c, p in s.pools.items()
                    if p.nominal <= 0 and c not in down]
            live = {c: 1.0 / len(alts) for c in alts}
        return {c: live.get(c, 0.0) for c in s.pools}

    def _outage_edge(self, st, t, down, events, *, reason: str,
                     cloud: str) -> set:
        """A cloud just died or came back: every model re-derives its live
        weights from the nominal split and the down set.  The edge is a
        plain weight shift -- failover/recovery have no code path of their
        own."""
        touched = set()
        for name, s in st.items():
            desired = self._desired_weights(s, down)
            changed = any(abs(desired[c] - p.weight) > 1e-12
                          for c, p in s.pools.items())
            dead = s.pools.get(cloud)
            must_drain = (reason == "fail" and dead is not None
                          and (dead.replicas or dead.scheduled_up))
            if not changed and not must_drain:
                continue
            if reason == "recover":
                home = cloud in s.pools and s.pools[cloud].nominal > 0
                why = "recover" if home else "fail"
            else:
                why = "fail"
            self._set_weights(s, t, desired, reason=why, events=events,
                              st=st, down=down,
                              edge_cloud=cloud if reason == "fail" else None)
            touched.add(name)
        return touched

    def _apply_migration(self, st, t, plan, events, down) -> set:
        """Apply a MigrationPlan (or raw {model: {cloud: weight}}) live:
        one weight shift per step, opening pools for clouds the deployment
        has not served from before (gateway:migrate reason=plan)."""
        if hasattr(plan, "steps"):
            steps = list(plan.steps)
        else:
            # normalize the raw-dict form into MigrationSteps so BOTH entry
            # points share one validation rule set (weights sum to 1,
            # non-negative, profiles cover every cloud)
            steps = []
            for model, weights in plan.items():
                if model not in st:
                    raise KeyError(f"no deployment named {model!r}")
                pools = st[model].pools
                steps.append(MigrationStep(
                    model, dict(weights), {},
                    {c: (pools[c].profile if c in pools else get_profile(c))
                     for c in weights}))
        touched = set()
        for step in steps:
            if step.model not in st:
                raise KeyError(f"no deployment named {step.model!r}")
            s = st[step.model]
            for cloud in step.weights:
                if cloud not in s.pools:
                    p = s.pools[cloud] = _Pool(step.profiles[cloud], 0.0)
                    if s.dep.disagg is not None:
                        p.kind = s.dep.disagg.kind(cloud)
                        p.kv_total = s.dep.disagg.blocks_for(cloud)
            self.log.record("gateway:migrate", 0.0, model=step.model,
                            t_sim=round(t, 6), reason="plan",
                            weights={c: round(w, 6)
                                     for c, w in step.weights.items()})
            self._set_weights(s, t, dict(step.weights), reason="migrate",
                              events=events, st=st, down=down,
                              update_nominal=True,
                              size_hint=dict(step.replicas) or None)
            touched.add(step.model)
        return touched

    def _set_weights(self, s: _ModelState, t: float, target: dict, *,
                     reason: str, events, st, down,
                     update_nominal: bool = False,
                     size_hint: Optional[dict] = None,
                     edge_cloud: Optional[str] = None) -> None:
        """THE weight-shift primitive (drain-and-shift, exactly-once).

        - dead-cloud pools drain hard: in-flight batches reclaim (pods are
          gone), replicas clear, pending launches invalidate;
        - pools migrated to zero weight on a LIVE cloud drain soft: idle
          replicas retire now, busy ones finish their batch and retire on
          its "free" (no work is dropped);
        - every queued request re-routes by the NEW weights via its
          original uniform draw, merged in arrival order;
        - pools gaining weight from zero relaunch forced-cold, sized by
          Autoscaler.relaunch_pool against the DESTINATION cloud's
          headroom (the working set that left the shrinking pools, or the
          MigrationPlan's replica hint).
        """
        dep = s.dep
        old_live = {c: p.weight for c, p in s.pools.items()}
        old_size = {c: p.size() for c, p in s.pools.items()}
        for c, pool in s.pools.items():
            w = float(target.get(c, 0.0))
            if update_nominal:
                pool.nominal = w
            pool.weight = 0.0 if c in down else w
            if pool.weight <= 0:
                # the shed demand re-routes with the backlog: stale
                # pressure on a drained pool must not trigger a phantom
                # scale-from-zero launch later
                pool.shed_pressure = 0
        floors = _apportion(dep.autoscaler.cfg.min_replicas,
                            {c: p.weight for c, p in s.pools.items()})
        requeued = 0
        moved = 0
        for c, pool in s.pools.items():
            pool.floor = floors[c]
            if c in down and (pool.replicas or pool.scheduled_up):
                for r in list(pool.replicas.values()):
                    if r.busy and r.inflight is not None:
                        requeued += self._reclaim(s, pool, r, t)
                moved += old_size[c]
                for r in pool.replicas.values():
                    pool.replica_seconds += max(t - r.created_s, 0.0)
                if self.market is not None:
                    # the pods are gone: give every slot back at once
                    self._market_release(dep.name, c, t,
                                         len(pool.replicas)
                                         + pool.scheduled_up)
                pool.replicas.clear()
                pool.generation += 1     # stale "up" events are dropped
                pool.scheduled_up = 0
                s.trace.append((t, s.total_pool()))
                self._note_usage(st, c, t)
            elif (pool.weight <= 0 and old_live[c] > 0
                  and (pool.replicas or pool.scheduled_up)):
                moved += old_size[c]
                pool.generation += 1
                if self.market is not None and pool.scheduled_up:
                    # invalidated pending launches free their slots now;
                    # live replicas release theirs as they retire
                    self._market_release(dep.name, c, t, pool.scheduled_up)
                pool.scheduled_up = 0
                for r in [x for x in pool.replicas.values() if not x.busy]:
                    self._retire(s, pool, r, t, st)
        # shift the backlog: re-route every queued request by the new split
        pend = []
        for pool in s.pools.values():
            for q in pool.pending.values():
                pend.extend(q)
            pool.pending = {}
        pend.sort()
        live = [p for p in s.pools.values() if p.weight > 0]
        if pend and len(live) <= 1:
            # one destination for the whole backlog (single live pool, or
            # full outage waiting on the primary): _route is constant over
            # pend, so the drain folds to one grouped append -- the exact
            # order the per-row loop below would produce.  This is the
            # failover hot path at scale (the backlog is the overload).
            dest = live[0] if live else s.pools[dep.profile.name]
            rows = np.asarray(pend, dtype=np.intp)
            self._grouped_append(
                s, rows, s.ver[rows] * len(s.classes) + s.cls_code[rows],
                dest)
        else:
            for i in pend:
                pool = self._route(s, i)
                key = (int(s.ver[i]), s.slo(i).name)
                pool.pending.setdefault(key, IndexQueue()).append(i)
        # relaunch on pools that just came alive
        gainers = [(c, p) for c, p in s.pools.items()
                   if p.weight > 0 and old_live.get(c, 0.0) <= 0
                   and p.size() == 0 and c not in down]
        wsum = sum(p.weight for _, p in gainers) or 1.0
        for c, pool in gainers:
            if size_hint is not None and c in size_hint:
                share = int(size_hint[c])
            else:
                share = int(round(moved * pool.weight / wsum))
            # surge headroom: the shrinking pools are still finishing their
            # in-flight batches, so the deployment-total bound must not
            # count them against the destination (they retire right after)
            n = dep.autoscaler.relaunch_pool(
                share, pool.queue_len(),
                self._pool_headroom(st, s, pool, assume_live=True, t=t))
            if n > 0 and self.market is not None \
                    and self.market.state_bytes > 0:
                # replica warm handoff: the relaunched cohort migrates the
                # model state from the largest shrinking pool over the
                # interconnect instead of paying a cold model load --
                # whichever is cheaper (priced like artifact transfers)
                srcs = [(old_size[c2], c2) for c2, p2 in s.pools.items()
                        if c2 != c and old_size[c2] > 0
                        and old_live.get(c2, 0.0) > 0]
                if srcs:
                    from ...pipelines.artifacts import transfer_time_s
                    src_prof = s.pools[max(srcs)[1]].profile
                    tr = transfer_time_s(src_prof, pool.profile,
                                         self.market.state_bytes)
                    if tr < pool.profile.model_load_s:
                        pool.handoff_load_s = tr
                        pool.handoff_n = n
                        self.log.record(
                            "capacity:handoff", 0.0, model=dep.name,
                            src=src_prof.name, dst=c, t_sim=round(t, 6),
                            replicas=n, transfer_s=round(tr, 6),
                            saved_s=round(pool.profile.model_load_s - tr,
                                          6))
            for i in range(n):
                self._launch(s, pool, t, events, st, down,
                             from_zero=(i == 0 and pool.queue_len() > 0),
                             forced_cold=True)
        norm = self._norm_weights(s)
        self.log.record("gateway:split", 0.0, model=dep.name,
                        t_sim=round(t, 6), reason=reason, requeued=requeued,
                        weights={c: round(w, 6) for c, w in norm.items()})
        if reason in ("fail", "recover"):
            # src/dst compare NORMALIZED shares: src is the cloud that LOST
            # traffic share (the failed cloud on an outage, the absorber on
            # recovery), dst the largest gainer -- a surviving split pool
            # that absorbs a dead cloud's traffic by renormalization is a
            # real destination; dst=None means nowhere to go at all
            old_total = sum(old_live.values())
            old_norm = {c: (w / old_total if old_total > 0 else 0.0)
                        for c, w in old_live.items()}
            losses = {c: old_norm[c] - norm[c] for c in s.pools
                      if old_norm[c] - norm[c] > 1e-12}
            gains = {c: norm[c] - old_norm[c] for c in s.pools
                     if norm[c] - old_norm[c] > 1e-12}
            # no share moved but something drained (e.g. a dead cloud's
            # lingering soft-drain replicas): attribute the edge's cloud,
            # not the primary
            src = (max(losses, key=losses.get) if losses
                   else edge_cloud or dep.profile.name)
            dst = max(gains, key=gains.get) if gains else None
            event = ("gateway:failover" if reason == "fail"
                     else "gateway:recover")
            self.log.record(event, 0.0, model=dep.name, src=src, dst=dst,
                            t_sim=round(t, 6), requeued=requeued)

    # -- continuous re-planning (probes) ------------------------------------
    def _work_left(self, st) -> bool:
        return any(p.queue_len() or p.scheduled_up
                   or any(r.busy for r in p.replicas.values())
                   for s in st.values() for p in s.pools.values())

    def _pool_overloaded(self, s: _ModelState, pool: _Pool) -> bool:
        """ReplanConfig overload rule, shared by the blocked detection and
        the destination filter so the two can never drift apart.  Counts
        shed-pressure as queue depth: a pool shedding hard keeps a short
        queue, but it is still overloaded."""
        cfg = self.replan
        q = s.dep.autoscaler.effective_queue(pool.queue_len(),
                                             pool.shed_pressure,
                                             self._alert_pressure(s))
        return q > (cfg.overload_factor * s.dep.autoscaler.cfg.target_queue
                    * max(pool.size(), 1))

    def _alert_pressure(self, s: _ModelState) -> int:
        """Extra queue depth an active SLO burn-rate alert contributes to
        every scaling / overload read for this model (telemetry/slo.py)."""
        if self.burn is None:
            return 0
        return self.burn.pressure(s.dep.name,
                                  s.dep.autoscaler.cfg.target_queue)

    def _probe(self, st, t, events, down) -> set:
        """One auto-replan check over every model (ReplanConfig)."""
        cfg = self.replan
        touched = set()
        for m, s in st.items():
            # during an outage the live weights are a temporary emergency
            # adjustment: probe shifts then stay live-only, so recovery
            # still restores the DECLARED (nominal) split
            update_nominal = not any(c in down for c in s.pools)
            live = [(c, p) for c, p in s.pools.items() if p.weight > 0]
            if not live:
                s.streak["hot"] = s.streak["cold"] = 0
                s.win_n = s.win_miss = s.win_shed = 0
                s.win_epoch += 1
                continue
            asc = s.dep.autoscaler
            blocked = [
                (c, p) for c, p in live
                if self._pool_overloaded(s, p)
                and self._pool_headroom(st, s, p, down, t=t) <= 0]
            miss = (s.win_n >= cfg.min_window_n
                    and s.win_miss / s.win_n > cfg.max_miss_rate)
            # shedding is an overload signal, never a mask: a window shed
            # rate over budget arms the same shift as a miss-rate breach
            offered = s.win_n + s.win_shed
            shed_hot = (offered >= cfg.min_window_n
                        and s.win_shed / offered > cfg.max_shed_rate)
            # an active burn-rate alert arms the same shift: the monitor's
            # sliding windows typically trip BEFORE the probe-window rates
            # accumulate (it sees every completion, not probe epochs)
            burning = self.burn is not None and self.burn.is_burning(m)
            # an active profile-drift alert arms the same shift: the live
            # placement was sized from numbers the DriftMonitor has shown
            # to be stale, so re-plan from observed demand while the
            # re-profile is in flight
            drifting = self.drift is not None and self.drift.is_drifting(m)
            was_shedding = s.win_shed > 0
            # the window is consumed by THIS probe whatever it decides --
            # an aborted shift (no destination) must not leak completions
            # into the next window.  Pool shed-pressure is window-scoped
            # too once probes are running (launches also clear it).
            s.win_n = s.win_miss = s.win_shed = 0
            s.win_epoch += 1
            for _, p in live:
                p.shed_pressure = 0
            if blocked or miss or shed_hot or burning or drifting:
                s.streak["hot"] += 1
                s.streak["cold"] = 0
                # remember what ARMED the trigger: the firing probe's own
                # flags may differ from what built the streak
                s.streak_why = ("overload" if blocked
                                else "miss_rate" if miss
                                else "shed_rate" if shed_hot
                                else "slo_burn" if burning
                                else "profile_drift")
            else:
                s.streak["hot"] = 0
                idle_split = (cfg.consolidate and len(live) > 1
                              and s.queue_len() == 0
                              and not was_shedding
                              and not any(r.busy
                                          for _, p in live
                                          for r in p.replicas.values()))
                s.streak["cold"] = s.streak["cold"] + 1 if idle_split else 0
            if s.streak["hot"] >= cfg.sustain:
                # hottest pool sheds toward the cheapest cloud with headroom
                src_c, src_p = max(live, key=lambda cp: (
                    cp[1].queue_len() / max(cp[1].size(), 1),
                    cp[1].profile.cost_per_s, cp[0]))
                views = []
                for c, p in s.pools.items():
                    if c == src_c or c in down:
                        continue
                    if self._pool_overloaded(s, p):
                        continue     # equally drowning: shifting there just
                    views.append(    # ping-pongs the backlog, no relief
                        PoolView(c, p.profile.cost_per_s, p.size(),
                                 self._pool_headroom(st, s, p, down,
                                                     assume_live=True,
                                                     t=t)))
                pick = asc.pick_scale_up(views)
                if pick is None:
                    continue     # streak stays armed: the first probe after
                                 # a destination frees up shifts immediately
                s.streak["hot"] = 0
                delta = cfg.shift * src_p.weight
                target = {c: p.weight for c, p in s.pools.items()}
                target[src_c] -= delta
                target[pick.cloud] += delta
                self.log.record("gateway:migrate", 0.0, model=m,
                                t_sim=round(t, 6), src=src_c, dst=pick.cloud,
                                delta=round(delta, 6), reason=s.streak_why)
                self._set_weights(s, t, target, reason="migrate",
                                  events=events, st=st, down=down,
                                  update_nominal=update_nominal)
                touched.add(m)
            elif s.streak["cold"] >= cfg.sustain:
                # idle fleet: fold the most expensive pool into the cheapest
                src = asc.pick_retire(
                    [PoolView(c, p.profile.cost_per_s, p.size(), 0)
                     for c, p in live])
                # real headroom, like the overload branch: never fold the
                # whole split onto a cloud that cannot actually grow
                others = [PoolView(c, p.profile.cost_per_s, p.size(),
                                   self._pool_headroom(st, s, p, down,
                                                       assume_live=True,
                                                       t=t))
                          for c, p in live if c != (src.cloud if src else None)]
                dst = asc.pick_scale_up(others)
                if src is None or dst is None:
                    continue     # streak stays armed, same as the hot path
                s.streak["cold"] = 0
                target = {c: p.weight for c, p in s.pools.items()}
                target[dst.cloud] += target[src.cloud]
                target[src.cloud] = 0.0
                self.log.record("gateway:migrate", 0.0, model=m,
                                t_sim=round(t, 6), src=src.cloud,
                                dst=dst.cloud,
                                delta=round(target[dst.cloud], 6),
                                reason="cost")
                self._set_weights(s, t, target, reason="migrate",
                                  events=events, st=st, down=down,
                                  update_nominal=update_nominal)
                touched.add(m)
        return touched

    # -- scaling ------------------------------------------------------------
    def _pool_cap(self, s: _ModelState, pool: _Pool) -> int:
        """Max replicas this pool may hold: its ceil-share of max_replicas
        by live weight (a pool holding ALL the traffic gets the whole
        budget), never below its floor."""
        total = sum(p.weight for p in s.pools.values())
        if pool.weight <= 0 or total <= 0:
            return pool.floor
        cfg = s.dep.autoscaler.cfg
        cap = max(cfg.max_replicas, cfg.min_replicas)
        return max(math.ceil(cap * pool.weight / total), pool.floor)

    def _pool_headroom(self, st, s: _ModelState, pool: _Pool,
                       down: Optional[dict] = None,
                       assume_live: bool = False,
                       t: Optional[float] = None) -> int:
        """Replicas this pool can still add under its weight share, the
        deployment budget, and the shared cloud capacity.  assume_live
        asks "could this cloud absorb a weight shift?": it prices a
        zero-weight pool as if it held traffic and skips the
        deployment-total bound, because the source pool drains after the
        shift (live migration runs a transient surge on purpose)."""
        cloud = pool.profile.name
        if down and cloud in down:
            return 0
        cfg = s.dep.autoscaler.cfg
        budget = max(cfg.max_replicas, cfg.min_replicas)
        if assume_live:
            room = budget - pool.size()
        elif pool.weight <= 0:
            room = 0
        else:
            room = min(self._pool_cap(s, pool) - pool.size(),
                       budget - s.total_pool())
        cap = self.capacity.get(cloud)
        if cap is not None:
            used = self._cloud_usage(st, cloud)
            if self.market is not None and t is not None \
                    and not self.market.serving_priority:
                # without priority, live training leases block the slots;
                # with priority they are preemptible, i.e. free headroom
                used += self.market.training_active(cloud, t)
            room = min(room, cap - used)
        return max(room, 0)

    def _autoscale(self, s: _ModelState, t: float, events: EventHeap, st,
                   down) -> None:
        cfg = s.dep.autoscaler.cfg
        budget = max(cfg.max_replicas, cfg.min_replicas)
        alert_q = self._alert_pressure(s)
        for pool in s.pools.values():
            # shed-pressure counts as queue depth: demand that admission
            # control dropped is still demand, and must drive scale-up
            # rather than be masked by the now-short queue; an active SLO
            # burn alert adds model-wide pressure the same way -- but only
            # to pools actually carrying traffic (a zero-weight standby
            # must not scale from zero on an alert it cannot serve)
            q = s.dep.autoscaler.effective_queue(
                pool.queue_len(), pool.shed_pressure,
                alert_q if pool.weight > 0 else 0)
            if q > 0 and pool.size() == 0:   # scale from zero: spin up one
                if s.total_pool() >= budget:
                    # queued work is pinned to THIS pool (routing moves only
                    # on weight shifts), so starving it would stall the run:
                    # breach the deployment budget loudly instead
                    self.log.record("gateway:budget_exceeded", 0.0,
                                    model=s.dep.name,
                                    cloud=pool.profile.name,
                                    t_sim=round(t, 6))
                self._launch(s, pool, t, events, st, down,
                             from_zero=True)
                continue
            # at most ONE launch per pool per evaluation (KPA rate-limits
            # scale-up; also the pre-gateway sim's cadence of one replica
            # per batch completion, which the legacy kserve path depends
            # on); per-pool ceil-share caps may SUM over the budget, so the
            # deployment total is enforced here too
            if (s.dep.autoscaler.scale_up_needed(q, pool.size())
                    and pool.size() < self._pool_cap(s, pool)
                    and s.total_pool() < budget):
                self._launch(s, pool, t, events, st, down)

    def _cloud_usage(self, st, cloud: str) -> int:
        return sum(p.size() for x in st.values()
                   for c, p in x.pools.items() if c == cloud)

    def _note_usage(self, st, cloud: str, t: float) -> None:
        if self.record_batches:
            self.usage_trace.append((t, cloud, self._cloud_usage(st, cloud)))

    # -- capacity-market bridge (market mode only) ---------------------------
    def _market_lease(self, model: str, cloud: str, t: float, *,
                      force: bool = False):
        """Take one serving lease for ``model`` on ``cloud`` at ``t``,
        preempting recorded/live training leases while the ledger is full
        (serving priority; ``force`` is the floor path, which always
        wins).  Returns the Lease, or None on an unledgered cloud / when
        priority is off and the cloud is full."""
        led = self.market.ledger(cloud)
        if led is None:
            return None
        lease = led.lease("serving", f"pool:{model}", t)
        while lease is None and (force or self.market.serving_priority):
            victim = led.preempt_youngest(t, "training")
            if victim is None:
                break
            self.log.record("capacity:preempt", 0.0, model=model,
                            cloud=cloud, holder=victim.holder,
                            t_sim=round(t, 6))
            lease = led.lease("serving", f"pool:{model}", t)
        if lease is not None:
            self._leases.setdefault((model, cloud), []).append(lease)
            self.log.record("capacity:lease", 0.0, model=model, cloud=cloud,
                            kind="serving", t_sim=round(t, 6))
        return lease

    def _market_release(self, model: str, cloud: str, t: float,
                        n: int = 1) -> None:
        """Close ``n`` of ``model``'s serving leases on ``cloud`` at
        ``t``.  Leases are fungible within a pool: the newest open one is
        released first."""
        led = self.market.ledger(cloud)
        leases = self._leases.get((model, cloud))
        if led is None or not leases:
            return
        for _ in range(n):
            while leases and leases[-1].status != "active":
                leases.pop()
            if not leases:
                return
            led.release(leases.pop(), t)

    def _launch(self, s: _ModelState, pool: _Pool, t: float,
                events: EventHeap, st, down, *, from_zero: bool = False,
                forced_cold: bool = False) -> bool:
        cloud = pool.profile.name
        if cloud in down:                # nothing schedules on a dead cloud
            self.log.record("gateway:scale_denied", 0.0, model=s.dep.name,
                            cloud=cloud, t_sim=round(t, 6),
                            reason="cloud_down")
            return False
        cap = self.capacity.get(cloud)
        if cap is not None:
            used = self._cloud_usage(st, cloud)
            if self.market is not None:
                # the ledger is the source of truth: live training leases
                # occupy slots too.  With serving priority they are spot --
                # preempt the youngest until this replica fits.
                used += self.market.training_active(cloud, t)
                while used >= cap:
                    victim = self.market.preempt_training(cloud, t)
                    if victim is None:
                        break
                    self.log.record("capacity:preempt", 0.0,
                                    model=s.dep.name, cloud=cloud,
                                    holder=victim.holder,
                                    t_sim=round(t, 6))
                    used -= 1
            if used >= cap:
                if not from_zero:
                    self.log.record("gateway:scale_denied", 0.0,
                                    model=s.dep.name, cloud=cloud,
                                    t_sim=round(t, 6), reason="capacity")
                    return False
                # a pool at size 0 would starve forever if every other pool
                # on this cloud is warm-pinned: serve over budget, loudly
                self.log.record("gateway:capacity_exceeded", 0.0,
                                model=s.dep.name, cloud=cloud,
                                t_sim=round(t, 6))
        if self.market is not None:
            self._market_lease(s.dep.name, cloud, t)
        delay = s.dep.autoscaler.cfg.scale_up_delay_s
        pool.scheduled_up += 1
        pool.shed_pressure = 0           # the overload signal did its job
        s.trace.append((t, s.total_pool()))
        self._note_usage(st, cloud, t)
        events.push(t + delay, "up", s.dep.name,
                    (cloud, pool.generation, forced_cold))
        self.log.record("gateway:scale_up", delay, model=s.dep.name,
                        t_sim=round(t, 6), pool=s.total_pool(), cloud=cloud,
                        from_zero=from_zero)
        return True

    def _retire(self, s: _ModelState, pool: _Pool, r: _Replica, t: float,
                st) -> None:
        pool.replica_seconds += max(t - r.created_s, 0.0)
        del pool.replicas[r.rid]
        if self.market is not None:
            self._market_release(s.dep.name, pool.profile.name, t)
        s.trace.append((t, s.total_pool()))
        self._note_usage(st, pool.profile.name, t)
        self.log.record("gateway:scale_down", 0.0, model=s.dep.name,
                        t_sim=round(t, 6), pool=s.total_pool(),
                        cloud=pool.profile.name)
        if s.total_pool() == 0:
            self.log.record("gateway:scale_to_zero", 0.0, model=s.dep.name,
                            t_sim=round(t, 6))

    def _maybe_retire(self, s: _ModelState, t: float, payload, st) -> None:
        cloud, rid, stamp = payload
        pool = s.pools[cloud]
        r = pool.replicas.get(rid)
        if r is None or r.busy or r.last_active > stamp:
            return                       # reused since the check was scheduled
        if not s.dep.autoscaler.can_remove(pool.size(), pool.floor):
            return
        self._retire(s, pool, r, t, st)
