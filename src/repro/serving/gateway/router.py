"""Model-mesh serving gateway: one router fronting MANY models.

The pre-gateway repo could stress-test a single InferenceService; this
package is the fleet layer (ROADMAP north star: "heavy traffic from
millions of users").  A Gateway owns per-model Deployments -- each a
backend (Predictor or BatcherBackend), a CloudProfile, a replica pool and
an Autoscaler -- and runs a mixed multi-model workload (per-model burst /
Poisson TrafficSpecs) through ONE discrete-event simulation with shared
per-cloud replica capacity.

The simulation contract is the repo-wide hardware gate (DESIGN.md):
compute service times are MEASURED on this host (jitted predict per pow2
batch bucket, or real decode steps for the LLM backend); network RTT /
load-balancer / model-load constants are SIMULATED from the CloudProfile.
InferenceService (serving/kserve.py) is now a single-model client of this
router, so the paper's Table-3 stress test and the fleet simulation share
one event loop.

SLO layer (DESIGN.md S3): every request carries an SLOClass
(latency / standard / batch).  Dispatch serves the queue maximizing
``weight * age-of-oldest`` instead of longest-queue; a ``latency`` batch
may preempt an in-flight ``batch`` batch (the victim re-queues,
gateway:preempt).  A FailureSpec marks a cloud down mid-run: affected
pools drain (in-flight work re-queues), deployments fail over to their
standby CloudProfile paying control-plane + model_load_s cold starts
(gateway:failover), and migrate back the same way when the window ends
(gateway:recover).

Event kinds: "arr" request arrival, "up" replica joins the pool after the
control-plane delay, "free" replica finishes a batch, "idle" idle-window
expiry check (scale-down / scale-to-zero, autoscaler.py), "fail"/"recover"
FailureSpec window edges.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import time
from typing import Any, Callable, Optional

import numpy as np

from ...clouds.profiles import CloudProfile
from ...telemetry.events import EventLog
from .autoscaler import Autoscaler, AutoscalerConfig


# -- SLO classes -------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SLOClass:
    """A traffic priority class.

    weight scales queue age in dispatch scoring (higher = served sooner);
    deadline_mult sets the per-request deadline as a multiple of the
    deployment's warm single-request path (rtt + lb + service_time(1)), so
    the same class means the same *relative* promise on any backend.
    ``preempts`` classes may evict an in-flight ``preemptible`` batch when
    no replica is idle.
    """
    name: str
    weight: float
    deadline_mult: float
    preempts: bool = False
    preemptible: bool = False


SLO_CLASSES = {
    "latency": SLOClass("latency", weight=8.0, deadline_mult=4.0,
                        preempts=True),
    "standard": SLOClass("standard", weight=1.0, deadline_mult=20.0),
    "batch": SLOClass("batch", weight=0.25, deadline_mult=math.inf,
                      preemptible=True),
}


def resolve_slo(slo) -> SLOClass:
    if isinstance(slo, SLOClass):
        return slo
    try:
        return SLO_CLASSES[slo]
    except KeyError:
        raise ValueError(f"unknown SLO class {slo!r}; "
                         f"known: {sorted(SLO_CLASSES)}") from None


@dataclasses.dataclass(frozen=True)
class FailureSpec:
    """A simulated cloud outage: ``cloud`` is down over
    [at_s, at_s + duration_s).  Injected via Gateway.run(failures=[...])."""
    cloud: str
    at_s: float
    duration_s: float

    def __post_init__(self):
        if self.at_s < 0 or self.duration_s <= 0:
            raise ValueError("FailureSpec needs at_s >= 0 and duration_s > 0")


# -- results / backends (moved from kserve.py; it re-exports them) ----------

def _class_stats(lats: list, misses: int) -> dict:
    n = len(lats)
    return {"n": n,
            "p50_s": round(float(np.percentile(lats, 50)), 6),
            "p99_s": round(float(np.percentile(lats, 99)), 6),
            "miss_rate": round(misses / n, 4)}


@dataclasses.dataclass
class ServeResult:
    strategy: str
    n_requests: int
    total_time_s: float
    latencies_s: list
    replica_trace: list = dataclasses.field(default_factory=list)
    per_version: dict = dataclasses.field(default_factory=dict)
    class_latencies: dict = dataclasses.field(default_factory=dict)
    class_misses: dict = dataclasses.field(default_factory=dict)
    observed: dict = dataclasses.field(default_factory=dict)

    @property
    def p50(self):
        return float(np.percentile(self.latencies_s, 50))

    @property
    def p99(self):
        return float(np.percentile(self.latencies_s, 99))

    def per_class(self) -> dict:
        """Per-SLO-class p50/p99 and deadline-miss rate."""
        return {c: _class_stats(lats, self.class_misses.get(c, 0))
                for c, lats in sorted(self.class_latencies.items())}

    def summary(self) -> dict:
        return {"strategy": self.strategy, "n": self.n_requests,
                "total_s": round(self.total_time_s, 4),
                "p50_s": round(self.p50, 4), "p99_s": round(self.p99, 4),
                "replicas_max": max([r for _, r in self.replica_trace], default=1),
                **({"per_version": self.per_version} if self.per_version else {}),
                **({"per_class": self.per_class()}
                   if self.class_latencies else {})}


class Predictor:
    """A deployable model version: jitted predict over a batch of inputs."""

    def __init__(self, name: str, predict_fn: Callable, example_input: Any):
        self.name = name
        self.predict_fn = predict_fn
        self.example_input = example_input
        self._lat_cache: dict[int, float] = {}

    def _batch_of(self, b: int):
        x = self.example_input
        reps = [b] + [1] * (np.ndim(x) - 1)
        return np.tile(x[:1], reps)

    def warmup(self, batch_sizes=(1,)):
        for b in batch_sizes:
            self.service_time(b)

    def service_time(self, b: int) -> float:
        """Measured wall latency of a predict on this host, at b rounded up
        to its pow2 bucket (jit retrace control lives HERE, not in the
        router: analytic backends like BatcherBackend price exact b)."""
        b = _pow2(b)
        if b not in self._lat_cache:
            x = self._batch_of(b)
            out = self.predict_fn(x)
            jax_block(out)                       # compile
            t0 = time.perf_counter()
            for _ in range(3):
                jax_block(self.predict_fn(x))
            self._lat_cache[b] = (time.perf_counter() - t0) / 3
        return self._lat_cache[b]

    def predict(self, x):
        return self.predict_fn(x)


class BatcherBackend:
    """Adapt a ContinuousBatcher (serving/continuous.py) as a router backend.

    An LLM's unit of work is decode steps, not one jitted call: a request
    costs ``prompt_len + gen_tokens`` steps (teacher-forced catch-up, then
    generation), and b concurrent requests run in ``ceil(b / max_slots)``
    slot waves.  Per-step wall time is measured once by draining a real
    request through the batcher (after a jit warmup drain), keeping the
    compute term hardware-true like Predictor.service_time.
    """

    def __init__(self, name: str, batcher, *, prompt_len: int = 8,
                 gen_tokens: int = 8):
        self.name = name
        self.batcher = batcher
        self.prompt_len = prompt_len
        self.gen_tokens = gen_tokens
        self._step_time: Optional[float] = None

    def _measure(self) -> float:
        prompt = [1 + (i % 97) for i in range(self.prompt_len)]
        self.batcher.submit(prompt, self.gen_tokens)
        self.batcher.run()                       # warmup: jit compile
        steps0 = self.batcher.step_count
        self.batcher.submit(prompt, self.gen_tokens)
        t0 = time.perf_counter()
        self.batcher.run()
        dt = time.perf_counter() - t0
        return dt / max(self.batcher.step_count - steps0, 1)

    def service_time(self, b: int) -> float:
        if self._step_time is None:
            self._step_time = self._measure()
        waves = math.ceil(b / self.batcher.max_slots)
        return waves * (self.prompt_len + self.gen_tokens) * self._step_time

    def generate(self, prompts: list, max_new: int) -> list:
        """Real generation passthrough (not simulated)."""
        reqs = [self.batcher.submit(list(p), max_new) for p in prompts]
        self.batcher.run()
        return [r.output for r in reqs]


def jax_block(x):
    try:
        import jax
        jax.block_until_ready(x)
    except Exception:
        pass


def _pow2(b: int) -> int:
    """Measure service times on pow2 batch buckets (jit retrace control)."""
    n = 1
    while n < b:
        n *= 2
    return n


# -- workload / deployment ---------------------------------------------------

@dataclasses.dataclass
class TrafficSpec:
    """One arrival stream for one model.  Several specs may target the same
    model (e.g. two bursts separated by more than the idle window to force
    a scale-to-zero -> cold-start cycle).  ``slo`` is an SLO_CLASSES key or
    a custom SLOClass instance applied to every request of this stream."""
    model: str
    n: int
    arrival: str = "burst"               # "burst" | "poisson"
    rate: float = 0.0                    # poisson req/s
    start_s: float = 0.0
    arrivals: Optional[Any] = None       # explicit times override generation
    slo: Any = "standard"                # str key or SLOClass

    def gen(self, rng) -> np.ndarray:
        if self.arrivals is not None:
            return np.asarray(self.arrivals, float)
        if self.arrival == "burst":
            return np.full(self.n, float(self.start_s))
        if self.arrival == "poisson":
            gaps = rng.exponential(1.0 / max(self.rate, 1e-9), self.n)
            return self.start_s + np.cumsum(gaps)
        raise ValueError(f"unknown arrival kind {self.arrival!r}")


@dataclasses.dataclass
class Deployment:
    name: str
    backend: Any                         # .name + .service_time(b) -> s
    profile: CloudProfile
    autoscaler: Autoscaler
    max_batch: int = 32
    canary: Any = None
    canary_fraction: float = 0.0
    standby: Optional[CloudProfile] = None   # failover target cloud

    @property
    def backends(self) -> list:
        return [self.backend] + ([self.canary] if self.canary is not None
                                 else [])


@dataclasses.dataclass
class _Replica:
    rid: int
    warm: bool                           # cold replicas pay model_load_s once
    busy: bool = False
    last_active: float = 0.0
    epoch: int = 0                       # bumps per assignment/preemption;
    inflight: Optional[dict] = None      # stale "free" events check it


class _ModelState:
    def __init__(self, dep: Deployment, arr: np.ndarray, ver: np.ndarray,
                 cls: list):
        self.dep = dep
        self.arr = arr
        self.ver = ver
        self.cls = cls                   # SLOClass per request index
        self.lat = np.full(len(arr), -1.0)
        # dispatch queues keyed (version, slo name); requests stay in
        # arrival order within a queue
        self.pending: dict[tuple, list] = {}
        self.slo_by_name: dict[str, SLOClass] = {}
        for c in cls:
            prev = self.slo_by_name.setdefault(c.name, c)
            if prev != c:                # queues are keyed by name: two
                raise ValueError(        # defs would silently share one
                    f"conflicting SLOClass definitions named {c.name!r} "
                    f"on {dep.name!r}: {prev} vs {c}")
        self.replicas: dict[int, _Replica] = {}
        self.scheduled_up = 0
        self.next_rid = 0
        self.generation = 0              # bumps on failover; stale "up"
        self.active = dep.profile        # current cloud (failover switches)
        self.trace: list = []
        self.cold_starts = 0
        self.per_version: dict[str, int] = {}
        self.served = 0
        self.busy_s = 0.0                # realized backend service seconds

    @property
    def pool(self) -> int:
        return len(self.replicas) + self.scheduled_up

    def queue_len(self) -> int:
        return sum(len(q) for q in self.pending.values())


@dataclasses.dataclass
class GatewayResult:
    per_model: dict                      # name -> ServeResult
    cold_starts: dict                    # name -> int
    makespan_s: float

    def per_class(self) -> dict:
        """Fleet-wide per-SLO-class stats (latencies pooled across models)."""
        lats: dict[str, list] = {}
        miss: dict[str, int] = {}
        for r in self.per_model.values():
            for c, ls in r.class_latencies.items():
                lats.setdefault(c, []).extend(ls)
                miss[c] = miss.get(c, 0) + r.class_misses.get(c, 0)
        return {c: _class_stats(ls, miss.get(c, 0))
                for c, ls in sorted(lats.items())}

    def summary(self) -> dict:
        out = {"makespan_s": round(self.makespan_s, 4),
               "cold_starts": dict(self.cold_starts),
               "models": {m: r.summary() for m, r in self.per_model.items()}}
        pc = self.per_class()
        if pc:
            out["per_class"] = pc
        return out


# -- the router --------------------------------------------------------------

class Gateway:
    """Routes a mixed multi-model workload to per-model replica pools.

    capacity: optional {cloud_name: max_total_replicas} shared across every
    deployment placed on that cloud -- the knob the placement planner
    (placement.py) sizes against.  The cap bounds ELASTIC scale-up
    (over-budget requests are denied and logged gateway:scale_denied);
    run() rejects a configuration whose baseline min_replicas pools
    already exceed it, and a scale-from-zero launch that would otherwise
    starve forever proceeds over budget with a gateway:capacity_exceeded
    event (the K8s analog: the pod pends, then preempts -- we choose
    serve-and-log so the simulation always completes).

    record_batches=True keeps a per-batch audit trail (batch_log) and a
    per-cloud usage trace (usage_trace) for the invariant test suite.
    """

    def __init__(self, *, capacity: Optional[dict] = None,
                 log: Optional[EventLog] = None,
                 record_batches: bool = False):
        self.deployments: dict[str, Deployment] = {}
        self.capacity = dict(capacity or {})
        self.log = log or EventLog()
        self.record_batches = record_batches
        self.batch_log: list = []        # dicts, one per dispatched batch
        self.usage_trace: list = []      # (t, cloud, replicas_incl_scheduled)

    def deploy(self, name: str, backend, profile: CloudProfile, *,
               autoscaler=None, max_batch: int = 32,
               canary=None, canary_fraction: float = 0.0,
               standby: Optional[CloudProfile] = None) -> Deployment:
        if isinstance(autoscaler, AutoscalerConfig):
            autoscaler = Autoscaler(autoscaler)
        if standby is not None and standby.name == profile.name:
            raise ValueError("standby must be a different cloud")
        dep = Deployment(name, backend, profile, autoscaler or Autoscaler(),
                         max_batch, canary, canary_fraction, standby)
        self.deployments[name] = dep
        return dep

    # -- discrete-event loop ------------------------------------------------
    def run(self, traffic: list, seed: int = 0,
            failures: Optional[list] = None) -> GatewayResult:
        self.batch_log = []              # audit trails cover ONE run
        self.usage_trace = []
        rng = np.random.default_rng(seed)
        by_model: dict[str, list] = {}
        for spec in traffic:
            if spec.model not in self.deployments:
                raise KeyError(f"no deployment named {spec.model!r}")
            by_model.setdefault(spec.model, []).append(spec)

        base: dict[str, int] = {}        # cloud -> baseline min_replicas,
        for dep in self.deployments.values():   # over EVERY deployment: an
            base[dep.profile.name] = (base.get(dep.profile.name, 0)  # idle
                                      + dep.autoscaler.cfg.min_replicas)
        for cloud, n in base.items():    # pool still holds cloud capacity
            cap = self.capacity.get(cloud)
            if cap is not None and n > cap:
                raise ValueError(
                    f"min_replicas on {cloud!r} total {n} > capacity {cap}")

        events: list = []                # (t, seq, kind, model, payload)
        seq = itertools.count()
        down: dict[str, int] = {}        # cloud -> active failure windows
        st: dict[str, _ModelState] = {}
        for m, dep in self.deployments.items():
            specs = by_model.get(m, [])
            times, classes = [], []
            for spec in specs:
                ts = spec.gen(rng)
                times.append(ts)
                classes.extend([resolve_slo(spec.slo)] * len(ts))
            arr = np.concatenate(times) if times else np.zeros(0)
            order = np.argsort(arr, kind="stable")
            arr = arr[order]
            cls = [classes[i] for i in order]
            ver = np.zeros(len(arr), int)
            if dep.canary is not None and dep.canary_fraction > 0:
                ver = (rng.random(len(arr)) < dep.canary_fraction).astype(int)
            s = st[m] = _ModelState(dep, arr, ver, cls)
            for _ in range(dep.autoscaler.cfg.min_replicas):
                s.replicas[s.next_rid] = _Replica(s.next_rid, warm=True)
                s.next_rid += 1
            s.trace.append((0.0, len(s.replicas)))
            for i, t in enumerate(arr):
                heapq.heappush(events, (float(t), next(seq), "arr", m, i))
        for f in failures or []:
            heapq.heappush(events, (float(f.at_s), next(seq),
                                    "fail", "", f.cloud))
            heapq.heappush(events, (float(f.at_s + f.duration_s), next(seq),
                                    "recover", "", f.cloud))

        with self.log.stage("gateway:run", models=sorted(by_model),
                            n=int(sum(len(x.arr) for x in st.values()))):
            while events:
                t = events[0][0]
                touched, idle_checks = set(), []
                # apply every state change at time t before dispatching so a
                # burst admits as full batches (pre-gateway sim semantics);
                # idle expiries run last so a coincident arrival wins the
                # replica instead of forcing a retire + cold start
                while events and events[0][0] == t:
                    _, _, kind, m, data = heapq.heappop(events)
                    if kind == "fail":
                        down[data] = down.get(data, 0) + 1
                        if down[data] == 1:
                            for name, x in st.items():
                                if x.active.name == data:
                                    self._migrate(x, t, events, seq, st, down,
                                                  reason="fail")
                                    touched.add(name)
                        continue
                    if kind == "recover":
                        down[data] -= 1
                        if down[data] == 0:
                            del down[data]
                            for name, x in st.items():
                                if (x.dep.profile.name == data
                                        and x.active.name != data):
                                    self._migrate(x, t, events, seq, st, down,
                                                  reason="recover")
                                    touched.add(name)
                                elif x.active.name == data:
                                    # pool drained in place (no standby):
                                    # relaunch COLD -- the outage destroyed
                                    # the pods, whatever cold_scale_up says
                                    self._migrate(x, t, events, seq, st, down,
                                                  reason="recover")
                                    touched.add(name)
                                elif (x.active.name in down and x.dep.standby
                                      and x.dep.standby.name == data):
                                    # primary still down, standby back up:
                                    # delayed failover
                                    self._migrate(x, t, events, seq, st, down,
                                                  reason="fail")
                                    touched.add(name)
                        continue
                    s = st[m]
                    if kind == "arr":
                        key = (int(s.ver[data]), s.cls[data].name)
                        s.pending.setdefault(key, []).append(data)
                        touched.add(m)
                    elif kind == "up":
                        gen, forced_cold = data
                        if gen != s.generation:
                            continue     # scheduled before a failover drain
                        s.scheduled_up -= 1
                        warm = (not s.dep.autoscaler.cfg.cold_scale_up
                                and not forced_cold)
                        s.replicas[s.next_rid] = _Replica(
                            s.next_rid, warm=warm, last_active=t)
                        if s.dep.autoscaler.tracks_idle:
                            # a replica that joins after the queue drained
                            # would otherwise never get an idle check
                            heapq.heappush(events, (
                                t + s.dep.autoscaler.cfg.idle_window_s,
                                next(seq), "idle", m, (s.next_rid, t)))
                        s.next_rid += 1
                        touched.add(m)
                    elif kind == "free":
                        rid, epoch = data
                        r = s.replicas.get(rid)
                        if r is not None and r.epoch == epoch:
                            r.busy = False
                            r.inflight = None
                            r.last_active = t
                            if s.dep.autoscaler.tracks_idle:
                                heapq.heappush(events, (
                                    t + s.dep.autoscaler.cfg.idle_window_s,
                                    next(seq), "idle", m, (rid, t)))
                            touched.add(m)
                    else:                # "idle"
                        idle_checks.append((m, data))
                for m in touched:
                    self._dispatch(st[m], t, events, seq)
                    self._autoscale(st[m], t, events, seq, st, down)
                for m, payload in idle_checks:
                    self._maybe_retire(st[m], t, payload, st)

        results, cold, makespan = {}, {}, 0.0
        for m, s in st.items():
            if not len(s.arr):           # deployed but untrafficked: holds
                continue                 # capacity, reports no results
            if s.served < len(s.arr):
                raise RuntimeError(
                    f"gateway stalled: {m} served {s.served}/{len(s.arr)}")
            total = max((float(s.arr[i] + s.lat[i]) for i in range(len(s.arr))),
                        default=0.0)
            makespan = max(makespan, total)
            results[m] = self._result(s, total)
            cold[m] = s.cold_starts
        return GatewayResult(results, cold, makespan)

    def _result(self, s: _ModelState, total: float) -> ServeResult:
        dep = s.dep
        # deadline base: the warm single-request path on the PRIMARY cloud
        # (failover cold starts count against the same promise)
        base = (dep.profile.network_rtt_s + dep.profile.lb_overhead_s
                + dep.backend.service_time(1))
        cls_lats: dict[str, list] = {}
        cls_miss: dict[str, int] = {}
        for i in range(len(s.arr)):
            c = s.cls[i]
            cls_lats.setdefault(c.name, []).append(float(s.lat[i]))
            if s.lat[i] > c.deadline_mult * base:
                cls_miss[c.name] = cls_miss.get(c.name, 0) + 1
        n = len(s.arr)
        window = float(s.arr.max() - s.arr.min()) if n > 1 else 0.0
        if window <= 1e-9:               # pure burst: fall back to the span
            window = max(total - float(s.arr.min()), 1e-9)
        observed = {"rate_rps": n / window,
                    "service_time_s": s.busy_s / n,
                    "window_s": window, "n": n}
        self.log.record("gateway:observed", 0.0, model=dep.name,
                        rate_rps=round(observed["rate_rps"], 4),
                        service_time_s=round(observed["service_time_s"], 8),
                        n=n)
        return ServeResult(f"gateway:{dep.name}", n, total, s.lat.tolist(),
                           s.trace, per_version=s.per_version,
                           class_latencies=cls_lats, class_misses=cls_miss,
                           observed=observed)

    # -- dispatch -----------------------------------------------------------
    def _best_queue(self, s: _ModelState, keys: list, t: float) -> tuple:
        """Class-weighted age: serve the queue maximizing weight * age of
        its oldest request; ties fall to weight then earliest arrival."""
        def rank(k):
            q = s.pending[k]
            w = s.slo_by_name[k[1]].weight
            return (w * (t - float(s.arr[q[0]])), w, -q[0])
        return max(keys, key=rank)

    def _dispatch(self, s: _ModelState, t: float, events, seq) -> None:
        while True:
            keys = [k for k, q in s.pending.items() if q]
            if not keys:
                return
            idle = [r for r in s.replicas.values() if not r.busy]
            if idle:
                key = self._best_queue(s, keys, t)
                r = min(idle, key=lambda x: x.rid)
            else:
                pkeys = [k for k in keys if s.slo_by_name[k[1]].preempts]
                if not pkeys:
                    return
                key = self._best_queue(s, pkeys, t)
                w = s.slo_by_name[key[1]].weight
                # strict weight order prevents preemption livelock (a class
                # can never evict work of its own or a higher class)
                victims = [r for r in s.replicas.values()
                           if r.busy and r.inflight is not None
                           and r.inflight["slo"].preemptible
                           and r.inflight["slo"].weight < w]
                if not victims:
                    return
                # evict the batch with the most remaining work (least sunk)
                r = max(victims, key=lambda x: (x.inflight["done"], x.rid))
                n_back = self._reclaim(s, r, t)
                self.log.record("gateway:preempt", 0.0, model=s.dep.name,
                                t_sim=round(t, 6), rid=r.rid, requeued=n_back,
                                by=key[1])
            self._assign(s, r, key, t, events, seq)

    def _assign(self, s: _ModelState, r: _Replica, key: tuple, t: float,
                events, seq) -> None:
        dep = s.dep
        v, cname = key
        take = s.pending[key][:dep.max_batch]
        s.pending[key] = s.pending[key][len(take):]
        cold = 0.0
        if not r.warm:
            cold = s.active.model_load_s
            r.warm = True
            s.cold_starts += 1
            self.log.record("gateway:cold_start", cold, model=dep.name,
                            cloud=s.active.name, t_sim=round(t, 6))
        backend = dep.backends[v]
        b = len(take)
        svc = backend.service_time(b)
        done = (t + s.active.network_rtt_s + s.active.lb_overhead_s
                + cold + svc)
        for i in take:
            s.lat[i] = done - s.arr[i]
        s.served += b
        s.busy_s += svc
        s.per_version[backend.name] = s.per_version.get(backend.name, 0) + b
        r.busy = True
        r.last_active = done
        r.epoch += 1
        rec = None
        if self.record_batches:
            rec = {"model": dep.name, "rid": r.rid, "cloud": s.active.name,
                   "cls": cname, "version": v, "idx": tuple(take),
                   "start_s": t, "end_s": done, "preempted": False}
            self.batch_log.append(rec)
        r.inflight = {"idx": take, "v": v, "cls": cname,
                      "slo": s.slo_by_name[cname], "backend": backend.name,
                      "service_s": svc, "done": done, "record": rec}
        heapq.heappush(events, (done, next(seq), "free", dep.name,
                                (r.rid, r.epoch)))

    def _reclaim(self, s: _ModelState, r: _Replica, t: float) -> int:
        """Undo an in-flight batch (preemption or cloud failure): requests
        re-queue with their original arrival times, so they complete exactly
        once when re-dispatched.  Request index order IS arrival order
        (arrivals are sorted at init), so a sorted merge restores the
        queue's FIFO invariant even when several replicas reclaim into the
        same queue (e.g. a whole-pool failover drain)."""
        fl = r.inflight
        take = fl["idx"]
        key = (fl["v"], fl["cls"])
        s.pending[key] = sorted(take + s.pending.get(key, []))
        for i in take:
            s.lat[i] = -1.0
        s.served -= len(take)
        s.busy_s -= fl["service_s"]
        s.per_version[fl["backend"]] -= len(take)
        if fl["record"] is not None:
            fl["record"]["end_s"] = t
            fl["record"]["preempted"] = True
        r.busy = False
        r.inflight = None
        r.epoch += 1                     # invalidate the scheduled "free"
        r.last_active = t
        return len(take)

    # -- failover -----------------------------------------------------------
    def _migrate(self, s: _ModelState, t: float, events, seq, st, down, *,
                 reason: str) -> None:
        """Drain a pool off its current cloud and restart it on the target
        (standby on failure, primary on recovery).  In-flight work re-queues
        -- pod identity is not portable across clouds -- and every restarted
        replica is cold: it pays the control-plane delay plus the target
        profile's model_load_s on its first batch."""
        dep = s.dep
        pool_before = s.pool
        requeued = 0
        for r in list(s.replicas.values()):
            if r.busy and r.inflight is not None:
                requeued += self._reclaim(s, r, t)
        s.replicas.clear()
        s.generation += 1                # stale "up" events are dropped
        s.scheduled_up = 0
        s.trace.append((t, 0))
        if self.record_batches:
            self.usage_trace.append((t, s.active.name,
                                     self._cloud_usage(st, s.active.name)))
        src = s.active.name
        if reason == "recover":
            target = dep.profile
        else:
            target = (dep.standby if s.active.name == dep.profile.name
                      else dep.profile)
        if target is not None and target.name in down:
            target = None                # nowhere to go: drain and wait
        event = "gateway:failover" if reason == "fail" else "gateway:recover"
        self.log.record(event, 0.0, model=dep.name, src=src,
                        dst=target.name if target else None,
                        t_sim=round(t, 6), requeued=requeued)
        if target is None:
            return
        s.active = target
        n = dep.autoscaler.relaunch_pool(pool_before, s.queue_len())
        for i in range(n):
            self._launch(s, t, events, seq, st, down,
                         from_zero=(i == 0 and s.queue_len() > 0),
                         forced_cold=True)

    # -- scaling ------------------------------------------------------------
    def _autoscale(self, s: _ModelState, t: float, events, seq, st,
                   down) -> None:
        q = s.queue_len()
        if q > 0 and s.pool == 0:        # scale from zero: spin up one
            self._launch(s, t, events, seq, st, down, from_zero=True)
            return
        # at most ONE launch per evaluation (KPA rate-limits scale-up; also
        # the pre-gateway sim's cadence of one replica per batch completion,
        # which the legacy InferenceService path depends on)
        if s.dep.autoscaler.scale_up_needed(q, s.pool):
            self._launch(s, t, events, seq, st, down)

    def _cloud_usage(self, st, cloud: str) -> int:
        return sum(x.pool for x in st.values()
                   if x.active.name == cloud)

    def _launch(self, s: _ModelState, t: float, events, seq, st, down, *,
                from_zero: bool = False, forced_cold: bool = False) -> bool:
        cloud = s.active.name
        if cloud in down:                # nothing schedules on a dead cloud
            self.log.record("gateway:scale_denied", 0.0, model=s.dep.name,
                            cloud=cloud, t_sim=round(t, 6),
                            reason="cloud_down")
            return False
        cap = self.capacity.get(cloud)
        if cap is not None and self._cloud_usage(st, cloud) >= cap:
            if not from_zero:
                self.log.record("gateway:scale_denied", 0.0, model=s.dep.name,
                                cloud=cloud, t_sim=round(t, 6),
                                reason="capacity")
                return False
            # a deployment at pool 0 would starve forever if every other
            # pool on this cloud is warm-pinned: serve over budget, loudly
            self.log.record("gateway:capacity_exceeded", 0.0,
                            model=s.dep.name, cloud=cloud, t_sim=round(t, 6))
        delay = s.dep.autoscaler.cfg.scale_up_delay_s
        s.scheduled_up += 1
        s.trace.append((t, s.pool))
        if self.record_batches:
            self.usage_trace.append((t, cloud, self._cloud_usage(st, cloud)))
        heapq.heappush(events, (t + delay, next(seq), "up", s.dep.name,
                                (s.generation, forced_cold)))
        self.log.record("gateway:scale_up", delay, model=s.dep.name,
                        t_sim=round(t, 6), pool=s.pool, from_zero=from_zero)
        return True

    def _maybe_retire(self, s: _ModelState, t: float, payload, st) -> None:
        rid, stamp = payload
        r = s.replicas.get(rid)
        if r is None or r.busy or r.last_active > stamp:
            return                       # reused since the check was scheduled
        if not s.dep.autoscaler.can_remove(s.pool):
            return
        del s.replicas[rid]
        s.trace.append((t, s.pool))
        if self.record_batches:
            self.usage_trace.append((t, s.active.name,
                                     self._cloud_usage(st, s.active.name)))
        self.log.record("gateway:scale_down", 0.0, model=s.dep.name,
                        t_sim=round(t, 6), pool=s.pool)
        if s.pool == 0:
            self.log.record("gateway:scale_to_zero", 0.0, model=s.dep.name,
                            t_sim=round(t, 6))
