"""Multi-cloud placement planner (automated placement, MLModelCI analog --
arXiv:2006.05096): assign models to cloud profiles to minimize cost or p99
under per-cloud replica capacity.

Sizing is queueing-theoretic, not simulated: a model offering
``rate * service_time`` Erlangs needs ``ceil(load / target_util)`` replicas,
and its latency estimate inflates service time by an M/M/1-style waiting
term per replica.  The plan's capacity map feeds Gateway(capacity=...) so
the discrete-event simulation enforces what the planner assumed.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from ...clouds.profiles import CloudProfile

TARGET_UTILIZATION = 0.7


@dataclasses.dataclass(frozen=True)
class ModelDemand:
    name: str
    rate: float                  # expected offered load, req/s
    service_time_s: float        # per-request service time at typical batch

    @property
    def load(self) -> float:
        return self.rate * self.service_time_s   # Erlangs


@dataclasses.dataclass(frozen=True)
class CloudCapacity:
    profile: CloudProfile
    max_replicas: int
    cost_per_replica_hr: float


def replicas_needed(demand: ModelDemand, *,
                    target_util: float = TARGET_UTILIZATION) -> int:
    return max(1, math.ceil(demand.load / target_util))


def est_p99_s(profile: CloudProfile, demand: ModelDemand,
              replicas: int) -> float:
    """rtt + lb + service + 3x an M/M/1-style waiting term at per-replica
    utilization rho -- a tail estimate, deliberately coarse (the gateway
    simulation is the ground truth; this only has to rank clouds).

    Saturated assignments (rho >= 1, or no replicas at all) have no finite
    tail: the queue grows without bound, so the estimate is inf, never a
    misleading finite number."""
    if replicas <= 0:
        return math.inf
    rho = demand.load / replicas
    if rho >= 1.0:
        return math.inf
    wait = demand.service_time_s * rho / (1.0 - rho)
    return (profile.network_rtt_s + profile.lb_overhead_s
            + demand.service_time_s + 3.0 * wait)


@dataclasses.dataclass
class Assignment:
    model: str
    cloud: Optional[str]         # None => unplaceable under capacity
    replicas: int
    est_p99_s: float
    cost_hr: float

    @property
    def saturated(self) -> bool:
        """True when the assignment offers no finite latency bound
        (unplaced, zero replicas, or utilization >= 1)."""
        return not math.isfinite(self.est_p99_s)


@dataclasses.dataclass
class PlacementPlan:
    objective: str
    assignments: list
    feasible: bool
    clouds: list = dataclasses.field(default_factory=list)  # CloudCapacity

    @property
    def total_cost_hr(self) -> float:
        return sum(a.cost_hr for a in self.assignments if a.cloud)

    @property
    def worst_p99_s(self) -> float:
        """Worst estimated tail over the whole plan.  A saturated or
        unplaced assignment makes this inf: an infeasible plan must not
        report the finite tail of whatever happened to fit."""
        if any(a.saturated for a in self.assignments):
            return math.inf
        return max((a.est_p99_s for a in self.assignments if a.cloud),
                   default=0.0)

    def capacity_map(self) -> dict:
        """Planned replica budget per cloud, ready for Gateway(capacity=...)."""
        out: dict = {}
        for a in self.assignments:
            if a.cloud:
                out[a.cloud] = out.get(a.cloud, 0) + a.replicas
        return out

    def summary(self) -> dict:
        fin = lambda x: round(x, 6) if math.isfinite(x) else "inf"
        return {"objective": self.objective, "feasible": self.feasible,
                "total_cost_hr": round(self.total_cost_hr, 4),
                "worst_p99_s": fin(self.worst_p99_s),
                "assignments": {a.model: {
                    "cloud": a.cloud, "replicas": a.replicas,
                    "est_p99_s": fin(a.est_p99_s),
                    "saturated": a.saturated,
                    "cost_hr": round(a.cost_hr, 4)}
                    for a in self.assignments}}


def plan_placement(models: list, clouds: list,
                   objective: str = "cost") -> PlacementPlan:
    """Greedy by offered load, heaviest model first: each model takes the
    feasible cloud minimizing (cost, p99) or (p99, cost).  Greedy is exact
    enough at fleet sizes where this repo runs (tens of models, few clouds)
    and keeps the plan explainable."""
    assert objective in ("cost", "p99")
    remaining = {c.profile.name: c.max_replicas for c in clouds}
    assignments, feasible = [], True
    for d in sorted(models, key=lambda d: d.load, reverse=True):
        need = replicas_needed(d)
        best = None
        for c in clouds:
            if remaining[c.profile.name] < need:
                continue
            p99 = est_p99_s(c.profile, d, need)
            cost = need * c.cost_per_replica_hr
            key = (cost, p99) if objective == "cost" else (p99, cost)
            if best is None or key < best[0]:
                best = (key, c, p99, cost)
        if best is None:
            feasible = False
            assignments.append(Assignment(d.name, None, 0, math.inf, 0.0))
            continue
        _, c, p99, cost = best
        remaining[c.profile.name] -= need
        assignments.append(Assignment(d.name, c.profile.name, need, p99, cost))
    return PlacementPlan(objective, assignments, feasible, clouds=list(clouds))


def replan(plan: PlacementPlan, result, *, clouds: Optional[list] = None,
           objective: Optional[str] = None) -> PlacementPlan:
    """Re-plan from OBSERVED load (closing the estimate -> measure ->
    re-plan loop, MLModelCI analog): each model's demand is rebuilt from
    the arrival rate and realized per-request service time the gateway
    measured (ServeResult.observed / gateway:observed events), then placed
    again under the same clouds and objective.

    ``result`` is a GatewayResult from Gateway.run; ``clouds`` defaults to
    the CloudCapacity list the original plan was built against.  Models in
    the original plan that saw no traffic this window (Gateway.run omits
    them from per_model) keep their prior assignment: their replicas stay
    reserved, so the revised capacity_map still covers the whole fleet."""
    clouds = list(clouds) if clouds is not None else list(plan.clouds)
    if not clouds:
        raise ValueError("replan needs the CloudCapacity list: the original "
                         "plan carries none (pass clouds=...)")
    demands = []
    for name in sorted(result.per_model):
        obs = result.per_model[name].observed
        if not obs:
            raise ValueError(f"no observed load for {name!r}: run the "
                             "traffic through Gateway.run first")
        demands.append(ModelDemand(name, obs["rate_rps"],
                                   obs["service_time_s"]))
    kept = [a for a in plan.assignments if a.model not in result.per_model]
    reserve: dict = {}
    for a in kept:
        if a.cloud:
            reserve[a.cloud] = reserve.get(a.cloud, 0) + a.replicas
    shrunk = [dataclasses.replace(
        c, max_replicas=c.max_replicas - reserve.get(c.profile.name, 0))
        for c in clouds]
    new = plan_placement(demands, shrunk, objective=objective
                         or plan.objective)
    new.assignments.extend(kept)
    new.feasible = new.feasible and all(a.cloud for a in kept)
    new.clouds = clouds                  # report the REAL budgets, not the
    return new                           # reservation-shrunk ones
