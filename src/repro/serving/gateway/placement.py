"""Multi-cloud placement planner (automated placement, MLModelCI analog --
arXiv:2006.05096): assign models to cloud profiles to minimize cost or p99
under per-cloud replica capacity.

Sizing is queueing-theoretic, not simulated: a model offering
``rate * service_time`` Erlangs needs ``ceil(load / target_util)`` replicas,
and its latency estimate inflates service time by an M/M/1-style waiting
term per replica.  The plan's capacity map feeds Gateway(capacity=...) so
the discrete-event simulation enforces what the planner assumed.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from ...clouds.profiles import CloudProfile

TARGET_UTILIZATION = 0.7


@dataclasses.dataclass(frozen=True)
class ModelDemand:
    name: str
    rate: float                  # expected offered load, req/s
    service_time_s: float        # per-request service time at typical batch

    @property
    def load(self) -> float:
        return self.rate * self.service_time_s   # Erlangs


@dataclasses.dataclass(frozen=True)
class CloudCapacity:
    profile: CloudProfile
    max_replicas: int
    cost_per_replica_hr: float


def replicas_needed(demand: ModelDemand, *,
                    target_util: float = TARGET_UTILIZATION) -> int:
    return max(1, math.ceil(demand.load / target_util))


def est_p99_s(profile: CloudProfile, demand: ModelDemand,
              replicas: int) -> float:
    """rtt + lb + service + 3x an M/M/1-style waiting term at per-replica
    utilization rho -- a tail estimate, deliberately coarse (the gateway
    simulation is the ground truth; this only has to rank clouds)."""
    rho = demand.load / replicas
    if rho >= 1.0:
        return math.inf
    wait = demand.service_time_s * rho / (1.0 - rho)
    return (profile.network_rtt_s + profile.lb_overhead_s
            + demand.service_time_s + 3.0 * wait)


@dataclasses.dataclass
class Assignment:
    model: str
    cloud: Optional[str]         # None => unplaceable under capacity
    replicas: int
    est_p99_s: float
    cost_hr: float


@dataclasses.dataclass
class PlacementPlan:
    objective: str
    assignments: list
    feasible: bool

    @property
    def total_cost_hr(self) -> float:
        return sum(a.cost_hr for a in self.assignments if a.cloud)

    @property
    def worst_p99_s(self) -> float:
        return max((a.est_p99_s for a in self.assignments if a.cloud),
                   default=0.0)

    def capacity_map(self) -> dict:
        """Planned replica budget per cloud, ready for Gateway(capacity=...)."""
        out: dict = {}
        for a in self.assignments:
            if a.cloud:
                out[a.cloud] = out.get(a.cloud, 0) + a.replicas
        return out

    def summary(self) -> dict:
        fin = lambda x: round(x, 6) if math.isfinite(x) else "inf"
        return {"objective": self.objective, "feasible": self.feasible,
                "total_cost_hr": round(self.total_cost_hr, 4),
                "worst_p99_s": fin(self.worst_p99_s),
                "assignments": {a.model: {
                    "cloud": a.cloud, "replicas": a.replicas,
                    "est_p99_s": fin(a.est_p99_s),
                    "cost_hr": round(a.cost_hr, 4)}
                    for a in self.assignments}}


def plan_placement(models: list, clouds: list,
                   objective: str = "cost") -> PlacementPlan:
    """Greedy by offered load, heaviest model first: each model takes the
    feasible cloud minimizing (cost, p99) or (p99, cost).  Greedy is exact
    enough at fleet sizes where this repo runs (tens of models, few clouds)
    and keeps the plan explainable."""
    assert objective in ("cost", "p99")
    remaining = {c.profile.name: c.max_replicas for c in clouds}
    assignments, feasible = [], True
    for d in sorted(models, key=lambda d: d.load, reverse=True):
        need = replicas_needed(d)
        best = None
        for c in clouds:
            if remaining[c.profile.name] < need:
                continue
            p99 = est_p99_s(c.profile, d, need)
            cost = need * c.cost_per_replica_hr
            key = (cost, p99) if objective == "cost" else (p99, cost)
            if best is None or key < best[0]:
                best = (key, c, p99, cost)
        if best is None:
            feasible = False
            assignments.append(Assignment(d.name, None, 0, math.inf, 0.0))
            continue
        _, c, p99, cost = best
        remaining[c.profile.name] -= need
        assignments.append(Assignment(d.name, c.profile.name, need, p99, cost))
    return PlacementPlan(objective, assignments, feasible)
