"""Multi-cloud placement planner (automated placement, MLModelCI analog --
arXiv:2006.05096): assign models to cloud profiles to minimize cost or p99
under per-cloud replica capacity.  With ``split=True`` an assignment may
SPREAD one model across several clouds -- fractional traffic shares backed
by per-cloud replica counts -- which is what the router's active-active
mode (serving/gateway/router.py) consumes.

Sizing is queueing-theoretic, not simulated: a model offering
``rate * service_time`` Erlangs needs ``ceil(load / target_util)`` replicas,
and its latency estimate inflates service time by an M/M/1-style waiting
term per replica.  The plan's capacity map feeds Gateway(capacity=...) so
the discrete-event simulation enforces what the planner assumed.

``diff_plans(old, new)`` emits a MigrationPlan -- the per-model weight /
replica deltas between two plans -- which ``Gateway.run(migrations=[
MigrationSpec(at_s, plan)])`` applies live, mid-run, without dropping
requests (drain-and-shift, router.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from ...clouds.profiles import CloudProfile

TARGET_UTILIZATION = 0.7


@dataclasses.dataclass(frozen=True)
class ModelDemand:
    name: str
    rate: float                  # expected offered load, req/s
    service_time_s: float        # per-request service time at typical batch
    # disaggregated demand (ISSUE 8): split the per-request cost into the
    # compute-bound prompt-ingest phase and the bandwidth-bound decode
    # phase so a planner can size prefill and decode tiers separately.
    # When both are set they OVERRIDE service_time_s for load purposes
    # (effective per-request time = prefill_s + decode_s); left at None
    # the blended single-phase model is unchanged.
    prefill_s: Optional[float] = None    # compute: serial prompt ingest
    decode_s: Optional[float] = None     # bandwidth: generation steps

    @property
    def effective_service_s(self) -> float:
        if self.prefill_s is not None and self.decode_s is not None:
            return self.prefill_s + self.decode_s
        return self.service_time_s

    @property
    def load(self) -> float:
        return self.rate * self.effective_service_s   # Erlangs

    @property
    def prefill_load(self) -> float:
        """Erlangs of prompt-ingest compute (0 when not disaggregated)."""
        return self.rate * (self.prefill_s or 0.0)

    @property
    def decode_load(self) -> float:
        """Erlangs of generation bandwidth (full load when blended)."""
        if self.prefill_s is not None and self.decode_s is not None:
            return self.rate * self.decode_s
        return self.load


@dataclasses.dataclass(frozen=True)
class CloudCapacity:
    profile: CloudProfile
    max_replicas: int
    cost_per_replica_hr: Optional[float] = None  # None: profile price sheet

    @property
    def replica_cost_hr(self) -> float:
        if self.cost_per_replica_hr is not None:
            return self.cost_per_replica_hr
        return self.profile.cost_per_s * 3600.0


def replicas_needed(demand: ModelDemand, *,
                    target_util: float = TARGET_UTILIZATION) -> int:
    # the 1e-9 slack absorbs float noise in the Erlang arithmetic: a rate
    # derived as k*util/t then multiplied back by t can land a hair above
    # k*util and must not round up to an extra replica
    return max(1, math.ceil(demand.load / target_util - 1e-9))


def est_wait_s(demand: ModelDemand, replicas: int) -> float:
    """Expected steady-state queueing wait (M/M/1-style, per replica) --
    the planner's expected-queue hint.  An Assignment carries one per
    cloud; the router's queue-aware `_route` uses it as a prior for pools
    that have no live queue signal yet (Gateway.deploy(queue_hint=...)).
    inf when saturated, same rule as est_p99_s."""
    if replicas <= 0:
        return math.inf
    rho = demand.load / replicas
    if rho >= 1.0:
        return math.inf
    return demand.effective_service_s * rho / (1.0 - rho)


def est_p99_s(profile: CloudProfile, demand: ModelDemand,
              replicas: int) -> float:
    """rtt + lb + service + 3x an M/M/1-style waiting term at per-replica
    utilization rho -- a tail estimate, deliberately coarse (the gateway
    simulation is the ground truth; this only has to rank clouds).

    Saturated assignments (rho >= 1, or no replicas at all) have no finite
    tail: the queue grows without bound, so the estimate is inf, never a
    misleading finite number."""
    wait = est_wait_s(demand, replicas)
    if not math.isfinite(wait):
        return math.inf
    return (profile.network_rtt_s + profile.lb_overhead_s
            + demand.effective_service_s + 3.0 * wait)


@dataclasses.dataclass
class Assignment:
    """One model's placement: per-cloud replica shares plus the traffic
    weights the router should split arrivals by.  A single-cloud placement
    is the degenerate one-entry case; ``shares == {}`` means unplaceable
    under capacity.  Weights always sum to 1 for a placed model.
    ``est_wait_s`` is the per-cloud expected-queue hint (steady-state
    queueing wait at the planned utilization) that feeds
    Gateway.deploy(queue_hint=...) for queue-aware routing."""
    model: str
    shares: dict                 # cloud -> replicas (int)
    weights: dict                # cloud -> traffic fraction
    est_p99_s: float             # worst share's tail estimate
    cost_hr: float
    est_wait_s: dict = dataclasses.field(default_factory=dict)

    @property
    def cloud(self) -> Optional[str]:
        """Primary cloud (largest traffic weight); None when unplaceable."""
        if not self.shares:
            return None
        return max(self.weights, key=lambda c: (self.weights[c], c))

    @property
    def replicas(self) -> int:
        return sum(self.shares.values())

    @property
    def saturated(self) -> bool:
        """True when the assignment offers no finite latency bound
        (unplaced, zero replicas, or utilization >= 1)."""
        return not math.isfinite(self.est_p99_s)


def _single(model: str, cloud: Optional[str], replicas: int,
            p99: float, cost: float, wait: float = math.inf) -> Assignment:
    if cloud is None:
        return Assignment(model, {}, {}, math.inf, 0.0)
    return Assignment(model, {cloud: replicas}, {cloud: 1.0}, p99, cost,
                      {cloud: wait})


@dataclasses.dataclass
class PlacementPlan:
    objective: str
    assignments: list
    feasible: bool
    clouds: list = dataclasses.field(default_factory=list)  # CloudCapacity
    split: bool = False          # planner allowed multi-cloud assignments

    @property
    def total_cost_hr(self) -> float:
        return sum(a.cost_hr for a in self.assignments if a.shares)

    @property
    def worst_p99_s(self) -> float:
        """Worst estimated tail over the whole plan.  A saturated or
        unplaced assignment makes this inf: an infeasible plan must not
        report the finite tail of whatever happened to fit."""
        if any(a.saturated for a in self.assignments):
            return math.inf
        return max((a.est_p99_s for a in self.assignments if a.shares),
                   default=0.0)

    def capacity_map(self) -> dict:
        """Planned replica budget per cloud, ready for Gateway(capacity=...)."""
        out: dict = {}
        for a in self.assignments:
            for cloud, n in a.shares.items():
                out[cloud] = out.get(cloud, 0) + n
        return out

    def summary(self) -> dict:
        fin = lambda x: round(x, 6) if math.isfinite(x) else "inf"
        return {"objective": self.objective, "feasible": self.feasible,
                "split": self.split,
                "total_cost_hr": round(self.total_cost_hr, 4),
                "worst_p99_s": fin(self.worst_p99_s),
                "assignments": {a.model: {
                    "cloud": a.cloud, "replicas": a.replicas,
                    "est_p99_s": fin(a.est_p99_s),
                    "saturated": a.saturated,
                    "cost_hr": round(a.cost_hr, 4),
                    **({"est_wait_s": {c: fin(w)
                                       for c, w in a.est_wait_s.items()}}
                       if a.est_wait_s else {}),
                    **({"shares": dict(a.shares),
                        "weights": {c: round(w, 6)
                                    for c, w in a.weights.items()}}
                       if len(a.shares) > 1 else {})}
                    for a in self.assignments}}


def _split_assign(d: ModelDemand, need: int, clouds: list,
                  remaining: dict, objective: str) -> Optional[Assignment]:
    """Fill the ranked clouds first-to-last until the replica need is met,
    splitting the model when the best cloud alone cannot hold it.  Traffic
    weights are proportional to replica shares, so per-pool utilization is
    uniform and the share-weighted tail estimate stays honest."""
    def rank(c):
        p99 = est_p99_s(c.profile, d, need)
        cost = c.replica_cost_hr
        return ((cost, p99, c.profile.name) if objective == "cost"
                else (p99, cost, c.profile.name))

    shares: dict = {}
    left = need
    for c in sorted(clouds, key=rank):
        take = min(remaining[c.profile.name], left)
        if take <= 0:
            continue
        shares[c.profile.name] = take
        left -= take
        if left == 0:
            break
    if left > 0:                 # does not fit anywhere: leave capacity alone
        return None
    by_name = {c.profile.name: c for c in clouds}
    weights = {cl: n / need for cl, n in shares.items()}
    # a mixture's tail is pinned by its SLOWEST share as soon as that share
    # holds more than ~1% of traffic, so the estimate is the max over
    # pools, never a weight-average that would under-report it
    # replace() keeps the prefill/decode split (when set) attached to each
    # share, so disaggregated demand prices identically across pools
    est = max(est_p99_s(
        by_name[cl].profile,
        dataclasses.replace(d, rate=d.rate * weights[cl]), n)
        for cl, n in shares.items())
    waits = {cl: est_wait_s(
        dataclasses.replace(d, rate=d.rate * weights[cl]), n)
        for cl, n in shares.items()}
    cost = sum(n * by_name[cl].replica_cost_hr for cl, n in shares.items())
    for cl, n in shares.items():
        remaining[cl] -= n
    return Assignment(d.name, shares, weights, est, cost, waits)


def plan_placement(models: list, clouds: list, objective: str = "cost", *,
                   split: bool = False) -> PlacementPlan:
    """Greedy by offered load, heaviest model first: each model takes the
    feasible cloud minimizing (cost, p99) or (p99, cost).  Greedy is exact
    enough at fleet sizes where this repo runs (tens of models, few clouds)
    and keeps the plan explainable.

    split=True allows fractional assignments: a model fills the ranked
    clouds in order, spilling onto the next when capacity runs out, and the
    plan records per-cloud traffic weights for the router's active-active
    splitter.  A fleet that is infeasible single-cloud can be feasible
    split (the capacity fragments add up)."""
    assert objective in ("cost", "p99")
    remaining = {c.profile.name: c.max_replicas for c in clouds}
    assignments, feasible = [], True
    for d in sorted(models, key=lambda d: d.load, reverse=True):
        need = replicas_needed(d)
        if split:
            a = _split_assign(d, need, clouds, remaining, objective)
            if a is None:
                feasible = False
                a = _single(d.name, None, 0, math.inf, 0.0)
            assignments.append(a)
            continue
        best = None
        for c in clouds:
            if remaining[c.profile.name] < need:
                continue
            p99 = est_p99_s(c.profile, d, need)
            cost = need * c.replica_cost_hr
            key = (cost, p99) if objective == "cost" else (p99, cost)
            if best is None or key < best[0]:
                best = (key, c, p99, cost)
        if best is None:
            feasible = False
            assignments.append(_single(d.name, None, 0, math.inf, 0.0))
            continue
        _, c, p99, cost = best
        remaining[c.profile.name] -= need
        assignments.append(_single(d.name, c.profile.name, need, p99, cost,
                                   est_wait_s(d, need)))
    return PlacementPlan(objective, assignments, feasible,
                         clouds=list(clouds), split=split)


# -- plan diffs: the live-migration contract ---------------------------------

@dataclasses.dataclass(frozen=True)
class MigrationStep:
    """Target state for ONE model: the traffic weights the router should
    shift to (sum to 1), the planned per-cloud replica counts (a sizing
    hint for relaunches), and the CloudProfiles backing any cloud the
    deployment has not seen before."""
    model: str
    weights: dict                # cloud -> target traffic fraction
    replicas: dict               # cloud -> planned replicas
    profiles: dict               # cloud -> CloudProfile

    def __post_init__(self):
        if not self.weights or any(w < 0 for w in self.weights.values()):
            raise ValueError(f"{self.model}: weights must be non-negative "
                             f"and non-empty, got {self.weights}")
        total = sum(self.weights.values())
        if abs(total - 1.0) > 1e-6:
            raise ValueError(f"{self.model}: migration weights must sum "
                             f"to 1, got {total}")
        missing = set(self.weights) - set(self.profiles)
        if missing:
            raise ValueError(f"{self.model}: no CloudProfile for "
                             f"{sorted(missing)}")


@dataclasses.dataclass
class MigrationPlan:
    """The diff between two PlacementPlans: one MigrationStep per model
    whose split changed.  Applied live by the router (drain-and-shift)."""
    steps: list

    @property
    def models(self) -> list:
        return [s.model for s in self.steps]

    def summary(self) -> dict:
        return {s.model: {"weights": {c: round(w, 6)
                                      for c, w in s.weights.items()},
                          "replicas": dict(s.replicas)}
                for s in self.steps}


def diff_plans(old: PlacementPlan, new: PlacementPlan) -> MigrationPlan:
    """Per-model weight/replica deltas between two plans.  Models placed
    identically in both plans are omitted; a model unplaceable in ``new``
    is omitted too (there is no routable target to shift it to -- the
    plan's ``feasible`` flag already says so)."""
    profiles = {c.profile.name: c.profile
                for c in list(old.clouds) + list(new.clouds)}
    old_by = {a.model: a for a in old.assignments}
    steps = []
    for a in new.assignments:
        if not a.shares:
            continue
        o = old_by.get(a.model)
        if o is not None and o.shares == a.shares and o.weights == a.weights:
            continue
        steps.append(MigrationStep(
            a.model, dict(a.weights), dict(a.shares),
            {c: profiles[c] for c in a.shares}))
    return MigrationPlan(steps)


def replan(plan: PlacementPlan, result, *, clouds: Optional[list] = None,
           objective: Optional[str] = None,
           split: Optional[bool] = None,
           alerts: Optional[set] = None,
           alert_headroom: float = 1.25) -> PlacementPlan:
    """Re-plan from OBSERVED load (closing the estimate -> measure ->
    re-plan loop, MLModelCI analog): each model's demand is rebuilt from
    the arrival rate and realized per-request service time the gateway
    measured (ServeResult.observed / gateway:observed events), then placed
    again under the same clouds and objective.

    ``result`` is a GatewayResult from Gateway.run; ``clouds`` defaults to
    the CloudCapacity list the original plan was built against; ``split``
    defaults to whatever the original plan allowed.  Models in the original
    plan that saw no traffic this window (Gateway.run omits them from
    per_model) keep their prior assignment: their replicas stay reserved,
    so the revised capacity_map still covers the whole fleet.

    ``alerts`` is a set of model names under an SLO burn-rate alert
    (telemetry/slo.py: BurnRateMonitor.alerting_models(), or the models in
    the run's ``gateway:alert`` events): the observed rate alone UNDERSTATES
    their demand (it is what the overloaded fleet managed to absorb, sheds
    included only as a count), so their demand is inflated by
    ``alert_headroom`` before placement."""
    clouds = list(clouds) if clouds is not None else list(plan.clouds)
    if not clouds:
        raise ValueError("replan needs the CloudCapacity list: the original "
                         "plan carries none (pass clouds=...)")
    if alert_headroom < 1.0:
        raise ValueError("alert_headroom must be >= 1")
    demands = []
    for name in sorted(result.per_model):
        obs = result.per_model[name].observed
        if not obs:
            raise ValueError(f"no observed load for {name!r}: run the "
                             "traffic through Gateway.run first")
        rate = obs["rate_rps"]
        if alerts and name in alerts:
            rate *= alert_headroom
        demands.append(ModelDemand(name, rate,
                                   obs["service_time_s"]))
    kept = [a for a in plan.assignments if a.model not in result.per_model]
    reserve: dict = {}
    for a in kept:
        for cloud, n in a.shares.items():
            reserve[cloud] = reserve.get(cloud, 0) + n
    shrunk = [dataclasses.replace(
        c, max_replicas=c.max_replicas - reserve.get(c.profile.name, 0))
        for c in clouds]
    new = plan_placement(demands, shrunk, objective=objective
                         or plan.objective,
                         split=plan.split if split is None else split)
    new.assignments.extend(kept)
    new.feasible = new.feasible and all(a.shares for a in kept)
    new.clouds = clouds                  # report the REAL budgets, not the
    return new                           # reservation-shrunk ones
