"""Artifact / checkpoint store -- the PVC analog.

Pytrees are flattened to path-keyed .npz shards; every artifact gets a
content hash, so pipeline steps can be cached (Kubeflow component caching
analog) and model versions can be diffed for canary rollouts.
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def tree_hash(tree: Any) -> str:
    h = hashlib.sha256()
    for key, arr in sorted(_flatten(tree).items()):
        h.update(key.encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(np.ascontiguousarray(arr).tobytes()[:65536])  # prefix hash
    return h.hexdigest()[:16]


class ArtifactStore:
    """Content-addressed artifact store rooted at a directory ("volume")."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, name: str) -> str:
        return os.path.join(self.root, name)

    # -- pytrees (params, optimizer states) --------------------------------
    def save_tree(self, name: str, tree: Any, meta: Optional[dict] = None) -> str:
        flat = _flatten(tree)
        path = self._path(f"{name}.npz")
        np.savez(path, **flat)
        record = {"name": name, "kind": "tree", "hash": tree_hash(tree),
                  "time": time.time(), "meta": meta or {},
                  "leaves": len(flat)}
        with open(self._path(f"{name}.json"), "w") as f:
            json.dump(record, f)
        return f"file://{path}"

    def load_tree(self, name: str, like: Any) -> Any:
        """Restore into the structure of `like` (shapes/dtypes preserved)."""
        data = np.load(self._path(f"{name}.npz"))
        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, leaf in paths:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            arr = data[key]
            leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    # -- json blobs (metrics, configs, pipeline specs) ----------------------
    def save_json(self, name: str, obj: Any) -> str:
        path = self._path(f"{name}.json")
        with open(path, "w") as f:
            json.dump(obj, f, indent=1, default=str)
        return f"file://{path}"

    def load_json(self, name: str) -> Any:
        with open(self._path(f"{name}.json")) as f:
            return json.load(f)

    def exists(self, name: str) -> bool:
        return os.path.exists(self._path(f"{name}.json")) or os.path.exists(
            self._path(f"{name}.npz"))

    def list(self) -> list:
        return sorted(os.listdir(self.root))
