"""Single-token GQA decode attention Pallas kernel (the decode_32k /
long_500k hot spot).

One new query token per sequence attends over a long, padded KV cache.
Tiling: grid = (batch, kv_heads, kv_blocks); each step loads a
(block_k, head_dim) KV tile into VMEM and updates fp32 online-softmax
accumulators for the whole GQA *group* of queries at once ((group, d) tile),
so the MXU sees a (group x block_k) matmul instead of a vector dot.
Valid cache lengths arrive via scalar prefetch (SMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                   scale: float, block_k: int, n_kv_blocks: int):
    ib = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale               # (group, d)
    k = k_ref[0, :, 0, :].astype(jnp.float32)                 # (bk, d)
    s = q @ k.T                                               # (group, bk)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < len_ref[ib], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v_ref[0, :, 0, :].astype(jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_k", "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *, scale: float | None = None,
                     block_k: int = 512, interpret: bool = True) -> jax.Array:
    """q: (B,Hq,D); caches: (B,S,Hkv,D); cache_len: (B,) int32 -> (B,Hq,D)."""
    b, hq, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    group = max(hq // hkv, 1)
    scale_ = scale if scale is not None else d ** -0.5
    bk = min(block_k, s)
    pad_k = (-s) % bk
    if pad_k:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nk = k_cache.shape[1] // bk
    qg = q.reshape(b, hkv, group, d)
    kernel = functools.partial(_decode_kernel, scale=scale_, block_k=bk, n_kv_blocks=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, group, d), lambda ib, ih, ik, lens: (ib, ih, 0, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda ib, ih, ik, lens: (ib, ik, ih, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda ib, ih, ik, lens: (ib, ik, ih, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d), lambda ib, ih, ik, lens: (ib, ih, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(qg.shape, q.dtype),
        interpret=interpret,
    )(cache_len.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(b, hq, d)
