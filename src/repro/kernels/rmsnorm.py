"""Fused RMSNorm Pallas kernel (memory-bound fusion target).

Tiling: rows of the flattened (rows, D) input are blocked into VMEM with the
full feature dim resident (D <= a few K for all assigned archs, well within
the ~16 MB v5e VMEM at fp32 for block_rows*D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * (1.0 + scale_ref[...].astype(jnp.float32))
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool = True) -> jax.Array:
    """x: (..., D), scale: (D,). Returns same shape/dtype as x."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = max(1, x.size // d)
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // br,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
