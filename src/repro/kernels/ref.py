"""Pure-jnp reference oracles for every Pallas kernel.

These are the ground truth the kernels are validated against (tests/ sweeps
shapes & dtypes with assert_allclose), and also the default compute path on
CPU (interpret-mode Pallas is slow; model code dispatches via ops.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rmsnorm_ref(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def _gqa_expand(k: jax.Array, n_q_heads: int) -> jax.Array:
    """(B,S,Hkv,D) -> (B,S,Hq,D) by repeating kv heads."""
    b, s, hkv, d = k.shape
    group = n_q_heads // hkv
    return jnp.repeat(k, group, axis=2) if group > 1 else k


def flash_attention_ref(
    q: jax.Array,              # (B, Sq, Hq, D)
    k: jax.Array,              # (B, Skv, Hkv, D)
    v: jax.Array,              # (B, Skv, Hkv, D)
    *,
    causal: bool = True,
    window: int = 0,           # 0 = full; >0 = sliding window (causal)
    scale: float | None = None,
    q_offset: int = 0,         # absolute position of q[0] (for cached prefill)
) -> jax.Array:
    """Masked multi-head attention oracle, fp32 softmax accumulation.

    Dots use preferred_element_type=f32 on native-dtype operands rather
    than .astype(f32) inputs: casting k/v materialises f32 copies of the
    biggest tensors in the program (EXPERIMENTS.md §Perf C1)."""
    b, sq, hq, d = q.shape
    skv = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    k = _gqa_expand(k, hq)
    v = _gqa_expand(v, hq)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,              # (B, Hq, D) single query token per sequence
    k_cache: jax.Array,        # (B, S, Hkv, D)
    v_cache: jax.Array,        # (B, S, Hkv, D)
    cache_len: jax.Array,      # (B,) int32 valid lengths
    *,
    scale: float | None = None,
) -> jax.Array:
    """Single-token decode attention against a (padded) KV cache."""
    b, hq, d = q.shape
    s = k_cache.shape[1]
    scale = scale if scale is not None else d ** -0.5
    k = _gqa_expand(k_cache, hq)
    v = _gqa_expand(v_cache, hq)
    # native-dtype dots with f32 accumulation: never materialise an f32
    # copy of the KV cache (the dominant decode byte term, §Perf C1)
    logits = jnp.einsum("bhd,bkhd->bhk", q.astype(k.dtype), k,
                        preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(s)[None, :] < cache_len[:, None]          # (B, S)
    logits = jnp.where(valid[:, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def ssm_scan_ref(
    x: jax.Array,      # (B, S, H, P)   inputs per head
    dt: jax.Array,     # (B, S, H)      softplus'd timestep (>0)
    A: jax.Array,      # (H,)           negative decay rates
    Bm: jax.Array,     # (B, S, N)      input  projection (G=1 group)
    Cm: jax.Array,     # (B, S, N)      output projection
    *,
    h0: jax.Array | None = None,   # (B, H, P, N) initial state
):
    """Sequential Mamba2/SSD oracle.

    h_t = exp(A*dt_t) h_{t-1} + dt_t * (x_t outer B_t);  y_t = h_t . C_t
    Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf, Af = Bm.astype(jnp.float32), Cm.astype(jnp.float32), A.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    def step(hprev, t):
        decay = jnp.exp(Af[None, :] * dtf[:, t])                     # (B,H)
        inject = jnp.einsum("bh,bhp,bn->bhpn", dtf[:, t], xf[:, t], Bf[:, t])
        hnew = hprev * decay[..., None, None] + inject
        y = jnp.einsum("bhpn,bn->bhp", hnew, Cf[:, t])
        return hnew, y

    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), jnp.arange(s))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h_final


def mlstm_scan_ref(
    q: jax.Array,      # (B, S, H, D)
    k: jax.Array,      # (B, S, H, D)
    v: jax.Array,      # (B, S, H, D)
    i_gate: jax.Array, # (B, S, H)  log-space input gate preact
    f_gate: jax.Array, # (B, S, H)  forget gate preact (sigmoid-log space)
):
    """Sequential mLSTM oracle (xLSTM matrix memory, stabilised).

    C_t = f_t C_{t-1} + i_t v_t k_t^T ; n_t = f_t n_{t-1} + i_t k_t
    y_t = C~_t q_t / max(|n~_t . q_t|, exp(-m_t))
    where C~, n~ are the exp(-m_t)-stabilised accumulators and
    m_t = max(log f_t + m_{t-1}, log i_t) -- the xLSTM stabilised form;
    y is invariant to the stabiliser, so chunked implementations with a
    different m agree exactly.  Returns y (B,S,H,D).
    """
    b, s, h, d = q.shape
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))            # (B,S,H)
    logi = i_gate.astype(jnp.float32)

    def step(carry, t):
        C, n, m = carry                                              # (B,H,D,D),(B,H,D),(B,H)
        m_new = jnp.maximum(logf[:, t] + m, logi[:, t])
        fe = jnp.exp(logf[:, t] + m - m_new)
        ie = jnp.exp(logi[:, t] - m_new)
        C = C * fe[..., None, None] + ie[..., None, None] * jnp.einsum(
            "bhd,bhe->bhde", vf[:, t], kf[:, t])
        n = n * fe[..., None] + ie[..., None] * kf[:, t]
        qt = qf[:, t] * (d ** -0.5)
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt)), jnp.exp(-m_new))
        y = jnp.einsum("bhde,bhe->bhd", C, qt) / denom[..., None]
        return (C, n, m_new), y

    init = (
        jnp.zeros((b, h, d, d), jnp.float32),
        jnp.zeros((b, h, d), jnp.float32),
        jnp.full((b, h), -1e30, jnp.float32),
    )
    _, ys = jax.lax.scan(step, init, jnp.arange(s))
    return ys.transpose(1, 0, 2, 3).astype(q.dtype)
