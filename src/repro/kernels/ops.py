"""Jit'd dispatch layer: Pallas kernel on TPU (or interpret-mode when asked),
pure-jnp reference otherwise.

Model code calls these entry points only; ``use_kernel`` comes from
ArchConfig.use_kernels.  On this CPU container interpret=True executes the
kernel body in Python (slow) -- tests use it for correctness sweeps, while
smoke tests / benchmarks default to the jnp reference path.  On a real TPU
``interpret=False`` compiles the same kernels to Mosaic.
"""
from __future__ import annotations

import jax

from . import ref
from .decode_attention import decode_attention as _decode_pallas
from .flash_attention import flash_attention as _flash_pallas
from .rmsnorm import rmsnorm as _rmsnorm_pallas
from .ssm_scan import ssm_scan as _ssm_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def rmsnorm(x, scale, *, eps: float = 1e-6, use_kernel: bool = False):
    if use_kernel:
        return _rmsnorm_pallas(x, scale, eps=eps, interpret=_interpret())
    return ref.rmsnorm_ref(x, scale, eps)


def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    use_kernel: bool = False, block_q=128, block_k=128,
                    chunked: bool = False, chunk_k: int = 1024,
                    unroll: bool = False):
    if use_kernel:
        return _flash_pallas(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, block_q=block_q,
                             block_k=block_k, interpret=_interpret())
    if chunked:
        return flash_chunked_jnp(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset, chunk_k=chunk_k,
                                 unroll=unroll)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset)


def flash_chunked_jnp(q, k, v, *, causal=True, window=0, q_offset=0,
                      chunk_k: int = 1024, unroll: bool = False):
    """Online-softmax attention, lax.scan over KV chunks -- the jnp twin of
    the Pallas flash kernel: the (Sq, Skv) score matrix never exists as a
    whole, so HBM traffic stays O(S*D) instead of O(S^2).  Used as the
    'fused attention' model path for dry-run perf variants (on TPU the
    Pallas kernel takes over)."""
    import jax.numpy as jnp

    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]            # MLA: v head dim may differ from qk dim
    group = max(hq // hkv, 1)
    t = min(chunk_k, skv)
    pad = (-skv) % t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = k.shape[1] // t
    qf = q.astype(jnp.float32) * (d ** -0.5)
    qpos = jnp.arange(sq)[:, None] + q_offset                     # (Sq, 1)

    def kv_step(carry, ic):
        acc, m, l = carry                                          # fp32
        # dynamic_slice per chunk: a (B,nc,t,H,D) pre-reshape overflows the
        # 2^31 element limit for 32k x 16-head x 128 tensors
        kb = jax.lax.dynamic_slice_in_dim(k, ic * t, t, axis=1)    # (B,t,Hkv,D)
        vb = jax.lax.dynamic_slice_in_dim(v, ic * t, t, axis=1)
        kb = jnp.repeat(kb.astype(jnp.float32), group, axis=2)
        vb = jnp.repeat(vb.astype(jnp.float32), group, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb)                  # (B,Hq,Sq,t)
        kpos = ic * t + jnp.arange(t)[None, :]                     # (1, t)
        mask = kpos < skv
        if causal:
            mask = mask & (kpos <= qpos)
        if window > 0:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vb)
        return (acc, m_new, l), None

    init = (jnp.zeros((b, hq, sq, dv), jnp.float32),
            jnp.full((b, hq, sq), -1e30, jnp.float32),
            jnp.zeros((b, hq, sq), jnp.float32))
    (acc, m, l), _ = jax.lax.scan(kv_step, init, jnp.arange(nc),
                                  unroll=True if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, use_kernel: bool = False,
                     block_k=512):
    if use_kernel:
        return _decode_pallas(q, k_cache, v_cache, cache_len,
                              block_k=block_k, interpret=_interpret())
    return ref.decode_attention_ref(q, k_cache, v_cache, cache_len)


def ssm_scan(x, dt, A, Bm, Cm, *, chunk=256, use_kernel: bool = False,
             unroll: bool = False):
    """Returns (y, h_final). Reference path uses the chunked jnp algorithm
    (same math as the kernel), itself validated against the sequential
    oracle in tests."""
    if use_kernel:
        return _ssm_pallas(x, dt, A, Bm, Cm, chunk=chunk, interpret=_interpret())
    return ssd_chunked_jnp(x, dt, A, Bm, Cm, chunk=chunk, unroll=unroll)


def ssd_chunked_jnp(x, dt, A, Bm, Cm, *, chunk=256, h0=None, unroll: bool = False):
    """Chunked SSD in pure jnp (lax.scan over chunks) -- compact HLO for the
    512-device dry-run (one while-loop instead of S sequential steps)."""
    import jax.numpy as jnp

    b, s, h, p = x.shape
    n = Bm.shape[-1]
    t = min(chunk, s)
    pad = (-s) % t
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // t
    xf = x.astype(jnp.float32).reshape(b, nc, t, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, t, h)
    Bf = Bm.astype(jnp.float32).reshape(b, nc, t, n)
    Cf = Cm.astype(jnp.float32).reshape(b, nc, t, n)
    Af = A.astype(jnp.float32)
    tri = jnp.tril(jnp.ones((t, t), jnp.float32))

    def chunk_step(hprev, args):
        xc, dtc, bc, cc = args                       # (B,t,H,P),(B,t,H),(B,t,N),(B,t,N)
        log_a = Af[None, None, :] * dtc              # (B,t,H)
        cum = jnp.cumsum(log_a, axis=1)
        # mask the exponent BEFORE exp: upper-triangle cum_t-cum_s is large
        # positive (cum decreasing) and exp overflows -> inf*0 = NaN
        delta = jnp.where(tri[None, :, :, None] > 0,
                          cum[:, :, None, :] - cum[:, None, :, :], -1e30)
        L = jnp.exp(delta)
        G = jnp.einsum("btn,bsn->bts", cc, bc)       # (B,t,t)
        M = G[:, :, :, None] * L * dtc[:, None, :, :]        # (B,t,s,H)
        y_intra = jnp.einsum("btsh,bshp->bthp", M, xc)
        y_state = jnp.exp(cum)[..., None] * jnp.einsum("btn,bhpn->bthp", cc, hprev)
        w = dtc * jnp.exp(cum[:, -1:, :] - cum)      # (B,t,H)
        h_new = hprev * jnp.exp(cum[:, -1])[:, :, None, None] + jnp.einsum(
            "bthp,btn,bth->bhpn", xc, bc, w)
        return h_new, y_intra + y_state

    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    args = tuple(a.transpose(1, 0, *range(2, a.ndim)) for a in (xf, dtf, Bf, Cf))
    h_final, ys = jax.lax.scan(chunk_step, h0, args, unroll=True if unroll else 1)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * t, h, p)[:, :s]
    return y.astype(x.dtype), h_final
