"""Chunked mLSTM (xLSTM matrix-memory) Pallas kernel.

Same TPU mapping as ssm_scan: grid = (batch, heads, chunks) with chunks
innermost/sequential; the stabilised (C, n, m) carry lives in VMEM scratch;
within a chunk the recurrence becomes (T,T)/(T,D) MXU matmuls.  Matches
kernels.ref.mlstm_scan_ref (y is stabiliser-invariant) and the jnp twin
models/ssm._mlstm_chunked.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, y_ref,
                  c_ref, n_ref, m_ref, *, chunk: int, d: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)

    qs = q_ref[0, :, 0, :].astype(jnp.float32) * (d ** -0.5)      # (T,D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    li = li_ref[0, :, 0].astype(jnp.float32)                      # (T,)
    lf = lf_ref[0, :, 0].astype(jnp.float32)
    C, nv, m = c_ref[...], n_ref[0], m_ref[0, 0]

    bcum = jnp.cumsum(lf)                                         # (T,)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    wlog = jnp.where(t_idx >= s_idx,
                     bcum[:, None] - bcum[None, :] + li[None, :], NEG)
    glog = bcum + m                                               # (T,)
    m_row = jnp.maximum(jnp.max(wlog, axis=1), glog)
    wexp = jnp.exp(wlog - m_row[:, None])
    gexp = jnp.exp(glog - m_row)

    scores = (qs @ k.T) * wexp                                    # (T,T)
    y_intra = scores @ v
    y_state = gexp[:, None] * (qs @ C.T)                          # C[d,e]: q over e
    nq = jnp.sum(scores, axis=1) + gexp * (qs @ nv)
    denom = jnp.maximum(jnp.abs(nq), jnp.exp(-m_row))
    y_ref[0, :, 0, :] = ((y_intra + y_state) / denom[:, None]).astype(y_ref.dtype)

    # carry update, restabilised at m_new
    m_new = jnp.maximum(bcum[-1] + m, jnp.max(li + (bcum[-1] - bcum)))
    c_decay = jnp.exp(bcum[-1] + m - m_new)
    inj = jnp.exp(li + (bcum[-1] - bcum) - m_new)                 # (T,)
    c_ref[...] = C * c_decay + (v * inj[:, None]).T @ k           # (D,D)
    n_ref[0] = nv * c_decay + jnp.sum(k * inj[:, None], axis=0)
    m_ref[0, 0] = m_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_scan(q, k, v, logi, logf, *, chunk: int = 128, interpret: bool = True):
    """q,k,v: (B,S,H,D); logi/logf: (B,S,H) (log-space gates). -> y (B,S,H,D)."""
    b, s, h, d = q.shape
    t = min(chunk, s)
    pad = (-s) % t
    if pad:
        zp4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(a, zp4) for a in (q, k, v))
        logi = jnp.pad(logi, ((0, 0), (0, pad), (0, 0)), constant_values=NEG)
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))
    nc = q.shape[1] // t
    kernel = functools.partial(_mlstm_kernel, chunk=t, d=d)
    y = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, t, 1, d), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, t, 1, d), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, t, 1, d), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, t, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1, t, 1), lambda ib, ih, ic: (ib, ic, ih)),
        ],
        out_specs=pl.BlockSpec((1, t, 1, d), lambda ib, ih, ic: (ib, ic, ih, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((d, d), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, logi, logf)
    return y[:, :s] if pad else y
