"""Blocked (flash-style) causal attention Pallas kernel with GQA + sliding
window, for prefill / training.

TPU mapping: grid = (batch, q_heads, q_blocks, kv_blocks) with the kv axis
innermost -- TPU executes the grid sequentially, so fp32 online-softmax
accumulators live in VMEM scratch and persist across kv steps.  Block sizes
default to 128x128 (MXU-aligned); q/k/v tiles are (block, head_dim) in VMEM.
GQA is handled in the BlockSpec index_map (kv head = q head // group).
Padded kv positions (when Skv % block_k != 0) are masked via kv_len.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int, kv_len: int,
                  block_q: int, block_k: int, n_kv_blocks: int, q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0, :, 0, :].astype(jnp.float32)                  # (bk, d)
    s = q @ k.T                                                # (bq, bk)

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + q_offset
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < kv_len
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v_ref[0, :, 0, :].astype(jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "q_offset", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: float | None = None, q_offset: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (B,Sq,Hq,D); k,v: (B,Skv,Hkv,D). Returns (B,Sq,Hq,D)."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    group = max(hq // hkv, 1)
    scale_ = scale if scale is not None else d ** -0.5
    bq, bk = min(block_q, sq), min(block_k, skv)
    pad_q, pad_k = (-sq) % bq, (-skv) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = q.shape[1] // bq, k.shape[1] // bk
    kernel = functools.partial(
        _flash_kernel, scale=scale_, causal=causal, window=window, kv_len=skv,
        block_q=bq, block_k=bk, n_kv_blocks=nk, q_offset=q_offset)
    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, d), lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda ib, ih, iq, ik: (ib, ik, ih // group, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda ib, ih, iq, ik: (ib, ik, ih // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, d), lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    if pad_q:
        out = out[:, :sq]
    return out
