"""Chunked SSD (Mamba2) scan Pallas kernel, used by the xlstm/zamba2 paths.

TPU adaptation of the GPU SSD algorithm: instead of warp-level prefix scans,
the sequence is blocked into VMEM-resident chunks of length T; within a
chunk the recurrence is re-expressed as dense (T x T) / (T x N) matmuls (MXU
work), and the (P x N) state is carried across chunks in VMEM scratch --
grid = (batch, heads, chunks) with chunks innermost/sequential.

Math per chunk (a_t = exp(A*dt_t), cum_t = cumsum(log a)):
  y_t = exp(cum_t) * (C_t . h_in) + sum_{s<=t} exp(cum_t - cum_s) dt_s (C_t.B_s) x_s
  h_out = exp(cum_T) h_in + sum_s exp(cum_T - cum_s) dt_s (x_s outer B_s)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_ref, *,
                chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (T, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (T,)
    a = a_ref[0]                                     # scalar decay rate (<0)
    bm = b_ref[0].astype(jnp.float32)                # (T, N)
    cm = c_ref[0].astype(jnp.float32)                # (T, N)
    h = h_ref[...]                                   # (P, N) f32 carry

    log_a = a * dt                                   # (T,)
    cum = jnp.cumsum(log_a)                          # (T,)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    # L[t,s] = exp(cum_t - cum_s) for s<=t else 0 (mask exponent pre-exp to
    # avoid overflow in the dead upper triangle)
    L = jnp.exp(jnp.where(t_idx >= s_idx, cum[:, None] - cum[None, :], -1e30))
    G = cm @ bm.T                                    # (T, T)
    M = G * L * dt[None, :]
    y_intra = M @ x                                  # (T, P)
    y_state = jnp.exp(cum)[:, None] * (cm @ h.T)     # (T, P)
    y_ref[0, :, 0, :] = (y_intra + y_state).astype(y_ref.dtype)

    w = dt * jnp.exp(cum[-1] - cum)                  # (T,)
    h_new = h * jnp.exp(cum[-1]) + jnp.einsum("tp,tn->pn", x * w[:, None], bm)
    h_ref[...] = h_new

    @pl.when(ic == n_chunks - 1)
    def _emit_state():
        hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, *, chunk: int = 256, interpret: bool = True):
    """Chunked SSD scan.

    x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,N).
    Returns (y (B,S,H,P), h_final (B,H,P,N) fp32).
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    t = min(chunk, s)
    pad = (-s) % t
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))   # dt=0 -> identity steps
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // t
    kernel = functools.partial(_ssd_kernel, chunk=t, n_chunks=nc)
    y, h_final = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, t, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, t, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, t, n), lambda ib, ih, ic: (ib, ic, 0)),
            pl.BlockSpec((1, t, n), lambda ib, ih, ic: (ib, ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, t, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, 1, p, n), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), Bm, Cm)
    if pad:
        y = y[:, :s]
    return y, h_final
