"""Cloud / hardware profiles.

The paper compares Kubeflow on GCP vs IBM Cloud (plus two non-Kubeflow
baselines).  Our TPU-native analog: a CloudProfile bundles the hardware
constants (roofline terms), the mesh topology, and the serving-network
characteristics.  The roofline table in EXPERIMENTS.md always uses the
canonical TPU_V5E constants from the assignment (197 TFLOP/s bf16, 819 GB/s
HBM, 50 GB/s/link ICI); gcp/ibm profiles differ in topology + network RTT,
mirroring the paper's observed deltas (its §7: IBM's same-VPC network made
inference faster; GCP's cluster made pipelines faster).  RTT constants are
calibrated from the paper's Table 3 ratios -- they are *simulation* inputs,
not measurements (repro band 1/5: hardware gates are simulated, DESIGN.md).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops_bf16: float      # per chip, FLOP/s
    hbm_bw: float               # per chip, B/s
    ici_bw: float               # per link, B/s
    dcn_bw: float               # cross-pod per-chip bandwidth, B/s
    hbm_bytes: float            # per chip capacity
    vmem_bytes: float


TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    dcn_bw=6.25e9,              # ~1/8 ICI; used for the "pod" axis note
    hbm_bytes=16 * 2**30,
    vmem_bytes=128 * 2**20,
)


@dataclasses.dataclass(frozen=True)
class CloudProfile:
    name: str
    hardware: HardwareSpec
    mesh_shape: tuple           # (data, model) within a pod
    # serving-network simulation (paper Table 3 analog)
    network_rtt_s: float        # per-request network round trip
    lb_overhead_s: float        # load-balancer / ingress hop
    model_load_s: float         # cost of (re)loading the model ("baremetal")
    startup_s: float            # cluster/job spin-up (pipeline stage analog)
    # per-replica price sheet, $/replica-second.  SIMULATED: the absolute
    # scale is arbitrary (1 "replica-hour" on gcp == $1); only the ratios
    # are calibrated, mirroring the paper's provider comparison where the
    # managed-Kubeflow IBM setup priced above GCP for the same chips.  Any
    # dollar figure derived from this field is a simulation output
    # (DESIGN.md §1), never a measurement.
    cost_per_s: float = 1.0 / 3600.0
    # cross-cloud artifact movement (pipeline orchestrator,
    # repro/pipelines/artifacts.py): $ per GB leaving this cloud and the
    # sustained cross-cloud pipe one transfer sees.  SIMULATED like the
    # price sheet above -- egress ratios mirror the public-cloud pattern
    # (managed clouds bill egress, a bare host does not), the pipe is a
    # deliberately-WAN ~10 Gb/s, far below the intra-pod DCN.
    egress_per_gb: float = 0.08
    interconnect_bw: float = 1.25e9      # B/s


PROFILES = {
    # Kubeflow-on-GCP analog: canonical v5e pod.
    "gcp": CloudProfile("gcp", TPU_V5E, (16, 16),
                        network_rtt_s=0.0025, lb_overhead_s=0.0004,
                        model_load_s=0.20, startup_s=3.0,
                        cost_per_s=1.0 / 3600.0,
                        egress_per_gb=0.08, interconnect_bw=1.25e9),
    # Kubeflow-on-IBM analog: same chips, same-VPC network (lower RTT), but
    # slower control plane (paper: setup friction, slower pipeline stages)
    # and a ~1.4x replica price (the premium the lower RTT costs).
    "ibm": CloudProfile("ibm", TPU_V5E, (16, 16),
                        network_rtt_s=0.0010, lb_overhead_s=0.0004,
                        model_load_s=0.20, startup_s=5.0,
                        cost_per_s=1.4 / 3600.0,
                        egress_per_gb=0.09, interconnect_bw=1.25e9),
    # non-Kubeflow baselines (serving strategies; see serving/kserve.py)
    "baremetal": CloudProfile("baremetal", TPU_V5E, (1, 1),
                              network_rtt_s=0.0030, lb_overhead_s=0.0,
                              model_load_s=0.25, startup_s=0.0,
                              cost_per_s=0.9 / 3600.0,
                              egress_per_gb=0.0, interconnect_bw=0.625e9),
    "k8s": CloudProfile("k8s", TPU_V5E, (1, 1),
                        network_rtt_s=0.0030, lb_overhead_s=0.0006,
                        model_load_s=0.20, startup_s=1.0,
                        cost_per_s=1.1 / 3600.0,
                        egress_per_gb=0.08, interconnect_bw=0.625e9),
}


def get_profile(name: str) -> CloudProfile:
    return PROFILES[name]
