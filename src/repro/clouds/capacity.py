"""Unified per-cloud capacity market (ISSUE 9, ROADMAP item 5).

The paper deploys Kubeflow training pipelines and KServe-style serving
onto the *same* per-cloud clusters; until now the repro kept them in two
disjoint universes (orchestrator worker slots vs gateway replica counts).
This module is the single source of truth both subsystems draw from:

  CapacityLedger   one cloud's slots.  A slot grant is a Lease -- a
                   ``[t0, t1)`` sim-time interval with a holder kind
                   (``"serving"`` / ``"training"``), a priority class and
                   a lifecycle status (active/released/preempted/
                   cancelled).  Every mutation appends an audit op with a
                   monotonically increasing ``seq``, so the whole history
                   replays deterministically and the conservation
                   invariant (concurrent leases <= slots at every point
                   of the committed timeline) is checkable after the run.

  CapacityMarket   the per-cloud ledgers plus the economics: serving
                   priority (training leases are preemptible, like spot
                   instances), a per-cloud serving ``reserve`` produced
                   by the budget planner (``plan_budget`` trades training
                   makespan against reserved serving headroom), and the
                   ``state_bytes`` knob that prices replica warm handoff
                   (state transfer over interconnect_bw instead of a cold
                   model load).

The gateway and the orchestrator run as *separate* discrete-event
simulations on the shared event-heap core, each restarting its own sim
clock; the market bridges them through the recorded lease timeline.  The
subsystem that runs later contends against the intervals the earlier run
left behind: a gateway scale-up that finds a cloud full preempts the
youngest training lease (``preempt_youngest``), and an orchestrator run
watches the recorded serving rise-edges (``serving_edges``) and kills its
own youngest running attempt when one over-commits the cloud.

Both subsystems accept ``shared_capacity=None`` (the default), which
keeps every pre-ISSUE-9 code path bit-identical -- contention only
activates when one explicit ``CapacityMarket`` is passed to both.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Optional


@dataclasses.dataclass
class Lease:
    """One slot grant on one cloud over the sim-time interval [t0, t1).

    ``t1`` is ``inf`` while the lease is open; release/preempt/cancel
    close it.  ``status`` is the lifecycle outcome:

      active      open, holder still occupies the slot
      released    closed normally by the holder
      preempted   truncated by the market (serving priority over spot
                  training, or a recorded-timeline kill)
      cancelled   closed because the holder became redundant (the losing
                  side of a speculative-retry pair)
    """
    lease_id: int
    cloud: str
    kind: str                    # "serving" | "training"
    holder: str
    t0: float
    t1: float = math.inf
    status: str = "active"
    priority: int = 0

    def covers(self, t: float) -> bool:
        return self.t0 <= t < self.t1


class CapacityLedger:
    """One cloud's slot ledger: lease/release/preempt primitives plus the
    monotonic-``seq`` audit trail.  Conservation is enforced at lease
    time *instantaneously*: a lease at ``t`` is refused (returns None)
    when the slots covering ``t`` are all taken -- callers preempt
    (serving priority) or deny.  Later over-commits against *recorded*
    intervals (a serving rise-edge crossing an open training lease) are
    resolved by preemption at the edge, so the committed timeline never
    exceeds ``slots`` anywhere."""

    def __init__(self, cloud: str, slots: int, *, _seq=None) -> None:
        if slots < 1:
            raise ValueError(f"ledger {cloud!r} needs >= 1 slot, got {slots}")
        self.cloud = cloud
        self.slots = int(slots)
        self.leases: list[Lease] = []
        self.audit: list[dict] = []
        self._seq = itertools.count() if _seq is None else _seq
        self._ids = itertools.count()

    # -- queries -------------------------------------------------------------

    def used(self, t: float, kind: Optional[str] = None) -> int:
        return sum(1 for l in self.leases
                   if l.covers(t) and (kind is None or l.kind == kind))

    def free(self, t: float) -> int:
        return max(self.slots - self.used(t), 0)

    def next_release_after(self, t: float) -> Optional[float]:
        """Earliest recorded lease end strictly after ``t`` (wake-up time
        for a caller blocked on a full ledger), or None."""
        ends = [l.t1 for l in self.leases if t < l.t1 < math.inf]
        return min(ends) if ends else None

    def serving_edges(self, lo: float = 0.0,
                      hi: float = math.inf) -> list[float]:
        """Times in (lo, hi] where recorded serving occupancy rises."""
        return sorted({l.t0 for l in self.leases
                       if l.kind == "serving" and lo < l.t0 <= hi})

    def max_overlap(self, kind: Optional[str] = None) -> int:
        """Peak concurrent leases over the committed timeline (the
        conservation invariant asserts this never exceeds ``slots``)."""
        edges = []
        for l in self.leases:
            if kind is not None and l.kind != kind:
                continue
            edges.append((l.t0, 1))
            if l.t1 < math.inf:
                edges.append((l.t1, -1))
        peak = cur = 0
        for _, d in sorted(edges):           # ends sort before starts at
            cur += d                         # equal t ((-1) < (+1)): the
            peak = max(peak, cur)            # interval [t0, t1) is half-open
        return peak

    # -- mutations (each appends one audit op) -------------------------------

    def _op(self, op: str, lease: Lease, t: float) -> None:
        self.audit.append({"seq": next(self._seq), "op": op,
                           "lease": lease.lease_id, "cloud": self.cloud,
                           "kind": lease.kind, "holder": lease.holder,
                           "t": t})

    def lease(self, kind: str, holder: str, t: float, *,
              priority: int = 0) -> Optional[Lease]:
        if self.used(t) >= self.slots:
            return None
        l = Lease(next(self._ids), self.cloud, kind, holder, t,
                  priority=priority)
        self.leases.append(l)
        self._op("lease", l, t)
        return l

    def release(self, lease: Lease, t: float, *,
                status: str = "released") -> None:
        lease.t1 = max(t, lease.t0)
        lease.status = status
        self._op({"released": "release", "preempted": "preempt",
                  "cancelled": "cancel"}.get(status, status), lease, t)

    def preempt_youngest(self, t: float,
                         kind: str = "training") -> Optional[Lease]:
        """Truncate the youngest ``kind`` lease covering ``t`` (max t0,
        ties broken by max lease_id) at ``t1 = t``.  Also truncates
        *recorded* (already-released) intervals from an earlier run --
        the kill is then a market-level fact about the shared timeline."""
        cands = [l for l in self.leases if l.kind == kind and l.covers(t)]
        if not cands:
            return None
        victim = max(cands, key=lambda l: (l.t0, l.lease_id))
        victim.t1 = max(t, victim.t0)
        victim.status = "preempted"
        self._op("preempt", victim, t)
        return victim


class CapacityMarket:
    """Per-cloud ledgers plus the shared-substrate economics.

    ``slots`` maps cloud name -> slot count; clouds absent from the map
    are unconstrained (the subsystems fall back to their own limits).
    ``serving_priority=True`` lets serving preempt training (spot
    semantics); False means a full cloud denies the serving scale-up
    instead.  ``state_bytes > 0`` prices replica warm handoff: a gateway
    relaunch that migrates load pays the state transfer over the clouds'
    interconnect instead of a cold model load, when cheaper.  A single
    ``seq`` counter is shared by every ledger so the audit trail has one
    global order."""

    def __init__(self, slots: dict, *, serving_priority: bool = True,
                 state_bytes: float = 0.0) -> None:
        seq = itertools.count()
        self.ledgers = {c: CapacityLedger(c, n, _seq=seq)
                        for c, n in sorted(slots.items())}
        self.serving_priority = serving_priority
        self.state_bytes = float(state_bytes)
        self.reserve: dict = {}          # cloud -> slots held for serving

    # -- per-cloud views (unconstrained when the cloud has no ledger) --------

    def ledger(self, cloud: str) -> Optional[CapacityLedger]:
        return self.ledgers.get(cloud)

    def training_free(self, cloud: str, t: float) -> int:
        """Slots a *training* lease may take at ``t``: ledger free minus
        the serving reserve.  Unconstrained clouds report a large free."""
        led = self.ledgers.get(cloud)
        if led is None:
            return 1 << 30
        return max(led.free(t) - int(self.reserve.get(cloud, 0)), 0)

    def training_active(self, cloud: str, t: float) -> int:
        led = self.ledgers.get(cloud)
        return 0 if led is None else led.used(t, kind="training")

    def preempt_training(self, cloud: str, t: float) -> Optional[Lease]:
        if not self.serving_priority:
            return None
        led = self.ledgers.get(cloud)
        return None if led is None else led.preempt_youngest(t, "training")

    # -- budget planner ------------------------------------------------------

    def plan_budget(self, serving_load: dict, work_s: float, *,
                    target_util: float = 0.7) -> dict:
        """Trade training makespan against reserved serving headroom.

        ``serving_load`` maps cloud -> expected steady serving occupancy
        (replicas); the planner reserves ``ceil(load / target_util)``
        slots per cloud for serving (bounded by the ledger), leaves the
        rest to training, and estimates the training makespan as the
        total work spread over the remaining slots.  The reserve is
        installed on the market (``training_free`` honors it) and the
        plan is returned for logging."""
        reserve, train = {}, {}
        for cloud, led in self.ledgers.items():
            load = float(serving_load.get(cloud, 0.0))
            r = min(led.slots, math.ceil(load / target_util)) if load else 0
            reserve[cloud] = r
            train[cloud] = led.slots - r
        total_train = sum(train.values())
        self.reserve = reserve
        return {"reserve": reserve, "training_slots": train,
                "est_makespan_s": (work_s / total_train
                                   if total_train else math.inf)}

    # -- invariant helper (tests / benches) ----------------------------------

    def check_conservation(self) -> None:
        """Raise if any ledger's committed timeline ever exceeds its
        slots (the no-over-commit invariant, checked post-run over the
        full audit history)."""
        for cloud, led in self.ledgers.items():
            peak = led.max_overlap()
            if peak > led.slots:
                raise AssertionError(
                    f"{cloud}: {peak} concurrent leases > {led.slots} slots")
            seqs = [op["seq"] for op in led.audit]
            if seqs != sorted(seqs) or len(set(seqs)) != len(seqs):
                raise AssertionError(f"{cloud}: audit seq not monotonic")
