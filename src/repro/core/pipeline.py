"""Kubeflow-Pipelines analog: typed component DAG with artifact passing,
content-hash step caching, per-stage telemetry, and YAML spec export.

The paper's workflow (its Fig. 14: func_to_container_op -> pipeline) maps to:

    pipe = Pipeline("e2e-mnist", store)
    data  = pipe.step(download_data)
    prep  = pipe.step(preprocess, data)
    tuned = pipe.step(tune, prep)
    model = pipe.step(train, prep, tuned)
    pipe.step(serve_eval, model)
    result = pipe.run()

Components are plain python functions ("lightweight components"); the
framework contributes orchestration: dependency resolution, caching (re-use
of components, a headline Kubeflow feature), artifact lineage, stage timing
(Tables 4/5), and a serialized pipeline spec -- the analog of the paper's
`minikf_generated_gcp.yaml`.

This module is the AUTHORING front-end.  ``Pipeline.run()`` is the serial
in-process executor (every step on the calling thread, wall-clock timing);
``Pipeline.compile()`` lowers the same DAG into a ``PipelineSpec`` that the
multi-cloud orchestrator (repro.pipelines.scheduler.Orchestrator) schedules
onto simulated per-cloud clusters -- parallel branches, retries, artifact
transfer accounting, and a terminal ``kind="deploy"`` step that hands the
trained model to the serving gateway.  Both executors share the
content-hash cache keys (``step_cache_key``), so a step cached by one is a
cache hit for the other.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import inspect
import time
from typing import Any, Callable, Optional

import yaml

from ..checkpoint.store import ArtifactStore, tree_hash
from ..telemetry.events import EventLog


@dataclasses.dataclass
class StepRef:
    """Handle to a pipeline step; resolves to its output at execution."""
    name: str
    index: int


class Step:
    def __init__(self, name: str, fn: Callable, args: tuple, kwargs: dict,
                 cache: bool = True, kind: str = "compute",
                 payload: Any = None, sim_s: Optional[float] = None,
                 pin: Optional[str] = None):
        self.name = name
        self.fn = fn
        self.args = args
        self.kwargs = kwargs
        self.cache = cache
        self.kind = kind                 # "compute" | "deploy" | "profile"
        self.payload = payload           # kind-specific config (DeploySpec
        # for deploy, modelci.ProfileSpec for profile)
        self.sim_s = sim_s               # analytic simulated compute seconds
        self.pin = pin                   # force this cloud (orchestrator)
        self.output: Any = None
        self.cached = False
        self.duration_s: float = 0.0

    def deps(self) -> list:
        out = []
        for a in list(self.args) + list(self.kwargs.values()):
            if isinstance(a, StepRef):
                out.append(a.index)
        return out


def value_hash(v: Any) -> str:
    try:
        if hasattr(v, "dtype") or isinstance(v, (dict, list, tuple)):
            return tree_hash(v)
        return hashlib.sha256(repr(v).encode()).hexdigest()[:16]
    except Exception:
        return "unhashable"


_value_hash = value_hash                 # backward-compatible alias


def step_cache_key(pipeline: str, step_name: str, fn: Callable,
                   args, kwargs) -> str:
    """Content-hash cache key over (pipeline, step, fn source, resolved
    inputs).  Shared by the serial executor and the orchestrator
    (repro.pipelines), so the two reuse each other's cached artifacts."""
    h = hashlib.sha256()
    h.update(pipeline.encode())
    h.update(step_name.encode())
    try:
        h.update(inspect.getsource(fn).encode())
    except (OSError, TypeError):
        # source unavailable (REPL/lambda): fall back to a stable name,
        # never repr() (contains memory addresses -> cache always misses)
        h.update(f"{getattr(fn, '__module__', '')}."
                 f"{getattr(fn, '__qualname__', str(fn))}".encode())
    for a in list(args) + sorted(kwargs.items(), key=str):
        h.update(value_hash(a).encode())
    return "cache_" + h.hexdigest()[:16]


def value_cacheable(v: Any) -> bool:
    """Whether a step output can be persisted in the JSON store record.
    The ONE predicate shared by the serial executor and the orchestrator's
    ArtifactCache -- a drift here would silently desynchronize their
    shared cache."""
    return isinstance(v, (str, int, float, list, dict, type(None)))


def cache_record(value: Any, step_name: str, clouds=(), nbytes=None) -> dict:
    """The ONE on-disk cache record shape (ArtifactStore JSON), shared by
    Pipeline.run and ArtifactCache.put/commit_transfer.  ``clouds`` is the
    simulated residency ([] for the serial executor, which runs on no
    simulated cloud: the orchestrator then has no honest source to bill a
    transfer against and moves the artifact for free); ``nbytes`` is the
    measured payload size when the producer knows it."""
    cacheable = value_cacheable(value)
    rec = {"cacheable": cacheable,
           "value": value if cacheable else None,
           "step": step_name,
           "clouds": sorted(clouds)}
    if nbytes is not None:
        rec["nbytes"] = int(nbytes)
    return rec


def toposort(deps: list) -> list:
    """Deterministic Kahn's algorithm: ``deps[i]`` lists the indices step
    ``i`` depends on.  Ready nodes are seeded in insertion-index order and
    popped FIFO from a deque (O(V+E); the old list.pop(0) was O(n^2)), and
    a node's children unlock in insertion order too, so the returned order
    is a pure function of the DAG -- orchestrator schedules built on it are
    reproducible run to run and across processes."""
    n = len(deps)
    indeg = [0] * n
    adj: list = [[] for _ in range(n)]
    for i in range(n):
        for d in deps[i]:
            adj[d].append(i)
            indeg[i] += 1
    queue = collections.deque(i for i in range(n) if indeg[i] == 0)
    order = []
    while queue:
        i = queue.popleft()
        order.append(i)
        for j in adj[i]:
            indeg[j] -= 1
            if indeg[j] == 0:
                queue.append(j)
    if len(order) != n:
        raise ValueError("pipeline DAG has a cycle")
    return order


# -- compiled form (the orchestrator's input) --------------------------------

@dataclasses.dataclass
class StepSpec:
    """One compiled step: pure data, no execution state (the orchestrator
    keeps its own).  ``sim_s`` replaces the measured wall duration with an
    analytic simulated compute time (the AnalyticBackend idiom -- tests and
    benchmark replays stay host-independent); ``pin`` forces a cloud."""
    name: str
    fn: Callable
    args: tuple
    kwargs: dict
    index: int
    deps: tuple
    cache: bool = True
    kind: str = "compute"                # "compute" | "deploy" | "profile"
    payload: Any = None                  # kind-specific (pipelines.DeploySpec
    # for deploy, modelci.ProfileSpec for profile)
    sim_s: Optional[float] = None
    pin: Optional[str] = None


def _step_rows(steps: list) -> list:
    """The ONE serializer for step rows (StepSpec list -> dict rows),
    shared by Pipeline.spec() and PipelineSpec.to_dict() so the two
    exported artifacts can never drift."""
    return [{"name": s.name,
             "component": getattr(s.fn, "__name__", str(s.fn)),
             "dependencies": [steps[d].name for d in s.deps],
             "cache": s.cache,
             **({"kind": s.kind} if s.kind != "compute" else {}),
             **({"pin": s.pin} if s.pin else {})}
            for s in steps]


@dataclasses.dataclass
class PipelineSpec:
    """A compiled pipeline DAG, ready for Orchestrator.execute()."""
    name: str
    steps: list

    def to_dict(self) -> dict:
        return {
            "apiVersion": "repro/v1",
            "kind": "PipelineSpec",
            "metadata": {"name": self.name},
            "spec": {"steps": _step_rows(self.steps)},
        }


class Pipeline:
    """A DAG of components executed topologically with caching + telemetry."""

    def __init__(self, name: str, store: Optional[ArtifactStore] = None,
                 log: Optional[EventLog] = None, enable_cache: bool = True):
        self.name = name
        self.store = store
        self.log = log or EventLog()
        self.steps: list[Step] = []
        self.enable_cache = enable_cache and store is not None

    # -- authoring ----------------------------------------------------------
    def step(self, fn: Callable, *args, name: Optional[str] = None,
             cache: bool = True, kind: str = "compute", payload: Any = None,
             sim_s: Optional[float] = None, pin: Optional[str] = None,
             **kwargs) -> StepRef:
        """Add a step.  Steps sharing a function (or an explicit name) are
        deduplicated ``train``, ``train_2``, ``train_3`` ... -- the suffix
        is re-checked against every existing name, so a generated name can
        never silently collide with an explicit one (two steps sharing a
        name made ``run()``'s {name: output} dict drop the earlier output
        and let cache keys alias)."""
        if kind not in ("compute", "deploy", "profile"):
            raise ValueError(f"unknown step kind {kind!r}")
        sname = name or fn.__name__
        taken = {s.name for s in self.steps}
        if sname in taken:
            k = 2
            while f"{sname}_{k}" in taken:
                k += 1
            sname = f"{sname}_{k}"
        self.steps.append(Step(sname, fn, args, kwargs, cache,
                               kind=kind, payload=payload, sim_s=sim_s,
                               pin=pin))
        return StepRef(sname, len(self.steps) - 1)

    # -- spec export (minikf_generated_gcp.yaml analog) ---------------------
    def spec(self) -> dict:
        return {
            "apiVersion": "repro/v1",
            "kind": "Pipeline",
            "metadata": {"name": self.name},
            "spec": {"steps": _step_rows(self.compile().steps)},
        }

    def export_yaml(self, path: Optional[str] = None) -> str:
        text = yaml.safe_dump(self.spec(), sort_keys=False)
        if path:
            with open(path, "w") as f:
                f.write(text)
        return text

    def compile(self) -> PipelineSpec:
        """Lower the authored DAG into the orchestrator's PipelineSpec.
        Deploy steps are never cached (the gateway handoff is a side
        effect); profile steps DO cache -- the fn's output is the raw
        measurement dict and the ProfileStore commit re-runs on cached
        completions.  The serial run() treats both as plain steps (the fn
        runs; handoff/commit are orchestrator-only semantics)."""
        return PipelineSpec(self.name, [
            StepSpec(name=s.name, fn=s.fn, args=tuple(s.args),
                     kwargs=dict(s.kwargs), index=i,
                     deps=tuple(dict.fromkeys(s.deps())),
                     cache=s.cache and s.kind != "deploy",
                     kind=s.kind, payload=s.payload, sim_s=s.sim_s,
                     pin=s.pin)
            for i, s in enumerate(self.steps)])

    # -- execution ----------------------------------------------------------
    def _resolve(self, v: Any):
        if isinstance(v, StepRef):
            return self.steps[v.index].output
        return v

    def _cache_key(self, step: Step, args, kwargs) -> str:
        return step_cache_key(self.name, step.name, step.fn, args, kwargs)

    def run(self, verbose: bool = False) -> dict:
        """Execute all steps; returns {step_name: output}."""
        order = self._toposort()
        t_start = time.perf_counter()
        for idx in order:
            step = self.steps[idx]
            args = tuple(self._resolve(a) for a in step.args)
            kwargs = {k: self._resolve(v) for k, v in step.kwargs.items()}
            key = None
            if self.enable_cache and step.cache:
                key = self._cache_key(step, args, kwargs)
                if self.store.exists(key):
                    cached = self.store.load_json(key)
                    if cached.get("cacheable", False):
                        step.output = cached["value"]
                        step.cached = True
                        self.log.record(step.name, 0.0, cached=True)
                        if verbose:
                            print(f"[{self.name}] {step.name}: cached")
                        continue
            t0 = time.perf_counter()
            step.output = step.fn(*args, **kwargs)
            step.duration_s = time.perf_counter() - t0
            self.log.record(step.name, step.duration_s, cached=False)
            if verbose:
                print(f"[{self.name}] {step.name}: {step.duration_s:.3f}s")
            if key is not None:
                self.store.save_json(key, cache_record(step.output, step.name))
        total = time.perf_counter() - t_start
        self.log.record(f"pipeline:{self.name}", total)
        return {s.name: s.output for s in self.steps}

    def _toposort(self) -> list:
        return toposort([s.deps() for s in self.steps])


def component(fn: Callable) -> Callable:
    """Marker decorator (func_to_container_op analog) -- components are
    plain functions; the decorator just tags them for spec export."""
    fn.__component__ = True
    return fn
