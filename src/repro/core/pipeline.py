"""Kubeflow-Pipelines analog: typed component DAG with artifact passing,
content-hash step caching, per-stage telemetry, and YAML spec export.

The paper's workflow (its Fig. 14: func_to_container_op -> pipeline) maps to:

    pipe = Pipeline("e2e-mnist", store)
    data  = pipe.step(download_data)
    prep  = pipe.step(preprocess, data)
    tuned = pipe.step(tune, prep)
    model = pipe.step(train, prep, tuned)
    pipe.step(serve_eval, model)
    result = pipe.run()

Components are plain python functions ("lightweight components"); the
framework contributes orchestration: dependency resolution, caching (re-use
of components, a headline Kubeflow feature), artifact lineage, stage timing
(Tables 4/5), and a serialized pipeline spec -- the analog of the paper's
`minikf_generated_gcp.yaml`.
"""
from __future__ import annotations

import dataclasses
import hashlib
import inspect
import time
from typing import Any, Callable, Optional

import yaml

from ..checkpoint.store import ArtifactStore, tree_hash
from ..telemetry.events import EventLog


@dataclasses.dataclass
class StepRef:
    """Handle to a pipeline step; resolves to its output at execution."""
    name: str
    index: int


class Step:
    def __init__(self, name: str, fn: Callable, args: tuple, kwargs: dict,
                 cache: bool = True):
        self.name = name
        self.fn = fn
        self.args = args
        self.kwargs = kwargs
        self.cache = cache
        self.output: Any = None
        self.cached = False
        self.duration_s: float = 0.0

    def deps(self) -> list:
        out = []
        for a in list(self.args) + list(self.kwargs.values()):
            if isinstance(a, StepRef):
                out.append(a.index)
        return out


def _value_hash(v: Any) -> str:
    try:
        if hasattr(v, "dtype") or isinstance(v, (dict, list, tuple)):
            return tree_hash(v)
        return hashlib.sha256(repr(v).encode()).hexdigest()[:16]
    except Exception:
        return "unhashable"


class Pipeline:
    """A DAG of components executed topologically with caching + telemetry."""

    def __init__(self, name: str, store: Optional[ArtifactStore] = None,
                 log: Optional[EventLog] = None, enable_cache: bool = True):
        self.name = name
        self.store = store
        self.log = log or EventLog()
        self.steps: list[Step] = []
        self.enable_cache = enable_cache and store is not None

    # -- authoring ----------------------------------------------------------
    def step(self, fn: Callable, *args, name: Optional[str] = None,
             cache: bool = True, **kwargs) -> StepRef:
        sname = name or fn.__name__
        if any(s.name == sname for s in self.steps):
            sname = f"{sname}_{len(self.steps)}"
        self.steps.append(Step(sname, fn, args, kwargs, cache))
        return StepRef(sname, len(self.steps) - 1)

    # -- spec export (minikf_generated_gcp.yaml analog) ---------------------
    def spec(self) -> dict:
        return {
            "apiVersion": "repro/v1",
            "kind": "Pipeline",
            "metadata": {"name": self.name},
            "spec": {"steps": [
                {"name": s.name,
                 "component": getattr(s.fn, "__name__", str(s.fn)),
                 "dependencies": [self.steps[d].name for d in s.deps()],
                 "cache": s.cache}
                for s in self.steps
            ]},
        }

    def export_yaml(self, path: Optional[str] = None) -> str:
        text = yaml.safe_dump(self.spec(), sort_keys=False)
        if path:
            with open(path, "w") as f:
                f.write(text)
        return text

    # -- execution ----------------------------------------------------------
    def _resolve(self, v: Any):
        if isinstance(v, StepRef):
            return self.steps[v.index].output
        return v

    def _cache_key(self, step: Step, args, kwargs) -> str:
        h = hashlib.sha256()
        h.update(self.name.encode())
        h.update(step.name.encode())
        try:
            h.update(inspect.getsource(step.fn).encode())
        except (OSError, TypeError):
            # source unavailable (REPL/lambda): fall back to a stable name,
            # never repr() (contains memory addresses -> cache always misses)
            fn = step.fn
            h.update(f"{getattr(fn, '__module__', '')}."
                     f"{getattr(fn, '__qualname__', str(fn))}".encode())
        for a in list(args) + sorted(kwargs.items(), key=str):
            h.update(_value_hash(a).encode())
        return "cache_" + h.hexdigest()[:16]

    def run(self, verbose: bool = False) -> dict:
        """Execute all steps; returns {step_name: output}."""
        order = self._toposort()
        t_start = time.perf_counter()
        for idx in order:
            step = self.steps[idx]
            args = tuple(self._resolve(a) for a in step.args)
            kwargs = {k: self._resolve(v) for k, v in step.kwargs.items()}
            key = None
            if self.enable_cache and step.cache:
                key = self._cache_key(step, args, kwargs)
                if self.store.exists(key):
                    cached = self.store.load_json(key)
                    if cached.get("cacheable", False):
                        step.output = cached["value"]
                        step.cached = True
                        self.log.record(step.name, 0.0, cached=True)
                        if verbose:
                            print(f"[{self.name}] {step.name}: cached")
                        continue
            t0 = time.perf_counter()
            step.output = step.fn(*args, **kwargs)
            step.duration_s = time.perf_counter() - t0
            self.log.record(step.name, step.duration_s, cached=False)
            if verbose:
                print(f"[{self.name}] {step.name}: {step.duration_s:.3f}s")
            if key is not None:
                cacheable = isinstance(step.output, (str, int, float, list, dict,
                                                     type(None)))
                self.store.save_json(key, {"cacheable": cacheable,
                                           "value": step.output if cacheable else None,
                                           "step": step.name})
        total = time.perf_counter() - t_start
        self.log.record(f"pipeline:{self.name}", total)
        return {s.name: s.output for s in self.steps}

    def _toposort(self) -> list:
        n = len(self.steps)
        indeg = [0] * n
        adj: list[list[int]] = [[] for _ in range(n)]
        for i, s in enumerate(self.steps):
            for d in s.deps():
                adj[d].append(i)
                indeg[i] += 1
        queue = [i for i in range(n) if indeg[i] == 0]
        order = []
        while queue:
            i = queue.pop(0)
            order.append(i)
            for j in adj[i]:
                indeg[j] -= 1
                if indeg[j] == 0:
                    queue.append(j)
        if len(order) != n:
            raise ValueError("pipeline DAG has a cycle")
        return order


def component(fn: Callable) -> Callable:
    """Marker decorator (func_to_container_op analog) -- components are
    plain functions; the decorator just tags them for spec export."""
    fn.__component__ = True
    return fn
