"""TFJob analog: a distributed training job over a device mesh.

Two flavours:
  * SupervisedTrainJob -- classifier (LeNet/MNIST, the paper's workload);
  * LMTrainJob         -- any of the 10 assigned architectures, pjit'd over
                          the active mesh with the launch-layer shardings.
Both log metrics through the Experiment tracker, checkpoint into the
ArtifactStore (PVC analog), and time their stages for the Tables 4/5 repro.
"""
from __future__ import annotations

import functools
import time
from typing import Any, Callable, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.store import ArtifactStore
from ..configs.base import ArchConfig
from ..data import tokens as token_data
from ..models import lenet, lm, sharding as msh, steps
from ..optim import adamw
from ..optim.schedules import warmup_cosine
from ..telemetry.events import EventLog


class SupervisedTrainJob:
    """Train a classifier given (init_fn, loss_fn) pure functions."""

    def __init__(self, *, lr: float = 1e-3, batch_size: int = 64,
                 n_steps: int = 200, width: int = 16, seed: int = 0,
                 store: Optional[ArtifactStore] = None,
                 log: Optional[EventLog] = None):
        self.lr = lr
        self.batch_size = batch_size
        self.n_steps = n_steps
        self.width = width
        self.seed = seed
        self.store = store
        self.log = log or EventLog()

    def run(self, data: Iterable[dict], *, report: Optional[Callable] = None,
            checkpoint_name: str = "lenet") -> dict:
        opt_cfg = adamw.AdamWConfig(lr=self.lr, weight_decay=1e-4)
        params = lenet.init_params(jax.random.PRNGKey(self.seed), width=self.width)
        opt = adamw.init_opt_state(params)

        @jax.jit
        def step(params, opt, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lenet.loss_fn, has_aux=True)(params, batch)
            params, opt, om = adamw.adamw_update(params, grads, opt, opt_cfg)
            return params, opt, {**metrics, **om}

        it = iter(data)
        metrics = {}
        t0 = time.perf_counter()
        with self.log.stage("tfjob:train"):
            for i in range(self.n_steps):
                try:
                    batch = next(it)
                except StopIteration:
                    it = iter(data)
                    batch = next(it)
                params, opt, metrics = step(params, opt, batch)
                if report and (i + 1) % max(self.n_steps // 5, 1) == 0:
                    report(i + 1, float(metrics["loss"]))
        wall = time.perf_counter() - t0
        out = {k: float(v) for k, v in metrics.items()}
        out["wall_s"] = wall
        if self.store:
            with self.log.stage("tfjob:checkpoint"):
                out["checkpoint"] = self.store.save_tree(checkpoint_name, params,
                                                         meta=out)
        out["params"] = params
        return out


class LMTrainJob:
    """Distributed LM training over the active mesh (pjit + shardings)."""

    def __init__(self, cfg: ArchConfig, *, batch_size: int, seq_len: int,
                 n_steps: int, lr: float = 3e-4, seed: int = 0,
                 mesh=None, store: Optional[ArtifactStore] = None,
                 log: Optional[EventLog] = None):
        self.cfg = cfg
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.n_steps = n_steps
        self.lr = lr
        self.seed = seed
        self.mesh = mesh
        self.store = store
        self.log = log or EventLog()

    def run(self, *, report: Optional[Callable] = None,
            checkpoint_name: Optional[str] = None,
            resume_from: Optional[str] = None) -> dict:
        """resume_from: checkpoint name in the store -- restores params AND
        optimizer state, continuing the step counter (preemption recovery,
        the Kubernetes-rescheduling analog)."""
        cfg = self.cfg
        opt_cfg = adamw.AdamWConfig(lr=self.lr)
        schedule = functools.partial(warmup_cosine, warmup=max(self.n_steps // 10, 1),
                                     total=self.n_steps)

        def train_step(params, opt_state, batch, step_i):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: steps.loss_fn(p, cfg, batch), has_aux=True)(params)
            params, opt_state, om = adamw.adamw_update(
                params, grads, opt_state, opt_cfg, lr_scale=schedule(step_i))
            return params, opt_state, {"loss": loss, **metrics, **om}

        ctx = msh.use_mesh(self.mesh) if self.mesh is not None else msh.use_mesh(None)
        with ctx:
            with self.log.stage("tfjob:init"):
                params = lm.init_params(jax.random.PRNGKey(self.seed), cfg)
                opt = adamw.init_opt_state(params)
                if resume_from and self.store:
                    params = self.store.load_tree(resume_from, params)
                    if self.store.exists(f"{resume_from}_opt"):
                        opt = self.store.load_tree(f"{resume_from}_opt", opt)
                if self.mesh is not None:
                    shardings = msh.param_shardings(params, self.mesh)
                    params = jax.device_put(params, shardings)
                jstep = jax.jit(train_step, donate_argnums=(0, 1))
            data = token_data.lm_batches(cfg, self.batch_size, self.seq_len,
                                         seed=self.seed)
            history = []
            with self.log.stage("tfjob:train"):
                for i, batch in enumerate(data):
                    if i >= self.n_steps:
                        break
                    params, opt, metrics = jstep(params, opt, batch, i)
                    loss = float(metrics["loss"])
                    history.append(loss)
                    if report:
                        report(i + 1, loss)
            out = {"loss": history[-1] if history else float("nan"),
                   "history": history}
            if self.store and checkpoint_name:
                with self.log.stage("tfjob:checkpoint"):
                    out["checkpoint"] = self.store.save_tree(checkpoint_name, params,
                                                             meta={"loss": out["loss"]})
                    self.store.save_tree(f"{checkpoint_name}_opt", opt)
            out["params"] = params
        return out
