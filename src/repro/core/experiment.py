"""Experiment tracking (Kubeflow "Experiments (AutoML)" tab analog):
trials, per-step metrics, best-trial queries.  Backing store is the
ArtifactStore so Katib results survive across pipeline runs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

from ..checkpoint.store import ArtifactStore


@dataclasses.dataclass
class Trial:
    trial_id: int
    params: dict
    metrics: dict = dataclasses.field(default_factory=dict)
    history: list = dataclasses.field(default_factory=list)  # intermediate
    status: str = "created"      # created | running | done | early_stopped
    duration_s: float = 0.0

    def report(self, step: int, value: float):
        self.history.append((step, float(value)))

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class Experiment:
    def __init__(self, name: str, objective_key: str, goal: str = "minimize",
                 store: Optional[ArtifactStore] = None):
        assert goal in ("minimize", "maximize")
        self.name = name
        self.objective_key = objective_key
        self.goal = goal
        self.store = store
        self.trials: list[Trial] = []

    def new_trial(self, params: dict) -> Trial:
        t = Trial(trial_id=len(self.trials), params=params)
        self.trials.append(t)
        return t

    def objective(self, trial: Trial) -> Optional[float]:
        v = trial.metrics.get(self.objective_key)
        return None if v is None else float(v)

    def best_trial(self) -> Optional[Trial]:
        done = [t for t in self.trials if t.status == "done"
                and self.objective(t) is not None]
        if not done:
            return None
        key = lambda t: self.objective(t)
        return min(done, key=key) if self.goal == "minimize" else max(done, key=key)

    def save(self):
        if self.store:
            self.store.save_json(f"experiment_{self.name}", {
                "name": self.name, "objective": self.objective_key,
                "goal": self.goal, "time": time.time(),
                "trials": [t.as_dict() for t in self.trials],
            })

    def summary(self) -> dict:
        best = self.best_trial()
        return {
            "name": self.name,
            "n_trials": len(self.trials),
            "early_stopped": sum(t.status == "early_stopped" for t in self.trials),
            "best_params": best.params if best else None,
            "best_objective": self.objective(best) if best else None,
            "total_time_s": sum(t.duration_s for t in self.trials),
        }
