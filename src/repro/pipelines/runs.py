"""Pipeline runs: retry policy, per-run records, and the Runs / Recurring
Runs manager (the paper's Kubeflow-UI concept).

A ``RunRecord`` is the orchestrator's answer for ONE execution of a
compiled ``PipelineSpec``: per-step status / cloud / attempts / simulated
timing / simulated dollars, with the exactly-once contract -- every step
ends in exactly one of ``done`` / ``failed`` / ``skipped``, a ``done`` step
has exactly one successful attempt, and a ``failed`` step exhausted its
``RetryPolicy`` (each failed attempt either logged ``pipeline:retry`` and
backed off, or logged ``pipeline:fail`` and permanently failed, cascading
``skipped`` to every descendant).

``PipelineRuns`` keeps the run history: one-shot ``submit`` and
``recurring`` (fire every ``every_s`` of simulated time; a run that
overruns its period delays the next trigger -- catch-up, never overlap).
Recurring runs share the orchestrator's ArtifactCache, so an unchanged
step is a cache hit on every run after the first.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff over simulated time: attempt k (0-based) that
    fails re-enters the ready queue after ``backoff_s * backoff_mult**k``,
    up to ``max_retries`` retries (so at most ``max_retries + 1`` attempts
    total) before the step permanently fails."""
    max_retries: int = 2
    backoff_s: float = 0.5
    backoff_mult: float = 2.0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_s <= 0:
            raise ValueError("backoff_s must be > 0")
        if self.backoff_mult < 1.0:
            raise ValueError("backoff_mult must be >= 1")

    def delay_s(self, attempt: int) -> float:
        return self.backoff_s * self.backoff_mult ** attempt


@dataclasses.dataclass
class StepRecord:
    """One step's bookkeeping inside a run.  ``attempts`` holds one dict
    per attempt: {cloud, start_s, end_s, status, cost_usd} with status one
    of "ok" (completed), "outage" (killed by a failure window, retryable),
    "exception" (the fn raised) or "infeasible" (deploy plan did not fit)
    -- the latter two fail fast, zero-cost, no retries.  ``cost_usd``
    totals every attempt's worker-seconds x price plus egress; outage
    attempts are charged too (the pod ran until the cloud died)."""
    name: str
    status: str = "pending"              # done | failed | skipped
    cloud: Optional[str] = None          # cloud of the deciding attempt
    cached: bool = False
    start_s: float = 0.0                 # first attempt start (sim)
    end_s: float = 0.0                   # deciding attempt end (sim)
    compute_s: float = 0.0               # measured (or sim_s) compute
    transfer_s: float = 0.0
    transfer_cost_usd: float = 0.0
    cost_usd: float = 0.0
    attempts: list = dataclasses.field(default_factory=list)
    span_id: Optional[int] = None        # pipeline.step span (tracer runs)

    @property
    def retries(self) -> int:
        return max(len(self.attempts) - 1, 0)

    @property
    def duration_s(self) -> float:
        """Simulated wall of the whole step (first start -> deciding end),
        backoff gaps included."""
        return max(self.end_s - self.start_s, 0.0)


@dataclasses.dataclass
class RunRecord:
    run_id: str
    pipeline: str
    status: str                          # succeeded | failed
    t0: float                            # simulated submit time
    finished_s: float                    # simulated completion time
    steps: dict                          # name -> StepRecord
    outputs: dict                        # name -> value (done steps only)
    cost_usd: float = 0.0
    cache_hits: int = 0
    span_id: Optional[int] = None        # pipeline.run span (tracer runs):
    # the handle telemetry/analyze.py run_critical_path / run_table take

    @property
    def makespan_s(self) -> float:
        return max(self.finished_s - self.t0, 0.0)

    def stage_s(self) -> dict:
        """Per-step simulated duration (Tables 4/5 row shape)."""
        return {n: round(r.duration_s, 6) for n, r in self.steps.items()
                if r.status == "done"}

    def summary(self) -> dict:
        return {"run_id": self.run_id, "status": self.status,
                "makespan_s": round(self.makespan_s, 6),
                "sim_cost_usd": round(self.cost_usd, 8),
                "cache_hits": self.cache_hits,
                "steps": {n: {"status": r.status, "cloud": r.cloud,
                              "cached": r.cached,
                              "sim_s": round(r.duration_s, 6),
                              "attempts": len(r.attempts),
                              "cost_usd": round(r.cost_usd, 8)}
                          for n, r in self.steps.items()}}


class PipelineRuns:
    """Run history + triggers over one Orchestrator (its ArtifactCache and
    EventLog persist across runs, so recurring runs cache-hit and the
    ``pipeline:*`` event stream covers the whole history)."""

    def __init__(self, orchestrator):
        self.orchestrator = orchestrator
        self.history: list = []          # RunRecord, submit order

    def _next_id(self, spec) -> str:
        return f"{spec.name}-{len(self.history):03d}"

    def submit(self, spec, *, at_s: float = 0.0, failures: Optional[list] = None,
               gateway=None) -> RunRecord:
        """One-shot run at simulated time ``at_s`` (FailureSpec windows are
        absolute simulated times, shared across the whole history)."""
        rec = self.orchestrator.execute(spec, t0=at_s, failures=failures,
                                        gateway=gateway,
                                        run_id=self._next_id(spec))
        self.history.append(rec)
        return rec

    def recurring(self, spec, *, every_s: float, runs: int,
                  failures: Optional[list] = None, gateway=None,
                  start_s: float = 0.0) -> list:
        """Fire ``runs`` runs, one every ``every_s`` of simulated time from
        ``start_s``; a run overrunning its period delays the next trigger
        (catch-up semantics: runs never overlap -- they share the cache)."""
        if every_s <= 0:
            raise ValueError("every_s must be > 0")
        if runs < 1:
            raise ValueError("runs must be >= 1")
        out = []
        t = float(start_s)
        for k in range(runs):
            t = max(t, start_s + k * every_s)
            self.orchestrator.log.record("pipeline:recurring", 0.0,
                                         pipeline=spec.name, index=k,
                                         t_sim=round(t, 6))
            rec = self.submit(spec, at_s=t, failures=failures,
                              gateway=gateway)
            out.append(rec)
            t = rec.finished_s
        return out

    def summary(self) -> dict:
        return {r.run_id: {"status": r.status,
                           "makespan_s": round(r.makespan_s, 6),
                           "sim_cost_usd": round(r.cost_usd, 8),
                           "cache_hits": r.cache_hits}
                for r in self.history}
