"""Discrete-event pipeline orchestrator: the Kubeflow-Pipelines / Argo
control-plane analog, scheduling a compiled step DAG onto simulated
per-cloud clusters instead of executing it serially in-process.

The simulation contract is the repo-wide hardware gate (DESIGN.md §1):
each step's COMPUTE time is measured on this host (the fn runs for real,
exactly once, wall-clocked -- or takes an analytic ``sim_s``), while the
control-plane and network terms are CloudProfile constants: every attempt
charges ``startup_s`` (cluster/pod spin-up, the paper's per-stage
control-plane delta) + ``network_rtt_s`` (the control-plane hop) + any
cross-cloud artifact transfers (artifacts.py) + compute, and bills
``duration x cost_per_s`` worker-seconds against the price sheet.

Scheduling (the ``placement.plan_placement`` analog, per step): a ready
step takes a free worker on an eligible cloud -- not down, pin honored --
ranked by ``policy``: "makespan" minimizes the estimated completion
(startup + rtt + transfer estimate + known duration), "cost" takes the
cheapest cloud first.  Independent DAG branches therefore run in PARALLEL
across the ``{cloud: workers}`` slots; the greedy scheduler never idles a
worker while a step is ready, so with no failures the simulated makespan
never exceeds the serial sum of step durations (work conservation -- the
invariant suite asserts it).

Failures: ``FailureSpec``-style outage windows (duck-typed: cloud / at_s /
duration_s, same shape the gateway injects) kill every attempt running on
that cloud at the window start; the step retries with exponential backoff
(``RetryPolicy``), usually landing on a surviving cloud, until it
permanently fails and its descendants are skipped.  Completion is exactly
once: the fn's real execution happens once per run regardless of simulated
attempts, and a step ends in exactly one of done / failed / skipped.

A terminal ``kind="deploy"`` step closes the paper's train->serve loop:
its fn builds a serving backend from the trained artifact, the orchestrator
sizes a placement (``plan_placement``) from the backend's MEASURED service
time and hands it to ``Gateway.deploy`` -- one run goes pipeline ->
placement -> live gateway.  With ``DeploySpec.profile`` set, demand comes
from committed Model-CI profile artifacts instead (``ProfileStore.demand``)
and the planned-from profile rides into ``Gateway.deploy`` for drift
watching.

A ``kind="profile"`` step (payload: ``modelci.ProfileSpec``) is the
profiling DAG's measurement unit: its fn returns the raw profile field
dict (``modelci.measure``/``roofline_fields`` -- JSON-able, so it CACHES
across recurring runs) and on completion the orchestrator stamps the
executing cloud's constants and commits the ModelProfile artifact into
the spec's ProfileStore.  The commit re-runs on cached completions, so a
cache-hit recurring firing still refreshes the store's ``latest``.

Event vocabulary (telemetry/events.py): pipeline:run / schedule / step /
cache_hit / transfer / retry / fail / skip / deploy / recurring, plus
modelci:profile on profile-step completion.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

from ..clouds.profiles import PROFILES, CloudProfile, get_profile
from ..core.pipeline import PipelineSpec, StepRef, step_cache_key, toposort
from ..sim.engine import EventHeap
from ..telemetry.events import EventLog
from .artifacts import ArtifactCache, best_transfer, payload_bytes
from .runs import RetryPolicy, RunRecord, StepRecord


@dataclasses.dataclass
class DeploySpec:
    """Config for a terminal ``kind="deploy"`` step (the train->serve
    handoff).  The step's fn is the BACKEND FACTORY: it receives the
    upstream artifacts (e.g. trained params) and returns a gateway backend
    (``.name`` + ``.service_time(b)``).  The orchestrator then builds a
    ``ModelDemand`` from the backend's measured service time -- either a
    fixed ``rate`` (req/s) or a host-independent ``load_erlangs`` (offered
    load; rate = load / service_time) -- plans a placement over ``clouds``
    (placement.CloudCapacity list) and deploys the model active-active
    through ``Gateway.deploy`` with the plan's weights and queue hints.

    ``profile``: a ``modelci.ProfileStore`` (or anything with
    ``demand``/``worst``).  When set, every demand number comes from
    committed profile artifacts -- ``ModelDemand`` (service time and the
    prefill/decode split) is derived via ``profile.demand(model, ...)``
    restricted to the placement's candidate clouds, no profiles committed
    for the model on those clouds is an infeasible deploy, and the worst
    planned-from profile is handed through ``Gateway.deploy(planned_from=)``
    so the serving-side drift monitor can compare plan vs observed."""
    model: str
    clouds: list
    rate: Optional[float] = None
    load_erlangs: Optional[float] = None
    objective: str = "cost"
    split: bool = True
    autoscaler: Any = None               # gateway Autoscaler(Config) or None
    max_batch: int = 32
    profile: Any = None                  # modelci.ProfileStore or None

    def __post_init__(self):
        if (self.rate is None) == (self.load_erlangs is None):
            raise ValueError("set exactly one of rate / load_erlangs")
        if self.objective not in ("cost", "p99"):
            raise ValueError(f"unknown objective {self.objective!r}")


class _WorkerPool:
    def __init__(self, profile: CloudProfile, workers: int):
        self.profile = profile
        self.workers = workers
        self.busy = 0

    def free(self) -> int:
        return self.workers - self.busy


class _StepState:
    def __init__(self, record: StepRecord):
        self.record = record
        self.status = "pending"          # ready|running|done|failed|skipped
        self.executed = False            # the real fn ran (exactly once)
        self.output: Any = None
        self.compute_s = 0.0
        self.extra_s = 0.0               # deploy model loads
        self.out_clouds: Optional[set] = None
        self.nbytes = 0
        self.entry = None                # ArtifactCache entry, if cached
        self.cache_key: Optional[str] = None
        self.key_done = False            # inputs are fixed once deps are
        # done, so the content hash is computed once, not per ready pass
        self.deploy_info: Optional[dict] = None
        self.deploy_apply: Optional[dict] = None  # Gateway.deploy kwargs,
        # applied on successful COMPLETION only: a permanently failed
        # deploy step must not leave a live deployment behind
        self.pending: Optional[dict] = None   # in-flight attempt bookkeeping
        self.span = None                 # pipeline.step span (tracer only)
        self.attempt_span = None         # current pipeline.attempt span
        # market mode only (shared_capacity): live / speculative-backup
        # attempt info dicts -- the preemption victim registry
        self.live: Optional[dict] = None
        self.backup: Optional[dict] = None


class Orchestrator:
    """Event-driven executor over ``{cloud: worker pool}`` slots.

    clusters: {CloudProfile | name: n_workers} -- the simulated per-cloud
    clusters steps schedule onto.  policy: "makespan" | "cost" (see module
    docstring).  The ArtifactCache and EventLog persist across execute()
    calls, which is what makes recurring runs cache-hit (runs.py).
    """

    def __init__(self, clusters: dict, *, policy: str = "makespan",
                 retry: Optional[RetryPolicy] = None,
                 cache: Optional[ArtifactCache] = None,
                 log: Optional[EventLog] = None,
                 tracer=None, metrics=None, shared_capacity=None):
        if policy not in ("cost", "makespan"):
            raise ValueError(f"unknown policy {policy!r}")
        self.pools: dict[str, _WorkerPool] = {}
        for key, n in clusters.items():
            prof = key if isinstance(key, CloudProfile) else get_profile(key)
            if int(n) < 1:
                raise ValueError(f"{prof.name}: needs >= 1 worker")
            if prof.name in self.pools:
                raise ValueError(f"duplicate cluster {prof.name!r}")
            self.pools[prof.name] = _WorkerPool(prof, int(n))
        if not self.pools:
            raise ValueError("orchestrator needs at least one cluster")
        self.policy = policy
        self.retry = retry or RetryPolicy()
        self.cache = cache if cache is not None else ArtifactCache()
        self.log = log or EventLog()
        # observability plane (DESIGN.md S5): pipeline.run > pipeline.step
        # > pipeline.attempt > pipeline.transfer span tree on the simulated
        # clock, plus pipeline_* metric series.  Share the tracer with the
        # serving Gateway and the terminal deploy step links the serving
        # trace to this one (Deployment.trace_link).
        self.tracer = tracer
        self.metrics = metrics
        self._run_span = None            # open pipeline.run span (execute)
        # unified capacity market (clouds/capacity.py, ISSUE 9): when a
        # CapacityMarket is shared with the Gateway, every training attempt
        # takes a spot-style preemptible lease on its cloud's ledger and
        # recorded serving occupancy contends with scheduling.  None (the
        # default) keeps every pre-ISSUE-9 code path bit-identical.
        self.market = shared_capacity

    # -- outage windows ------------------------------------------------------
    @staticmethod
    def _windows(failures) -> dict:
        out: dict = {}
        for f in failures or []:
            if f.at_s < 0 or f.duration_s <= 0:
                raise ValueError("failure windows need at_s >= 0 and "
                                 "duration_s > 0")
            out.setdefault(f.cloud, []).append(
                (float(f.at_s), float(f.at_s + f.duration_s)))
        for w in out.values():
            w.sort()
        return out

    @staticmethod
    def _down_at(windows: dict, cloud: str, t: float) -> bool:
        return any(a <= t < e for a, e in windows.get(cloud, ()))

    @staticmethod
    def _fails_at(windows: dict, cloud: str, t: float,
                  t_end: float) -> Optional[float]:
        """First outage start strictly inside (t, t_end), else None."""
        for a, _ in windows.get(cloud, ()):
            if t < a < t_end:
                return a
        return None

    # -- input artifacts -----------------------------------------------------
    @staticmethod
    def _dep_indices(step) -> list:
        return list(step.deps)

    def _inputs_blocked(self, st: list, step, windows: dict,
                        t: float) -> bool:
        """True when some input artifact has residency but every resident
        cloud is mid-outage: the control plane cannot fetch it from
        anywhere, so the step must wait for a recovery edge -- the same
        rule the cache-hit path applies.  (Destination-independent.)"""
        for d in self._dep_indices(step):
            clouds = st[d].out_clouds
            if clouds and all(self._down_at(windows, c, t) for c in clouds):
                return True
        return False

    def _plan_inputs(self, st: list, step, cloud: str, windows: dict,
                     t: float) -> list:
        """Transfers needed to make every input local on ``cloud``:
        [(dep_idx, src_cloud, seconds, usd, nbytes)] -- priced by the one
        shared rule (artifacts.best_transfer), sourcing only from clouds
        that are LIVE at ``t`` (a dead cloud cannot serve bytes; callers
        gate on _inputs_blocked first, so a live source exists whenever
        residency is known)."""
        out = []
        dst = self.pools[cloud].profile
        profiles = {c: p.profile for c, p in self.pools.items()}
        for d in self._dep_indices(step):
            s = st[d]
            live = {c for c in (s.out_clouds or ())
                    if not self._down_at(windows, c, t)}
            move = best_transfer(live, s.nbytes, dst, profiles)
            if move is not None:
                src_c, t_s, usd = move
                out.append((d, src_c, t_s, usd, s.nbytes))
        return out

    # -- the run -------------------------------------------------------------
    def execute(self, spec: PipelineSpec, *, t0: float = 0.0,
                failures: Optional[list] = None, gateway=None,
                run_id: Optional[str] = None) -> RunRecord:
        names = [s.name for s in spec.steps]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate step names in {spec.name!r}")
        for s in spec.steps:
            if s.pin is not None and s.pin not in self.pools:
                raise ValueError(f"step {s.name!r} pinned to unknown cloud "
                                 f"{s.pin!r}")
            if s.kind == "deploy":
                if s.payload is None:
                    raise ValueError(f"deploy step {s.name!r} needs a "
                                     "DeploySpec payload")
                if gateway is None:
                    raise ValueError(f"deploy step {s.name!r} needs "
                                     "execute(gateway=...)")
            if s.kind == "profile":
                # duck-typed on purpose: importing modelci here would cycle
                # (modelci -> pipelines.artifacts -> this module)
                p = s.payload
                if p is None or not getattr(p, "model", None) \
                        or not hasattr(getattr(p, "store", None), "put"):
                    raise ValueError(f"profile step {s.name!r} needs a "
                                     "ProfileSpec payload (model + store)")
        toposort([list(s.deps) for s in spec.steps])   # cycle check
        run_id = run_id or spec.name
        windows = self._windows(failures)
        for pool in self.pools.values():
            pool.busy = 0
        if self.tracer is not None:
            self._run_span = self.tracer.start(
                "pipeline.run", float(t0), run_id=run_id,
                pipeline=spec.name)

        st = [_StepState(StepRecord(s.name)) for s in spec.steps]
        children: list = [[] for _ in spec.steps]
        for s in spec.steps:
            for d in s.deps:
                children[d].append(s.index)
        indeg = [len(s.deps) for s in spec.steps]

        events = EventHeap()             # shared sim core (repro.sim.engine)
        ready: set = set()
        for s in spec.steps:
            if indeg[s.index] == 0:
                events.push(float(t0), "ready", s.index)
        for cloud, ws in windows.items():
            for _, end in ws:            # recovery edges re-arm scheduling
                events.push(end, "recover", cloud)
        cap_woken: set = set()           # dedup for ledger wake-up events
        if self.market is not None:
            # recorded serving rise-edges from an earlier gateway run on
            # the shared market: at each edge the cloud may over-commit,
            # killing the youngest training lease (spot semantics)
            for cloud in self.pools:
                led = self.market.ledger(cloud)
                if led is not None:
                    for edge in led.serving_edges(lo=float(t0)):
                        events.push(edge, "capacity", cloud)
        self._worker_gauges()

        t_last = float(t0)
        wall0 = time.perf_counter()

        def cascade_skip(i: int, t: float) -> None:
            stack = list(children[i])
            while stack:
                j = stack.pop()
                if st[j].status in ("pending", "ready"):
                    st[j].status = "skipped"
                    st[j].record.status = "skipped"
                    ready.discard(j)
                    self.log.record("pipeline:skip", 0.0, step=names[j],
                                    reason="upstream", t_sim=round(t, 6))
                    stack.extend(children[j])

        def finish(i: int, t: float, pend: dict) -> None:
            nonlocal t_last
            s = st[i]
            rec = s.record
            s.status = "done"
            rec.status = "done"
            rec.cloud = pend["cloud"]
            rec.cached = pend["cached"]
            rec.end_s = t
            t_last = max(t_last, t)
            if pend["cached"]:
                s.output = pend["value"]
                s.entry = pend["entry"]
                s.out_clouds = pend["entry"].clouds
                s.nbytes = pend["entry"].nbytes
                pend["entry"].hits += 1
            else:
                self.pools[pend["cloud"]].busy -= 1
                att = rec.attempts[pend.get("att_idx", -1)]
                att["end_s"] = t
                att["status"] = "ok"
                att["cost_usd"] = pend["cost"]
                rec.cost_usd += pend["cost"]
                self._worker_gauges()
                if pend["key"] is not None:
                    s.entry = self.cache.put(pend["key"], s.output,
                                             names[i], pend["cloud"])
                    s.out_clouds = s.entry.clouds
                    s.nbytes = s.entry.nbytes
                else:
                    s.out_clouds = {pend["cloud"]}
                    s.nbytes = payload_bytes(s.output)
                for d, _src, _ts, _usd, _nb in pend["transfers"]:
                    if st[d].entry is not None:
                        self.cache.commit_transfer(st[d].entry, pend["cloud"])
                    else:
                        st[d].out_clouds.add(pend["cloud"])
            if s.deploy_apply is not None:
                # the handoff side effect happens exactly once, HERE: a
                # deploy step that never completes never touches the fleet
                if self.tracer is not None and s.span is not None:
                    # the serving gateway links every request span of this
                    # model back to THIS deploy step span: the cross-trace
                    # edge that makes one train-to-serve run one connected
                    # trace (telemetry/trace.py)
                    s.deploy_apply["trace_link"] = s.span.span_id
                gateway.deploy(**s.deploy_apply)
                s.deploy_apply = None
            if s.deploy_info is not None:
                self.log.record("pipeline:deploy", 0.0, step=names[i],
                                t_sim=round(t, 6), **s.deploy_info)
            if spec.steps[i].kind == "profile":
                # runs on cached completions too: a cache-hit recurring
                # firing must still refresh the store's `latest` pointer
                self._profile_commit(spec.steps[i], s, pend["cloud"], t)
            self.log.record("pipeline:step", pend["dur"], step=names[i],
                            cloud=pend["cloud"], cached=pend["cached"],
                            attempts=len(rec.attempts),
                            cost=round(rec.cost_usd, 10), t_sim=round(t, 6))
            if self.tracer is not None:
                sp = pend.get("span")
                if sp is not None and sp.t1 is None:
                    self.tracer.end(sp, t)
                if s.span is not None:
                    self.tracer.end(s.span, t, cloud=pend["cloud"],
                                    cached=pend["cached"], status="done")
                    rec.span_id = s.span.span_id
            if self.metrics is not None:
                self.metrics.histogram("pipeline_step_seconds",
                                       pipeline=spec.name,
                                       step=names[i]).observe(pend["dur"])
                if pend["cached"]:
                    self.metrics.counter("pipeline_cache_hits_total",
                                         pipeline=spec.name).inc()
            for j in children[i]:
                indeg[j] -= 1
                if indeg[j] == 0 and st[j].status == "pending":
                    st[j].status = "ready"
                    ready.add(j)

        def perm_fail(i: int, t: float, reason: str) -> None:
            nonlocal t_last
            st[i].status = "failed"
            st[i].record.status = "failed"
            st[i].record.end_s = t
            t_last = max(t_last, t)
            self.log.record("pipeline:fail", 0.0, step=names[i],
                            attempts=len(st[i].record.attempts),
                            reason=reason, t_sim=round(t, 6))
            if self.tracer is not None and st[i].span is not None:
                if st[i].attempt_span is not None \
                        and st[i].attempt_span.t1 is None:
                    self.tracer.end(st[i].attempt_span, t, status=reason)
                self.tracer.end(st[i].span, t, status="failed",
                                reason=reason)
                st[i].record.span_id = st[i].span.span_id
            cascade_skip(i, t)

        def kill_attempt(i: int, info: dict, t: float, status: str) -> None:
            """Market mode: close a live attempt early (preempted by a
            serving rise-edge, or cancelled as the losing side of a
            speculative pair).  The fn already ran exactly once; only the
            simulated occupancy is torn down."""
            nonlocal t_last
            s = st[i]
            info["dead"] = True          # stale done/abort events no-op
            self.pools[info["cloud"]].busy -= 1
            if info.get("lease") is not None:
                self.market.ledger(info["cloud"]).release(
                    info["lease"], t, status=status)
            cost = (t - info["start"]) \
                * self.pools[info["cloud"]].profile.cost_per_s \
                + info["tr_usd"]
            att = s.record.attempts[info["att_idx"]]
            att["end_s"] = t
            att["status"] = status
            att["cost_usd"] = cost
            s.record.cost_usd += cost
            t_last = max(t_last, t)
            if self.tracer is not None:
                for tsp in info.get("spans", ()):
                    if tsp.t1 is None or tsp.t1 > t:
                        self.tracer.end(tsp, t, truncated=True)
                asp = info.get("att_span")
                if asp is not None and asp.t1 is None:
                    self.tracer.end(asp, t, status=status)
            if s.live is info:
                s.live = None
            if s.backup is info:
                s.backup = None
            if s.pending is info:
                s.pending = None         # the scheduled abort is void
            self._worker_gauges()

        def preempt_training(cloud: str, t: float) -> bool:
            """Kill the youngest live training attempt on ``cloud`` (max
            start time, ties by attempt/step order).  The killed attempt
            re-enters the normal RetryPolicy backoff path -- unless its
            speculative twin is still running, which then IS the retry."""
            cands = []
            for j, s2 in enumerate(st):
                for info in (s2.live, s2.backup):
                    if info is not None and not info.get("dead") \
                            and info["cloud"] == cloud:
                        cands.append((info["start"], info["att_idx"], j,
                                      info))
            if not cands:
                return False
            _, _, j, info = max(cands, key=lambda x: x[:3])
            kill_attempt(j, info, t, "preempted")
            s2 = st[j]
            self.log.record("capacity:preempt", 0.0, step=names[j],
                            cloud=cloud, attempt=info["att_idx"] + 1,
                            t_sim=round(t, 6))
            if s2.live is None and s2.backup is not None:
                s2.live = s2.backup      # surviving twin carries on
                s2.backup = None
            if s2.live is not None:
                return True
            n_att = len(s2.record.attempts)
            if n_att > self.retry.max_retries:
                perm_fail(j, t, "preempted")
            else:
                nxt = t + self.retry.delay_s(n_att - 1)
                s2.status = "retry_wait"
                self.log.record("pipeline:retry", 0.0, step=names[j],
                                cloud=cloud, attempt=n_att,
                                t_sim=round(t, 6), next_s=round(nxt, 6),
                                reason="preempt")
                events.push(nxt, "ready", j)
            return True

        def market_edges(t: float) -> None:
            """A recorded serving rise-edge may over-commit a cloud whose
            slots are partly held by open training leases: preempt the
            youngest until the committed timeline fits again."""
            for cloud in sorted(self.market.ledgers):
                led = self.market.ledgers[cloud]
                while led.used(t) > led.slots:
                    if not preempt_training(cloud, t):
                        break            # nothing left to evict here

        def schedule(t: float) -> None:
            for i in sorted(ready):
                step = spec.steps[i]
                s = st[i]
                # cache hit: the control plane reuses the artifact without
                # starting a pod -- no worker, no startup, one rtt to the
                # resident cloud (Kubeflow step caching)
                key = None
                if step.cache:
                    if not s.key_done:
                        args = tuple(self._resolve(st, a) for a in step.args)
                        kwargs = {k: self._resolve(st, v)
                                  for k, v in step.kwargs.items()}
                        s.cache_key = step_cache_key(
                            spec.name, step.name, step.fn, args, kwargs)
                        s.key_done = True
                    key = s.cache_key
                    entry = self.cache.get(key)
                    if entry is not None:
                        # serve the hit from a LIVE resident cloud; if the
                        # artifact lives only on dead clouds the control
                        # plane cannot fetch it -- wait for recovery (an
                        # outage must hit cached recurring runs too)
                        homes = sorted(entry.clouds)
                        live = [c for c in homes
                                if not self._down_at(windows, c, t)]
                        if homes and not live:
                            continue

                        def _prof(c):
                            # a resident cloud outside this cluster map (a
                            # retired cluster's store entry) still prices
                            # its own control-plane RTT -- same PROFILES
                            # fallback as best_transfer
                            p = self.pools.get(c)
                            return p.profile if p else PROFILES.get(c)

                        # fastest live resident cloud serves the hit, the
                        # same fastest-then-name rule best_transfer uses
                        home = min(
                            live,
                            key=lambda c: ((_prof(c).network_rtt_s
                                            if _prof(c) else 0.0), c)) \
                            if live else None
                        hp = _prof(home) if home else None
                        rtt = hp.network_rtt_s if hp else 0.0
                        s.status = "running"
                        ready.discard(i)
                        s.record.start_s = t
                        self.log.record("pipeline:cache_hit", 0.0,
                                        step=names[i], key=key,
                                        cloud=home, t_sim=round(t, 6))
                        hit_span = None
                        if self.tracer is not None:
                            if s.span is None:
                                s.span = self.tracer.start(
                                    "pipeline.step", t,
                                    parent=self._run_span, step=names[i],
                                    deps=[names[d] for d in step.deps])
                            hit_span = self.tracer.start(
                                "pipeline.attempt", t, parent=s.span,
                                cloud=home, cached=True, control_s=rtt,
                                transfer_s=0.0, compute_s=0.0)
                        events.push(
                            t + rtt, "done",
                            (i, {"cloud": home, "cached": True,
                                 "value": entry.value, "entry": entry,
                                 "dur": rtt, "cost": 0.0, "key": None,
                                 "transfers": [], "span": hit_span}))
                        continue
                if self._inputs_blocked(st, step, windows, t):
                    continue             # inputs live only on dead clouds:
                cands = [p for c, p in self.pools.items()   # wait, like a
                         if p.free() > 0                    # cache hit would
                         and not self._down_at(windows, c, t)
                         and (step.pin is None or step.pin == c)]
                if self.market is not None and cands:
                    # the ledger is the source of truth: a cloud whose
                    # slots are taken (serving occupancy + reserve) admits
                    # no new training lease at t -- the step waits for the
                    # next recorded lease end (wake-up event)
                    open_ = [p for p in cands
                             if self.market.training_free(
                                 p.profile.name, t) > 0]
                    if not open_:
                        for p in cands:
                            led = self.market.ledger(p.profile.name)
                            nxt = (led.next_release_after(t)
                                   if led is not None else None)
                            if nxt is not None and nxt not in cap_woken:
                                cap_woken.add(nxt)
                                events.push(nxt, "recover", p.profile.name)
                    cands = open_
                if not cands:
                    continue             # stays ready; a done/abort/recover
                ready.discard(i)         # event re-runs this pass
                pool = min(cands, key=lambda p: self._rank(st, step, p,
                                                           windows, t))
                transfers = self._plan_inputs(st, step, pool.profile.name,
                                              windows, t)
                self._start_attempt(spec, st, i, pool, t, key, transfers,
                                    windows, events, perm_fail)

        while events:
            # collect-then-apply batching: a same-t push during the batch
            # (e.g. a zero-RTT cache hit) lands in the NEXT batch, then
            # schedule(t) runs again at the same timestamp -- the
            # orchestrator's historical semantics, kept by pop_batch()
            t, batch = events.pop_batch()
            for kind, data in batch:
                if kind == "ready":
                    if st[data].status == "pending":
                        st[data].status = "ready"
                        ready.add(data)
                    elif st[data].status == "retry_wait":
                        st[data].status = "ready"
                        ready.add(data)
                elif kind == "recover":
                    pass                 # scheduling pass below re-checks
                elif kind == "capacity":
                    market_edges(t)      # serving rise-edge on `data` cloud
                elif kind == "done":
                    i, pend = data
                    if self.market is not None and not pend["cached"]:
                        info = pend.get("info")
                        if info is not None:
                            if info.get("dead"):
                                continue  # preempted/cancelled: stale done
                            s2 = st[i]
                            if info.get("lease") is not None:
                                self.market.ledger(info["cloud"]).release(
                                    info["lease"], t)
                            info["dead"] = True
                            loser = (s2.backup if s2.live is info
                                     else s2.live)
                            if s2.live is info:
                                s2.live = None
                            else:
                                s2.backup = None
                            if loser is not None and not loser.get("dead"):
                                # the speculative twin lost the race:
                                # cancel it through the ledger
                                kill_attempt(i, loser, t, "cancelled")
                    finish(i, t, pend)
                else:                    # "abort": outage killed the attempt
                    i = data
                    s = st[i]
                    pend = s.pending
                    if self.market is not None \
                            and (pend is None or pend.get("dead")):
                        continue         # already torn down via the market
                    s.pending = None
                    self.pools[pend["cloud"]].busy -= 1
                    if self.market is not None:
                        pend["dead"] = True
                        if pend.get("lease") is not None:
                            # with a live speculative twin the outage ends
                            # the race: the twin is promoted below and the
                            # primary is the cancelled loser in the audit
                            self.market.ledger(pend["cloud"]).release(
                                pend["lease"], t,
                                status=("cancelled" if s.backup is not None
                                        else "released"))
                        if s.live is pend:
                            s.live = None
                    self._worker_gauges()
                    cost = (t - pend["start"]) \
                        * self.pools[pend["cloud"]].profile.cost_per_s \
                        + pend["tr_usd"]
                    att = s.record.attempts[pend.get("att_idx", -1)]
                    att["end_s"] = t
                    att["status"] = "outage"
                    att["cost_usd"] = cost
                    s.record.cost_usd += cost
                    t_last = max(t_last, t)
                    if self.tracer is not None:
                        # the outage truncates the attempt (and any
                        # in-flight transfer child) at the window start
                        for tsp in pend.get("spans", ()):
                            if tsp.t1 is None or tsp.t1 > t:
                                self.tracer.end(tsp, t, truncated=True)
                        asp = pend.get("att_span", s.attempt_span)
                        if asp is not None and asp.t1 is None:
                            self.tracer.end(asp, t, status="outage")
                    n_att = len(s.record.attempts)
                    if self.market is not None and s.backup is not None:
                        s.live = s.backup   # the speculative backup IS the
                        s.backup = None     # retry: no backoff re-entry
                    elif n_att > self.retry.max_retries:
                        perm_fail(i, t, "outage")
                    else:
                        nxt = t + self.retry.delay_s(n_att - 1)
                        s.status = "retry_wait"
                        self.log.record("pipeline:retry", 0.0, step=names[i],
                                        cloud=pend["cloud"], attempt=n_att,
                                        t_sim=round(t, 6),
                                        next_s=round(nxt, 6),
                                        reason="outage")
                        events.push(nxt, "ready", i)
            schedule(t)

        bad = [names[i] for i, s in enumerate(st)
               if s.status not in ("done", "failed", "skipped")]
        if bad:
            raise RuntimeError(f"orchestrator stalled on {bad}")

        steps = {names[i]: s.record for i, s in enumerate(st)}
        status = ("succeeded" if all(s.status == "done" for s in st)
                  else "failed")
        rec = RunRecord(
            run_id, spec.name, status, float(t0), t_last, steps,
            {names[i]: s.output for i, s in enumerate(st)
             if s.status == "done"},
            cost_usd=sum(r.cost_usd for r in steps.values()),
            cache_hits=sum(1 for r in steps.values() if r.cached))
        self.log.record("pipeline:run", rec.makespan_s, run_id=run_id,
                        pipeline=spec.name, status=status,
                        cost=round(rec.cost_usd, 10),
                        wall_s=round(time.perf_counter() - wall0, 4))
        if self.tracer is not None and self._run_span is not None:
            self.tracer.end(self._run_span, t_last, status=status)
            rec.span_id = self._run_span.span_id
            self._run_span = None
        if self.metrics is not None:
            self.metrics.counter("pipeline_runs_total", pipeline=spec.name,
                                 status=status).inc()
            self.metrics.counter("pipeline_cost_usd_total",
                                 pipeline=spec.name).inc(rec.cost_usd)
        return rec

    # -- attempt machinery ---------------------------------------------------
    @staticmethod
    def _resolve(st: list, v: Any):
        if isinstance(v, StepRef):
            return st[v.index].output
        return v

    def _rank(self, st: list, step, pool: _WorkerPool, windows: dict,
              t: float) -> tuple:
        """Policy key for one eligible pool (lower is better).  The
        completion estimate only counts KNOWN terms: control-plane
        constants, the transfer plan, and the compute duration when it is
        analytic (sim_s) or already measured by an earlier attempt."""
        prof = pool.profile
        tr = sum(x[2] for x in self._plan_inputs(st, step, prof.name,
                                                 windows, t))
        known = step.sim_s if step.sim_s is not None else (
            st[step.index].compute_s if st[step.index].executed else 0.0)
        est = prof.startup_s + prof.network_rtt_s + tr + known
        if self.policy == "cost":
            return (prof.cost_per_s, est, prof.name)
        return (est, prof.cost_per_s, prof.name)

    def _start_attempt(self, spec, st, i: int, pool: _WorkerPool, t: float,
                       key, transfers, windows, events: EventHeap,
                       perm_fail) -> None:
        step = spec.steps[i]
        s = st[i]
        names = step.name
        cloud = pool.profile.name
        tr_s = sum(x[2] for x in transfers)
        tr_usd = sum(x[3] for x in transfers)
        if self.tracer is not None and s.span is None:
            # opened on the FIRST attempt (exception paths included) and
            # closed by finish/perm_fail; deps attr carries the dependency
            # step names the critical-path walk follows
            s.span = self.tracer.start(
                "pipeline.step", t, parent=self._run_span, step=names,
                deps=[spec.steps[d].name for d in step.deps])
        if not s.executed:
            args = tuple(self._resolve(st, a) for a in step.args)
            kwargs = {k: self._resolve(st, v)
                      for k, v in step.kwargs.items()}
            w0 = time.perf_counter()
            try:
                s.output = step.fn(*args, **kwargs)
            except Exception as e:       # authoring bug, not an outage:
                s.executed = True        # fail fast, no retries
                s.record.start_s = t
                s.record.attempts.append(
                    {"cloud": cloud, "start_s": t, "end_s": t,
                     "status": "exception", "cost_usd": 0.0})
                perm_fail(i, t, f"exception:{type(e).__name__}")
                return
            wall = time.perf_counter() - w0
            s.executed = True
            s.compute_s = step.sim_s if step.sim_s is not None else wall
            if step.kind == "deploy":
                ok = self._plan_handoff(step, s)
                if not ok:
                    s.record.start_s = t
                    s.record.attempts.append(
                        {"cloud": cloud, "start_s": t, "end_s": t,
                         "status": "infeasible", "cost_usd": 0.0})
                    perm_fail(i, t, "deploy_infeasible")
                    return
        dur = (pool.profile.startup_s + pool.profile.network_rtt_s
               + tr_s + s.compute_s + s.extra_s)
        t_end = t + dur
        s.status = "running"
        if not s.record.attempts:
            s.record.start_s = t
        s.record.compute_s = s.compute_s
        s.record.transfer_s += tr_s
        s.record.transfer_cost_usd += tr_usd
        s.record.attempts.append({"cloud": cloud, "start_s": t,
                                  "end_s": t_end, "status": "ok",
                                  "cost_usd": 0.0})
        pool.busy += 1
        lease = None
        if self.market is not None:
            led = self.market.ledger(cloud)
            if led is not None:
                lease = led.lease("training", f"{spec.name}:{names}", t)
                self.log.record("capacity:lease", 0.0, cloud=cloud,
                                kind="training", step=names,
                                t_sim=round(t, 6))
        self._worker_gauges()
        self.log.record("pipeline:schedule", 0.0, step=names, cloud=cloud,
                        attempt=len(s.record.attempts), t_sim=round(t, 6))
        att_span = None
        tspans = []
        if self.tracer is not None:
            # attempt attrs carry the simulated-time decomposition the
            # critical-path analyzer reads back: control (startup + rtt +
            # deploy model loads), transfer, compute
            att_span = s.attempt_span = self.tracer.start(
                "pipeline.attempt", t, parent=s.span, cloud=cloud,
                attempt=len(s.record.attempts),
                control_s=(pool.profile.startup_s
                           + pool.profile.network_rtt_s + s.extra_s),
                transfer_s=tr_s, compute_s=s.compute_s)
        for d, src, t_tr, usd, nb in transfers:
            self.log.record("pipeline:transfer", t_tr, step=names,
                            src=src, dst=cloud, bytes=int(nb),
                            cost=round(usd, 10), t_sim=round(t, 6))
            if self.tracer is not None:
                tsp = self.tracer.start("pipeline.transfer", t,
                                        parent=att_span, src=src, dst=cloud,
                                        bytes=int(nb))
                self.tracer.end(tsp, t + t_tr)
                tspans.append(tsp)
        att_idx = len(s.record.attempts) - 1
        info = None
        if self.market is not None:
            info = {"cloud": cloud, "start": t, "tr_usd": tr_usd,
                    "spans": tspans, "lease": lease, "att_idx": att_idx,
                    "att_span": att_span, "dead": False}
            s.live = info
        t_f = self._fails_at(windows, cloud, t, t_end)
        if t_f is not None:
            s.pending = info if info is not None else \
                {"cloud": cloud, "start": t, "tr_usd": tr_usd,
                 "spans": tspans, "att_idx": att_idx}
            events.push(t_f, "abort", i)
            if info is not None:
                # the outage window threatens this attempt: hedge with a
                # speculative backup on a second cloud (PR 5's carried
                # candidate) -- the loser is cancelled via the ledger
                self._speculate(spec, st, i, t, key, windows, events)
            return
        cost = dur * pool.profile.cost_per_s + tr_usd
        pend = {"cloud": cloud, "cached": False, "dur": dur, "cost": cost,
                "key": key, "transfers": transfers, "span": att_span,
                "att_idx": att_idx}
        if info is not None:
            pend["info"] = info
        events.push(t_end, "done", (i, pend))

    def _worker_gauges(self) -> None:
        """Expose cluster occupancy to the metrics plane (ISSUE 9): the
        gauges are refreshed on every busy/free transition and frozen by
        whatever scrape runs next (e.g. a shared registry's gateway
        scrape loop)."""
        if self.metrics is None:
            return
        for c in sorted(self.pools):
            p = self.pools[c]
            self.metrics.gauge("pipeline_workers_busy", cloud=c).set(p.busy)
            self.metrics.gauge("pipeline_workers_free",
                               cloud=c).set(p.free())

    def _speculate(self, spec, st, i: int, t: float, key, windows,
                   events: EventHeap) -> None:
        """Market mode: the just-started attempt is doomed by a known
        outage window.  Launch a backup attempt of the same step on a
        second cloud that (a) has a free worker and a free ledger slot and
        (b) survives its own duration -- the first attempt to complete
        wins and the loser is cancelled through the ledger.  The fn is
        NOT re-run (exactly once); the backup replays the measured
        compute under the alternate cloud's control-plane constants."""
        step = spec.steps[i]
        s = st[i]
        prim = s.live["cloud"]
        cands = []
        for c, p in self.pools.items():
            if c == prim or p.free() <= 0 \
                    or self._down_at(windows, c, t) \
                    or (step.pin is not None and step.pin != c) \
                    or self.market.training_free(c, t) <= 0:
                continue
            transfers = self._plan_inputs(st, step, c, windows, t)
            tr_s = sum(x[2] for x in transfers)
            dur = (p.profile.startup_s + p.profile.network_rtt_s + tr_s
                   + s.compute_s + s.extra_s)
            if self._fails_at(windows, c, t, t + dur) is not None:
                continue                 # a doomed backup hedges nothing
            cands.append((self._rank(st, step, p, windows, t), c, p,
                          transfers, tr_s, dur))
        if not cands:
            return
        _, cloud, pool, transfers, tr_s, dur = min(cands,
                                                   key=lambda x: x[0])
        tr_usd = sum(x[3] for x in transfers)
        t_end = t + dur
        led = self.market.ledger(cloud)
        lease = None
        if led is not None:
            lease = led.lease("training", f"{spec.name}:{step.name}", t)
            if lease is None:
                return                   # lost the slot; no hedge
            self.log.record("capacity:lease", 0.0, cloud=cloud,
                            kind="training", step=step.name,
                            t_sim=round(t, 6))
        pool.busy += 1
        s.record.transfer_s += tr_s
        s.record.transfer_cost_usd += tr_usd
        s.record.attempts.append({"cloud": cloud, "start_s": t,
                                  "end_s": t_end, "status": "ok",
                                  "cost_usd": 0.0})
        att_idx = len(s.record.attempts) - 1
        self._worker_gauges()
        self.log.record("capacity:speculate", 0.0, step=step.name,
                        cloud=cloud, primary=prim, attempt=att_idx + 1,
                        t_sim=round(t, 6))
        self.log.record("pipeline:schedule", 0.0, step=step.name,
                        cloud=cloud, attempt=att_idx + 1,
                        t_sim=round(t, 6))
        att_span = None
        tspans = []
        if self.tracer is not None:
            att_span = self.tracer.start(
                "pipeline.attempt", t, parent=s.span, cloud=cloud,
                attempt=att_idx + 1, speculative=True,
                control_s=(pool.profile.startup_s
                           + pool.profile.network_rtt_s + s.extra_s),
                transfer_s=tr_s, compute_s=s.compute_s)
        for d, src, t_tr, usd, nb in transfers:
            self.log.record("pipeline:transfer", t_tr, step=step.name,
                            src=src, dst=cloud, bytes=int(nb),
                            cost=round(usd, 10), t_sim=round(t, 6))
            if self.tracer is not None:
                tsp = self.tracer.start("pipeline.transfer", t,
                                        parent=att_span, src=src,
                                        dst=cloud, bytes=int(nb))
                self.tracer.end(tsp, t + t_tr)
                tspans.append(tsp)
        info = {"cloud": cloud, "start": t, "tr_usd": tr_usd,
                "spans": tspans, "lease": lease, "att_idx": att_idx,
                "att_span": att_span, "dead": False}
        s.backup = info
        cost = dur * pool.profile.cost_per_s + tr_usd
        events.push(t_end, "done",
                    (i, {"cloud": cloud, "cached": False, "dur": dur,
                         "cost": cost, "key": key, "transfers": transfers,
                         "span": att_span, "att_idx": att_idx,
                         "info": info}))

    def _profile_commit(self, step, s: _StepState, cloud: Optional[str],
                        t: float) -> None:
        """kind="profile" terminal side effect: stamp the executing
        cloud's constants onto the fn's raw measurement dict and commit
        the ModelProfile artifact into the spec's store.  ``cloud`` is the
        pin when set (per-cloud profiling DAGs pin their steps), else the
        cloud the attempt/cache-hit landed on."""
        from ..modelci.profile import finalize   # lazy: modelci imports
        ps = step.payload                        # pipelines.artifacts
        if not isinstance(s.output, dict):
            raise TypeError(f"profile step {step.name!r} fn must return "
                            "the raw profile field dict "
                            "(modelci.measure / roofline_fields)")
        name = step.pin or cloud
        pool = self.pools.get(name) if name else None
        prof = pool.profile if pool else PROFILES.get(name)
        if prof is None:                 # retired cluster / unknown pool:
            prof = next(iter(self.pools.values())).profile
        mp = finalize(s.output, ps.model, prof)
        ps.store.put(mp)
        self.log.record("modelci:profile", 0.0, step=step.name,
                        model=ps.model, cloud=mp.cloud, key=mp.key,
                        service_time_s=round(mp.service_time_s, 9),
                        source=mp.source, t_sim=round(t, 6))
        if self.metrics is not None:
            self.metrics.counter("modelci_profiles_total",
                                 model=ps.model, cloud=mp.cloud).inc()

    def _plan_handoff(self, step, s: _StepState) -> bool:
        """Deploy planning: size a placement from the backend's measured
        service time -- or, with ``DeploySpec.profile`` set, from the
        committed Model-CI profile artifacts (ProfileStore.demand), so no
        hand-tuned service-time constant enters the plan.  The
        Gateway.deploy call itself is DEFERRED to the step's successful
        completion (finish) so a deploy step that permanently fails leaves
        no live deployment behind.  The fn's output (the backend) is
        replaced by a JSON-able summary; the backend itself lives on
        inside the prepared deploy kwargs."""
        from ..serving.gateway.placement import ModelDemand, plan_placement
        ds: DeploySpec = step.payload
        backend = s.output
        planned_profile = None
        if ds.profile is not None:
            cnames = [cc.profile.name for cc in ds.clouds]
            try:
                planned_profile = ds.profile.worst(ds.model, cnames)
            except KeyError:
                return False             # no artifacts: deploy_infeasible
            dem = planned_profile.demand(rate=ds.rate,
                                         load_erlangs=ds.load_erlangs)
            svc, rate = dem.service_time_s, dem.rate
        else:
            svc = backend.service_time(ds.max_batch) / ds.max_batch
            rate = ds.rate if ds.rate is not None else ds.load_erlangs / svc
            dem = ModelDemand(ds.model, rate, svc)
        clouds = ds.clouds
        if self.market is not None:
            # placement headroom reads the ledger: a cloud can never host
            # more replicas than its market slots (serving holds priority,
            # so the full slot count -- not slots minus reserve -- bounds)
            clouds = [
                cc if self.market.ledger(cc.profile.name) is None
                else dataclasses.replace(
                    cc, max_replicas=min(
                        cc.max_replicas,
                        self.market.ledger(cc.profile.name).slots))
                for cc in ds.clouds]
        plan = plan_placement([dem], clouds,
                              objective=ds.objective, split=ds.split)
        a = plan.assignments[0]
        if not plan.feasible or not a.shares:
            return False
        profiles = {c.profile.name: c.profile for c in ds.clouds}
        s.deploy_apply = dict(
            name=ds.model, backend=backend,
            split={profiles[c]: w for c, w in a.weights.items()},
            autoscaler=ds.autoscaler, max_batch=ds.max_batch,
            queue_hint=dict(a.est_wait_s))
        if planned_profile is not None:
            # the drift monitor compares serving observations against the
            # exact artifact the placement was planned from
            s.deploy_apply["planned_from"] = planned_profile
        # weights loaded onto every serving cloud: one model_load_s each
        s.extra_s = sum(profiles[c].model_load_s for c in a.shares)
        s.deploy_info = {"model": ds.model,
                         "weights": {c: round(w, 6)
                                     for c, w in a.weights.items()},
                         "replicas": dict(a.shares),
                         "cost_hr": round(a.cost_hr, 6),
                         "profiled": planned_profile is not None}
        s.output = {"model": ds.model, "weights": dict(a.weights),
                    "replicas": dict(a.shares), "cost_hr": a.cost_hr,
                    "est_p99_s": a.est_p99_s,
                    "profiled": planned_profile is not None}
        return True
