"""Multi-cloud pipeline orchestrator (Kubeflow-Pipelines control-plane
analog): discrete-event scheduling of compiled step DAGs onto simulated
per-cloud worker pools (scheduler.py), recurring / fault-tolerant runs
with retries and exactly-once completion (runs.py), a cross-run
cloud-local artifact cache with transfer-cost accounting (artifacts.py),
and a terminal deploy step that hands the trained model to the serving
gateway.  See DESIGN.md §4."""
from .artifacts import (ArtifactCache, CacheEntry, best_transfer,
                        payload_bytes, transfer_cost_usd, transfer_time_s)
from .runs import PipelineRuns, RetryPolicy, RunRecord, StepRecord
from .scheduler import DeploySpec, Orchestrator

__all__ = [
    "ArtifactCache", "CacheEntry", "best_transfer", "payload_bytes",
    "transfer_cost_usd", "transfer_time_s",
    "PipelineRuns", "RetryPolicy", "RunRecord", "StepRecord",
    "DeploySpec", "Orchestrator",
]
