"""Cross-run, cloud-local artifact cache with transfer-cost accounting.

Every successful orchestrator step publishes its output here under the
content-hash key from ``core.pipeline.step_cache_key`` -- the SAME key the
serial ``Pipeline.run`` cache uses, so the two executors reuse each other's
artifacts.  An entry remembers which simulated clouds hold a local copy
("cloud-local", the PVC-per-cluster analog): a step scheduled on a cloud
that does not hold one of its inputs pays a TRANSFER -- seconds over the
cross-cloud interconnect plus simulated egress dollars, both priced from
the CloudProfile fields (``interconnect_bw``, ``egress_per_gb``) -- and the
destination cloud becomes a holder once the consuming attempt completes,
so a recurring run only pays each cross-cloud move once.

Like every CloudProfile-derived number (DESIGN.md §1), transfer seconds and
egress dollars are simulation outputs, never measurements; only the
artifact SIZES are real (bytes of the actual in-memory value).

An optional ArtifactStore backs the cache on disk using the one shared
record shape (``core.pipeline.cache_record``), so cache hits survive the
process when the value is JSON-able and committed residency is never
re-billed cross-process.  An artifact written by the SERIAL executor
carries no residency (it ran on no simulated cloud): the orchestrator
reuses it with no resident cloud to serve from and no honest source to
bill a transfer against -- it moves for free, by design.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

from ..checkpoint.store import ArtifactStore
from ..clouds.profiles import PROFILES, CloudProfile
from ..core.pipeline import cache_record, value_cacheable


def payload_bytes(v: Any) -> int:
    """Real in-memory size of an artifact value: array leaves count their
    buffers, everything else falls back to its repr.  This is the one
    MEASURED term in the transfer formula."""
    try:
        import jax
        leaves = jax.tree_util.tree_leaves(v)
    except Exception:
        leaves = [v]
    total = 0
    for leaf in leaves:
        try:
            total += int(np.asarray(leaf).nbytes)
        except Exception:
            total += len(repr(leaf).encode())
    return total


def transfer_time_s(src: CloudProfile, dst: CloudProfile,
                    nbytes: int) -> float:
    """Seconds to move ``nbytes`` from src to dst: one control-plane RTT on
    each side plus the bytes over the narrower interconnect."""
    return (src.network_rtt_s + dst.network_rtt_s
            + nbytes / min(src.interconnect_bw, dst.interconnect_bw))


def transfer_cost_usd(src: CloudProfile, nbytes: int) -> float:
    """Simulated egress dollars: billed by the SOURCE cloud per GB sent
    (the bytes leave even if the consuming attempt later fails)."""
    return (nbytes / 1e9) * src.egress_per_gb


def best_transfer(src_clouds, nbytes: int, dst: CloudProfile,
                  profiles: dict):
    """(src_cloud, seconds, usd) for the cheapest move of ``nbytes`` onto
    ``dst`` from any of the resident ``src_clouds`` (fastest, then lowest
    egress, then name -- deterministic), or None when dst already holds a
    copy or nothing is priceable.  Egress is always billed at the SOURCE
    cloud's rate: a residency cloud missing from the caller's clusters is
    resolved from the global PROFILES sheet (a store entry written against
    a retired cluster), and only a cloud known to neither transfers for
    free -- there is nothing honest to price it against.  The ONE
    transfer-pricing rule, shared by the scheduler's input planning and
    its placement ranking."""
    if not src_clouds or dst.name in src_clouds:
        return None
    best = None
    for c in sorted(src_clouds):
        src = profiles.get(c) or PROFILES.get(c)
        if src is None:
            continue
        k = (transfer_time_s(src, dst, nbytes),
             transfer_cost_usd(src, nbytes), c)
        if best is None or k < best:
            best = k
    if best is None:
        return None
    t_s, usd, src_c = best
    return (src_c, t_s, usd)


@dataclasses.dataclass
class CacheEntry:
    key: str
    value: Any
    step: str
    nbytes: int
    clouds: set                          # cloud names holding a local copy
    hits: int = 0
    persisted: bool = False              # JSON-able -> mirrored to the store


class ArtifactCache:
    """Content-addressed, residency-aware artifact cache (in-memory, with
    an optional ArtifactStore mirror shared with the serial Pipeline)."""

    def __init__(self, store: Optional[ArtifactStore] = None):
        self.store = store
        self.entries: dict[str, CacheEntry] = {}
        self.transfers = 0               # lifetime cross-cloud moves

    def get(self, key: str) -> Optional[CacheEntry]:
        e = self.entries.get(key)
        if e is not None:
            return e
        if self.store is not None and self.store.exists(key):
            rec = self.store.load_json(key)
            if not rec.get("cacheable", False):
                return None              # value was not persistable
            nbytes = (int(rec["nbytes"]) if "nbytes" in rec
                      else payload_bytes(rec["value"]))
            e = CacheEntry(key, rec["value"], rec.get("step", "?"), nbytes,
                           set(rec.get("clouds", [])), persisted=True)
            self.entries[key] = e
        return e

    def put(self, key: str, value: Any, step: str, cloud: str) -> CacheEntry:
        e = CacheEntry(key, value, step, payload_bytes(value), {cloud})
        self.entries[key] = e
        if self.store is not None:
            e.persisted = value_cacheable(value)
            self.store.save_json(key, cache_record(value, step, e.clouds,
                                                   e.nbytes))
        return e

    def commit_transfer(self, entry: CacheEntry, dst_cloud: str) -> None:
        """The consuming attempt completed: dst now holds a local copy.
        Persisted entries rewrite their residency meta too, so a future
        PROCESS reloading this entry does not re-bill a move already
        paid (the in-memory set covers recurring runs in-process)."""
        entry.clouds.add(dst_cloud)
        self.transfers += 1
        if self.store is not None and entry.persisted:
            self.store.save_json(entry.key, cache_record(
                entry.value, entry.step, entry.clouds, entry.nbytes))
