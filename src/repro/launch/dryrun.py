"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against the production mesh, with NO real allocation
(ShapeDtypeStruct stand-ins everywhere, params included via eval_shape).

MUST run as a module entry point (python -m repro.launch.dryrun ...): the
XLA_FLAGS below are read at first jax init, so they are set before ANY other
import.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse      # noqa: E402
import functools     # noqa: E402
import json          # noqa: E402
import subprocess    # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import registry                    # noqa: E402
from ..models import lm, sharding as msh, steps   # noqa: E402
from ..optim import adamw                         # noqa: E402
from . import mesh as mesh_mod, roofline, shardings  # noqa: E402


def _shape_cfg(cfg, shape):
    """Shape-specific config: bf16 compute; decode budget; unrolled scans so
    cost_analysis sees true totals (XLA counts while bodies once); chunked
    time-scans widened so the unrolled chunk count stays ~16."""
    cfg = cfg.replace(dtype="bfloat16", scan_unroll=True)
    if shape.kind == "decode":
        cfg = cfg.replace(max_decode_len=shape.seq_len)
    if cfg.family in ("ssm", "hybrid") and shape.kind in ("train", "prefill"):
        # layer scans unroll; time-chunk scans stay rolled (compile-time
        # bound) and are analytically corrected in roofline terms
        cfg = cfg.replace(chunk_unroll=False)
    return cfg


def build_lowering(cfg, shape, mesh, *, zero1=False, donate=True,
                   min_relocate_bytes=0):
    """Returns (lowered, chips). Must be called under msh.use_mesh(mesh)."""
    param_spec = steps.params_spec(cfg)
    param_sh = msh.param_shardings(param_spec, mesh,
                                   min_relocate_bytes=min_relocate_bytes)

    if shape.kind == "train":
        opt_spec = steps.opt_state_spec(param_spec)
        opt_sh = shardings.opt_shardings(opt_spec, param_spec, mesh, zero1=zero1)
        bspec = steps.batch_spec(cfg, shape.global_batch, shape.seq_len, train=True)
        batch_sh = shardings.batch_shardings(bspec, mesh)
        fn = functools.partial(steps.train_step, cfg=cfg)
        jitted = jax.jit(fn, in_shardings=(param_sh, opt_sh, batch_sh),
                         out_shardings=(param_sh, opt_sh, None),
                         donate_argnums=(0, 1) if donate else ())
        return jitted.lower(param_spec, opt_spec, bspec)

    if shape.kind == "prefill":
        bspec = steps.batch_spec(cfg, shape.global_batch, shape.seq_len, train=False)
        batch_sh = shardings.batch_shardings(bspec, mesh)
        fn = functools.partial(steps.prefill, cfg=cfg, cache_len=shape.seq_len)
        jitted = jax.jit(fn, in_shardings=(param_sh, batch_sh))
        return jitted.lower(param_spec, bspec)

    # decode: ONE token against a seq_len cache
    tok_spec, pos_spec, cache_spec = steps.decode_specs(
        cfg, shape.global_batch, shape.seq_len)
    cache_sh = shardings.cache_shardings(cache_spec, mesh)
    bsh = shardings.batch_shardings({"t": tok_spec, "p": pos_spec}, mesh)
    fn = functools.partial(steps.serve_step, cfg=cfg)
    jitted = jax.jit(fn, in_shardings=(param_sh, cache_sh, bsh["t"], bsh["p"]),
                     out_shardings=(None, cache_sh),
                     donate_argnums=(1,) if donate else ())
    return jitted.lower(param_spec, cache_spec, tok_spec, pos_spec)


def run_one(arch: str, shape_name: str, mesh_kind: str, *, zero1=False,
            fused_attn=False, profile="tp", remat=False, tag="",
            expert_pad=0, min_relocate_bytes=0, serve_bf16=False,
            ssm_chunk=0) -> dict:
    cfg = registry.get_config(arch)
    shape = registry.INPUT_SHAPES[shape_name]
    ok, reason = registry.runnable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag}
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    cfg = _shape_cfg(cfg, shape)
    if zero1:
        cfg = cfg.replace(zero1=True)
    if fused_attn:
        cfg = cfg.replace(fused_attention=True)
    if remat:
        cfg = cfg.replace(remat=True)
    if profile != "tp":
        cfg = cfg.replace(sharding_profile=profile)
    if expert_pad:
        cfg = cfg.replace(expert_pad_to=expert_pad)
    if ssm_chunk:
        cfg = cfg.replace(ssm_chunk=ssm_chunk)
    if serve_bf16 and shape.kind != "train":
        # deployment artifact: serving reads bf16 weights (no optimizer, no
        # master copy) -- halves weight traffic + kills convert copies (C2)
        cfg = cfg.replace(param_dtype="bfloat16")
    mesh = mesh_mod.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.size
    try:
        with msh.use_profile(cfg.sharding_profile), msh.use_mesh(mesh):
            t0 = time.time()
            lowered = build_lowering(cfg, shape, mesh, zero1=zero1,
                                     min_relocate_bytes=min_relocate_bytes)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0
        cost = roofline.cost_dict(compiled)
        flops = float(cost.get("flops", 0.0))
        byts = float(cost.get("bytes accessed", 0.0))
        scan_fix = (roofline.slstm_correction_flops(
            cfg, shape.kind, shape.global_batch, shape.seq_len)
            + roofline.chunk_scan_correction_flops(
                cfg, shape.kind, shape.global_batch, shape.seq_len)) / chips
        flops += scan_fix
        hlo_text = compiled.as_text()
        coll = roofline.collective_bytes(hlo_text)
        terms = roofline.roofline(flops, byts, coll["total_bytes"], chips)
        fused_bytes = roofline.fusion_modeled_bytes(hlo_text)
        mf = roofline.model_flops(cfg, shape.kind, shape.global_batch, shape.seq_len)
        mem = {}
        try:
            ma = compiled.memory_analysis()
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "generated_code_size_in_bytes",
                         "alias_size_in_bytes"):
                if hasattr(ma, attr):
                    mem[attr] = int(getattr(ma, attr))
        except Exception as e:                                   # CPU backend gaps
            mem = {"error": str(e)}
        rec.update(
            status="ok", chips=chips, lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            roofline=terms.as_dict(),
            memory_fused_s=fused_bytes / 819e9,
            bytes_fused_model=fused_bytes,
            collectives=coll,
            model_flops=mf,
            useful_flops_ratio=(mf / (flops * chips) if flops else None),
            memory_analysis=mem,
        )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}")
    return rec


def matrix(mesh_kinds):
    for arch in registry.list_archs():
        for shape_name in registry.INPUT_SHAPES:
            for mk in mesh_kinds:
                yield arch, shape_name, mk


def main(argv=None):
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(registry.INPUT_SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--zero1", action="store_true", help="ZeRO-1 optimizer sharding")
    ap.add_argument("--fused-attn", action="store_true",
                    help="chunked online-softmax attention (perf variant)")
    ap.add_argument("--remat", action="store_true", help="activation ckpt")
    ap.add_argument("--profile", default="tp", choices=("tp", "dp"),
                    help="sharding profile (dp = pure data-parallel)")
    ap.add_argument("--expert-pad", type=int, default=0,
                    help="pad expert count to enable expert-parallel dispatch")
    ap.add_argument("--min-relocate-bytes", type=int, default=0,
                    help="replicate (not relocate) params smaller than this")
    ap.add_argument("--serve-bf16", action="store_true",
                    help="bf16 weight artifact for prefill/decode (C2)")
    ap.add_argument("--ssm-chunk", type=int, default=0,
                    help="override SSD/mLSTM chunk length (perf sweep)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="", help="suffix for output files")
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)
    mesh_kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all:
        failures = 0
        for arch, shape_name, mk in matrix(mesh_kinds):
            tag = f"_{args.tag}" if args.tag else ""
            path = os.path.join(args.out, f"{arch}_{shape_name}_{mk}{tag}.json")
            if os.path.exists(path):
                print(f"skip (exists): {path}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
                   "--shape", shape_name, "--mesh", mk, "--out", args.out]
            if args.zero1:
                cmd.append("--zero1")
            if args.tag:
                cmd += ["--tag", args.tag]
            r = subprocess.run(cmd)
            failures += (r.returncode != 0)
        sys.exit(1 if failures else 0)

    rec = run_one(args.arch, args.shape, mesh_kinds[0], zero1=args.zero1,
                  fused_attn=args.fused_attn, profile=args.profile,
                  remat=args.remat, tag=args.tag, expert_pad=args.expert_pad,
                  min_relocate_bytes=args.min_relocate_bytes,
                  serve_bf16=args.serve_bf16, ssm_chunk=args.ssm_chunk)
    tag = f"_{args.tag}" if args.tag else ""
    path = os.path.join(args.out, f"{args.arch}_{args.shape}_{mesh_kinds[0]}{tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps({k: rec[k] for k in rec if k not in ("collectives", "memory_analysis")},
                     indent=1))
    sys.exit(0 if rec["status"] in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
