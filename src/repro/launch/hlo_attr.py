"""HLO byte attribution: parse a compiled module's text and rank ops by
output-buffer size (proxy for HBM traffic) grouped by op kind and by shape.

  python -m repro.launch.hlo_attr --arch X --shape Y [--fused-attn ...]
prints the top-N op kinds and top-N individual shapes.  Used by the §Perf
iterations to find where the memory term actually lives.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse        # noqa: E402
import collections     # noqa: E402
import re              # noqa: E402

from ..configs import registry                    # noqa: E402
from ..models import sharding as msh              # noqa: E402
from . import dryrun, mesh as mesh_mod, roofline  # noqa: E402

_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.-]+\s*=\s*(.+?)\s*([\w-]+)\(")


def attribute(hlo_text: str, top: int = 25, fused_model: bool = True):
    """Rank ops by output bytes.  fused_model=True applies the same filter
    as roofline.fusion_modeled_bytes (entry params + materialising ops in
    non-fusion computations), so the ranking explains that metric."""
    by_kind: dict = collections.Counter()
    by_shape: dict = collections.Counter()
    in_fusion = in_entry = False
    depth = 0
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        hdr = roofline._COMP_HDR.match(line.strip()) \
            if line.strip().endswith("{") else None
        if hdr and depth == 0:
            name = hdr.group(2)
            in_fusion = "fused" in name or "region" in name
            in_entry = bool(hdr.group(1))
            depth = 1
            continue
        if depth and line.strip() == "}":
            depth = 0
            in_fusion = in_entry = False
            continue
        if fused_model and (not depth or in_fusion):
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        type_part, op = m.groups()
        if fused_model:
            if op == "parameter" and not in_entry:
                continue
            if op not in roofline._MATERIALIZING and op != "fusion" \
               and op != "parameter":
                continue
        nbytes = roofline._shape_bytes(type_part)
        if nbytes <= 0:
            continue
        by_kind[op] += nbytes
        key = f"{op}:{type_part.strip()[:70]}"
        by_shape[key] += nbytes
    return by_kind, by_shape


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--fused-attn", action="store_true")
    ap.add_argument("--profile", default="tp")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--top", type=int, default=20)
    args = ap.parse_args(argv)

    cfg = registry.get_config(args.arch)
    shape = registry.INPUT_SHAPES[args.shape]
    cfg = dryrun._shape_cfg(cfg, shape)
    if args.fused_attn:
        cfg = cfg.replace(fused_attention=True)
    if args.profile != "tp":
        cfg = cfg.replace(sharding_profile=args.profile)
    if args.zero1:
        cfg = cfg.replace(zero1=True)
    mesh = mesh_mod.make_production_mesh(multi_pod=(args.mesh == "multi"))
    with msh.use_profile(cfg.sharding_profile), msh.use_mesh(mesh):
        compiled = dryrun.build_lowering(cfg, shape, mesh,
                                         zero1=args.zero1).compile()
    by_kind, by_shape = attribute(compiled.as_text())
    total = sum(by_kind.values())
    print(f"total output-buffer bytes (per device): {total / 1e12:.2f} TB")
    print("\n== by op kind ==")
    for op, b in by_kind.most_common(args.top):
        print(f"  {op:30s} {b / 1e12:8.3f} TB  ({100 * b / total:4.1f}%)")
    print("\n== top shapes ==")
    for key, b in by_shape.most_common(args.top):
        print(f"  {b / 1e12:8.3f} TB  {key}")


if __name__ == "__main__":
    main()
