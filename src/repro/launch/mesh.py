"""Production meshes.  Functions, not module constants -- importing this
module never touches jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax use).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_local_mesh():
    """Single-device mesh for smoke tests / local serving."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
