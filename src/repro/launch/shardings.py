"""Input/cache/optimizer sharding specs for the jit boundaries.

Params use models.sharding rule tables; this module covers everything else:
data batches (batch dim over pod+data), decode caches (batch over pod+data,
heads/state over model), optimizer state (params' spec, optionally ZeRO-1
sharded over data).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import sharding as msh


def _axes(mesh: Mesh, logical) -> Any:
    mesh_axes = msh._axis_table().get(logical, (logical,) if logical else None)
    if mesh_axes is None:
        return None
    present = tuple(a for a in mesh_axes if a in mesh.axis_names)
    return present if len(present) > 1 else (present[0] if present else None)


def _batch_ways(mesh: Mesh) -> int:
    ax = _axes(mesh, "batch")
    if ax is None:
        return 1
    axes = ax if isinstance(ax, tuple) else (ax,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def batch_shardings(batch_spec: Any, mesh: Mesh) -> Any:
    """Every batch leaf: dim0 = batch over (pod, data); small batches
    (long_500k b=1) stay unsharded rather than GSPMD-padding 32x."""
    ways = _batch_ways(mesh)

    def f(leaf):
        ax = _axes(mesh, "batch") if leaf.shape and leaf.shape[0] % ways == 0 else None
        spec = (ax,) + (None,) * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map(f, batch_spec)


# cache leaf key -> logical spec tail (after the leading group-stack dim and
# the batch dim, which are fixed (None, batch)).
_CACHE_RULES = {
    "k": (None, "model", None),        # (G,B,S,Hkv,hd)
    "v": (None, "model", None),
    "cross_k": (None, "model", None),
    "cross_v": (None, "model", None),
    "c_kv": (None, None),              # (G,B,S,r) MLA compressed: replicated tail
    "k_rope": (None, None),
    "ssm": ("model", None, None),      # (G,B,H,P,N)
    "conv": (None, "model"),           # (G,B,k,conv_dim)
    "C": ("model", None, None),        # (G,B,H,hd,hd) mlstm
    "n": ("model", None),
    "m": ("model",),
    "c": ("model", None),              # slstm
    "h": ("model", None),
}


def cache_shardings(cache_spec: Any, mesh: Mesh) -> Any:
    ways = _batch_ways(mesh)

    def f(path, leaf):
        key = None
        for p in reversed(path):
            name = getattr(p, "key", None)
            if isinstance(name, str):
                key = name
                break
        ndim = len(leaf.shape)
        if key == "enc_len":
            ax = _axes(mesh, "batch") if leaf.shape[0] % ways == 0 else None
            return NamedSharding(mesh, P(ax))
        tail = _CACHE_RULES.get(key, ())
        tail = tail[:max(ndim - 2, 0)]
        tail = tail + (None,) * (ndim - 2 - len(tail))
        batch_ax = _axes(mesh, "batch") if ndim >= 2 and leaf.shape[1] % ways == 0 else None
        spec = (None, batch_ax) + tuple(_axes(mesh, t) for t in tail)
        fitted = msh.fit_pspec(tuple(leaf.shape), P(*spec[:ndim]), mesh, relocate=False)
        return NamedSharding(mesh, fitted)
    return jax.tree_util.tree_map_with_path(f, cache_spec)


def opt_shardings(opt_spec: Any, params_spec_tree: Any, mesh: Mesh,
                  *, zero1: bool = False) -> Any:
    """mu/nu follow the param sharding; ZeRO-1 additionally shards the first
    unsharded dim over the data axis."""
    param_sh = msh.param_shardings(params_spec_tree, mesh)

    def zero_ify(sh: NamedSharding, leaf):
        if not zero1 or "data" not in mesh.axis_names:
            return sh
        spec = list(sh.spec) + [None] * (len(leaf.shape) - len(sh.spec))
        data_n = mesh.shape["data"]
        for i, (ax, dim) in enumerate(zip(spec, leaf.shape)):
            if ax is None and dim >= data_n and dim % data_n == 0:
                spec[i] = "data"
                return NamedSharding(mesh, P(*spec))
        return sh

    mu = jax.tree_util.tree_map(zero_ify, param_sh, params_spec_tree)
    return {"mu": mu, "nu": mu, "step": NamedSharding(mesh, P())}
