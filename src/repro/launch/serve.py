"""Serving launcher: batched greedy generation through the KServe analog.

`python -m repro.launch.serve --arch zamba2-1.2b --requests 32` spins up an
InferenceService whose predictor runs prefill + a greedy decode loop on the
reduced config, then runs the paper's stress test against it.
"""
from __future__ import annotations

import argparse
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np

from ..clouds.profiles import get_profile
from ..configs import registry
from ..models import lm, steps
from ..serving.kserve import InferenceService, Predictor
from ..telemetry.events import EventLog


def make_lm_predictor(cfg, *, gen_tokens: int = 8, prompt_len: int = 16,
                      seed: int = 0) -> Predictor:
    params = lm.init_params(jax.random.PRNGKey(seed), cfg)
    cache_len = prompt_len + gen_tokens + 1

    @jax.jit
    def predict(tokens):
        batch = {"tokens": tokens}
        if cfg.use_mrope:
            b, s = tokens.shape
            batch["mrope_positions"] = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32)[None, None], (b, 3, s))
        if cfg.family == "vlm":
            batch["vision_embeds"] = jnp.zeros(
                (tokens.shape[0], min(cfg.n_vision_tokens, tokens.shape[1]),
                 cfg.d_model), cfg.compute_dtype)
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (tokens.shape[0], cfg.encoder_len, cfg.d_model), cfg.compute_dtype)
        last, cache = steps.prefill(params, batch, cfg=cfg, cache_len=cache_len)
        first = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
        start = jnp.full((tokens.shape[0],), tokens.shape[1], jnp.int32)
        if cfg.use_mrope:
            start = jnp.broadcast_to(start[:, None], (tokens.shape[0], 3))
        toks, _ = steps.greedy_decode_loop(params, cache, first, start,
                                           gen_tokens, cfg=cfg)
        return toks

    example = np.zeros((1, prompt_len), np.int32)
    return Predictor(cfg.name, predict, example)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--profile", default="gcp")
    ap.add_argument("--strategy", default="kserve",
                    choices=("baremetal", "k8s", "kserve"))
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--gen-tokens", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = registry.get_smoke_config(args.arch)
    pred = make_lm_predictor(cfg, gen_tokens=args.gen_tokens)
    log = EventLog()
    svc = InferenceService(pred, get_profile(args.profile), args.strategy,
                           max_batch=args.max_batch, log=log)
    res = svc.stress_test(args.requests)
    print(json.dumps(res.summary(), indent=1))


if __name__ == "__main__":
    main()
