"""Render EXPERIMENTS.md tables from experiments/dryrun/*.json.

  python -m repro.launch.report [--dir experiments/dryrun] [--mesh single]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dryrun_dir: str, mesh: str, tag: str = "") -> list[dict]:
    recs = []
    suffix = f"_{mesh}{('_' + tag) if tag else ''}.json"
    for path in sorted(glob.glob(os.path.join(dryrun_dir, f"*{suffix}"))):
        base = os.path.basename(path)[: -len(suffix)]
        with open(path) as f:
            rec = json.load(f)
        if tag == "" and any(base.endswith(x) for x in ("_zero1", "_opt")):
            continue
        recs.append(rec)
    return recs


def _fmt(x, digits=4):
    if x is None:
        return "-"
    if x == 0:
        return "0"
    return f"{x:.{digits}g}"


def roofline_table(recs: list[dict]) -> str:
    hdr = ("| arch | shape | status | compute_s | memory_s | collective_s | "
           "bound_s | dominant | useful_ratio | collectives (AR/AG/RS/A2A/CP) |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in recs:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | skipped "
                         f"({r['reason'][:40]}...) |" + " - |" * 7)
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR |" + " - |" * 7)
            continue
        t = r["roofline"]
        c = r["collectives"]["per_kind_counts"]
        counts = "/".join(str(c.get(k, 0)) for k in (
            "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
            "collective-permute"))
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {_fmt(t['compute_s'])} | "
            f"{_fmt(t['memory_s'])} | {_fmt(t['collective_s'])} | "
            f"{_fmt(t['bound_s'])} | **{t['dominant']}** | "
            f"{_fmt(r.get('useful_flops_ratio'), 3)} | {counts} |")
    return "\n".join(lines)


def dryrun_table(recs: list[dict]) -> str:
    hdr = ("| arch | shape | status | chips | lower_s | compile_s | "
           "flops/chip | bytes/chip | coll_bytes/chip |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in recs:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['status']} |"
                         + " - |" * 6)
            continue
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['chips']} | "
            f"{r['lower_s']} | {r['compile_s']} | {_fmt(t['flops'], 4)} | "
            f"{_fmt(t['bytes_accessed'], 4)} | {_fmt(t['coll_bytes'], 4)} |")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="")
    ap.add_argument("--kind", choices=("roofline", "dryrun"), default="roofline")
    args = ap.parse_args(argv)
    recs = load(args.dir, args.mesh, args.tag)
    print((roofline_table if args.kind == "roofline" else dryrun_table)(recs))


if __name__ == "__main__":
    main()
