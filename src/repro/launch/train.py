"""Training launcher: `python -m repro.launch.train --arch gemma3-4b --smoke`.

Full configs are for the dry-run mesh; on this CPU host use --smoke (the
reduced per-arch variant) or override --layers/--d-model.
"""
from __future__ import annotations

import argparse
import json

from ..checkpoint.store import ArtifactStore
from ..configs import registry
from ..core.trainjob import LMTrainJob
from ..telemetry.events import EventLog
from . import mesh as mesh_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--store", default="experiments/artifacts")
    ap.add_argument("--mesh", choices=("local", "none"), default="none")
    args = ap.parse_args(argv)

    cfg = (registry.get_smoke_config(args.arch) if args.smoke
           else registry.get_config(args.arch))
    if args.layers:
        cfg = cfg.replace(n_layers=args.layers)
    mesh = mesh_mod.make_local_mesh() if args.mesh == "local" else None
    log = EventLog()
    job = LMTrainJob(cfg, batch_size=args.batch, seq_len=args.seq,
                     n_steps=args.steps, lr=args.lr, mesh=mesh,
                     store=ArtifactStore(args.store), log=log)
    res = job.run(checkpoint_name=f"{cfg.name}-smoke")
    print(json.dumps({"arch": cfg.name, "loss_first": res["history"][0],
                      "loss_last": res["loss"],
                      "stages": log.totals()}, indent=1))


if __name__ == "__main__":
    main()
