"""Roofline extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds.  jax lowers to the
per-device (post-SPMD-partitioning) module, so cost_analysis() FLOPs/bytes
and the HLO collective shapes are ALREADY per-chip quantities:
  compute    = HLO_FLOPs_per_chip  / peak_FLOP/s
  memory     = HLO_bytes_per_chip  / HBM_bw
  collective = collective_bytes_per_chip / link_bw
(equivalently total/(chips*rate) -- the assignment's formula -- since
total = chips * per-chip for an evenly sharded program).

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis().  Collective bytes
are NOT in cost_analysis: we parse the optimized HLO text and sum the
*output* buffer sizes of every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute (async *-start ops counted once, -done
skipped).  Output-bytes is a consistent per-op traffic proxy (ring
all-reduce moves ~2x this; documented convention, same across all combos).

The MODEL_FLOPS / (HLO_FLOPs * chips) ratio reports how much of the
compiled compute is "useful" -- GSPMD padding waste, remat recompute and
softmax/normalisation overhead all push it away from ~1.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

from ..clouds.profiles import HardwareSpec, TPU_V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def cost_dict(compiled) -> dict:
    """compiled.cost_analysis() across jax versions: 0.4.x returns a
    one-element list of per-program dicts, newer releases the dict itself."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


# ops whose outputs genuinely travel to HBM even under TPU fusion; the
# elementwise/broadcast/select/convert chains around them fuse away on TPU
# (the CPU backend, which compiles this dry-run, fuses far less -- so raw
# "bytes accessed" is a fusion-naive upper bound; this models the TPU view)
_MATERIALIZING = {
    "dot", "convolution", "reduce", "reduce-window", "scatter", "gather",
    "sort", "concatenate", "copy", "transpose", "dynamic-update-slice",
    "dynamic-slice", "pad", "select-and-scatter", "rng", "cholesky",
    "triangular-solve", "fft", "all-reduce", "all-gather", "reduce-scatter",
    "all-to-all", "collective-permute", "while", "custom-call",
}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$")
_OP_LINE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.-]+\s*=\s*(.+?)\s*([\w-]+)\(")


def fusion_modeled_bytes(hlo_text: str) -> int:
    """Bytes that still hit HBM assuming TPU-grade elementwise fusion:
    ENTRY parameters (weights/activations read once) + outputs of
    materialising ops in non-fusion computations.  Fusion subcomputations
    are skipped entirely (their 'parameter' lines duplicate producer
    buffers); `fusion` op outputs ARE counted (the fused kernel's single
    write)."""
    total = 0
    in_fusion = False
    in_entry = False
    depth = 0
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line.strip()) if line.strip().endswith("{") else None
        if hdr and depth == 0:
            name = hdr.group(2)
            in_fusion = "fused" in name or "region" in name
            in_entry = bool(hdr.group(1))
            depth = 1
            continue
        if depth and line.strip() == "}":
            depth = 0
            in_fusion = in_entry = False
            continue
        if not depth or in_fusion:
            continue
        m = _OP_LINE.match(line)
        if not m:
            # entry parameters: "%p = f32[..] parameter(0)" matches _OP_LINE;
            # nothing else to do here
            continue
        type_part, op = m.groups()
        if op == "parameter":
            if in_entry:
                total += _shape_bytes(type_part)
            continue
        if op == "fusion" or op in _MATERIALIZING:
            total += _shape_bytes(type_part)
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-buffer bytes per collective kind from optimized HLO."""
    out = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        lhs, _, rhs = line.partition("=")
        m = re.match(r"\s*([\w.-]+)\s*\(?", rhs.strip())
        # find op name: first token after the output type annotation
        op = None
        for kind in COLLECTIVES:
            if re.search(rf"\b{kind}(-start)?\(", rhs):
                if re.search(rf"\b{kind}-done\(", rhs):
                    op = None
                else:
                    op = kind
                break
        if op is None:
            continue
        # output type(s) are between '=' and the op name
        type_part = rhs.split(op)[0]
        out[op] += _shape_bytes(type_part)
        counts[op] += 1
    out_total = sum(out.values())
    return {"per_kind_bytes": out, "per_kind_counts": counts, "total_bytes": out_total}


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_accessed: float
    coll_bytes: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def total_s(self) -> float:
        # terms overlap on real hardware; max() is the roofline bound
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {**dataclasses.asdict(self), "dominant": self.dominant,
                "bound_s": self.total_s}


def roofline(flops: float, bytes_accessed: float, coll_bytes: float,
             chips: int, hw: HardwareSpec = TPU_V5E) -> RooflineTerms:
    """Inputs are per-chip (the lowered module is the per-device program)."""
    return RooflineTerms(
        compute_s=flops / hw.peak_flops_bf16,
        memory_s=bytes_accessed / hw.hbm_bw,
        collective_s=coll_bytes / hw.ici_bw,
        flops=flops, bytes_accessed=bytes_accessed, coll_bytes=coll_bytes,
        chips=chips,
    )


def chunk_scan_correction_flops(cfg, shape_kind: str, batch: int, seq: int) -> float:
    """Analytic add-back for the rolled time-chunk scans (mamba2 SSD /
    mLSTM) in the dry-run.  With layer scans unrolled, HLO counts ONE chunk
    body per layer, i.e. total/nc -- so we add total*(nc-1)/nc.  Per-layer
    forward flops (matmul terms only):
      SSD    ~ 2BST(N + H*P) + 4BSHPN
      mLSTM  ~ 6BSTHD + 6BSHD^2
    Train counts fwd+bwd (x3)."""
    if cfg.family not in ("ssm", "hybrid") or shape_kind == "decode":
        return 0.0
    B, S, T = batch, seq, cfg.ssm_chunk
    nc = max(-(-S // T), 1)
    if nc <= 1:
        return 0.0
    mult = 3.0 if shape_kind == "train" else 1.0
    if cfg.family == "hybrid":                      # zamba2: mamba2 layers
        H, P, N = cfg.n_heads, cfg.d_inner // cfg.n_heads, cfg.ssm_state
        per_layer = 2 * B * S * T * (N + H * P) + 4 * B * S * H * P * N
        n_layers = cfg.n_layers
    else:                                           # xlstm: mLSTM layers
        H = cfg.n_heads
        D = cfg.d_model // H
        per_layer = 6 * B * S * T * H * D + 6 * B * S * H * D * D
        n_layers = cfg.n_layers - (cfg.n_layers // cfg.slstm_every
                                   if cfg.slstm_every else 0)
    return mult * n_layers * per_layer * (nc - 1) / nc


def slstm_correction_flops(cfg, shape_kind: str, batch: int, seq: int) -> float:
    """Analytic add-back for the sLSTM time scan, the one loop the dry-run
    cannot unroll (S sequential steps).  Covers the in-loop recurrent
    matmuls (4 gates x per-head hd x hd); input projections are outside the
    loop and already counted by HLO.  Train counts fwd+bwd (x3)."""
    if cfg.family != "ssm" or not cfg.slstm_every or shape_kind == "decode":
        return 0.0
    n_slstm = cfg.n_layers // cfg.slstm_every
    h, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    per_token = 4 * h * hd * hd * 2
    mult = 3.0 if shape_kind == "train" else 1.0
    return mult * n_slstm * batch * seq * per_token


def model_flops(cfg, shape_kind: str, batch: int, seq: int) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*D for train, 2*N_active*D for a
    forward-only token pass (prefill/decode)."""
    n_active = cfg.approx_active_params()
    mult = 6.0 if shape_kind == "train" else 2.0
    tokens = batch * (1 if shape_kind == "decode" else seq)
    return mult * n_active * tokens
