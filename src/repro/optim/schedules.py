"""LR schedules (pure fns of step)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, base_lr=1.0, warmup=100, total=1000, min_frac=0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * warm * cos


def constant(step, *, base_lr=1.0):
    return jnp.asarray(base_lr, jnp.float32)


SCHEDULES = {"warmup_cosine": warmup_cosine, "constant": constant}
