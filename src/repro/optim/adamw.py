"""AdamW with decoupled weight decay + grad clipping (pure pytree fns).

Optimizer state mirrors the param tree (mu, nu in fp32).  With cfg.zero1 the
launch layer shards mu/nu over the data axis in addition to the param's own
model-axis sharding (ZeRO-1) -- see launch/dryrun.py; this module is
sharding-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_opt_state(params: Any) -> Any:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(params: Any, grads: Any, state: Any, cfg: AdamWConfig,
                 lr_scale: jax.Array | float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        step_dir = (mu / b1c) / (jnp.sqrt(nu / b2c) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (step_dir + decay)
        return new_p.astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_mu = jax.tree_util.tree_leaves(state["mu"])
    flat_nu = jax.tree_util.tree_leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_state = {
        "mu": jax.tree_util.tree_unflatten(tdef, [o[1] for o in out]),
        "nu": jax.tree_util.tree_unflatten(tdef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
