"""Metrics plane: counters / gauges / histograms with a log-linear
quantile sketch, scraped on the SIMULATED clock (DESIGN.md S5).

The registry is the Prometheus analog the paper leans on (Istio metrics):
instruments are keyed by (name, sorted label items), observations land in
O(1) sketch buckets (no per-event dict churn on the hot path), and
``scrape(t_sim)`` appends an immutable snapshot so p50/p99/miss/shed/cost
SERIES exist over simulated time -- the single source the benches and the
SLO burn-rate monitor read, reconciled exactly against the event log by
the invariant suites (served + shed == offered).

Metric naming scheme (Prometheus conventions):
  <subsystem>_<noun>_<unit>[_total]   e.g. gateway_requests_total,
  gateway_request_latency_seconds, gateway_queue_depth,
  pipeline_step_seconds, gateway_cost_usd
labels: model / cloud / cls / outcome (served|shed) / pipeline / step.

The sketch is HDR/Prometheus-native-histogram style log-linear: each
power-of-two order is split into ``sub`` linear sub-buckets, giving a
relative quantile error <= 1/sub (sub=32 -> ~3%) over any value range
with a sparse dict of counts.  Quantiles interpolate inside the winning
bucket, and exact min/max are tracked so q=0/q=1 are exact.
"""
from __future__ import annotations

import json
import math
from typing import Optional

import numpy as np


class QuantileSketch:
    """Log-linear histogram sketch.  ``observe`` is O(1); ``quantile``
    walks the sparse buckets (analysis-time only)."""

    __slots__ = ("sub", "counts", "n", "total", "vmin", "vmax")

    def __init__(self, sub: int = 32):
        if sub < 1:
            raise ValueError("sub must be >= 1")
        self.sub = sub
        self.counts: dict[int, int] = {}   # flat bucket key -> count
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def _key(self, v: float) -> int:
        """Flat bucket key: exponent e (2^e <= v < 2^(e+1)) * sub + linear
        sub-bucket.  Non-positive values share one underflow bucket."""
        if v <= 0.0:
            return -(1 << 30)
        m, e = math.frexp(v)             # v = m * 2^e, m in [0.5, 1)
        sub = int((m - 0.5) * 2 * self.sub)   # 0..sub-1
        return e * self.sub + min(sub, self.sub - 1)

    def _lo(self, key: int) -> float:
        e, sub = divmod(key, self.sub)
        return math.ldexp(0.5 + sub / (2 * self.sub), e)

    def _hi(self, key: int) -> float:
        e, sub = divmod(key, self.sub)
        return math.ldexp(0.5 + (sub + 1) / (2 * self.sub), e)

    def observe(self, v: float) -> None:
        v = float(v)
        k = self._key(v)
        self.counts[k] = self.counts.get(k, 0) + 1
        self.n += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def observe_many(self, values) -> None:
        """Vectorized bulk observe (the gateway's scrape-time fold, which
        rebuilds series off the hot path).  Bucket counts / n / min / max
        are identical to a loop of ``observe`` calls over the same values;
        ``total`` may differ in the last float bits (pairwise vs serial
        summation), which every consumer tolerates."""
        v = np.asarray(values, float)
        if v.size == 0:
            return
        if v.size <= 32:                 # numpy dispatch overhead beats a
            for x in v.tolist():         # plain loop on small chunks
                self.observe(x)
            return
        vmin, vmax = float(v.min()), float(v.max())
        if vmin > 0.0:                   # the common all-positive chunk
            vp = v                       # skips the underflow filter
        else:
            pos = v > 0.0
            under = int(v.size - pos.sum())
            if under:                    # shared underflow bucket, as _key
                k = -(1 << 30)
                self.counts[k] = self.counts.get(k, 0) + under
            vp = v[pos]
        if vp.size:
            m, e = np.frexp(vp)          # same op chain as _key, so the
            sub = ((m - 0.5) * 2 * self.sub).astype(np.int64)   # keys match
            sub = np.minimum(sub, self.sub - 1)                 # bit-exactly
            keys, cnts = np.unique(e.astype(np.int64) * self.sub + sub,
                                   return_counts=True)
            get = self.counts.get
            for k, c in zip(keys.tolist(), cnts.tolist()):
                self.counts[k] = get(k, 0) + c
        self.n += int(v.size)
        self.total += float(v.sum())
        self.vmin = min(self.vmin, vmin)
        self.vmax = max(self.vmax, vmax)

    def merge(self, other: "QuantileSketch") -> None:
        if other.sub != self.sub:
            raise ValueError("cannot merge sketches with different sub")
        for k, c in other.counts.items():
            self.counts[k] = self.counts.get(k, 0) + c
        self.n += other.n
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)

    def quantile(self, q: float) -> Optional[float]:
        """Value at quantile ``q`` (linear interpolation inside the
        winning bucket, clamped to the exact observed min/max); None when
        empty.  Relative error <= 1/sub vs the exact percentile."""
        return self.quantiles((q,))[0]

    def quantiles(self, qs) -> list:
        """Several quantiles in ONE sorted bucket walk -- what snapshot()
        uses so each scrape sorts the buckets once, not per quantile."""
        out: list = [None] * len(qs)
        if self.n == 0:
            return out
        order = sorted(range(len(qs)), key=lambda i: qs[i])
        items = sorted(self.counts.items())
        acc, pos = 0.0, 0
        for i in order:
            q = qs[i]
            if q <= 0.0:
                out[i] = self.vmin
                continue
            if q >= 1.0:
                out[i] = self.vmax
                continue
            rank = q * self.n
            while pos < len(items) and acc + items[pos][1] < rank:
                acc += items[pos][1]
                pos += 1
            if pos >= len(items):
                out[i] = self.vmax
                continue
            k, c = items[pos]
            if k == -(1 << 30):          # underflow bucket: exact floor
                out[i] = min(0.0, self.vmin)
                continue
            frac = (rank - acc) / c
            lo, hi = self._lo(k), self._hi(k)
            out[i] = min(max(lo + frac * (hi - lo), self.vmin), self.vmax)
        return out

    @property
    def mean(self) -> Optional[float]:
        return self.total / self.n if self.n else None

    def snapshot(self) -> dict:
        p50, p99 = self.quantiles((0.5, 0.99))
        return {"n": self.n, "sum": self.total, "p50": p50, "p99": p99}


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError("counters only go up")
        self.value += v

    def snapshot(self):
        return self.value


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self):
        return self.value


class Histogram:
    __slots__ = ("sketch",)

    def __init__(self, sub: int = 32):
        self.sketch = QuantileSketch(sub)

    def observe(self, v: float) -> None:
        self.sketch.observe(v)

    def quantile(self, q: float) -> Optional[float]:
        return self.sketch.quantile(q)

    @property
    def n(self) -> int:
        return self.sketch.n

    def snapshot(self):
        return self.sketch.snapshot()


def _escape_label(v) -> str:
    """Prometheus exposition escaping for label VALUES: backslash first
    (escaping the escapes), then double-quote and newline."""
    return (str(v).replace("\\", r"\\").replace('"', r"\"")
            .replace("\n", r"\n"))


def _escape_help(v: str) -> str:
    """HELP text escaping: only backslash and newline (quotes are legal)."""
    return v.replace("\\", r"\\").replace("\n", r"\n")


class MetricsRegistry:
    """Label-keyed instrument families + simulated-time scrape snapshots.

    ``counter/gauge/histogram(name, **labels)`` get-or-create (stable key:
    sorted label items), so hot-path callers can cache the returned
    instrument and skip the lookup entirely.  ``scrape(t_sim)`` appends
    one frozen snapshot of every live series to ``scrapes``.
    """

    def __init__(self, *, sub: int = 32):
        self.sub = sub
        self._series: dict[tuple, object] = {}
        self._kinds: dict[str, str] = {}     # family name -> kind
        self._help: dict[str, str] = {}      # family name -> HELP text
        self.scrapes: list[dict] = []
        self._fmt_cache: list = []           # sorted (key_str, inst) pairs;
        # rebuilt when a series appears (scrape re-sorts + re-formats
        # otherwise -- measurable at gateway scrape frequency)

    def _get(self, kind: str, name: str, labels: dict):
        known = self._kinds.setdefault(name, kind)
        if known != kind:
            raise ValueError(f"{name!r} is a {known}, not a {kind}")
        key = (name, tuple(sorted(labels.items())))
        inst = self._series.get(key)
        if inst is None:
            inst = self._series[key] = (
                Counter() if kind == "counter" else
                Gauge() if kind == "gauge" else Histogram(self.sub))
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", name, labels)

    def describe(self, name: str, text: str) -> None:
        """Attach HELP text to a family (rendered by ``to_prometheus``;
        families never described fall back to a kind-derived one-liner)."""
        self._help[name] = str(text)

    # -- reads --------------------------------------------------------------
    def value(self, name: str, **labels):
        """Current value (counter/gauge) or sketch snapshot (histogram);
        None when the series does not exist."""
        inst = self._series.get((name, tuple(sorted(labels.items()))))
        return None if inst is None else inst.snapshot()

    def total(self, name: str, **match) -> float:
        """Sum of a counter family over every series whose labels include
        ``match`` (e.g. total('gateway_requests_total', model='m'))."""
        out = 0.0
        want = set(match.items())
        for (n, labels), inst in self._series.items():
            if n == name and want <= set(labels):
                out += inst.value
        return out

    # -- scrapes (simulated-time series) ------------------------------------
    def scrape(self, t_sim: float, log=None) -> dict:
        """Freeze every live series at simulated time ``t_sim``.  Passing
        an EventLog records a ``metrics:scrape`` event."""
        if len(self._fmt_cache) != len(self._series):
            self._fmt_cache = [(self._fmt(n, dict(l)), inst)
                               for (n, l), inst
                               in sorted(self._series.items())]
        snap = {"t_sim": float(t_sim),
                "series": {k: inst.snapshot()
                           for k, inst in self._fmt_cache}}
        self.scrapes.append(snap)
        if log is not None:
            log.record("metrics:scrape", 0.0, t_sim=round(t_sim, 6),
                       series=len(snap["series"]))
        return snap

    def series(self, name: str, **labels) -> list:
        """(t_sim, snapshot) pairs for one series across every scrape."""
        key = self._fmt(name, labels)
        return [(s["t_sim"], s["series"][key]) for s in self.scrapes
                if key in s["series"]]

    # -- export -------------------------------------------------------------
    @staticmethod
    def _fmt(name: str, labels: dict) -> str:
        if not labels:
            return name
        inner = ",".join(f'{k}="{_escape_label(v)}"'
                         for k, v in sorted(labels.items()))
        return f"{name}{{{inner}}}"

    def to_prometheus(self) -> str:
        """Prometheus text exposition of the CURRENT values (histograms as
        _count/_sum plus p50/p99 quantile gauges from the sketch).  Every
        family gets a ``# HELP``/``# TYPE`` pair and label values are
        escaped per the exposition format (backslash, quote, newline)."""
        by_family: dict[str, list] = {}
        for (n, labels), inst in sorted(self._series.items()):
            by_family.setdefault(n, []).append((dict(labels), inst))
        lines = []
        for name, series in by_family.items():
            kind = self._kinds[name]
            help_text = self._help.get(
                name, f"{'summary' if kind == 'histogram' else kind} "
                      f"family {name}")
            lines.append(f"# HELP {name} {_escape_help(help_text)}")
            lines.append(f"# TYPE {name} "
                         f"{'summary' if kind == 'histogram' else kind}")
            for labels, inst in series:
                if kind == "histogram":
                    s = inst.snapshot()
                    lines.append(f"{self._fmt(name + '_count', labels)}"
                                 f" {s['n']}")
                    lines.append(f"{self._fmt(name + '_sum', labels)}"
                                 f" {s['sum']:.9g}")
                    for q in (0.5, 0.99):
                        v = inst.quantile(q)
                        if v is not None:
                            ql = dict(labels, quantile=q)
                            lines.append(f"{self._fmt(name, ql)} {v:.9g}")
                else:
                    lines.append(f"{self._fmt(name, labels)} "
                                 f"{inst.snapshot():.9g}")
        return "\n".join(lines) + "\n"

    def to_json(self, path: Optional[str] = None) -> str:
        s = json.dumps({"current": {self._fmt(n, dict(l)): i.snapshot()
                                    for (n, l), i
                                    in sorted(self._series.items())},
                        "scrapes": self.scrapes}, indent=1)
        if path:
            with open(path, "w") as f:
                f.write(s)
        return s
