"""Structured tracing over SIMULATED time (DESIGN.md S5).

A ``Tracer`` owns a flat list of ``Span``s with deterministic integer ids
(a per-tracer monotonic counter -- no wall clock, no randomness, so a
seeded run produces a bit-identical trace).  Spans form a forest:

- ``parent_id`` edges build the tree WITHIN one simulated-time axis: a
  child span's [t0, t1] interval nests inside its parent's (the
  well-formedness invariant the test suites check).  Roots (run spans)
  have ``parent_id=None``.
- ``links`` are OTel-style causal references ACROSS trees whose time axes
  differ: the serving gateway's request spans link to the pipeline's
  terminal deploy-step span (each ``Gateway.run`` / ``Orchestrator.
  execute`` restarts its own sim clock at t0, so the request cannot NEST
  inside the deploy step -- it is caused by it).  ``reachable`` follows
  parent->child edges plus link-target->linker edges, which is how the
  e2e acceptance walks from a pipeline run span to a served request.

Span vocabulary: gateway.run > gateway.request > {gateway.queue,
gateway.serve}; pipeline.run > pipeline.step > {pipeline.attempt >
pipeline.transfer}.  A ``trace_id`` groups each tree (the root span's own
id, inherited by descendants); links deliberately keep their own
trace_id -- that is what makes them links and not parents.
"""
from __future__ import annotations

import json
from typing import Any, Optional


class Span:
    """One timed operation on the simulated clock.  ``t1 is None`` while
    open; ``attrs`` is a small flat dict (cheap on the hot path)."""

    __slots__ = ("span_id", "trace_id", "parent_id", "name", "t0", "t1",
                 "attrs", "links")

    def __init__(self, span_id: int, trace_id: int, parent_id: Optional[int],
                 name: str, t0: float, attrs: dict, links: tuple):
        self.span_id = span_id
        self.trace_id = trace_id
        self.parent_id = parent_id
        self.name = name
        self.t0 = float(t0)
        self.t1: Optional[float] = None
        self.attrs = attrs
        self.links = links

    @property
    def duration_s(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0

    def to_dict(self) -> dict:
        return {"span_id": self.span_id, "trace_id": self.trace_id,
                "parent_id": self.parent_id, "name": self.name,
                "t0": round(self.t0, 9),
                "t1": None if self.t1 is None else round(self.t1, 9),
                "attrs": self.attrs, "links": list(self.links)}

    def __repr__(self):
        return (f"Span({self.span_id} {self.name!r} "
                f"[{self.t0:.4f},{self.t1 if self.t1 is None else round(self.t1, 4)}]"
                f" parent={self.parent_id})")


class Tracer:
    def __init__(self):
        self.spans: list[Span] = []      # id order == creation order
        self._next = 0

    def start(self, name: str, t: float, *, parent: Optional[Span] = None,
              links: tuple = (), **attrs) -> Span:
        """Open a span at simulated time ``t``.  ``links`` holds span ids
        of causally-related spans in OTHER trees (may be empty)."""
        sid = self._next
        self._next = sid + 1
        trace_id = parent.trace_id if parent is not None else sid
        parent_id = parent.span_id if parent is not None else None
        if links and None in links:      # hot path: links is almost always
            links = tuple(l for l in links if l is not None)   # () already
        span = Span(sid, trace_id, parent_id, name, t, attrs, links)
        self.spans.append(span)
        return span

    @staticmethod
    def end(span: Span, t: float, **attrs) -> Span:
        span.t1 = float(t)
        if attrs:
            span.attrs.update(attrs)
        return span

    # -- lookups (analysis-time; the hot path only calls start/end) ---------
    def get(self, span_id: int) -> Span:
        return self.spans[span_id]       # ids ARE list indices

    def named(self, name: str) -> list:
        return [s for s in self.spans if s.name == name]

    def roots(self) -> list:
        return [s for s in self.spans if s.parent_id is None]

    def children_index(self) -> dict:
        """parent span_id -> [child Span], in creation order."""
        idx: dict[Optional[int], list] = {}
        for s in self.spans:
            if s.parent_id is not None:
                idx.setdefault(s.parent_id, []).append(s)
        return idx

    def reachable(self, span_id: int) -> set:
        """Span ids reachable from ``span_id`` following parent->child
        edges AND link-target->linker edges (a span linking TO a reachable
        span is caused by it -- the cross-trace train->serve walk)."""
        children = self.children_index()
        linked_by: dict[int, list] = {}
        for s in self.spans:
            for l in s.links:
                linked_by.setdefault(l, []).append(s.span_id)
        seen = {span_id}
        stack = [span_id]
        while stack:
            cur = stack.pop()
            for nxt in ([c.span_id for c in children.get(cur, ())]
                        + linked_by.get(cur, [])):
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append(nxt)
        return seen

    # -- export -------------------------------------------------------------
    def to_json(self, path: Optional[str] = None,
                log=None) -> str:
        """Deterministic JSON trace export (spans in id order).  Passing
        an EventLog records a ``trace:export`` event."""
        s = json.dumps([sp.to_dict() for sp in self.spans], indent=1)
        if path:
            with open(path, "w") as f:
                f.write(s)
        if log is not None:
            log.record("trace:export", 0.0, path=path or "",
                       spans=len(self.spans))
        return s

    @classmethod
    def from_json(cls, text: str) -> "Tracer":
        """Rebuild a Tracer from a ``to_json`` export so the offline
        analyzers (``analyze.request_table`` / ``run_table``) run against
        the file exactly as they would against the live tracer.  The
        export writes spans in id order and ids ARE list indices (the
        ``get()`` contract), so a reordered or id-gapped blob is rejected
        rather than silently re-keyed."""
        rows = json.loads(text)
        tr = cls()
        for i, r in enumerate(rows):
            if r["span_id"] != i:
                raise ValueError(f"span id {r['span_id']} at position {i}: "
                                 "ids must be the list indices")
            span = Span(r["span_id"], r["trace_id"], r["parent_id"],
                        r["name"], r["t0"], dict(r.get("attrs", {})),
                        tuple(r.get("links", ())))
            if r.get("t1") is not None:
                span.t1 = float(r["t1"])
            tr.spans.append(span)
        tr._next = len(tr.spans)
        return tr

    @classmethod
    def load(cls, path: str) -> "Tracer":
        """``from_json`` over a file written by ``to_json(path)``."""
        with open(path) as f:
            return cls.from_json(f.read())
