"""Trace analyzer: critical paths + per-stage time attribution
(DESIGN.md S5) -- the paper's Tables 4/5 as a DERIVED artifact of the
span tree rather than hand-kept timers.

Request side (gateway.request trees): ``request_breakdown`` attributes
each served request's latency to queue-wait vs network (rtt+lb) vs
cold-start vs service from its gateway.queue / gateway.serve child spans;
``slowest_requests`` ranks them, and ``request_table`` renders the
breakdown of the slowest (p99-ish) requests.

Run side (pipeline.run trees): ``run_critical_path`` walks the step spans
backward from the last-finishing step through its latest-finishing
dependency (span attrs carry the dep step names), yielding the chain that
bounds the makespan; ``run_table`` attributes each link's simulated time
to control-plane (startup+rtt) vs transfer vs compute vs wait
(ready-but-queued + retry backoff).

``validate_trace`` is the well-formedness oracle the invariant suites
run: span ids unique, parent edges acyclic and interval-nested, exactly
one root per trace id, closed spans only.

Exports: ``Tracer.to_json`` (JSON trace) and
``MetricsRegistry.to_prometheus`` (Prometheus text) are the two wire
formats; ``export`` writes both next to each other.
"""
from __future__ import annotations

from typing import Optional

from .trace import Span, Tracer


# -- well-formedness (invariant-suite oracle) -------------------------------

def validate_trace(tracer: Tracer, *, eps: float = 1e-9) -> list:
    """Return a list of violation strings (empty == well-formed):
    duplicate ids, dangling/self/cyclic parent edges, open spans, a child
    interval escaping its parent's, or a non-root span whose trace_id
    does not match its root's."""
    bad = []
    seen = set()
    for s in tracer.spans:
        if s.span_id in seen:
            bad.append(f"duplicate span id {s.span_id}")
        seen.add(s.span_id)
    by_id = {s.span_id: s for s in tracer.spans}
    for s in tracer.spans:
        if s.t1 is None:
            bad.append(f"open span {s!r}")
            continue
        if s.t1 < s.t0 - eps:
            bad.append(f"negative interval {s!r}")
        if s.parent_id is None:
            if s.trace_id != s.span_id:
                bad.append(f"root {s.span_id} trace_id {s.trace_id}")
            continue
        p = by_id.get(s.parent_id)
        if p is None:
            bad.append(f"dangling parent {s.parent_id} on {s.span_id}")
            continue
        if s.trace_id != p.trace_id:
            bad.append(f"trace_id mismatch {s.span_id} vs {p.span_id}")
        if s.t0 < p.t0 - eps or (p.t1 is not None and s.t1 is not None
                                 and s.t1 > p.t1 + eps):
            bad.append(f"child {s.span_id} [{s.t0},{s.t1}] escapes "
                       f"parent {p.span_id} [{p.t0},{p.t1}]")
        # cycle check: walk to the root, bounded by the span count
        hops, cur = 0, s
        while cur.parent_id is not None and hops <= len(tracer.spans):
            cur = by_id.get(cur.parent_id)
            if cur is None:
                break
            hops += 1
        if hops > len(tracer.spans):
            bad.append(f"parent cycle through {s.span_id}")
    return bad


# -- request side ------------------------------------------------------------

def request_breakdown(tracer: Tracer, *, model: Optional[str] = None) -> list:
    """One row per SERVED gateway.request span: total latency and its
    attribution -- queue_s (sum of gateway.queue children), rtt_lb_s /
    cold_s / service_s (from the final gateway.serve child; preempted
    serve spans count as queue time: the work was thrown away)."""
    children = tracer.children_index()
    rows = []
    for r in tracer.named("gateway.request"):
        if r.t1 is None or r.attrs.get("outcome") != "served":
            continue
        if model is not None and r.attrs.get("model") != model:
            continue
        queue_s = wasted_s = 0.0
        serve = None
        for c in children.get(r.span_id, ()):
            if c.name == "gateway.queue":
                queue_s += c.duration_s
            elif c.name == "gateway.serve":
                if c.attrs.get("preempted"):
                    wasted_s += c.duration_s
                else:
                    serve = c
        row = {"model": r.attrs.get("model"), "idx": r.attrs.get("idx"),
               "cls": r.attrs.get("cls"), "total_s": r.duration_s,
               "queue_s": queue_s, "preempted_s": wasted_s,
               "rtt_lb_s": 0.0, "cold_s": 0.0, "service_s": 0.0,
               "cloud": None, "span_id": r.span_id}
        if serve is not None:
            row["rtt_lb_s"] = serve.attrs.get("rtt_lb_s", 0.0)
            row["cold_s"] = serve.attrs.get("cold_s", 0.0)
            row["service_s"] = serve.attrs.get("service_s", 0.0)
            row["cloud"] = serve.attrs.get("cloud")
        rows.append(row)
    return rows


def slowest_requests(tracer: Tracer, k: int = 1, *,
                     model: Optional[str] = None) -> list:
    rows = request_breakdown(tracer, model=model)
    rows.sort(key=lambda r: (-r["total_s"], r["span_id"]))
    return rows[:k]


def request_table(tracer: Tracer, k: int = 3, *,
                  model: Optional[str] = None) -> str:
    """Stage-breakdown table of the k slowest served requests (the 'where
    did the p99 request spend its time' answer)."""
    rows = slowest_requests(tracer, k, model=model)
    cols = ("model", "idx", "cls", "cloud", "total_s", "queue_s",
            "preempted_s", "rtt_lb_s", "cold_s", "service_s")
    return _table(rows, cols, title="slowest requests (trace-derived)")


# -- run side ----------------------------------------------------------------

def run_critical_path(tracer: Tracer, run_span_id: int) -> list:
    """The chain of pipeline.step spans bounding the run's makespan: start
    from the step finishing last, hop to its latest-finishing dependency
    (attrs['deps'] step names), repeat.  Returns spans in execution
    order."""
    children = tracer.children_index()
    steps = {s.attrs.get("step"): s
             for s in children.get(run_span_id, ())
             if s.name == "pipeline.step" and s.t1 is not None}
    if not steps:
        return []
    cur = max(steps.values(), key=lambda s: (s.t1, s.span_id))
    path = [cur]
    while True:
        deps = [steps[d] for d in cur.attrs.get("deps", ())
                if d in steps]
        if not deps:
            break
        cur = max(deps, key=lambda s: (s.t1, s.span_id))
        path.append(cur)
    path.reverse()
    return path


def run_breakdown(tracer: Tracer, run_span_id: int) -> list:
    """Per-step attribution along the critical path: control_s
    (startup+rtt over attempts), transfer_s, compute_s, and wait_s (the
    rest: ready-but-queued time + retry backoff gaps)."""
    children = tracer.children_index()
    rows = []
    for s in run_critical_path(tracer, run_span_id):
        attempts = [c for c in children.get(s.span_id, ())
                    if c.name == "pipeline.attempt"]
        control = sum(a.attrs.get("control_s", 0.0) for a in attempts)
        transfer = sum(a.attrs.get("transfer_s", 0.0) for a in attempts)
        compute = sum(a.attrs.get("compute_s", 0.0) for a in attempts)
        total = s.duration_s
        rows.append({"step": s.attrs.get("step"),
                     "cloud": s.attrs.get("cloud"),
                     "cached": s.attrs.get("cached", False),
                     "attempts": len(attempts),
                     "total_s": total, "control_s": control,
                     "transfer_s": transfer, "compute_s": compute,
                     "wait_s": max(total - control - transfer - compute,
                                   0.0)})
    return rows


def run_table(tracer: Tracer, run_span_id: int) -> str:
    rows = run_breakdown(tracer, run_span_id)
    cols = ("step", "cloud", "cached", "attempts", "total_s", "control_s",
            "transfer_s", "compute_s", "wait_s")
    return _table(rows, cols,
                  title="run critical path (trace-derived Tables 4/5)")


# -- rendering / export -------------------------------------------------------

def _table(rows: list, cols: tuple, *, title: str = "") -> str:
    def fmt(v):
        if isinstance(v, float):
            return f"{v:.5f}"
        return "-" if v is None else str(v)
    grid = [[fmt(r.get(c)) for c in cols] for r in rows]
    widths = [max([len(c)] + [len(g[i]) for g in grid])
              for i, c in enumerate(cols)]
    lines = ([title] if title else []) + [
        "  ".join(c.rjust(w) for c, w in zip(cols, widths))]
    lines += ["  ".join(v.rjust(w) for v, w in zip(g, widths))
              for g in grid]
    return "\n".join(lines)


def export(tracer: Tracer, registry=None, *, trace_path: str,
           prom_path: Optional[str] = None, log=None) -> None:
    """Write the JSON trace (and, with a registry, the Prometheus text
    exposition) to disk -- the two wire formats of DESIGN.md S5."""
    tracer.to_json(trace_path, log=log)
    if registry is not None and prom_path is not None:
        with open(prom_path, "w") as f:
            f.write(registry.to_prometheus())
