"""SLO burn-rate monitor: multi-window error-budget alerting that CLOSES
the control loop (DESIGN.md S5) instead of sitting beside it.

The rule is the SRE-workbook multiwindow burn-rate alert.  An SLO class
promises ``objective`` (fraction of offered requests that complete within
their class deadline; a shed request is a breach by definition).  The
error budget is ``1 - objective``; the burn rate over a window is

    burn = breach_fraction(window) / (1 - objective)

i.e. how many times faster than sustainable the budget is being consumed
(burn=1 -> the budget exactly lasts the period).  An alert FIRES for a
(model, class) pair when burn >= ``threshold`` over BOTH the short and the
long window (the short window gates on recency -- the alert resolves
promptly when the burn stops; the long window gates on significance -- a
single slow batch cannot page), each with at least ``min_n``
observations.  Edges are recorded as ``gateway:alert`` events
(state=firing / resolved) on the simulated clock, deterministic under the
run seed.

Consumers:
- ``Gateway._probe`` treats an active alert like a miss-rate breach
  (ReplanConfig arming reason ``slo_burn``), so weight shifts away from a
  burning model BEFORE the coarser window-rate triggers accumulate;
- ``Autoscaler.effective_queue`` folds ``pressure()`` in next to
  shed-pressure, so a burning pool scales up;
- ``placement.replan(alerts=...)`` over-provisions burning models.

Windows are simulated seconds; observations arrive in nondecreasing sim
time from the gateway event loop (completions at their "free" event,
sheds at shed time), so eviction is a deque pop from the left -- O(1)
amortized per observation, no per-event dict churn.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional


@dataclasses.dataclass(frozen=True)
class BurnRateConfig:
    objective: float = 0.9       # served-within-deadline fraction promised
    short_s: float = 0.5         # recency window (simulated seconds)
    long_s: float = 2.5          # significance window
    threshold: float = 2.0       # alert at >= threshold x sustainable burn
    min_n: int = 8               # observations needed per window

    def __post_init__(self):
        if not 0.0 < self.objective < 1.0:
            raise ValueError("objective must be in (0, 1)")
        if not 0.0 < self.short_s <= self.long_s:
            raise ValueError("need 0 < short_s <= long_s")
        if self.threshold <= 0:
            raise ValueError("threshold must be > 0")
        if self.min_n < 1:
            raise ValueError("min_n must be >= 1")


class BurnRateMonitor:
    """Per-(model, class) budget accounting over two sliding windows."""

    def __init__(self, cfg: Optional[BurnRateConfig] = None, *, log=None,
                 metrics=None):
        self.cfg = cfg or BurnRateConfig()
        self.log = log
        self.metrics = metrics
        # key -> [short deque[(t, bad)], long deque, bad_short, bad_long];
        # each window evicts by time from the left: O(1) amortized
        self._win: dict[tuple, list] = {}
        self.active: dict[tuple, float] = {} # key -> firing-since t_sim
        self.alerts: list[dict] = []         # every firing edge, in order

    def reset(self) -> None:
        """Forget window state between runs (alert history is kept)."""
        self._win.clear()
        self.active.clear()

    # -- feed ---------------------------------------------------------------
    def observe(self, t: float, model: str, cls: str, good: bool) -> None:
        """One terminal request outcome at simulated time ``t``: a served
        request (good = met its class deadline) or a shed (good=False).
        Evaluates the alert rule for this key on the spot."""
        cfg = self.cfg
        key = (model, cls)
        w = self._win.get(key)
        if w is None:
            w = self._win[key] = [deque(), deque(), 0, 0]
        bad = not good
        w[0].append((t, bad))
        w[1].append((t, bad))
        if bad:
            w[2] += 1
            w[3] += 1
        while w[0] and w[0][0][0] < t - cfg.short_s:
            if w[0].popleft()[1]:
                w[2] -= 1
        while w[1] and w[1][0][0] < t - cfg.long_s:
            if w[1].popleft()[1]:
                w[3] -= 1
        self._evaluate(t, key, w)

    def age(self, t: float) -> None:
        """Advance every window to simulated time ``t`` with no new
        observation.  The short window gates on RECENCY, so an alert must
        resolve once the burn stops even if no further requests ever
        arrive -- but ``observe`` is the only other place eviction runs,
        so without this a burst that ends in a firing alert pins
        ``pressure()`` forever: the autoscaler keeps launching replicas
        that idle out, each launch re-arms the event loop, and the run
        never terminates.  The gateway calls this once per timestep."""
        cfg = self.cfg
        for key, w in self._win.items():
            changed = False
            while w[0] and w[0][0][0] < t - cfg.short_s:
                if w[0].popleft()[1]:
                    w[2] -= 1
                changed = True
            while w[1] and w[1][0][0] < t - cfg.long_s:
                if w[1].popleft()[1]:
                    w[3] -= 1
                changed = True
            if changed:
                self._evaluate(t, key, w)

    def _evaluate(self, t: float, key: tuple, w: list) -> None:
        cfg = self.cfg
        budget = 1.0 - cfg.objective
        n_s, n_l = len(w[0]), len(w[1])
        burn_s = (w[2] / n_s) / budget if n_s else 0.0
        burn_l = (w[3] / n_l) / budget if n_l else 0.0
        firing = (n_s >= cfg.min_n and n_l >= cfg.min_n
                  and burn_s >= cfg.threshold and burn_l >= cfg.threshold)
        was = key in self.active
        if firing and not was:
            self.active[key] = t
            rec = {"model": key[0], "cls": key[1], "t_sim": round(t, 6),
                   "burn_short": round(burn_s, 4),
                   "burn_long": round(burn_l, 4)}
            self.alerts.append(rec)
            if self.log is not None:
                self.log.record("gateway:alert", 0.0, state="firing",
                                objective=cfg.objective, **rec)
            if self.metrics is not None:
                self.metrics.counter("gateway_slo_alerts_total",
                                     model=key[0], cls=key[1]).inc()
        elif was and not firing:
            since = self.active.pop(key)
            if self.log is not None:
                self.log.record("gateway:alert", 0.0, state="resolved",
                                model=key[0], cls=key[1],
                                t_sim=round(t, 6),
                                firing_s=round(t - since, 6))

    # -- control-loop reads -------------------------------------------------
    def is_burning(self, model: str) -> bool:
        return any(m == model for m, _ in self.active)

    def alerting_models(self) -> set:
        return {m for m, _ in self.active}

    def pressure(self, model: str, target_queue: int) -> int:
        """Extra queue depth the autoscaler should assume for a burning
        model (one target_queue worth: enough to tip the per-replica rule
        without double-counting the real backlog)."""
        return int(target_queue) if self.is_burning(model) else 0
