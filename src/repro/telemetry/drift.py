"""Profile-vs-observed drift detection: the Model-CI feedback edge
(DESIGN.md S9).

A placement planned from a ``ModelProfile`` artifact is only as good as
the profile's numbers.  The ``DriftMonitor`` closes that loop at serving
time: the gateway registers the exact profile each deployment was planned
from (``watch``), and at every metrics scrape feeds the model's
cumulative busy-seconds / served-count (``observe``).  The monitor takes
per-scrape deltas, so the comparison is the OBSERVED per-request service
time over the scrape interval against the profile's promised
``service_time_s``:

    ratio = observed_s / profile.service_time_s

Drift fires when the ratio leaves the tolerance band
[1/threshold, threshold] for ``sustain`` consecutive evaluated scrapes
(scrapes with fewer than ``min_n`` served requests in the interval are
not evidence either way -- they neither advance nor reset the streak).
Edges are ``profile:drift`` events (state=firing / resolved) on the
simulated clock, deterministic under the run seed.

The monitor is a CONTROLLER, not just an alarm:

- a firing edge arms the model for re-profiling (``reprofile`` set +
  one ``modelci:reprofile`` event) -- consumers re-run the profiling DAG
  for that model, producing a fresh artifact that supersedes the stale
  one in the ProfileStore;
- ``Gateway._probe`` treats a drifting model like an overload breach
  (ReplanConfig arming reason ``profile_drift``), so the placement is
  re-planned from OBSERVED demand while the re-profile is in flight.

Metric families: ``modelci_drift_ratio`` (last evaluated ratio per
model) and ``modelci_profile_staleness`` (simulated seconds since the
watched profile was planned from), refreshed on every observe and frozen
by whatever scrape runs next.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    threshold: float = 1.5       # band is [1/threshold, threshold]
    sustain: int = 2             # consecutive out-of-band evaluated scrapes
    min_n: int = 8               # served requests needed per interval

    def __post_init__(self):
        if self.threshold <= 1.0:
            raise ValueError("threshold must be > 1 (a band, not a point)")
        if self.sustain < 1:
            raise ValueError("sustain must be >= 1")
        if self.min_n < 1:
            raise ValueError("min_n must be >= 1")


class _Watch:
    __slots__ = ("profile", "t0", "busy0", "served0", "streak", "ratio")

    def __init__(self, profile, t0: float):
        self.profile = profile
        self.t0 = t0                 # planned-from time: staleness origin
        self.busy0 = 0.0             # cumulative counters at last scrape
        self.served0 = 0
        self.streak = 0              # consecutive out-of-band evaluations
        self.ratio = 1.0             # last evaluated ratio


class DriftMonitor:
    """Per-model observed-vs-profiled service-time accounting, fed by the
    gateway scrape loop on cumulative counters (the monitor does the
    deltas -- same contract a Prometheus rate() has with a counter)."""

    def __init__(self, cfg: Optional[DriftConfig] = None, *, log=None,
                 metrics=None):
        self.cfg = cfg or DriftConfig()
        self.log = log
        self.metrics = metrics
        self._watch: dict[str, _Watch] = {}
        self.active: dict[str, float] = {}   # model -> firing-since t_sim
        self.reprofile: set = set()          # models armed for re-profiling
        self.drifts: list[dict] = []         # every firing edge, in order

    # -- registration --------------------------------------------------------
    def watch(self, model: str, profile, t: float = 0.0) -> None:
        """Register the profile ``model``'s live placement was planned
        from.  Re-watching (a re-deploy after re-profiling) replaces the
        baseline and clears the model's drift state."""
        self._watch[model] = _Watch(profile, t)
        self.active.pop(model, None)
        self.reprofile.discard(model)

    def reset(self) -> None:
        """Forget per-run counters between runs (watched profiles and the
        drift history are kept; cumulative baselines restart at zero with
        the gateway's per-run state)."""
        for w in self._watch.values():
            w.busy0, w.served0, w.streak, w.ratio = 0.0, 0, 0, 1.0
        self.active.clear()

    # -- feed ---------------------------------------------------------------
    def observe(self, t: float, model: str, busy_s: float,
                served: int) -> None:
        """One scrape's cumulative counters for ``model``: total busy
        seconds and total served requests since run start.  Evaluates the
        drift rule over the delta since the previous scrape."""
        w = self._watch.get(model)
        if w is None:
            return
        d_busy = busy_s - w.busy0
        d_served = served - w.served0
        w.busy0, w.served0 = busy_s, served
        if self.metrics is not None:
            self.metrics.gauge("modelci_profile_staleness",
                               model=model).set(round(t - w.t0, 6))
        if d_served < self.cfg.min_n or d_busy <= 0:
            return                   # not evidence either way
        observed = d_busy / d_served
        expected = w.profile.service_time_s
        ratio = observed / expected
        w.ratio = ratio
        if self.metrics is not None:
            self.metrics.gauge("modelci_drift_ratio",
                               model=model).set(round(ratio, 6))
        out = ratio >= self.cfg.threshold or ratio <= 1.0 / self.cfg.threshold
        w.streak = w.streak + 1 if out else 0
        firing = w.streak >= self.cfg.sustain
        was = model in self.active
        if firing and not was:
            self.active[model] = t
            rec = {"model": model, "t_sim": round(t, 6),
                   "ratio": round(ratio, 4),
                   "expected_s": round(expected, 9),
                   "observed_s": round(observed, 9)}
            self.drifts.append(rec)
            if self.log is not None:
                self.log.record("profile:drift", 0.0, state="firing",
                                **rec)
            if self.metrics is not None:
                self.metrics.counter("modelci_drift_total",
                                     model=model).inc()
            if model not in self.reprofile:
                # the controller edge: one re-profile armed per drift
                # episode -- consumers re-run the profiling DAG
                self.reprofile.add(model)
                if self.log is not None:
                    self.log.record("modelci:reprofile", 0.0, model=model,
                                    ratio=round(ratio, 4),
                                    t_sim=round(t, 6))
        elif was and not firing and w.streak == 0:
            since = self.active.pop(model)
            if self.log is not None:
                self.log.record("profile:drift", 0.0, state="resolved",
                                model=model, t_sim=round(t, 6),
                                ratio=round(ratio, 4),
                                firing_s=round(t - since, 6))

    # -- control-loop reads -------------------------------------------------
    def is_drifting(self, model: str) -> bool:
        return model in self.active

    def drifting_models(self) -> set:
        return set(self.active)

    def pop_reprofile(self) -> set:
        """Drain the armed re-profile set (the consumer claims the work:
        e.g. a runner that fires the profiling DAG for each model)."""
        out, self.reprofile = self.reprofile, set()
        return out
