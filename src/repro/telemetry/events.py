"""Structured event log + stage timing (the Istio-metrics analog).

Every pipeline run / serving session records stage events; benchmarks read
these to build the paper's Tables 4/5 (per-stage pipeline timing).  The
log is one leg of the observability plane (DESIGN.md S5): events are the
flat audit stream, ``telemetry/trace.py`` holds the span tree,
``telemetry/metrics.py`` the counter/histogram series derived from both.

Determinism contract: ``record`` stamps a monotonic per-log ``seq`` --
never the wall clock -- and simulated timestamps ride in ``t_sim`` meta,
so ``dump()`` is byte-stable under a fixed seed.  Wall-clock measurements
(the hardware-gate side of DESIGN.md S1) are confined to two places:
``stage(...)`` events (marked ``wall=True``) and explicit ``wall_s`` meta
keys.  ``dump()`` strips both by default; ``dump(include_wall=True)``
keeps them for profiling.

Gateway event vocabulary (serving/gateway/router.py, DESIGN.md S3):
  gateway:run                the whole simulation (duration = simulated
                             makespan; wall_s meta carries the real wall)
  gateway:scale_up/down      replica launched / retired (cloud-stamped)
  gateway:scale_to_zero      every pool of a deployment emptied
  gateway:cold_start         first batch on a weightless replica
  gateway:scale_denied       launch refused (capacity or cloud_down)
  gateway:capacity_exceeded  documented scale-from-zero CLOUD budget breach
  gateway:budget_exceeded    documented scale-from-zero breach of a
                             deployment's max_replicas (queued work is
                             pinned to a pool; starving it would stall)
  gateway:preempt            latency-class batch evicted an in-flight batch
  gateway:shed               admission control dropped a request whose
                             expected completion already breached its
                             class deadline (exactly once per request;
                             carries model/cloud/cls/idx and at=enqueue
                             or at=dispatch; sheddable classes only --
                             batch work is deferred, never shed)
  gateway:split              a model's live split weights changed (carries
                             the normalized {cloud: weight} map, which sums
                             to 1 unless every cloud is down; reasons:
                             fail / recover / migrate)
  gateway:migrate            a re-planning decision: an explicit
                             MigrationSpec step (reason=plan) or an
                             auto-replan shift (reason=overload /
                             miss_rate / shed_rate / slo_burn / cost,
                             with src/dst/delta)
  gateway:failover/recover   outage edge as seen by one deployment -- the
                             degenerate split (dead cloud's weight -> 0,
                             restored on recovery)
  gateway:prefill            prompt-ingest accounting (DisaggSpec models
                             only).  staged=True: a prefill-pool batch
                             finished and its requests moved to the decode
                             pool (duration = the prefill batch service
                             time, n = batch size).  staged=False: a
                             unified ("both"-pool) dispatch's prefill
                             share, priced but not separately scheduled
  gateway:cache_shed         projected KV-block demand for a pool's queue
                             exceeded shed_margin x its kv_blocks budget;
                             the request is dropped BEFORE enqueue with a
                             paired gateway:shed at=cache (carries
                             kv_used / kv_projected / kv_total; physical
                             limit, so it fires even with admission
                             control off -- sheddable classes only)
  gateway:observed           measured arrival rate + realized service time
                             per model (placement.replan input)
  gateway:alert              SLO burn-rate alert edge (telemetry/slo.py):
                             a (model, class) pair is consuming error
                             budget faster than ``threshold`` x the
                             sustainable rate over BOTH the short and long
                             windows (state=firing), or stopped
                             (state=resolved); carries burn_short /
                             burn_long / objective

Observability vocabulary (telemetry/, DESIGN.md S5):
  metrics:scrape             a simulated-time MetricsRegistry snapshot was
                             taken (t_sim, number of live series); the
                             snapshot itself lives in
                             MetricsRegistry.scrapes
  trace:materialize          the gateway's deferred collector flushed: the
                             request span forest was built in bulk AFTER
                             the event loop from the per-batch records
                             (spans; wall_s meta carries the flush cost,
                             reported next to gateway:run's hot-loop wall
                             and excluded from it)
  trace:export               a Tracer span tree was exported (path, spans)

Pipeline-orchestrator vocabulary (pipelines/scheduler.py + runs.py,
DESIGN.md S4; t_sim stamps are simulated seconds):
  pipeline:run               one orchestrated run (duration = simulated
                             makespan; carries run_id / status / cost and
                             the real wall_s the step fns took)
  pipeline:schedule          a step attempt took a worker on a cloud
                             (step / cloud / attempt number)
  pipeline:step              a step completed exactly once (simulated
                             duration, cloud, cached flag, attempt count,
                             accumulated cost)
  pipeline:cache_hit         the control plane reused a content-hash
                             artifact without starting a pod (step / key /
                             resident cloud)
  pipeline:transfer          an input artifact moved cross-cloud
                             (src / dst / bytes; duration = simulated
                             transfer seconds, cost = simulated egress $)
  pipeline:retry             an outage killed an attempt and the step
                             backed off (attempt number, next_s)
  pipeline:fail              a step permanently failed (retries exhausted,
                             an exception, or an infeasible deploy plan)
  pipeline:skip              a step never ran because an ancestor failed
  pipeline:deploy            the terminal deploy step handed a model to
                             the serving gateway (model / weights /
                             replicas / cost_hr)
  pipeline:recurring         a recurring-run trigger fired (pipeline,
                             index, t_sim)

Model-CI vocabulary (modelci/ + telemetry/drift.py, DESIGN.md S9):
  modelci:profile            a measured ModelProfile artifact was committed
                             to a ProfileStore (model / cloud / key /
                             service_time_s) -- the profiling DAG's
                             terminal side effect, one per profile step
  modelci:reprofile          sustained profile-vs-observed drift armed a
                             re-profile run for a model (the DriftMonitor
                             is a controller: consumers re-run the
                             profiling DAG for the named model)
  profile:drift              drift edge between the profile a placement
                             was planned from and the scraped serving
                             metrics: state=firing when the observed /
                             profiled service-time ratio leaves the
                             tolerance band for ``sustain`` consecutive
                             scrapes (carries ratio / expected_s /
                             observed_s), state=resolved on recovery

Capacity-market vocabulary (clouds/capacity.py, DESIGN.md S8; recorded
only when a CapacityMarket is shared between the Gateway and the
Orchestrator -- shared_capacity=None emits none of these):
  capacity:lease             a slot lease was granted on a cloud ledger
                             (cloud, kind=serving|training, model/step)
  capacity:preempt           serving demand truncated the youngest
                             training lease (spot semantics), or a
                             recorded serving rise-edge killed a running
                             training attempt, which re-enters the
                             RetryPolicy backoff path
  capacity:handoff           a relaunched serving pool migrated its model
                             state over the interconnect instead of
                             paying a cold load (src / dst / replicas /
                             transfer_s / saved_s)
  capacity:speculate         an outage window threatened a running
                             training attempt and a backup attempt
                             launched on a second cloud (the loser is
                             cancelled through the ledger)
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Optional

# meta keys that carry wall-clock measurements; dump() gates them so the
# default export is byte-stable under a fixed seed
_WALL_KEYS = ("wall_s",)

# The machine-readable registry of every event kind documented above.
# ``unregistered(log)`` is the bench-side gate: a run emitting an event
# name missing from this set is recording vocabulary nobody documented
# (or typo'd a name), which the suites treat as a failure.
EVENT_KINDS = frozenset({
    # gateway (DESIGN.md S3)
    "gateway:run", "gateway:scale_up", "gateway:scale_down",
    "gateway:scale_to_zero", "gateway:cold_start", "gateway:scale_denied",
    "gateway:capacity_exceeded", "gateway:budget_exceeded",
    "gateway:preempt", "gateway:shed", "gateway:split", "gateway:migrate",
    "gateway:failover", "gateway:recover", "gateway:prefill",
    "gateway:cache_shed", "gateway:observed", "gateway:alert",
    # observability plane (DESIGN.md S5)
    "metrics:scrape", "trace:materialize", "trace:export",
    # pipeline orchestrator (DESIGN.md S4)
    "pipeline:run", "pipeline:schedule", "pipeline:step",
    "pipeline:cache_hit", "pipeline:transfer", "pipeline:retry",
    "pipeline:fail", "pipeline:skip", "pipeline:deploy",
    "pipeline:recurring",
    # model-CI profiling plane (DESIGN.md S9)
    "modelci:profile", "modelci:reprofile", "profile:drift",
    # capacity market (DESIGN.md S8)
    "capacity:lease", "capacity:preempt", "capacity:handoff",
    "capacity:speculate",
})


def unregistered(log: "EventLog") -> set:
    """Event names recorded in ``log`` that are absent from EVENT_KINDS.
    Stage events (``wall=True``) are exempt: their names are free-form
    wall-clock labels, not simulation vocabulary."""
    out = set()
    for e in log.events:
        if e.get("wall"):
            continue
        if e["name"] not in EVENT_KINDS:
            out.add(e["name"])
    return out


class EventLog:
    def __init__(self):
        self.events: list[dict] = []
        self._by_name: dict[str, list] = {}  # name -> events (same dicts)
        self._seq = 0                        # monotonic per-log sequence

    def record(self, name: str, duration_s: float, **meta):
        e = {"name": name, "duration_s": duration_s, "seq": self._seq,
             **meta}
        self._seq += 1
        self.events.append(e)
        self._by_name.setdefault(name, []).append(e)

    @contextlib.contextmanager
    def stage(self, name: str, **meta):
        """Wall-clock a code block (the hardware-measurement primitive,
        DESIGN.md S1: serial pipeline stages / train jobs are timed on
        this host).  The event is marked ``wall=True`` so ``dump()`` can
        gate its non-deterministic duration."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.record(name, time.perf_counter() - t0, wall=True, **meta)

    def named(self, name: str) -> list:
        """All events with this name, in record order (indexed: O(1) per
        call, not a scan -- the invariant suites call this O(events)
        times)."""
        return list(self._by_name.get(name, ()))

    def count(self, name: str) -> int:
        return len(self._by_name.get(name, ()))

    def totals(self) -> dict:
        out: dict = {}
        for e in self.events:
            out[e["name"]] = out.get(e["name"], 0.0) + e["duration_s"]
        return out

    def dump(self, path: Optional[str] = None, *,
             include_wall: bool = False) -> str:
        """JSON export.  By default every wall-clock field is stripped
        (``wall_s`` meta everywhere; ``duration_s`` on ``wall=True`` stage
        events), so two seeded simulated runs dump byte-identical text.
        ``include_wall=True`` keeps the measurements."""
        events = self.events
        if not include_wall:
            events = []
            for e in self.events:
                drop = _WALL_KEYS + (("duration_s",) if e.get("wall")
                                     else ())
                events.append({k: v for k, v in e.items() if k not in drop}
                              if any(k in e for k in drop) else e)
        s = json.dumps(events, indent=1, default=str)
        if path:
            with open(path, "w") as f:
                f.write(s)
        return s


# Legacy shared sink.  NO repro code records into it: gateway and
# orchestrator each own a run-scoped EventLog (pass log=... to share one).
# tests/conftest.py installs an autouse fixture that fails any test
# leaking events here.
GLOBAL_LOG = EventLog()
