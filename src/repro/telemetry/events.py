"""Structured event log + stage timing (the Istio-metrics analog).

Every pipeline run / serving session records stage events; benchmarks read
these to build the paper's Tables 4/5 (per-stage pipeline timing).

Gateway event vocabulary (serving/gateway/router.py, DESIGN.md S3):
  gateway:run                the whole simulation (a stage)
  gateway:scale_up/down      replica launched / retired (cloud-stamped)
  gateway:scale_to_zero      every pool of a deployment emptied
  gateway:cold_start         first batch on a weightless replica
  gateway:scale_denied       launch refused (capacity or cloud_down)
  gateway:capacity_exceeded  documented scale-from-zero CLOUD budget breach
  gateway:budget_exceeded    documented scale-from-zero breach of a
                             deployment's max_replicas (queued work is
                             pinned to a pool; starving it would stall)
  gateway:preempt            latency-class batch evicted an in-flight batch
  gateway:shed               admission control dropped a request whose
                             expected completion already breached its
                             class deadline (exactly once per request;
                             carries model/cloud/cls/idx and at=enqueue
                             or at=dispatch; sheddable classes only --
                             batch work is deferred, never shed)
  gateway:split              a model's live split weights changed (carries
                             the normalized {cloud: weight} map, which sums
                             to 1 unless every cloud is down; reasons:
                             fail / recover / migrate)
  gateway:migrate            a re-planning decision: an explicit
                             MigrationSpec step (reason=plan) or an
                             auto-replan shift (reason=overload /
                             miss_rate / shed_rate / cost, with
                             src/dst/delta)
  gateway:failover/recover   outage edge as seen by one deployment -- the
                             degenerate split (dead cloud's weight -> 0,
                             restored on recovery)
  gateway:observed           measured arrival rate + realized service time
                             per model (placement.replan input)

Pipeline-orchestrator vocabulary (pipelines/scheduler.py + runs.py,
DESIGN.md S4; t_sim stamps are simulated seconds):
  pipeline:run               one orchestrated run (duration = simulated
                             makespan; carries run_id / status / cost and
                             the real wall_s the step fns took)
  pipeline:schedule          a step attempt took a worker on a cloud
                             (step / cloud / attempt number)
  pipeline:step              a step completed exactly once (simulated
                             duration, cloud, cached flag, attempt count,
                             accumulated cost)
  pipeline:cache_hit         the control plane reused a content-hash
                             artifact without starting a pod (step / key /
                             resident cloud)
  pipeline:transfer          an input artifact moved cross-cloud
                             (src / dst / bytes; duration = simulated
                             transfer seconds, cost = simulated egress $)
  pipeline:retry             an outage killed an attempt and the step
                             backed off (attempt number, next_s)
  pipeline:fail              a step permanently failed (retries exhausted,
                             an exception, or an infeasible deploy plan)
  pipeline:skip              a step never ran because an ancestor failed
  pipeline:deploy            the terminal deploy step handed a model to
                             the serving gateway (model / weights /
                             replicas / cost_hr)
  pipeline:recurring         a recurring-run trigger fired (pipeline,
                             index, t_sim)
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Any, Optional


class EventLog:
    def __init__(self):
        self.events: list[dict] = []

    def record(self, name: str, duration_s: float, **meta):
        self.events.append({"name": name, "duration_s": duration_s,
                            "t": time.time(), **meta})

    @contextlib.contextmanager
    def stage(self, name: str, **meta):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.record(name, time.perf_counter() - t0, **meta)

    def named(self, name: str) -> list:
        """All events with this name, in record order."""
        return [e for e in self.events if e["name"] == name]

    def count(self, name: str) -> int:
        return len(self.named(name))

    def totals(self) -> dict:
        out: dict = {}
        for e in self.events:
            out[e["name"]] = out.get(e["name"], 0.0) + e["duration_s"]
        return out

    def dump(self, path: Optional[str] = None) -> str:
        s = json.dumps(self.events, indent=1, default=str)
        if path:
            with open(path, "w") as f:
                f.write(s)
        return s


GLOBAL_LOG = EventLog()
