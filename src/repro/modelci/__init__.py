"""Model-CI profiling plane (DESIGN.md S9, MLModelCI analog): measured,
versioned per-(model, cloud) profile artifacts produced by ``kind=
"profile"`` pipeline steps, stored content-hashed in the pipelines
ArtifactCache, consumed by placement (``ProfileStore.demand`` ->
``ModelDemand``) and watched at serving time by the drift monitor
(telemetry/drift.py).  Every demand number in the system becomes a
measured, monitored quantity."""
import dataclasses
from typing import Any, Optional

from .backends import ProfiledBackend
from .profile import (ModelProfile, ProfileStore, finalize, measure,
                      roofline_fields)


@dataclasses.dataclass
class ProfileSpec:
    """Payload for a ``kind="profile"`` pipeline step.  The step's fn is
    the MEASUREMENT: it returns the raw profile field dict (``measure``/
    ``roofline_fields`` -- JSON-able, so recurring runs cache it), and
    the orchestrator commits the (model, cloud)-stamped ``ModelProfile``
    into ``store`` when the step completes -- cached completions
    included, so a cache-hit recurring firing still refreshes the
    store's ``latest`` pointer."""
    model: str
    store: ProfileStore
    max_batch: int = 32

    def __post_init__(self):
        if not self.model:
            raise ValueError("profile step needs a model name")
        if not hasattr(self.store, "put"):
            raise ValueError("profile step needs a ProfileStore")


__all__ = ["ModelProfile", "ProfileSpec", "ProfileStore", "ProfiledBackend",
           "finalize", "measure", "roofline_fields"]
