"""Analytic serving backend built FROM a profile artifact.

The registry-model serving path: a ``ModelProfile`` (measured or
roofline-derived) already IS a service-time model, so a gateway backend
can be synthesized from it with no weights and no compilation --
``service_time(b)`` prices a batch linearly at the profiled per-request
time, and a disaggregated profile exposes ``prefill_time``/
``decode_time`` so the router's staged prefill/decode pricing engages
exactly as it would for a measured ``BatcherBackend``.
"""
from __future__ import annotations

from .profile import ModelProfile


class ProfiledBackend:
    """Gateway backend whose cost model is a committed ModelProfile."""

    def __init__(self, profile: ModelProfile):
        self.name = profile.model
        self.profile = profile
        if profile.prefill_s is not None and profile.decode_s is not None:
            # instance attributes, not class methods: the gateway engages
            # its disaggregated pricing on hasattr, so a blended profile
            # must NOT expose these
            self.prefill_time = lambda prompt_tokens=None: profile.prefill_s
            self.decode_time = lambda steps=None: profile.decode_s

    def service_time(self, b: int) -> float:
        return max(int(b), 1) * self.profile.service_time_s
